"""Benchmark harness: one function per paper table + framework benches.

Prints ``name,us_per_call,derived`` CSV rows (stdout also carries the
human-readable lines each bench emits).
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    rows = []

    # ---- paper tables I-IV (the reproduction) -------------------------- #
    from benchmarks import paper_tables as pt
    for name, fn in pt.ALL_TABLES.items():
        t0 = time.perf_counter()
        res = fn(verbose=True)
        dt = (time.perf_counter() - t0) * 1e6
        if name == "table1":
            derived = (f"speedup_host={res['speedup_vs_host']:.2f}"
                       f"(paper1.56) vm={res['speedup_vs_vm']:.2f}(1.73)")
        elif name == "table2":
            derived = (f"faster={res['faster_than_seq_pct']:.0f}%"
                       f"(paper~33%) makespan={res['makespan_h']:.2f}h(4.48)")
        elif name == "table3":
            derived = (f"app1={res['app1_h']:.2f}h(2.88) "
                       f"app2={res['app2_h']:.2f}h(3.50)")
        elif name == "scenario_v":
            derived = (f"origin_bytes/{res['origin_bytes_reduction']:.0f} "
                       f"makespan_x{res['makespan_speedup']:.0f} "
                       f"failover_done={res['failover']['done']}")
        elif name == "scenario_vi":
            derived = (f"dup_execs {res['baseline']['dup_execs']}->"
                       f"{res['choked']['dup_execs']} origin_up "
                       f"{res['baseline']['origin_up_mb']:.0f}MB->"
                       f"{res['choked']['origin_up_mb']:.0f}MB "
                       f"makespan {res['baseline']['makespan_s']:.0f}s->"
                       f"{res['choked']['makespan_s']:.0f}s")
        elif name == "scenario_vii":
            derived = (f"N={res['n_volunteers']} makespan="
                       f"{res['makespan_s']:.0f}s replication="
                       f"{res['full_replication_s']:.0f}s origin_up="
                       f"{res['origin_up_mb']:.0f}MB "
                       f"{res['events_per_sec']:.0f}ev/s "
                       f"rss={res['peak_rss_mb']:.0f}MB")
        elif name == "scenario_viii":
            derived = (f"chaos makespan x{res['makespan_overhead']:.2f} "
                       f"egress x{res['egress_overhead']:.2f} "
                       f"dropped={res['chaos']['dropped_msgs']} "
                       f"restarts={res['chaos']['restarts']} "
                       f"replicated={res['replicated']}")
        elif name == "scenario_ix":
            derived = (f"cross_isp/{res['cross_isp_reduction']:.1f} "
                       f"p99_x{res['p99_ratio']:.2f} "
                       f"replicated={res['replicated']}")
        elif name == "scenario_xi":
            derived = (f"R={res['n_replicas']} "
                       f"egress/{res['egress_reduction_flat']:.1f} "
                       f"ttr_p99_x{res['ttr_p99_speedup_flat']:.1f} "
                       f"all_ready={res['all_ready']}")
        else:
            derived = (f"speedup1={res['speedup_app1']:.2f}(3.5) "
                       f"speedup2={res['speedup_app2']:.2f}(3.3)")
        rows.append({"name": f"paper_{name}", "us_per_call": dt,
                     "derived": derived})

    # ---- framework benches --------------------------------------------- #
    from benchmarks import (checkpoint_bench, kernel_bench,
                            scheduler_bench, swarm_bench)
    rows += swarm_bench.bench()
    rows += checkpoint_bench.bench()
    rows += scheduler_bench.bench()
    rows += kernel_bench.bench()

    # ---- roofline summary (if dry-run artifacts exist) ------------------ #
    try:
        from repro.launch.roofline import load_cells
        cells = load_cells("artifacts/dryrun", "16x16")
        if cells:
            worst = min(cells, key=lambda c: c.roofline_fraction)
            med = sorted(c.roofline_fraction for c in cells)[len(cells) // 2]
            rows.append({
                "name": "roofline_summary", "us_per_call": 0.0,
                "derived": (f"{len(cells)} cells; median_frac={med:.3f}; "
                            f"worst={worst.arch}/{worst.shape}="
                            f"{worst.roofline_fraction:.3f}")})
    except Exception as e:  # noqa: BLE001
        print(f"(roofline summary skipped: {e})", file=sys.stderr)

    print("\nname,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},\"{r['derived']}\"")


if __name__ == "__main__":
    main()
