"""Kernel micro-benchmarks (CPU timings are indicative only; the Pallas
kernels target TPU and are validated in interpret mode)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def bench(verbose: bool = True):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import mha_reference
    from repro.kernels.ssd.ref import ssd_naive
    from repro.models.ssm import ssd_scan

    rows = []
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    B, S, Hq, Hkv, D = 1, 1024, 8, 2, 64
    q = jax.random.normal(ks[0], (B, S, Hq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)

    f_ref = jax.jit(lambda q, k, v: mha_reference(q, k, v, causal=True))
    f_fl = jax.jit(lambda q, k, v: flash_attention(q, k, v, True, 0, 256,
                                                   256, "jnp"))
    t_ref = _time(f_ref, q, k, v)
    t_fl = _time(f_fl, q, k, v)
    rows.append({"name": "attn_naive_1k", "us_per_call": t_ref,
                 "derived": "materialised scores"})
    rows.append({"name": "attn_flash_jnp_1k", "us_per_call": t_fl,
                 "derived": f"{t_ref / t_fl:.2f}x vs naive (CPU)"})

    H, P, G, N = 8, 64, 1, 64
    x = jax.random.normal(ks[0], (1, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (1, S, G, N)) * 0.5
    Cm = jax.random.normal(ks[4], (1, S, G, N)) * 0.5
    s_naive = jax.jit(lambda *a: ssd_naive(*a))
    s_chunk = jax.jit(lambda *a: ssd_scan(*a, chunk=128))
    t_n = _time(s_naive, x, dt, A, Bm, Cm)
    t_c = _time(s_chunk, x, dt, A, Bm, Cm)
    rows.append({"name": "ssd_naive_1k", "us_per_call": t_n,
                 "derived": "O(S^2) semiseparable"})
    rows.append({"name": "ssd_chunked_1k", "us_per_call": t_c,
                 "derived": f"{t_n / t_c:.2f}x vs naive (CPU)"})
    if verbose:
        for r in rows:
            print(f"[kernel] {r['name']}: {r['us_per_call']:.0f}us "
                  f"{r['derived']}")
    return rows
