"""Checkpoint save/restore + swarm-image benchmarks.

Times the store's disk path (save, restore, async_save), the packed
step-image codec that feeds the swarm (pack -> manifest -> unpack),
and prints the analytic cold-start cost model at headline scale so the
Scenario XI simulation numbers have a closed-form anchor next to them.

Rows follow the repo convention: {name, us_per_call, derived, metrics}.
Requires jax (the store serialises pytrees); swarm_bench carries the
no-jax rows.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time


def _param_tree(n_layers: int = 4, d: int = 256):
    import numpy as np
    rng = np.random.default_rng(0)
    return {f"layer_{i}": {"w": rng.standard_normal((d, d), dtype=np.float32),
                           "b": rng.standard_normal((d,), dtype=np.float32)}
            for i in range(n_layers)}


def bench(verbose: bool = True, smoke: bool = False):
    import jax
    import numpy as np
    from repro.checkpoint.store import (CheckpointStore, async_save,
                                        pack_step_image, unpack_step_image)
    from repro.core.workunit import PieceManifest
    from repro.parallel.weight_torrent import cold_start_cost_model

    rows = []
    tree = _param_tree(n_layers=2 if smoke else 4)
    nbytes = sum(a.nbytes for layer in tree.values()
                 for a in layer.values())
    root = tempfile.mkdtemp(prefix="ckpt_bench_")
    try:
        store = CheckpointStore(root, piece_bytes=1 << 20,
                                swarm_piece_bytes=256 << 10)

        t0 = time.perf_counter()
        store.save(0, tree)
        save_us = (time.perf_counter() - t0) * 1e6

        t0 = time.perf_counter()
        restored, _ = store.restore(tree, step=0)
        restore_us = (time.perf_counter() - t0) * 1e6
        flat_a = [np.asarray(x) for x in jax.tree_util.tree_leaves(tree)]
        flat_b = [np.asarray(x) for x in
                  jax.tree_util.tree_leaves(restored)]
        roundtrip_ok = all(np.array_equal(a, b)
                           for a, b in zip(flat_a, flat_b))
        rows.append({
            "name": "ckpt_save_restore",
            "us_per_call": save_us,
            "derived": (f"save={save_us / 1e3:.1f}ms "
                        f"restore={restore_us / 1e3:.1f}ms "
                        f"{nbytes / 1e6:.1f}MB ok={roundtrip_ok}"),
            "metrics": {"save_us": save_us, "restore_us": restore_us,
                        "tree_bytes": nbytes, "roundtrip_ok": roundtrip_ok},
        })

        t0 = time.perf_counter()
        th = async_save(store, 1, tree)
        snap_us = (time.perf_counter() - t0) * 1e6
        th.join()
        rows.append({
            "name": "ckpt_async_save",
            "us_per_call": snap_us,
            "derived": f"host_snapshot={snap_us / 1e3:.2f}ms (non-blocking)",
            "metrics": {"snapshot_us": snap_us},
        })

        # packed step image -> swarm manifest -> unpack roundtrip
        d = store.step_dir(0)
        t0 = time.perf_counter()
        image = pack_step_image(d)
        pack_us = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        pm = PieceManifest.from_bytes("bench", image, 256 << 10)
        hash_us = (time.perf_counter() - t0) * 1e6
        dest = os.path.join(root, "unpacked")
        t0 = time.perf_counter()
        unpack_step_image(image, dest)
        unpack_us = (time.perf_counter() - t0) * 1e6
        re_restored, _ = CheckpointStore(root).restore(tree, step=0)
        img_ok = all(np.array_equal(np.asarray(a), np.asarray(b))
                     for a, b in
                     zip(flat_a, jax.tree_util.tree_leaves(re_restored)))
        mbps = len(image) / 1e6 / max(hash_us / 1e6, 1e-9)
        rows.append({
            "name": "ckpt_image_codec",
            "us_per_call": pack_us,
            "derived": (f"pack={pack_us / 1e3:.1f}ms "
                        f"hash={hash_us / 1e3:.1f}ms "
                        f"({mbps:.0f}MB/s, {pm.n_pieces} pieces) "
                        f"unpack={unpack_us / 1e3:.1f}ms ok={img_ok}"),
            "metrics": {"pack_us": pack_us, "hash_us": hash_us,
                        "unpack_us": unpack_us, "image_bytes": len(image),
                        "n_pieces": pm.n_pieces, "roundtrip_ok": img_ok},
        })
    finally:
        shutil.rmtree(root, ignore_errors=True)

    # analytic anchor for Scenario XI: 2GB checkpoint, 50 replicas,
    # 200Mbps uplinks — the simulated swarm should approach these bounds
    cm = cold_start_cost_model(2.048e9, 50, link_Bps=25e6, n_pieces=128)
    rows.append({
        "name": "cold_start_model_2GB_50r",
        "us_per_call": 0.0,
        "derived": (f"origin={cm['origin_s']:.0f}s "
                    f"swarm>={cm['swarm_s']:.0f}s "
                    f"(x{cm['speedup']:.1f} bound) egress "
                    f"{cm['origin_egress_bytes'] / 1e9:.0f} -> "
                    f"{cm['swarm_origin_egress_bytes'] / 1e9:.0f}GB"),
        "metrics": cm,
    })
    if verbose:
        for r in rows:
            print(f"[ckpt] {r['name']}: {r['derived']}")
    return rows


if __name__ == "__main__":
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    bench()
