"""Torrent swarm vs naive fan-out: rounds, seeder load, makespan."""
from __future__ import annotations

import time

from repro.core.swarm import naive_rounds, plan_broadcast, rounds_of, simulate
from repro.parallel.weight_torrent import broadcast_cost_model


def bench(verbose: bool = True):
    rows = []
    for n_nodes, n_pieces in [(8, 8), (16, 16), (64, 64), (256, 64),
                              (1024, 128)]:
        t0 = time.perf_counter()
        plan = plan_broadcast(n_nodes, n_pieces, fanout=1)
        dt = (time.perf_counter() - t0) * 1e6
        r = rounds_of(plan)
        nr = naive_rounds(n_nodes, n_pieces)
        stats = simulate(plan, piece_bytes=64e6, link_Bps=25e9,
                         n_nodes=n_nodes)
        rows.append({
            "name": f"swarm_plan_n{n_nodes}_p{n_pieces}",
            "us_per_call": dt,
            "derived": (f"rounds={r} naive={nr} speedup={nr / r:.1f}x "
                        f"seeder_up={stats.seeder_uploads}"),
        })
    # analytic ppermute-ring model at checkpoint scale (20B params bf16)
    cm = broadcast_cost_model(40e9, n_pods=8)
    rows.append({"name": "weight_torrent_40GB_8pods", "us_per_call": 0.0,
                 "derived": (f"torrent={cm['torrent_s']:.1f}s "
                             f"naive={cm['naive_s']:.1f}s "
                             f"speedup={cm['speedup']:.2f}x")})
    if verbose:
        for r in rows:
            print(f"[swarm] {r['name']}: {r['derived']}")
    return rows
