"""Torrent swarm vs naive fan-out: rounds, seeder load, makespan.

Two layers: the offline `plan_broadcast` planner (analytic round bound)
and the *live* agent/tracker protocol running Scenario V — piece-wise
multi-seeder image distribution with per-node uplink contention and
origin-death failover (paper §V extension).
"""
from __future__ import annotations

import time

from repro.core.swarm import naive_rounds, plan_broadcast, rounds_of, simulate
from repro.parallel.weight_torrent import broadcast_cost_model


def bench_live(verbose: bool = True, n_volunteers: int = 8,
               image_mb: float = 32.0):
    """Scenario V through the real protocol (smaller than paper_tables')."""
    from benchmarks.paper_tables import scenario_v
    res = scenario_v(verbose=False, n_volunteers=n_volunteers,
                     image_mb=image_mb, n_pieces=16, n_parts=24)
    rows = [{
        "name": f"swarm_live_n{n_volunteers}_img{int(image_mb)}MB",
        "us_per_call": 0.0,
        "derived": (f"origin_up {res['single']['origin_up_mb']:.0f}MB->"
                    f"{res['swarm']['origin_up_mb']:.0f}MB "
                    f"makespan {res['single']['makespan_s']:.0f}s->"
                    f"{res['swarm']['makespan_s']:.0f}s "
                    f"failover_done={res['failover']['done']}"),
    }]
    if verbose:
        for r in rows:
            print(f"[swarm] {r['name']}: {r['derived']}")
    return rows


def bench(verbose: bool = True):
    rows = []
    for n_nodes, n_pieces in [(8, 8), (16, 16), (64, 64), (256, 64),
                              (1024, 128)]:
        t0 = time.perf_counter()
        plan = plan_broadcast(n_nodes, n_pieces, fanout=1)
        dt = (time.perf_counter() - t0) * 1e6
        r = rounds_of(plan)
        nr = naive_rounds(n_nodes, n_pieces)
        stats = simulate(plan, piece_bytes=64e6, link_Bps=25e9,
                         n_nodes=n_nodes)
        rows.append({
            "name": f"swarm_plan_n{n_nodes}_p{n_pieces}",
            "us_per_call": dt,
            "derived": (f"rounds={r} naive={nr} speedup={nr / r:.1f}x "
                        f"seeder_up={stats.seeder_uploads}"),
        })
    # analytic ppermute-ring model at checkpoint scale (20B params bf16)
    cm = broadcast_cost_model(40e9, n_pods=8)
    rows.append({"name": "weight_torrent_40GB_8pods", "us_per_call": 0.0,
                 "derived": (f"torrent={cm['torrent_s']:.1f}s "
                             f"naive={cm['naive_s']:.1f}s "
                             f"speedup={cm['speedup']:.2f}x")})
    if verbose:
        for r in rows:
            print(f"[swarm] {r['name']}: {r['derived']}")
    rows += bench_live(verbose=verbose)
    return rows


if __name__ == "__main__":
    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    bench()
