"""Torrent swarm vs naive fan-out: rounds, seeder load, makespan.

Two layers: the offline `plan_broadcast` planner (analytic round bound)
and the *live* agent/tracker protocol running Scenario V — piece-wise
multi-seeder image distribution with per-node uplink contention and
origin-death failover (paper §V extension).
"""
from __future__ import annotations

import time

from repro.core.swarm import naive_rounds, plan_broadcast, rounds_of, simulate
from repro.parallel.weight_torrent import broadcast_cost_model


def bench_scenario_vii(verbose: bool = True, n_volunteers: int = 200,
                       image_mb: float = 64.0):
    """Scenario VII (flash crowd at scale) as a perf-trajectory row:
    protocol metrics plus simulator throughput."""
    from benchmarks.paper_tables import scenario_vii
    res = scenario_vii(verbose=False, n_volunteers=n_volunteers,
                       image_mb=image_mb)
    row = {
        "name": f"swarm_flashcrowd_n{n_volunteers}_img{int(image_mb)}MB",
        "us_per_call": 0.0,
        "derived": (f"makespan {res['makespan_s']:.0f}s replication "
                    f"{res['full_replication_s']:.0f}s origin_up "
                    f"{res['origin_up_mb']:.0f}MB replicas "
                    f"{res['replicas']}/{n_volunteers} | "
                    f"{res['events_per_sec']:.0f} events/s "
                    f"rss {res['peak_rss_mb']:.0f}MB"),
        "metrics": {k: res[k] for k in
                    ("makespan_s", "full_replication_s", "origin_up_mb",
                     "replicas", "done", "replicated", "events",
                     "events_per_sec", "wall_s", "peak_rss_mb")},
    }
    if verbose:
        print(f"[swarm] {row['name']}: {row['derived']}")
    return [row]


def bench_scenario_viii(verbose: bool = True, n_volunteers: int = 48,
                        image_mb: float = 32.0, seed: int = 8):
    """Scenario VIII (chaos) as a perf-trajectory row: the same N=48
    flash crowd fault-free vs under 10% loss / 200ms jitter / 30% churn,
    reporting the makespan and origin-egress overhead of surviving it.
    The chaos invariants are asserted inside scenario_viii itself."""
    from benchmarks.paper_tables import scenario_viii
    res = scenario_viii(verbose=False, n_volunteers=n_volunteers,
                        image_mb=image_mb, seed=seed)
    b, c = res["baseline"], res["chaos"]
    row = {
        "name": f"swarm_chaos_n{n_volunteers}_img{int(image_mb)}MB"
                f"_seed{seed}",
        "us_per_call": 0.0,
        "derived": (f"makespan {b['makespan_s']:.0f}s->"
                    f"{c['makespan_s']:.0f}s "
                    f"(x{res['makespan_overhead']:.2f}) origin_up "
                    f"{b['origin_up_mb']:.0f}->{c['origin_up_mb']:.0f}MB "
                    f"dropped {c['dropped_msgs']} dup {c['dup_msgs']} "
                    f"restarts {c['restarts']} "
                    f"replicated={c['replicated']}"),
        "metrics": {
            "seed": seed,
            "makespan_overhead": res["makespan_overhead"],
            "egress_overhead": res["egress_overhead"],
            "baseline_makespan_s": b["makespan_s"],
            "chaos_makespan_s": c["makespan_s"],
            "dropped_msgs": c["dropped_msgs"],
            "dup_msgs": c["dup_msgs"],
            "crashes": c["crashes"],
            "restarts": c["restarts"],
            "replicated": c["replicated"],
            "invariants_ok": res["invariants_ok"],
        },
    }
    if verbose:
        print(f"[swarm] {row['name']}: {row['derived']}")
    return [row]


def bench_live(verbose: bool = True, n_volunteers: int = 8,
               image_mb: float = 32.0):
    """Scenarios V + VI through the real protocol (smaller than
    paper_tables' defaults)."""
    from benchmarks.paper_tables import scenario_v, scenario_vi
    res = scenario_v(verbose=False, n_volunteers=n_volunteers,
                     image_mb=image_mb, n_pieces=16, n_parts=24)
    rows = [{
        "name": f"swarm_live_n{n_volunteers}_img{int(image_mb)}MB",
        "us_per_call": 0.0,
        "derived": (f"origin_up {res['single']['origin_up_mb']:.0f}MB->"
                    f"{res['swarm']['origin_up_mb']:.0f}MB "
                    f"makespan {res['single']['makespan_s']:.0f}s->"
                    f"{res['swarm']['makespan_s']:.0f}s "
                    f"failover_done={res['failover']['done']}"),
        "metrics": {"origin_up_mb": res["swarm"]["origin_up_mb"],
                    "makespan_s": res["swarm"]["makespan_s"],
                    "failover_done": res["failover"]["done"]},
    }]
    # choke/endgame effects need a few seeders' worth of swarm: below ~8
    # volunteers the duplicate-execution counts are dominated by noise
    n_vi = max(n_volunteers, 8)
    vi = scenario_vi(verbose=False, n_volunteers=n_vi,
                     image_mb=image_mb, n_pieces=16, n_parts=4 * n_vi)
    rows.append({
        "name": f"swarm_choke_n{n_vi}_img{int(image_mb)}MB",
        "us_per_call": 0.0,
        "derived": (f"dup_execs {vi['baseline']['dup_execs']}->"
                    f"{vi['choked']['dup_execs']} origin_up "
                    f"{vi['baseline']['origin_up_mb']:.0f}MB->"
                    f"{vi['choked']['origin_up_mb']:.0f}MB "
                    f"makespan {vi['baseline']['makespan_s']:.0f}s->"
                    f"{vi['choked']['makespan_s']:.0f}s"),
        "metrics": {k: {"makespan_s": vi[k]["makespan_s"],
                        "origin_up_mb": vi[k]["origin_up_mb"],
                        "dup_execs": vi[k]["dup_execs"],
                        "done": vi[k]["done"]}
                    for k in ("baseline", "unchoked", "choked")},
    })
    if verbose:
        for r in rows:
            print(f"[swarm] {r['name']}: {r['derived']}")
    return rows


def bench_scenario_ix(verbose: bool = True, n_volunteers: int = 500,
                      n_islands: int = 8, image_mb: float = 32.0,
                      backend=None):
    """Scenario IX (topology-aware P4P selection) as perf-trajectory
    rows: the same WAN flash crowd with rarity-only vs cost-aware peer
    selection, one row per mode so bench_guard tracks the cross-ISP
    bytes and p99 completion of each independently."""
    from benchmarks.paper_tables import scenario_ix
    res = scenario_ix(verbose=False, n_volunteers=n_volunteers,
                      n_islands=n_islands, image_mb=image_mb,
                      backend=backend)
    rows = []
    for mode in ("naive", "p4p"):
        m = res[mode]
        rows.append({
            "name": f"swarm_scenario_ix_{mode}_n{n_volunteers}"
                    f"_i{n_islands}",
            "us_per_call": 0.0,
            "derived": (f"cross_isp {m['cross_isp_bytes'] / 1e6:.0f}MB "
                        f"p99 {m['p99_completion_s']:.0f}s makespan "
                        f"{m['makespan_s']:.0f}s replicas "
                        f"{m['replicas']}/{n_volunteers} "
                        f"[{m['backend']}]"),
            "metrics": {"n_volunteers": n_volunteers,
                        "n_islands": n_islands,
                        **{k: m[k] for k in
                           ("cross_isp_bytes", "p99_completion_s",
                            "makespan_s", "full_replication_s",
                            "origin_up_mb", "replicas", "done",
                            "replicated", "events", "events_per_sec",
                            "wall_s", "backend")}},
        })
    rows.append({
        "name": f"swarm_scenario_ix_summary_n{n_volunteers}"
                f"_i{n_islands}",
        "us_per_call": 0.0,
        "derived": (f"cross_isp cut {res['cross_isp_reduction']:.1f}x "
                    f"makespan x{res['makespan_ratio']:.3f} "
                    f"p99 x{res['p99_ratio']:.3f} "
                    f"replicated={res['replicated']}"),
        "metrics": {"cross_isp_reduction": res["cross_isp_reduction"],
                    "makespan_ratio": res["makespan_ratio"],
                    "p99_ratio": res["p99_ratio"],
                    "done": res["done"],
                    "replicated": res["replicated"]},
    })
    if verbose:
        for r in rows:
            print(f"[swarm] {r['name']}: {r['derived']}")
    return rows


def bench_scenario_x(verbose: bool = True, n_volunteers: int = 200,
                     image_mb: float = 64.0, n_pieces: int = 128,
                     delta_frac: float = 0.05, backend=None,
                     include_chaos: bool = True):
    """Scenario X (versioned-manifest delta upgrade) as perf-trajectory
    rows: one row per arm (delta upgrade vs scratch redistribution) so
    bench_guard tracks `upgrade_traffic_bytes` and `upgrade_makespan_s`
    independently, plus a summary row with the >=10x reduction ratios
    and the churn-overlay verdict (`no_stale` / `chaos_ready`)."""
    from benchmarks.paper_tables import scenario_x
    res = scenario_x(verbose=False, n_volunteers=n_volunteers,
                     image_mb=image_mb, n_pieces=n_pieces,
                     delta_frac=delta_frac, backend=backend,
                     include_chaos=include_chaos)
    rows = [{
        "name": f"swarm_scenario_x_upgrade_n{n_volunteers}",
        "us_per_call": 0.0,
        "derived": (f"delta {res['n_changed']}/{n_pieces} pieces: "
                    f"{res['upgrade_traffic_bytes'] / 1e6:.0f}MB "
                    f"{res['upgrade_makespan_s']:.0f}s reused "
                    f"{res['reused_pieces']} "
                    f"upgraded={res['upgraded']}"),
        "metrics": {"n_volunteers": n_volunteers, "n_pieces": n_pieces,
                    **{k: res[k] for k in
                       ("image_mb", "n_changed", "delta_frac",
                        "upgrade_traffic_bytes", "upgrade_makespan_s",
                        "reused_pieces", "upgraded", "stale_accepts",
                        "no_stale", "wall_s")}},
    }, {
        "name": f"swarm_scenario_x_scratch_n{n_volunteers}",
        "us_per_call": 0.0,
        "derived": (f"full {image_mb:.0f}MB redistribution: "
                    f"{res['scratch_traffic_bytes'] / 1e6:.0f}MB "
                    f"{res['scratch_makespan_s']:.0f}s "
                    f"replicated={res['replicated']}"),
        "metrics": {"n_volunteers": n_volunteers, "n_pieces": n_pieces,
                    **{k: res[k] for k in
                       ("image_mb", "scratch_traffic_bytes",
                        "scratch_makespan_s", "v1_makespan_s",
                        "v1_traffic_bytes", "replicated")}},
    }]
    summary = {"n_volunteers": n_volunteers,
               "traffic_reduction": res["traffic_reduction"],
               "makespan_speedup": res["makespan_speedup"],
               "no_stale": res["no_stale"],
               "upgraded": res["upgraded"],
               "replicated": res["replicated"]}
    if include_chaos:
        c = res["chaos"]
        summary["chaos_ready"] = res["chaos_ready"]
        summary["chaos_reused_pieces"] = c["reused_pieces"]
        summary["chaos_stale_have_demoted"] = c["stale_have_demoted"]
        summary["chaos_stale_accepts"] = c["stale_accepts"]
    rows.append({
        "name": f"swarm_scenario_x_summary_n{n_volunteers}",
        "us_per_call": 0.0,
        "derived": (f"traffic /{res['traffic_reduction']:.1f} makespan "
                    f"x{res['makespan_speedup']:.1f} "
                    f"no_stale={res['no_stale']} "
                    f"chaos_ready={summary.get('chaos_ready')}"),
        "metrics": summary,
    })
    if verbose:
        for r in rows:
            print(f"[swarm] {r['name']}: {r['derived']}")
    return rows


def bench_scenario_xi(verbose: bool = True, n_replicas: int = 50,
                      ckpt_mb: float = 2048.0, n_islands: int = 8,
                      n_pieces: int = 128):
    """Scenario XI (swarm-served checkpoints) as perf-trajectory rows:
    replica cold-start flash crowd, origin-only vs swarm on flat and
    island topologies, one row per (mode, topology) so bench_guard
    tracks `ttr_p99_s` and `origin_egress_bytes` independently, plus a
    summary row with the reduction ratios and the origin-death chaos
    verdict."""
    from benchmarks.paper_tables import scenario_xi
    res = scenario_xi(verbose=False, n_replicas=n_replicas,
                      ckpt_mb=ckpt_mb, n_islands=n_islands,
                      n_pieces=n_pieces)
    rows = []
    topos = [("flat", res["flat"])]
    if "islands" in res:
        topos.append((f"isl{n_islands}", res["islands"]))
    for tag, pair in topos:
        for mode in ("origin", "swarm"):
            m = pair[mode]
            rows.append({
                "name": f"ckpt_flashcrowd_{mode}_r{n_replicas}_{tag}",
                "us_per_call": 0.0,
                "derived": (f"ttr_p99 {m['ttr_p99_s']:.0f}s max "
                            f"{m['ttr_max_s']:.0f}s origin_egress "
                            f"{m['origin_egress_bytes'] / 1e9:.2f}GB "
                            f"ready {m['replicas_ready']}/{n_replicas}"),
                "metrics": {"n_replicas": n_replicas, "ckpt_mb": ckpt_mb,
                            **{k: m[k] for k in
                               ("ttr_p99_s", "ttr_max_s", "ttr_median_s",
                                "origin_egress_bytes", "cross_isp_bytes",
                                "ready", "replicas_ready", "events")}},
            })
    summary = {"ckpt_mb": ckpt_mb,
               "egress_reduction_flat": res["egress_reduction_flat"],
               "ttr_p99_speedup_flat": res["ttr_p99_speedup_flat"],
               "all_ready": res["all_ready"]}
    if "islands" in res:
        summary["egress_reduction_islands"] = \
            res["egress_reduction_islands"]
        summary["ttr_p99_speedup_islands"] = \
            res["ttr_p99_speedup_islands"]
    if "chaos" in res:
        summary["chaos_ready"] = res["chaos"]["ready"]
        summary["chaos_origin_died_at_s"] = \
            res["chaos"]["origin_died_at_s"]
    rows.append({
        "name": f"ckpt_flashcrowd_summary_r{n_replicas}",
        "us_per_call": 0.0,
        "derived": (f"flat: egress /{res['egress_reduction_flat']:.1f} "
                    f"ttr_p99 x{res['ttr_p99_speedup_flat']:.1f} | "
                    f"chaos_ready={summary.get('chaos_ready')} "
                    f"all_ready={res['all_ready']}"),
        "metrics": summary,
    })
    if verbose:
        for r in rows:
            print(f"[swarm] {r['name']}: {r['derived']}")
    return rows


def bench_sweep(ns, verbose: bool = True, backend=None,
                tick_s: float = 0.5, profile: bool = False):
    """N-sweep of the *batched* array-native Scenario VII: one row per N
    with events/s (logical and heap), wall-clock and peak RSS.  This is
    the scaling curve the batched engine exists for — the per-message
    path tops out around N≈500 while the hub path (with the ISSUE-10
    array ledger + fused tick) reaches N=10000.  With `profile`, each
    row also carries the per-tick wall breakdown: host Python vs kernel
    milliseconds, drain (message-burst) seconds and the incremental
    ledger-update count — the numbers that show host time staying
    sublinear in N."""
    from benchmarks.paper_tables import scenario_vii
    rows = []
    for n in ns:
        res = scenario_vii(verbose=False, n_volunteers=n, batched=True,
                           backend=backend, tick_s=tick_s)
        row = {
            "name": f"swarm_sweep_batched_n{n}",
            "us_per_call": 0.0,
            "derived": (f"makespan {res['makespan_s']:.0f}s replication "
                        f"{res['full_replication_s']:.0f}s replicas "
                        f"{res['replicas']}/{n} | "
                        f"{res['events_per_sec']:.0f} logical ev/s "
                        f"({res['heap_events_per_sec']:.0f} heap) "
                        f"wall {res['wall_s']:.1f}s "
                        f"rss {res['peak_rss_mb']:.0f}MB "
                        f"[{res['backend']}]"),
            "metrics": {k: res[k] for k in
                        ("n_volunteers", "makespan_s",
                         "full_replication_s", "p99_completion_s",
                         "cross_isp_bytes", "origin_up_mb", "replicas",
                         "done", "replicated", "events", "logical_events",
                         "events_per_sec", "heap_events_per_sec",
                         "batch_ops", "coalesced_events", "ticks",
                         "wall_s", "peak_rss_mb", "backend")},
        }
        if profile:
            ticks = max(int(res.get("ticks", 0)), 1)
            tick_w = float(res.get("tick_wall_s", 0.0))
            kern_w = float(res.get("kernel_wall_s", 0.0))
            host_ms = (tick_w - kern_w) / ticks * 1e3
            row["metrics"].update({
                "tick_wall_s": res.get("tick_wall_s"),
                "kernel_wall_s": res.get("kernel_wall_s"),
                "drain_wall_s": res.get("drain_wall_s"),
                "ledger_ops": res.get("ledger_ops"),
                "host_ms_per_tick": host_ms,
                "kernel_ms_per_tick": kern_w / ticks * 1e3,
            })
            row["derived"] += (
                f" | tick {tick_w:.1f}s (host {host_ms:.1f}ms/tick, "
                f"kernel {kern_w / ticks * 1e3:.1f}ms/tick) drain "
                f"{res.get('drain_wall_s', 0.0):.1f}s "
                f"ledger_ops {res.get('ledger_ops')}")
        rows.append(row)
        if verbose:
            print(f"[swarm] {row['name']}: {row['derived']}")
    return rows


def merge_rows(path, rows):
    """Merge bench rows into an existing BENCH json by row name (new rows
    replace same-named rows, others are preserved) so `--sweep` runs can
    update the scaling curve without clobbering the rest of the file."""
    import json
    import os
    doc = {"bench": "swarm", "rows": []}
    if os.path.exists(path):
        with open(path) as f:
            doc = json.load(f)
    by_name = {r["name"]: i for i, r in enumerate(doc.get("rows", []))}
    for r in rows:
        if r["name"] in by_name:
            doc["rows"][by_name[r["name"]]] = r
        else:
            doc["rows"].append(r)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, default=str)
    return doc


def bench(verbose: bool = True, smoke: bool = False):
    rows = []
    plan_cases = [(8, 8), (16, 16), (64, 64)] if smoke else \
        [(8, 8), (16, 16), (64, 64), (256, 64), (1024, 128)]
    for n_nodes, n_pieces in plan_cases:
        t0 = time.perf_counter()
        plan = plan_broadcast(n_nodes, n_pieces, fanout=1)
        dt = (time.perf_counter() - t0) * 1e6
        r = rounds_of(plan)
        nr = naive_rounds(n_nodes, n_pieces)
        stats = simulate(plan, piece_bytes=64e6, link_Bps=25e9,
                         n_nodes=n_nodes)
        rows.append({
            "name": f"swarm_plan_n{n_nodes}_p{n_pieces}",
            "us_per_call": dt,
            "derived": (f"rounds={r} naive={nr} speedup={nr / r:.1f}x "
                        f"seeder_up={stats.seeder_uploads}"),
        })
    # analytic ppermute-ring model at checkpoint scale (20B params bf16)
    cm = broadcast_cost_model(40e9, n_pods=8)
    rows.append({"name": "weight_torrent_40GB_8pods", "us_per_call": 0.0,
                 "derived": (f"torrent={cm['torrent_s']:.1f}s "
                             f"naive={cm['naive_s']:.1f}s "
                             f"speedup={cm['speedup']:.2f}x")})
    if verbose:
        for r in rows:
            print(f"[swarm] {r['name']}: {r['derived']}")
    rows += bench_live(verbose=verbose,
                       n_volunteers=6 if smoke else 8,
                       image_mb=16.0 if smoke else 32.0)
    # Scenario VII — the flash crowd runs at full N=200 even in smoke (the
    # incremental engine made it cheap enough for CI); a quick N=64 run
    # rides along for the scaling curve
    from benchmarks import exchange_bench
    rows += bench_scenario_vii(verbose=verbose, n_volunteers=64)
    rows += bench_scenario_vii(verbose=verbose, n_volunteers=200)
    # Scenario VIII chaos rows ride along at full N=48 even in smoke: the
    # fault-tolerance overhead is a tracked trajectory metric like the
    # flash-crowd numbers above
    rows += bench_scenario_viii(verbose=verbose)
    # Scenario IX (P4P): smoke runs the CI-sized N=64/4-island WAN, the
    # full bench the headline N=500/8-island configuration
    if smoke:
        rows += bench_scenario_ix(verbose=verbose, n_volunteers=64,
                                  n_islands=4, image_mb=8.0)
    else:
        rows += bench_scenario_ix(verbose=verbose)
    # Scenario XI (swarm-served checkpoints): smoke runs the CI-sized
    # R=8/256MB flash crowd, the full bench the headline R=50/2GB one
    if smoke:
        rows += bench_scenario_xi(verbose=verbose, n_replicas=8,
                                  ckpt_mb=256.0, n_islands=4,
                                  n_pieces=64)
    else:
        rows += bench_scenario_xi(verbose=verbose)
    # pump micro-benchmark: the ≥10x incremental-vs-reference ratio is the
    # acceptance gate for the bookkeeping rewrite
    rows += exchange_bench.bench(verbose=verbose, smoke=smoke)
    return rows


def main(argv=None) -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced scale for CI")
    ap.add_argument("--json", metavar="PATH",
                    help="write rows as JSON (perf trajectory artifact)")
    ap.add_argument("--sweep", metavar="N1,N2,...",
                    help="run ONLY the batched Scenario VII N-sweep at "
                         "these sizes (e.g. 50,200,500,1000,2000); with "
                         "--json, rows are merged into the file by name "
                         "instead of overwriting it")
    ap.add_argument("--backend", choices=("numpy", "jax", "pallas"),
                    help="kernel backend for --sweep (default: best "
                         "available)")
    ap.add_argument("--profile", action="store_true",
                    help="with --sweep: add the per-tick wall breakdown "
                         "(host vs kernel ms, drain seconds, ledger "
                         "update counts) to each row")
    ap.add_argument("--scenario-ix", metavar="N,K",
                    help="run ONLY Scenario IX (P4P vs naive) at N "
                         "volunteers over K islands (e.g. 500,8 or the "
                         "CI smoke 64,4); with --json, rows are merged "
                         "into the file by name")
    ap.add_argument("--scenario-x", metavar="N",
                    help="run ONLY Scenario X (versioned-manifest delta "
                         "upgrade) with N volunteers (e.g. 200 or the CI "
                         "smoke 32); with --json, rows are merged into "
                         "the file by name")
    ap.add_argument("--scenario-xi", metavar="R,MB",
                    help="run ONLY Scenario XI (checkpoint flash crowd) "
                         "at R replicas pulling an MB-sized checkpoint "
                         "(e.g. 50,2048 or the CI smoke 8,256); with "
                         "--json, rows are merged into the file by name")
    args = ap.parse_args(argv)
    if args.scenario_x:
        n = int(args.scenario_x)
        rows = bench_scenario_x(
            n_volunteers=n, image_mb=8.0 if n <= 64 else 64.0,
            n_pieces=64 if n <= 64 else 128, backend=args.backend)
        if args.json:
            merge_rows(args.json, rows)
            print(f"[swarm] merged {len(rows)} scenario-x rows "
                  f"into {args.json}")
        return
    if args.scenario_xi:
        r, mb = (int(x) for x in args.scenario_xi.split(","))
        rows = bench_scenario_xi(n_replicas=r, ckpt_mb=float(mb),
                                 n_islands=4 if r <= 16 else 8,
                                 n_pieces=64 if r <= 16 else 128)
        if args.json:
            merge_rows(args.json, rows)
            print(f"[swarm] merged {len(rows)} scenario-xi rows "
                  f"into {args.json}")
        return
    if args.scenario_ix:
        n, k = (int(x) for x in args.scenario_ix.split(","))
        rows = bench_scenario_ix(n_volunteers=n, n_islands=k,
                                 image_mb=8.0 if n <= 100 else 32.0,
                                 backend=args.backend)
        if args.json:
            merge_rows(args.json, rows)
            print(f"[swarm] merged {len(rows)} scenario-ix rows "
                  f"into {args.json}")
        return
    if args.sweep:
        ns = [int(x) for x in args.sweep.split(",") if x.strip()]
        rows = bench_sweep(ns, backend=args.backend,
                           profile=args.profile)
        if args.json:
            merge_rows(args.json, rows)
            print(f"[swarm] merged {len(rows)} sweep rows "
                  f"into {args.json}")
        return
    rows = bench(smoke=args.smoke)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "swarm", "smoke": args.smoke,
                       "rows": rows}, f, indent=2, default=str)
        print(f"[swarm] wrote {args.json}")


if __name__ == "__main__":
    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    main()
