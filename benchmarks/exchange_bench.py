"""PieceExchange.pump micro-benchmark: incremental vs reference bookkeeping.

`pump` runs on every HAVE announce, every UNCHOKE and every PIECE_DATA of
every fetching node, so its per-call cost bounds how large a swarm the
simulator (and a real agent) can sustain.  This bench builds one engine at
swarm scale — N peers that each announced a random bitmask over P pieces —
and measures pump calls/sec twice over the *same* state:

  * reference    — `use_incremental=False`: the pre-optimization path that
    rebuilds the full availability map (O(P·N)) and rescans the holder
    pool per piece (`_pump_reference`);
  * incremental  — the maintained count array + holder index + cached
    pool (O(P log P) argsort per call).

The two paths issue identical requests (asserted by the differential tests
in tests/test_exchange_scaling.py); only the bookkeeping differs.  Run
with --json to record the speedup into the perf-trajectory artifact
(swarm_bench merges these rows into BENCH_swarm.json).
"""
from __future__ import annotations

import random
import time

from repro.core import AgentConfig, Msg, PieceExchange, PieceManifest
from repro.core.messages import HAVE, UNCHOKE


def build_engine(n_peers: int = 64, n_pieces: int = 256, seed: int = 11,
                 incremental: bool = True) -> PieceExchange:
    """A leeching engine mid-swarm: some full seeders, N partial holders
    with random bitmasks, half the holders unchoked us."""
    cfg = AgentConfig(piece_pipeline=8)
    px = PieceExchange("bench-node", cfg, send=lambda dst, msg: None,
                       now=lambda: 0.0)
    px.use_incremental = incremental
    manifest = PieceManifest.synthetic("bench", n_pieces * 1000, 1000)
    px.join("bench", manifest)
    rng = random.Random(seed)
    peers = [f"P{i:03d}" for i in range(n_peers)]
    px.note_full_seeders("bench", set(peers[:max(n_peers // 8, 1)]))
    for peer in peers:
        px.on_have(Msg(HAVE, peer, {"app_id": "bench",
                                    "mask": rng.getrandbits(n_pieces)}))
    for peer in peers[::2]:
        px.on_unchoke(Msg(UNCHOKE, peer, {"app_id": "bench"}))
    return px


def time_pump(px: PieceExchange, iters: int) -> float:
    """Seconds per pump call; in-flight state is reset between calls so
    every iteration exercises a full scheduling decision (not the
    pipeline-full early-out)."""
    t0 = time.perf_counter()
    for _ in range(iters):
        px.pending["bench"].clear()
        px._sole_pending.clear()
        px.peer_load.clear()
        px.pump("bench")
    return (time.perf_counter() - t0) / iters


def bench(verbose: bool = True, smoke: bool = False,
          n_peers: int = 64, n_pieces: int = 256) -> list:
    iters_ref = 40 if smoke else 200
    iters_inc = 400 if smoke else 2000
    ref = build_engine(n_peers, n_pieces, incremental=False)
    inc = build_engine(n_peers, n_pieces, incremental=True)
    time_pump(ref, 5)                    # warmup
    time_pump(inc, 5)
    ref_s = time_pump(ref, iters_ref)
    inc_s = time_pump(inc, iters_inc)
    speedup = ref_s / max(inc_s, 1e-12)
    rows = [
        {"name": f"pump_reference_n{n_peers}_p{n_pieces}",
         "us_per_call": ref_s * 1e6,
         "derived": f"{1.0 / ref_s:.0f} pump calls/s (pre-PR bookkeeping)",
         "metrics": {"calls_per_sec": 1.0 / ref_s}},
        {"name": f"pump_incremental_n{n_peers}_p{n_pieces}",
         "us_per_call": inc_s * 1e6,
         "derived": f"{1.0 / inc_s:.0f} pump calls/s (incremental)",
         "metrics": {"calls_per_sec": 1.0 / inc_s}},
        {"name": f"pump_speedup_n{n_peers}_p{n_pieces}",
         "us_per_call": 0.0,
         "derived": f"incremental pump {speedup:.1f}x the reference",
         "metrics": {"speedup": speedup}},
    ]
    if verbose:
        for r in rows:
            print(f"[exchange] {r['name']}: {r['derived']}")
    return rows


def main(argv=None) -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced iteration counts for CI")
    ap.add_argument("--json", metavar="PATH",
                    help="write rows as JSON (perf trajectory artifact)")
    args = ap.parse_args(argv)
    rows = bench(smoke=args.smoke)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "exchange", "smoke": args.smoke,
                       "rows": rows}, f, indent=2, default=str)
        print(f"[exchange] wrote {args.json}")


if __name__ == "__main__":
    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    main()
