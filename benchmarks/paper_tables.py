"""Reproduction of the paper's Tables I-IV (one function per table).

Calibration (documented in EXPERIMENTS.md):
  * app1 = primes 3..2,000,000 in 2059 parts; host-class per-cycle 4.93 s,
    VM-class 5.51 s  (paper Table I sequential rows).
  * app2 = primes 2,000,000..3,000,000 in 1080 parts; host 21.21 s,
    VM 21.66 s      (paper Table II sequential rows).
  * per-cycle protocol/VM overhead = 6.35 - 5.51 = 0.84 s, measured from the
    paper's own Scenario I (parallel avg vs sequential-VM avg).  Applied
    unchanged to all four scenarios — Tables II-IV are then predictions.
  * second test machine (i3 + its VMs, Scenario IV) speed from the paper's
    app1 per-cycle ratio ~8.1/10.8 => 0.75 x VM-class.

The protocol itself (tracker, agents, leases, voting) runs for real on the
discrete-event runtime; only per-cycle compute cost is synthetic.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core import (Agent, AgentConfig, SimRuntime, TrackerConfig,
                        TrackerServer, make_prime_app)

H = 3600.0

# paper-measured sequential per-cycle seconds
APP1 = dict(lo=3, hi=2_000_000, parts=2059, host_cycle=4.93, vm_cycle=5.51,
            data_mb=8.33)
APP2 = dict(lo=2_000_000, hi=3_000_000, parts=1080, host_cycle=21.21,
            vm_cycle=21.66, data_mb=4.23)
VM_SPEED = APP1["host_cycle"] / APP1["vm_cycle"]        # 0.895
I3_SPEED = VM_SPEED * 0.75                              # scenario IV machines
# per-cycle overhead in reference work units: VM-observed 0.84s x VM speed
OVERHEAD_S = (6.35 - 5.51) * VM_SPEED                   # 0.752


def _mk_app(app_id, host, spec, m_min=1):
    per_number = spec["host_cycle"] * spec["parts"] / (spec["hi"] - spec["lo"])
    n = spec["parts"]
    part_bytes = int(spec["data_mb"] * 2**20 / n)
    return make_prime_app(app_id, host, spec["lo"], spec["hi"], n,
                          app_bytes=4096, part_data_bytes=part_bytes,
                          m_min=m_min, sim_time_per_number=per_number)


@dataclass
class ScenarioOut:
    makespan_h: Dict[str, float]
    cycles: Dict[Tuple[str, str], int]
    avg_s: Dict[Tuple[str, str], float]
    data_mb: Dict[Tuple[str, str], float]
    host_metrics: Dict[str, dict]


def run_scenario(apps: dict, speeds: dict, self_leech: bool = False,
                 until_h: float = 48.0, m_min: int = 1) -> ScenarioOut:
    """apps: app_id -> (host_id, spec); speeds: node_id -> speed."""
    rt = SimRuntime()
    rt.add_node(TrackerServer(config=TrackerConfig(ping_interval_s=5.0)))
    agents = {}
    for nid, sp in speeds.items():
        a = Agent(nid, config=AgentConfig(
            work_timeout_s=600.0, status_interval_s=5.0,
            cycle_overhead_s=OVERHEAD_S, self_leech=self_leech,
            max_parallel_apps=2))
        agents[nid] = a
        rt.add_node(a, speed=sp)
    objs = {}
    for app_id, (host, spec) in apps.items():
        app = _mk_app(app_id, host, spec, m_min)
        agents[host].host_app(app)
        objs[app_id] = (app, agents[host])

    rt.run(until=until_h * H,
           stop_when=lambda: all(a.done for a, _ in objs.values()))

    out = ScenarioOut({}, {}, {}, {}, {})
    for app_id, (app, host) in objs.items():
        out.makespan_h[app_id] = host.completed_at.get(app_id, rt.now()) / H
        out.host_metrics[app_id] = host.metrics[app_id].as_dict()
        for nid, ag in agents.items():
            c = ag.completed_cycles.get(app_id, 0)
            if c:
                out.cycles[(app_id, nid)] = c
                out.avg_s[(app_id, nid)] = ag.leech_time[app_id] / c
                out.data_mb[(app_id, nid)] = ag.leech_bytes[app_id] / 2**20
    return out


# --------------------------------------------------------------------------- #
def table1(verbose: bool = True) -> dict:
    """Scenario I: three volunteers, one application."""
    out = run_scenario({"app1": ("Y", APP1)},
                       {"Y": VM_SPEED, "X": VM_SPEED, "Z": VM_SPEED})
    t = out.makespan_h["app1"]
    seq_host, seq_vm = 2.82, 3.15
    res = {
        "parallel_h": t,
        "speedup_vs_host": seq_host / t,
        "speedup_vs_vm": seq_vm / t,
        "paper_speedup_vs_host": 1.56,
        "paper_speedup_vs_vm": 1.73,
        "cycles": {n: out.cycles.get(("app1", n), 0) for n in ("X", "Z")},
        "paper_cycles": {"X": 1031, "Z": 1028},
        "avg_s": {n: out.avg_s.get(("app1", n), 0.0) for n in ("X", "Z")},
        "paper_avg_s": 6.35,
    }
    if verbose:
        print(f"[table1] parallel={t:.2f}h (paper 1.82/1.81) "
              f"speedup host={res['speedup_vs_host']:.2f} (paper 1.56) "
              f"vm={res['speedup_vs_vm']:.2f} (paper 1.73) "
              f"cycles={res['cycles']} avg={res['avg_s']}")
    return res


def table2(verbose: bool = True) -> dict:
    """Scenario II: three volunteers, two applications.

    X hosts app1 (leeches app2); Z hosts app2 (leeches app1); Y leeches both.
    Paper headline: both apps complete ~33% faster than sequential app2."""
    out = run_scenario({"app1": ("X", APP1), "app2": ("Z", APP2)},
                       {"X": VM_SPEED, "Y": VM_SPEED, "Z": VM_SPEED})
    makespan = max(out.makespan_h.values())
    seq_app2_vm = 6.73
    res = {
        "makespan_h": makespan,
        "app1_h": out.makespan_h["app1"],
        "app2_h": out.makespan_h["app2"],
        "faster_than_seq_pct": 100.0 * (1 - makespan / seq_app2_vm),
        "paper_faster_pct": 33.0,
        "cycles": {k: v for k, v in out.cycles.items()},
        "paper_cycles": {("app1", "Y"): 139, ("app1", "Z"): 1920,
                         ("app2", "Y"): 462, ("app2", "X"): 618},
    }
    if verbose:
        print(f"[table2] makespan={makespan:.2f}h (paper ~4.48) "
              f"faster={res['faster_than_seq_pct']:.0f}% (paper ~33%) "
              f"cycles={res['cycles']}")
    return res


def table3(verbose: bool = True) -> dict:
    """Scenario III: II + hosts also run their own applications."""
    out = run_scenario({"app1": ("X", APP1), "app2": ("Z", APP2)},
                       {"X": VM_SPEED, "Y": VM_SPEED, "Z": VM_SPEED},
                       self_leech=True)
    res = {
        "app1_h": out.makespan_h["app1"],
        "app2_h": out.makespan_h["app2"],
        "paper_app1_h": 2.88,     # slowest client row (Y)
        "paper_app2_h": 3.50,
        "cycles": dict(out.cycles),
        "paper_cycles": {("app1", "X"): 736, ("app1", "Y"): 635,
                         ("app1", "Z"): 688, ("app2", "X"): 401,
                         ("app2", "Y"): 329, ("app2", "Z"): 350},
    }
    if verbose:
        print(f"[table3] app1={res['app1_h']:.2f}h (paper ~2.88) "
              f"app2={res['app2_h']:.2f}h (paper ~3.50) cycles-sum="
              f"{sum(v for (a, _), v in out.cycles.items() if a == 'app1')}/"
              f"{sum(v for (a, _), v in out.cycles.items() if a == 'app2')}")
    return res


def table4(verbose: bool = True) -> dict:
    """Scenario IV: six volunteers (3 VM-class + 3 i3-class), two apps."""
    speeds = {"X": VM_SPEED, "Y": VM_SPEED, "Z": VM_SPEED,
              "X'": I3_SPEED, "Y'": I3_SPEED, "Z'": I3_SPEED}
    out = run_scenario({"app1": ("X", APP1), "app2": ("Z", APP2)},
                       speeds, self_leech=True)
    seq_app1_vm, seq_app2_vm = 3.15, 6.73
    res = {
        "app1_h": out.makespan_h["app1"],
        "app2_h": out.makespan_h["app2"],
        "speedup_app1": seq_app1_vm / out.makespan_h["app1"],
        "speedup_app2": seq_app2_vm / out.makespan_h["app2"],
        "paper_speedup_app1": 3.5,
        "paper_speedup_app2": 3.3,
        "cycles": dict(out.cycles),
        "paper_app1_h": 0.89, "paper_app2_h": 1.94,
    }
    if verbose:
        print(f"[table4] app1={res['app1_h']:.2f}h (paper ~0.89) "
              f"app2={res['app2_h']:.2f}h (paper ~1.94) "
              f"speedups={res['speedup_app1']:.2f}/{res['speedup_app2']:.2f} "
              f"(paper 3.5/3.3)")
    return res


def scenario_v(verbose: bool = True, n_volunteers: int = 12,
               image_mb: float = 64.0, n_pieces: int = 16,
               n_parts: int = 48, uplink_mbps: float = 100.0) -> dict:
    """Scenario V (paper §V extension): piece-wise multi-seeder swarm.

    Not in the paper's tables — this is the extension §V names ("broken to
    pieces like regular file sharing in torrent") run through the live
    protocol.  Compares single-seeder (monolithic APP_DATA) against the
    swarm on a large app image with per-node uplink contention, and shows
    the app surviving origin-host death because replica seeders take over
    DIST/VAL.
    """
    from repro.core.runtime import LinkModel

    image_bytes = int(image_mb * 1e6)
    uplink_Bps = uplink_mbps * 1e6 / 8

    def build(swarm: bool):
        rt = SimRuntime(link=LinkModel(uplink_Bps=uplink_Bps))
        rt.add_node(TrackerServer(config=TrackerConfig(ping_interval_s=2.0)))
        host = Agent("host", config=AgentConfig(work_timeout_s=600.0))
        rt.add_node(host)
        app = make_prime_app("appv", "host", 3, 48_000, n_parts=n_parts,
                             sim_time_per_number=1e-4, swarm=swarm,
                             app_bytes=image_bytes,
                             piece_bytes=image_bytes // n_pieces)
        host.host_app(app)
        leechers = []
        for i in range(n_volunteers):
            a = Agent(f"V{i}", config=AgentConfig(work_timeout_s=600.0))
            rt.add_node(a)
            leechers.append(a)
        def done():
            if app.done:
                return True
            return any(a.apps.get("appv") and a.apps["appv"].done
                       for a in leechers)
        return rt, app, leechers, done

    # (a) single seeder: the origin re-ships the image with every part
    rt, app, _, done = build(swarm=False)
    rt.run(until=4 * H, stop_when=done)
    single = {"makespan_s": rt.now(), "done": done(),
              "origin_up_mb": rt.tx_bytes.get("host", 0) / 1e6}

    # (b) swarm: image moves once as pieces, every leecher re-seeds
    rt, app, _, done = build(swarm=True)
    rt.run(until=4 * H, stop_when=done)
    swarm_res = {"makespan_s": rt.now(), "done": done(),
                 "origin_up_mb": rt.tx_bytes.get("host", 0) / 1e6}

    # (c) churn: origin dies mid-run (plus one leecher), replicas take over
    rt, app, leechers, done = build(swarm=True)
    # wait until at least one replica seeder formed, then kill the origin
    rt.run(until=4 * H, stop_when=lambda: any(
        "appv" in a.images for a in leechers))
    killed_at = rt.now()
    rt.nodes.pop("host", None)
    rt.run(until=killed_at + 6.0)
    rt.nodes.pop(leechers[0].node_id, None)   # node churn on top
    rt.run(until=4 * H, stop_when=done)
    failover = {"makespan_s": rt.now(), "done": done(),
                "origin_died_at_s": killed_at}

    res = {
        "single": single, "swarm": swarm_res, "failover": failover,
        "origin_bytes_reduction": (single["origin_up_mb"]
                                   / max(swarm_res["origin_up_mb"], 1e-9)),
        "makespan_speedup": (single["makespan_s"]
                             / max(swarm_res["makespan_s"], 1e-9)),
        # the core/swarm.py round bound the live swarm should approach
        "bound_naive_rounds": n_volunteers * n_pieces,
        "bound_swarm_rounds": n_pieces + max(1, n_volunteers).bit_length(),
    }
    if verbose:
        dnf = "" if single["done"] else " (single DNF at cap — ratios are"
        dnf += "" if single["done"] else " lower bounds)"
        print(f"[scenarioV] single: makespan={single['makespan_s']:.0f}s "
              f"origin_up={single['origin_up_mb']:.0f}MB | swarm: "
              f"makespan={swarm_res['makespan_s']:.0f}s "
              f"origin_up={swarm_res['origin_up_mb']:.0f}MB | "
              f"origin bytes /{res['origin_bytes_reduction']:.0f}, "
              f"makespan x{res['makespan_speedup']:.0f} | failover "
              f"done={failover['done']} t={failover['makespan_s']:.0f}s"
              f"{dnf}")
    return res


def _duplicate_execs(agents, app_id: str, m_min: int) -> int:
    """Completed part executions beyond the m_min the quorum needs,
    summed over parts (the waste endgame PART_CANCEL exists to cap)."""
    import collections as _c
    per_part = _c.Counter(part_id for a in agents
                          for (_, aid, part_id) in a.results_log
                          if aid == app_id)
    return sum(max(0, n - m_min) for n in per_part.values())


def scenario_vi(verbose: bool = True, n_volunteers: int = 24,
                image_mb: float = 32.0, n_pieces: int = 16,
                n_parts: int = 96, m_min: int = 2,
                uplink_mbps: float = 100.0) -> dict:
    """Scenario VI: the PieceExchange engine's choke scheduler + endgame.

    Three swarm variants at N=24 with symmetric uplink/downlink
    contention:

      * baseline — PR 1 behaviour: no choking, no cancel messages;
        duplicate part executions from seeders' drained partitions run to
        completion and are wasted.
      * unchoked — cancels on (PIECE_CANCEL/PART_CANCEL), choking off:
        shows what endgame reconciliation alone buys.
      * choked   — full engine: fixed upload slots + optimistic unchoke
        on top of endgame cancels.

    Reports origin egress, makespan and duplicate-execution counts.
    """
    from repro.core.runtime import LinkModel

    image_bytes = int(image_mb * 1e6)
    link_Bps = uplink_mbps * 1e6 / 8

    def run(choke: bool, endgame: bool) -> dict:
        rt = SimRuntime(link=LinkModel(uplink_Bps=link_Bps,
                                       downlink_Bps=link_Bps))
        rt.add_node(TrackerServer(config=TrackerConfig(ping_interval_s=2.0)))
        cfg = dict(work_timeout_s=600.0, choke=choke, endgame=endgame,
                   rechoke_interval_s=5.0)
        host = Agent("host", config=AgentConfig(**cfg))
        rt.add_node(host)
        app = make_prime_app("appvi", "host", 3, 48_000, n_parts=n_parts,
                             sim_time_per_number=1e-2, m_min=m_min,
                             swarm=True, app_bytes=image_bytes,
                             piece_bytes=image_bytes // n_pieces)
        host.host_app(app)
        agents = [host]
        for i in range(n_volunteers):
            a = Agent(f"V{i}", config=AgentConfig(**cfg))
            # heterogeneous volunteers (cf. Scenario IV's mixed machine
            # classes): a homogeneous swarm completes duplicate leases in
            # lockstep, which no cancel message can race
            rt.add_node(a, speed=1.0 - 0.4 * i / max(n_volunteers, 1))
            agents.append(a)

        def done():
            return app.done or any(
                a.apps.get("appvi") and a.apps["appvi"].done
                for a in agents[1:])
        rt.run(until=8 * H, stop_when=done)
        return {"done": done(), "makespan_s": rt.now(),
                "origin_up_mb": rt.tx_bytes.get("host", 0) / 1e6,
                "dup_execs": _duplicate_execs(agents, "appvi", m_min),
                "cancelled_parts": sum(a.cancelled_parts for a in agents),
                "piece_cancels": sum(a.px.cancels_sent for a in agents)}

    baseline = run(choke=False, endgame=False)   # PR 1 behaviour
    unchoked = run(choke=False, endgame=True)
    choked = run(choke=True, endgame=True)
    res = {
        "baseline": baseline, "unchoked": unchoked, "choked": choked,
        "dup_exec_reduction": (baseline["dup_execs"]
                               - choked["dup_execs"]),
    }
    if verbose:
        for name in ("baseline", "unchoked", "choked"):
            r = res[name]
            print(f"[scenarioVI] {name}: makespan={r['makespan_s']:.0f}s "
                  f"origin_up={r['origin_up_mb']:.0f}MB "
                  f"dup_execs={r['dup_execs']} "
                  f"cancelled={r['cancelled_parts']} "
                  f"piece_cancels={r['piece_cancels']} "
                  f"done={r['done']}")
        print(f"[scenarioVI] endgame cancels cut duplicate executions by "
              f"{res['dup_exec_reduction']} vs the no-cancel baseline")
    return res


def scenario_vii(verbose: bool = True, n_volunteers: int = 200,
                 image_mb: float = 64.0, n_pieces: int = 64,
                 n_parts: Optional[int] = None, m_min: int = 1,
                 uplink_mbps: float = 100.0, until_h: float = 8.0,
                 batched: bool = False, tick_s: float = 0.5,
                 backend: Optional[str] = None) -> dict:
    """Scenario VII: flash crowd at production-ish scale (default N=200).

    The paper validates the protocol on six nodes; BOINC-class deployments
    (PAPERS.md) run orders of magnitude more.  Here every volunteer joins
    the swarm at t=0 — the worst case for the origin's uplink and for the
    simulator's bookkeeping, since each verified piece triggers O(N) HAVE
    announces.  Reports protocol metrics (makespan, origin egress) AND
    simulator throughput (events/sec, peak RSS), so BENCH_swarm.json
    tracks both the protocol's scaling and the simulator's perf
    trajectory.  Only feasible since the PieceExchange bookkeeping went
    incremental: the pre-optimization engine rebuilt an O(pieces × peers)
    availability map per pump and capped practical runs at N≈24.

    `batched=True` switches to the array-native path (core/swarm_arrays):
    one shared SwarmHub makes all piece/choke decisions in batched
    per-tick kernel passes and the control plane moves through the arrays
    instead of O(N^2) wire messages — the mode that reaches N=2000.  In
    batched mode `events` counts heap pops only; `logical_events` adds
    the control-plane deliveries the arrays replaced, and both rates are
    reported (`events_per_sec` is logical, `heap_events_per_sec` raw).
    """
    import resource
    import time as _time

    from repro.core.runtime import LinkModel

    if n_parts is None:
        n_parts = 2 * n_volunteers
    image_bytes = int(image_mb * 1e6)
    link_Bps = uplink_mbps * 1e6 / 8
    rt = SimRuntime(link=LinkModel(uplink_Bps=link_Bps,
                                   downlink_Bps=link_Bps))
    rt.add_node(TrackerServer(config=TrackerConfig(ping_interval_s=5.0)))
    cfg = dict(work_timeout_s=600.0, status_interval_s=5.0,
               rechoke_interval_s=5.0)
    hub = None
    if batched:
        from repro.core.swarm_arrays import SwarmHub
        hub = SwarmHub(backend=backend)
        rt.crash_hooks.append(hub.node_gone)
        # at flash-crowd scale, cap the replica *seeder* set: seeders
        # beyond a handful add tracker/gossip bookkeeping, not download
        # capacity (every completed volunteer still serves pieces)
        cfg["max_replica_seeders"] = 8
    host = Agent("host", config=AgentConfig(**cfg), hub=hub)
    rt.add_node(host)
    app = make_prime_app("appvii", "host", 3, 48_000, n_parts=n_parts,
                         sim_time_per_number=2e-3, m_min=m_min, swarm=True,
                         app_bytes=image_bytes,
                         piece_bytes=image_bytes // n_pieces)
    host.host_app(app)
    agents = [host]
    for i in range(n_volunteers):
        a = Agent(f"V{i:03d}", config=AgentConfig(**cfg), hub=hub)
        # heterogeneous volunteer speeds, as in Scenario IV/VI
        rt.add_node(a, speed=1.0 - 0.4 * i / max(n_volunteers, 1))
        agents.append(a)

    def _run(until, stop_when):
        if hub is not None:
            return rt.run_batched(until=until, stop_when=stop_when,
                                  tick_s=tick_s, on_tick=hub.tick)
        return rt.run(until=until, stop_when=stop_when)

    t0 = _time.perf_counter()
    # phase 1 — work: cheap O(1) stop probe; the host records completion
    # the moment the last part validates (directly or via PART_DONE gossip)
    _run(until_h * H, lambda: "appvii" in host.completed_at)
    work_done_s = rt.now()
    # phase 2 — full replication: the flash crowd ends when every
    # volunteer holds the verified image (the swarm keeps moving pieces
    # after the work drains); the probe list shrinks as volunteers finish
    # volunteers are appended fastest-first (speed 1.0 - 0.4*i/N), so the
    # list tail finishes last: popping finished agents off the tail keeps
    # the probe amortized O(1) — the run_batched loop calls it every 64
    # drained events, and a full list scan there is O(N) per call (the
    # dominant superlinear drain cost at N=10000 before this change)
    not_done = list(agents[1:])

    def all_replicated():
        while not_done and "appvii" in not_done[-1].images:
            not_done.pop()
        return not not_done

    _run(until_h * H, all_replicated)
    wall_s = max(_time.perf_counter() - t0, 1e-9)
    events = rt.events_processed
    coalesced = hub.coalesced if hub is not None else 0
    logical = events + coalesced
    replicas = sum(1 for a in agents[1:] if "appvii" in a.images)
    # p99 of the per-node image-completion distribution (stragglers that
    # never finished count as run end); cross_isp_bytes is 0 on this flat
    # scenario but keeps the row schema aligned with Scenario IX
    times = sorted(a.image_completed_at.get("appvii", rt.now())
                   for a in agents[1:])
    p99 = times[min(int(0.99 * (len(times) - 1)), len(times) - 1)] \
        if times else 0.0
    res = {
        "n_volunteers": n_volunteers,
        "image_mb": image_mb,
        "batched": batched,
        "done": "appvii" in host.completed_at,
        "makespan_s": work_done_s,
        "full_replication_s": rt.now(),
        "p99_completion_s": p99,
        "cross_isp_bytes": rt.cross_isp_bytes,
        "replicated": replicas == n_volunteers,
        "origin_up_mb": rt.tx_bytes.get("host", 0) / 1e6,
        "replicas": replicas,
        "events": events,
        "logical_events": logical,
        "events_per_sec": logical / wall_s,
        "heap_events_per_sec": events / wall_s,
        "nodes_per_sec": (n_volunteers + 1) / wall_s,
        "wall_s": wall_s,
        "peak_rss_mb": resource.getrusage(
            resource.RUSAGE_SELF).ru_maxrss / 1024.0,
    }
    if hub is not None:
        res.update(hub.stats())
        res["backend"] = hub.backend
        # host-Python wall split from the runtime: message-burst drains
        # vs the batched on_tick decision passes
        res["drain_wall_s"] = rt.batched_drain_s
    if verbose:
        mode = " batched" if batched else ""
        print(f"[scenarioVII{mode}] N={n_volunteers} "
              f"img={image_mb:.0f}MB: "
              f"makespan={res['makespan_s']:.0f}s "
              f"replication={res['full_replication_s']:.0f}s "
              f"origin_up={res['origin_up_mb']:.0f}MB "
              f"replicas={res['replicas']} done={res['done']} | sim: "
              f"{res['logical_events']} logical events "
              f"({res['events']} heap) in {res['wall_s']:.1f}s "
              f"({res['events_per_sec']:.0f}/s) "
              f"peak_rss={res['peak_rss_mb']:.0f}MB")
    return res


def scenario_viii(verbose: bool = True, n_volunteers: int = 48,
                  image_mb: float = 32.0, n_pieces: int = 32,
                  n_parts: Optional[int] = None, m_min: int = 1,
                  loss: float = 0.10, jitter_s: float = 0.2,
                  churn: float = 0.30, seed: int = 8,
                  uplink_mbps: float = 100.0, until_h: float = 4.0) -> dict:
    """Scenario VIII: chaos — the swarm under the volunteer-computing
    default operating conditions (lossy consumer links + churn).

    The same N=48 flash crowd is run twice from one seed: once fault-free
    and once under a `FaultPlan` with 10% message loss, 2% duplication,
    200ms reorder jitter and 30% volunteer churn (crash + restart as
    fresh incarnations, scheduled inside the fault-free makespan).  The
    chaos run must still fully replicate — every surviving volunteer
    converges to the verified image — and the headline numbers are the
    *overhead* of surviving the faults: makespan and origin-egress ratios
    vs the fault-free baseline.  The chaos invariants (convergence,
    quorum <= m_min+1, availability bookkeeping exact) are asserted, not
    just measured.
    """
    from repro.core.chaos import ChaosScenario

    if n_parts is None:
        n_parts = 2 * n_volunteers
    common = dict(n_volunteers=n_volunteers, n_pieces=n_pieces,
                  n_parts=n_parts, m_min=m_min,
                  image_bytes=int(image_mb * 1e6), real_image=False,
                  uplink_mbps=uplink_mbps, until_s=until_h * H)
    base = ChaosScenario(seed=seed, loss=0.0, dup=0.0, jitter_s=0.0,
                         churn=0.0, n_partitions=0, **common).run()
    base.check_invariants()
    # churn/partition schedule scaled to the fault-free makespan, so the
    # chaos run fights faults *during* the distribution, not after it
    horizon = max(base.makespan_s, 30.0)
    chaos = ChaosScenario(seed=seed, loss=loss, dup=0.02,
                          jitter_s=jitter_s, churn=churn, n_partitions=1,
                          partition_s=0.15 * horizon, horizon_s=horizon,
                          **common).run()
    chaos.check_invariants()
    b, c = base.report(), chaos.report()
    res = {
        "baseline": b, "chaos": c, "seed": seed,
        "makespan_overhead": c["makespan_s"] / max(b["makespan_s"], 1e-9),
        "egress_overhead": c["origin_up_mb"] / max(b["origin_up_mb"], 1e-9),
        "replicated": c["replicated"],
        "invariants_ok": True,          # check_invariants() raised otherwise
    }
    if verbose:
        print(f"[scenarioVIII] N={n_volunteers} img={image_mb:.0f}MB "
              f"loss={loss:.0%} churn={churn:.0%} seed={seed}: "
              f"makespan {b['makespan_s']:.0f}s -> {c['makespan_s']:.0f}s "
              f"(x{res['makespan_overhead']:.2f}) origin_up "
              f"{b['origin_up_mb']:.0f} -> {c['origin_up_mb']:.0f}MB "
              f"(x{res['egress_overhead']:.2f}) dropped={c['dropped_msgs']} "
              f"restarts={c['restarts']} replicated={c['replicated']}")
    return res


def scenario_ix(verbose: bool = True, n_volunteers: int = 500,
                n_islands: int = 8, image_mb: float = 32.0,
                n_pieces: int = 64, n_parts: Optional[int] = None,
                m_min: int = 1, uplink_mbps: float = 100.0,
                until_h: float = 8.0, tick_s: float = 0.5,
                seed: int = 9, trunk_Bps: Optional[float] = None,
                backend: Optional[str] = None) -> dict:
    """Scenario IX: topology-aware (P4P) peer selection on a WAN.

    Fixed total demand — the Scenario VII flash crowd, N volunteers
    spread round-robin across `n_islands` ISP islands with seeded
    inter-island latencies — run twice on the *identical* topology:

      * ``naive`` — rarity-only selection: the WAN is there (every
        cross-island message pays the latency, every cross-island byte is
        counted) but peers ignore it, the pre-ISSUE-7 behaviour;
      * ``p4p``   — the tracker serves its ALTO COST_MAP and the batched
        engine folds the cost plane into piece and holder selection
        (same-island holders first, rarity within a cost class).

    Headline metrics: **cross-ISP bytes** (the economics BOINC-scale
    swarms actually pay for) and **p99 node-completion time** (WAN tail
    latency).  Target: >=5x cross-ISP cut with <=5% work-makespan
    regression.  Rows land in BENCH_swarm.json, guarded by bench_guard.
    """
    import time as _time

    from repro.core.runtime import LinkModel
    from repro.core.swarm_arrays import SwarmHub
    from repro.core.topology import Topology

    if n_parts is None:
        n_parts = 2 * n_volunteers
    image_bytes = int(image_mb * 1e6)
    link_Bps = uplink_mbps * 1e6 / 8
    app_id = "appix"
    vol_ids = [f"V{i:03d}" for i in range(n_volunteers)]

    def _one(p4p: bool) -> dict:
        topo = Topology.make(["host"] + vol_ids, n_islands, seed=seed,
                             trunk_Bps=trunk_Bps)
        rt = SimRuntime(link=LinkModel(uplink_Bps=link_Bps,
                                       downlink_Bps=link_Bps),
                        topology=topo)
        rt.add_node(TrackerServer(
            config=TrackerConfig(ping_interval_s=5.0),
            topology=topo if p4p else None))
        hub = SwarmHub(backend=backend)
        rt.crash_hooks.append(hub.node_gone)
        if p4p:
            hub.set_topology(topo)
        cfg = dict(work_timeout_s=600.0, status_interval_s=5.0,
                   rechoke_interval_s=5.0, max_replica_seeders=8)
        host = Agent("host", config=AgentConfig(**cfg), hub=hub)
        rt.add_node(host)
        app = make_prime_app(app_id, "host", 3, 48_000, n_parts=n_parts,
                             sim_time_per_number=2e-3, m_min=m_min,
                             swarm=True, app_bytes=image_bytes,
                             piece_bytes=image_bytes // n_pieces)
        host.host_app(app)
        agents = []
        for i, nid in enumerate(vol_ids):
            a = Agent(nid, config=AgentConfig(**cfg), hub=hub)
            rt.add_node(a, speed=1.0 - 0.4 * i / max(n_volunteers, 1))
            agents.append(a)
        t0 = _time.perf_counter()
        rt.run_batched(until=until_h * H,
                       stop_when=lambda: app_id in host.completed_at,
                       tick_s=tick_s, on_tick=hub.tick)
        work_done_s = rt.now()
        not_done = list(agents)

        def all_replicated():
            not_done[:] = [a for a in not_done if app_id not in a.images]
            return not not_done

        rt.run_batched(until=until_h * H, stop_when=all_replicated,
                       tick_s=tick_s, on_tick=hub.tick)
        wall_s = max(_time.perf_counter() - t0, 1e-9)
        # per-node completion distribution: the sim time each volunteer
        # verified the full image; stragglers count as run end
        times = sorted(a.image_completed_at.get(app_id, rt.now())
                       for a in agents)
        p99 = times[min(int(0.99 * (len(times) - 1)), len(times) - 1)]
        replicas = sum(1 for a in agents if app_id in a.images)
        logical = rt.events_processed + hub.coalesced
        return {
            "mode": "p4p" if p4p else "naive",
            "done": app_id in host.completed_at,
            "replicated": replicas == n_volunteers,
            "replicas": replicas,
            "makespan_s": work_done_s,
            "full_replication_s": rt.now(),
            "p99_completion_s": p99,
            "cross_isp_bytes": rt.cross_isp_bytes,
            "origin_up_mb": rt.tx_bytes.get("host", 0) / 1e6,
            "events": rt.events_processed,
            "logical_events": logical,
            "events_per_sec": logical / wall_s,
            "wall_s": wall_s,
            "backend": hub.backend,
        }

    naive = _one(p4p=False)
    p4p = _one(p4p=True)
    res = {
        "n_volunteers": n_volunteers,
        "n_islands": n_islands,
        "image_mb": image_mb,
        "seed": seed,
        "naive": naive,
        "p4p": p4p,
        "cross_isp_reduction": naive["cross_isp_bytes"]
        / max(p4p["cross_isp_bytes"], 1),
        "makespan_ratio": p4p["makespan_s"]
        / max(naive["makespan_s"], 1e-9),
        "p99_ratio": p4p["p99_completion_s"]
        / max(naive["p99_completion_s"], 1e-9),
        "done": naive["done"] and p4p["done"],
        "replicated": naive["replicated"] and p4p["replicated"],
    }
    if verbose:
        print(f"[scenarioIX] N={n_volunteers} islands={n_islands} "
              f"img={image_mb:.0f}MB: cross-ISP "
              f"{naive['cross_isp_bytes'] / 1e6:.0f} -> "
              f"{p4p['cross_isp_bytes'] / 1e6:.0f}MB "
              f"({res['cross_isp_reduction']:.1f}x cut) "
              f"p99 {naive['p99_completion_s']:.0f} -> "
              f"{p4p['p99_completion_s']:.0f}s "
              f"makespan {naive['makespan_s']:.0f} -> "
              f"{p4p['makespan_s']:.0f}s "
              f"(x{res['makespan_ratio']:.3f}) "
              f"replicated={res['replicated']}")
    return res


def scenario_x(verbose: bool = True, n_volunteers: int = 200,
               image_mb: float = 64.0, n_pieces: int = 128,
               delta_frac: float = 0.05, uplink_mbps: float = 100.0,
               until_h: float = 8.0, tick_s: float = 0.5, seed: int = 10,
               batched: bool = True, backend: Optional[str] = None,
               include_chaos: bool = True, chaos_volunteers: int = 48,
               chaos_churn: float = 0.30, chaos_loss: float = 0.05,
               chaos_image_mb: float = 4.0, chaos_pieces: int = 32) -> dict:
    """Scenario X: versioned-manifest delta distribution (image upgrades).

    A swarm of N volunteers holds revision v1 of a 64 MB image; the host
    publishes v2 with `delta_frac` of the pieces changed (a versioned
    `PieceManifest` chained by `prev_manifest_hash`).  Volunteers carry
    over their unchanged verified pieces (`PieceInventory.seed_from`) and
    fetch only the delta, against a *scratch* baseline that redistributes
    the full image to the same swarm under a fresh app id.  Headline
    metrics: **upgrade_traffic_bytes** (total bytes on the wire, every
    sender counted) and **upgrade_makespan_s** — target >=10x less than
    scratch on both.

    Chaos overlay: a smaller swarm with REAL image bytes (the reuse rule
    re-hashes every carried-over piece) upgrades while `chaos_churn` of
    the volunteers crash around the publish — half resume with stale v1
    memory (the mixed-version announce case), half restart as fresh
    incarnations off the on-disk piece cache.  Asserted, not measured: no
    engine ever accepts a version-mismatched piece (`stale_accepts == 0`)
    and every survivor converges byte-identical to v2.
    """
    import random as _random
    import time as _time

    from repro.core.runtime import LinkModel
    from repro.core.workunit import Application, PieceManifest

    image_bytes = int(image_mb * 1e6)
    piece_bytes = image_bytes // n_pieces
    n_changed = max(1, int(round(delta_frac * n_pieces)))
    app_id = "appx"
    vol_ids = [f"V{i:03d}" for i in range(n_volunteers)]
    link_Bps = uplink_mbps * 1e6 / 8

    hub = None
    if batched:
        from repro.core.swarm_arrays import SwarmHub
        hub = SwarmHub(backend=backend)
    rt = SimRuntime(link=LinkModel(uplink_Bps=link_Bps,
                                   downlink_Bps=link_Bps))
    if hub is not None:
        rt.crash_hooks.append(hub.node_gone)
    rt.add_node(TrackerServer(config=TrackerConfig(ping_interval_s=5.0)))
    # upload_slots=8 / rechoke=15s: enough parallel unchoke capacity that
    # the 6-piece delta fetch isn't serialized behind the grant scheduler,
    # and rechoke churn doesn't reshuffle holders mid-delta.  Shared by
    # BOTH the upgrade arm and the scratch baseline so the comparison
    # stays apples-to-apples.
    cfg = dict(work_timeout_s=600.0, status_interval_s=5.0,
               rechoke_interval_s=15.0, replicate_completed=True,
               max_replica_seeders=8, upload_slots=8)
    origin = Agent("origin", config=AgentConfig(**cfg), hub=hub)
    rt.add_node(origin)
    app = Application(app_id, "origin", app_bytes=image_bytes, parts=[],
                      swarm=True, piece_bytes=piece_bytes)
    origin.host_app(app)
    agents = []
    for nid in vol_ids:
        a = Agent(nid, config=AgentConfig(**cfg), hub=hub)
        rt.add_node(a)
        agents.append(a)

    def _run(stop) -> None:
        if hub is not None:
            rt.run_batched(until=until_h * H, stop_when=stop,
                           tick_s=tick_s, on_tick=hub.tick)
        else:
            rt.run(until=until_h * H, stop_when=stop)

    def _tx() -> float:
        return float(sum(rt.tx_bytes.values()))

    t0 = _time.perf_counter()
    # phase 1 — v1 flash crowd: the pre-existing swarm state every
    # upgrade starts from
    m1 = app.ensure_manifest()
    not_done = list(agents)

    def v1_done():
        not_done[:] = [a for a in not_done if app_id not in a.images]
        return not not_done

    _run(v1_done)
    v1_makespan = rt.now()
    v1_traffic = _tx()

    # phase 2 — the host publishes v2: delta_frac of the pieces changed,
    # manifest chained to v1; volunteers reuse the rest
    rng = _random.Random(seed)
    changed = set(rng.sample(range(n_pieces), n_changed))
    m2 = PieceManifest.synthetic(app_id, image_bytes, piece_bytes,
                                 version=2, prev=m1, changed=changed)
    t_pub, b_pub = rt.now(), _tx()
    assert origin.publish_update(app_id, m2), "v2 must supersede v1"
    not_up = list(agents)

    def upgraded():
        not_up[:] = [a for a in not_up
                     if a.images.get(app_id) != m2.manifest_hash]
        return not not_up

    _run(upgraded)
    upgrade_makespan = rt.now() - t_pub
    upgrade_traffic = _tx() - b_pub
    engines = [a.px for a in agents] + [origin.px]
    reused = sum(px.reused_pieces for px in engines)
    stale_accepts = sum(px.stale_accepts for px in engines)
    on_v2 = sum(1 for a in agents
                if a.images.get(app_id) == m2.manifest_hash)

    # phase 3 — scratch baseline: the same swarm pulls the same 64 MB as
    # a brand-new app (what redistribution without versioned manifests
    # costs)
    scratch_id = "appx-scratch"
    scratch = Application(scratch_id, "origin", app_bytes=image_bytes,
                          parts=[], swarm=True, piece_bytes=piece_bytes)
    t_s, b_s = rt.now(), _tx()
    origin.host_app(scratch)
    not_s = list(agents)

    def scratch_done():
        not_s[:] = [a for a in not_s if scratch_id not in a.images]
        return not not_s

    _run(scratch_done)
    scratch_makespan = rt.now() - t_s
    scratch_traffic = _tx() - b_s
    wall_s = max(_time.perf_counter() - t0, 1e-9)

    res = {
        "n_volunteers": n_volunteers,
        "image_mb": image_mb,
        "n_pieces": n_pieces,
        "n_changed": n_changed,
        "delta_frac": delta_frac,
        "seed": seed,
        "batched": batched,
        "v1_makespan_s": v1_makespan,
        "v1_traffic_bytes": v1_traffic,
        "upgrade_makespan_s": upgrade_makespan,
        "upgrade_traffic_bytes": upgrade_traffic,
        "scratch_makespan_s": scratch_makespan,
        "scratch_traffic_bytes": scratch_traffic,
        "traffic_reduction": scratch_traffic / max(upgrade_traffic, 1.0),
        "makespan_speedup": scratch_makespan / max(upgrade_makespan, 1e-9),
        "reused_pieces": reused,
        "upgraded": on_v2 == n_volunteers,
        "replicated": (on_v2 == n_volunteers
                       and len(not_done) == 0 and len(not_s) == 0),
        "no_stale": stale_accepts == 0,
        "stale_accepts": stale_accepts,
        "wall_s": wall_s,
    }
    if hub is not None:
        res["backend"] = hub.backend
    if include_chaos:
        res["chaos"] = _scenario_x_chaos(
            n_volunteers=chaos_volunteers, image_mb=chaos_image_mb,
            n_pieces=chaos_pieces, delta_frac=delta_frac,
            churn=chaos_churn, loss=chaos_loss, seed=seed,
            uplink_mbps=uplink_mbps, until_h=until_h)
        res["chaos_ready"] = res["chaos"]["converged"]
        res["no_stale"] = res["no_stale"] and res["chaos"]["no_stale"]
    if verbose:
        print(f"[scenarioX] N={n_volunteers} img={image_mb:.0f}MB "
              f"delta={n_changed}/{n_pieces} pieces: upgrade "
              f"{upgrade_traffic / 1e6:.0f}MB/{upgrade_makespan:.0f}s vs "
              f"scratch {scratch_traffic / 1e6:.0f}MB/"
              f"{scratch_makespan:.0f}s "
              f"(/{res['traffic_reduction']:.1f} traffic, "
              f"x{res['makespan_speedup']:.1f} makespan) "
              f"reused={reused} stale_accepts={stale_accepts}")
        if include_chaos:
            c = res["chaos"]
            print(f"[scenarioX] chaos churn={chaos_churn:.0%}: "
                  f"converged={c['converged']} reused={c['reused_pieces']} "
                  f"demoted={c['stale_have_demoted']} "
                  f"stale_data={c['stale_piece_data']} "
                  f"refused={c['stale_reqs_refused']} "
                  f"stale_accepts={c['stale_accepts']}")
    return res


def _scenario_x_chaos(n_volunteers: int = 48, image_mb: float = 4.0,
                      n_pieces: int = 32, delta_frac: float = 0.05,
                      churn: float = 0.30, loss: float = 0.05,
                      seed: int = 10, uplink_mbps: float = 100.0,
                      until_h: float = 8.0) -> dict:
    """Scenario X chaos overlay: upgrade during churn, REAL image bytes.

    Run scalar (per-message) so every version gate fires on the wire
    path.  Crash `churn` of the volunteers around the publish: half
    resume with their v1 state intact (they re-announce stale v1 masks
    the upgraded swarm must demote), half restart as fresh incarnations
    whose only v1 remnant is the on-disk piece cache (reused only after
    the content re-hash).  Asserts convergence to byte-identical v2 and
    the mixed-version tripwire `stale_accepts == 0`.
    """
    import random as _random
    import shutil
    import tempfile

    from repro.core.faults import FaultPlan, LinkFault
    from repro.core.runtime import LinkModel
    from repro.core.workunit import Application, PieceManifest

    image_bytes = int(image_mb * 1e6)
    piece_bytes = image_bytes // n_pieces
    n_changed = max(1, int(round(delta_frac * n_pieces)))
    app_id = "appx-chaos"
    vol_ids = [f"C{i:02d}" for i in range(n_volunteers)]
    link_Bps = uplink_mbps * 1e6 / 8
    rng = _random.Random(seed + 1)
    root = tempfile.mkdtemp(prefix="scenario_x_chaos_")
    try:
        rt = SimRuntime(
            link=LinkModel(uplink_Bps=link_Bps, downlink_Bps=link_Bps),
            faults=FaultPlan(seed=seed + 1,
                             link=LinkFault(drop_p=loss, dup_p=0.02,
                                            jitter_s=0.2)))
        rt.add_node(TrackerServer(config=TrackerConfig(ping_interval_s=2.0)))
        cfg = dict(work_timeout_s=10.0, status_interval_s=1.0,
                   rechoke_interval_s=5.0, piece_timeout_s=5.0,
                   reregister_s=15.0, gossip_interval_s=5.0,
                   replicate_completed=True, root_dir=root)
        engines = []

        def mk(nid: str) -> Agent:
            a = Agent(nid, config=AgentConfig(**cfg))
            engines.append(a.px)
            return a

        origin = mk("origin")
        rt.add_node(origin)
        image1 = bytes((i * 89 + 17) % 256 for i in range(image_bytes))
        app = Application(app_id, "origin", app_bytes=image_bytes,
                          parts=[], swarm=True, piece_bytes=piece_bytes,
                          image=image1)
        origin.host_app(app)
        agents = {}
        for nid in vol_ids:
            agents[nid] = mk(nid)
            rt.add_node(agents[nid])
        m1 = app.ensure_manifest()

        not_done = list(vol_ids)

        def v1_done():
            not_done[:] = [n for n in not_done
                           if app_id not in rt.nodes[n].images]
            return not not_done

        rt.run(until=until_h * H, stop_when=v1_done)
        assert not not_done, "chaos overlay: v1 never fully replicated"

        # v2 image: flip one byte in each changed piece
        changed = set(rng.sample(range(n_pieces), n_changed))
        image2 = bytearray(image1)
        for pid in changed:
            image2[pid * piece_bytes] ^= 0xFF
        image2 = bytes(image2)
        m2 = PieceManifest.from_bytes(app_id, image2, piece_bytes,
                                      version=2, prev=m1)
        assert m2.delta(m1) == changed, "delta must match the edit set"

        # churn around the publish: crash before it (so the victims miss
        # the MANIFEST_UPDATE), restart shortly after.  Suspend/resume
        # victims come back holding complete v1 state in memory — the
        # stale-mask announce case; fresh-incarnation victims come back
        # empty except the on-disk v1 piece cache.
        t_pub = rt.now() + 5.0
        victims = rng.sample(vol_ids, int(round(churn * n_volunteers)))
        for k, nid in enumerate(victims):
            if k % 2 == 0:
                rt.restart_factory[nid] = lambda n=nid: mk(n)
            else:
                rt.restart_factory.pop(nid, None)   # suspend/resume
            rt._at(rng.uniform(rt.now(), t_pub), rt.crash, (nid,))
            rt._at(t_pub + rng.uniform(1.0, 10.0), rt.restart, (nid,))
        rt.run(until=t_pub, stop_when=lambda: False)
        assert origin.publish_update(app_id, m2, image=image2)

        def converged():
            for nid in vol_ids:
                node = rt.nodes.get(nid)
                if node is None or \
                        node.images.get(app_id) != m2.manifest_hash:
                    return False
            return True

        rt.run(until=until_h * H, stop_when=converged)
        ok = converged()
        byte_identical = ok and all(
            rt.nodes[nid].px.assembled_image(app_id) == image2
            for nid in vol_ids)
        stale_accepts = sum(px.stale_accepts for px in engines)
        assert stale_accepts == 0, \
            "mixed-version tripwire fired: a stale piece was accepted"
        assert byte_identical, \
            "chaos overlay: a survivor did not converge to v2 bytes"
        return {
            "n_volunteers": n_volunteers,
            "image_mb": image_mb,
            "churn": churn,
            "loss": loss,
            "converged": ok,
            "byte_identical": byte_identical,
            "no_stale": stale_accepts == 0,
            "stale_accepts": stale_accepts,
            "reused_pieces": sum(px.reused_pieces for px in engines),
            "stale_have_demoted": sum(px.stale_have_demoted
                                      for px in engines),
            "stale_piece_data": sum(px.stale_piece_data
                                    for px in engines),
            "stale_reqs_refused": sum(px.stale_reqs_refused
                                      for px in engines),
            "upgrades": sum(px.upgrades for px in engines),
            "crashes": rt.crash_count,
            "restarts": rt.restart_count,
            "makespan_s": rt.now(),
        }
    finally:
        shutil.rmtree(root, ignore_errors=True)


def scenario_xi(verbose: bool = True, n_replicas: int = 50,
                ckpt_mb: float = 2048.0, n_pieces: int = 128,
                n_islands: int = 8, uplink_mbps: float = 200.0,
                until_h: float = 48.0, seed: int = 11,
                include_chaos: bool = True,
                include_islands: bool = True) -> dict:
    """Scenario XI: swarm-served checkpoints — replica cold-start flash
    crowd pulling a multi-GB sharded checkpoint.

    The production story behind the ROADMAP's "close the loop with the
    jax side": an autoscaling event brings up R fresh serving replicas at
    t=0 and all of them need the same committed checkpoint.  The
    checkpoint is a pure-replication swarm Application (no work parts —
    `checkpoint/swarm_restore.checkpoint_application` builds the same
    shape from a real `CheckpointStore` step; here the multi-GB image is
    simulated bytes on the same protocol).  Two modes per topology:

      * ``origin`` — the blob-store baseline: every replica pulls every
        piece straight from the origin (`AgentConfig.fetch_from`), which
        serialises R full images through one uplink;
      * ``swarm``  — replicas exchange pieces leecher-to-seeder, so the
        origin uploads each piece roughly once.

    Run on a flat LAN and on an `n_islands` WAN (tracker serves the ALTO
    COST_MAP, scalar P4P selection).  Headline metrics per run:
    **ttr_p99_s** (p99 time-to-ready across replicas — a replica is
    ready the moment its verified piece set completes and it can load
    params) and **origin_egress_bytes**.  Targets: >=10x origin egress
    cut, >=3x p99 time-to-ready.  Chaos overlay: the origin dies as soon
    as the first replica is ready and every replica must still become
    ready from replica seeders alone.
    """
    from repro.core.runtime import LinkModel
    from repro.core.topology import Topology
    from repro.core.workunit import Application

    ckpt_bytes = int(ckpt_mb * 1e6)
    link_Bps = uplink_mbps * 1e6 / 8
    app_id = "ckpt"
    rep_ids = [f"R{i:03d}" for i in range(n_replicas)]

    def _one(origin_only: bool, islands: int, chaos: bool = False) -> dict:
        topo = Topology.make(["origin"] + rep_ids, islands, seed=seed) \
            if islands else None
        rt = SimRuntime(link=LinkModel(uplink_Bps=link_Bps,
                                       downlink_Bps=link_Bps),
                        topology=topo)
        rt.add_node(TrackerServer(config=TrackerConfig(ping_interval_s=5.0),
                                  topology=topo))
        cfg = dict(work_timeout_s=600.0, status_interval_s=5.0,
                   rechoke_interval_s=5.0, replicate_completed=True,
                   max_replica_seeders=8)
        origin = Agent("origin", config=AgentConfig(**cfg))
        rt.add_node(origin)
        # the checkpoint as a pure-replication Application: real deploys
        # host checkpoint_application(store); the benchmark's multi-GB
        # image stays synthetic so only metadata ever materialises
        app = Application(app_id, "origin", app_bytes=ckpt_bytes,
                          parts=[], swarm=True,
                          piece_bytes=ckpt_bytes // n_pieces)
        origin.host_app(app)
        rcfg = dict(cfg, fetch_from=("origin",)) if origin_only else cfg
        replicas = []
        for nid in rep_ids:
            a = Agent(nid, config=AgentConfig(**rcfg))
            rt.add_node(a)
            replicas.append(a)

        died_at = None
        if chaos:
            # flash crowd starts; the origin dies the moment the first
            # replica turns seeder (scenario V's failover pattern)
            rt.run(until=until_h * H,
                   stop_when=lambda: any(app_id in a.images
                                         for a in replicas))
            died_at = rt.now()
            rt.nodes.pop("origin", None)
        not_ready = list(replicas)

        def all_ready():
            not_ready[:] = [a for a in not_ready
                            if app_id not in a.images]
            return not not_ready

        rt.run(until=until_h * H, stop_when=all_ready)
        times = sorted(a.image_completed_at.get(app_id, rt.now())
                       for a in replicas)
        p99 = times[min(int(0.99 * (len(times) - 1)), len(times) - 1)]
        n_ready = sum(1 for a in replicas if app_id in a.images)
        out = {
            "mode": "chaos" if chaos
            else ("origin" if origin_only else "swarm"),
            "islands": islands,
            "ready": n_ready == n_replicas,
            "replicas_ready": n_ready,
            "ttr_p99_s": p99,
            "ttr_max_s": times[-1] if times else 0.0,
            "ttr_median_s": times[len(times) // 2] if times else 0.0,
            "origin_egress_bytes": float(rt.tx_bytes.get("origin", 0)),
            "cross_isp_bytes": rt.cross_isp_bytes,
            "events": rt.events_processed,
        }
        if died_at is not None:
            out["origin_died_at_s"] = died_at
        return out

    flat_origin = _one(origin_only=True, islands=0)
    flat_swarm = _one(origin_only=False, islands=0)
    res = {
        "n_replicas": n_replicas,
        "ckpt_mb": ckpt_mb,
        "n_pieces": n_pieces,
        "n_islands": n_islands,
        "seed": seed,
        "flat": {"origin": flat_origin, "swarm": flat_swarm},
        "egress_reduction_flat": flat_origin["origin_egress_bytes"]
        / max(flat_swarm["origin_egress_bytes"], 1.0),
        "ttr_p99_speedup_flat": flat_origin["ttr_p99_s"]
        / max(flat_swarm["ttr_p99_s"], 1e-9),
    }
    all_ready = flat_origin["ready"] and flat_swarm["ready"]
    if include_islands:
        isl_origin = _one(origin_only=True, islands=n_islands)
        isl_swarm = _one(origin_only=False, islands=n_islands)
        res["islands"] = {"origin": isl_origin, "swarm": isl_swarm}
        res["egress_reduction_islands"] = \
            isl_origin["origin_egress_bytes"] \
            / max(isl_swarm["origin_egress_bytes"], 1.0)
        res["ttr_p99_speedup_islands"] = isl_origin["ttr_p99_s"] \
            / max(isl_swarm["ttr_p99_s"], 1e-9)
        all_ready = all_ready and isl_origin["ready"] and isl_swarm["ready"]
    if include_chaos:
        chaos = _one(origin_only=False, islands=0, chaos=True)
        res["chaos"] = chaos
        all_ready = all_ready and chaos["ready"]
    res["all_ready"] = all_ready
    if verbose:
        o, s = flat_origin, flat_swarm
        print(f"[scenarioXI] R={n_replicas} ckpt={ckpt_mb:.0f}MB flat: "
              f"ttr_p99 {o['ttr_p99_s']:.0f} -> {s['ttr_p99_s']:.0f}s "
              f"(x{res['ttr_p99_speedup_flat']:.1f}) origin_egress "
              f"{o['origin_egress_bytes'] / 1e9:.1f} -> "
              f"{s['origin_egress_bytes'] / 1e9:.1f}GB "
              f"(/{res['egress_reduction_flat']:.1f})")
        if include_islands:
            o, s = res["islands"]["origin"], res["islands"]["swarm"]
            print(f"[scenarioXI] {n_islands} islands: ttr_p99 "
                  f"{o['ttr_p99_s']:.0f} -> {s['ttr_p99_s']:.0f}s "
                  f"(x{res['ttr_p99_speedup_islands']:.1f}) origin_egress "
                  f"{o['origin_egress_bytes'] / 1e9:.1f} -> "
                  f"{s['origin_egress_bytes'] / 1e9:.1f}GB "
                  f"(/{res['egress_reduction_islands']:.1f})")
        if include_chaos:
            c = res["chaos"]
            print(f"[scenarioXI] chaos: origin died at "
                  f"{c['origin_died_at_s']:.0f}s, "
                  f"{c['replicas_ready']}/{n_replicas} replicas ready "
                  f"(all_ready={c['ready']}) ttr_p99={c['ttr_p99_s']:.0f}s")
    return res


ALL_TABLES = {"table1": table1, "table2": table2, "table3": table3,
              "table4": table4, "scenario_v": scenario_v,
              "scenario_vi": scenario_vi, "scenario_vii": scenario_vii,
              "scenario_viii": scenario_viii, "scenario_ix": scenario_ix,
              "scenario_x": scenario_x, "scenario_xi": scenario_xi}

if __name__ == "__main__":
    for name, fn in ALL_TABLES.items():
        fn()
