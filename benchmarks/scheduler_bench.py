"""Coordinator lease throughput and discrete-event engine speed."""
from __future__ import annotations

import time

from repro.cluster.coordinator import JobCoordinator
from repro.core import (Agent, AgentConfig, SimRuntime, TrackerConfig,
                        TrackerServer, make_prime_app)


def bench(verbose: bool = True):
    rows = []
    # 1. coordinator lease/complete cycle throughput
    clock = {"t": 0.0}
    coord = JobCoordinator(lease_timeout_s=60.0, clock=lambda: clock["t"])
    for m in range(16):
        coord.join(f"m{m}")
    n = 20_000
    for i in range(n):
        coord.submit("data", {"i": i})
    t0 = time.perf_counter()
    done = 0
    while coord.outstanding:
        for m in range(16):
            item = coord.request(f"m{m}")
            if item:
                coord.complete(f"m{m}", item.item_id, elapsed_s=0.1)
                done += 1
        clock["t"] += 1.0
    dt = time.perf_counter() - t0
    rows.append({"name": "coordinator_lease_cycle",
                 "us_per_call": dt / max(done, 1) * 1e6,
                 "derived": f"{done / dt:,.0f} leases/s"})

    # 2. sim-runtime event throughput (protocol-heavy scenario)
    rt = SimRuntime()
    rt.add_node(TrackerServer(config=TrackerConfig(ping_interval_s=2.0)))
    host = Agent("h", config=AgentConfig(work_timeout_s=600))
    rt.add_node(host)
    app = make_prime_app("a", "h", 3, 200_000, n_parts=400,
                         sim_time_per_number=1e-3)
    host.host_app(app)
    for i in range(8):
        rt.add_node(Agent(f"l{i}", config=AgentConfig(work_timeout_s=600)))
    t0 = time.perf_counter()
    rt.run(until=100_000, stop_when=lambda: app.done)
    dt = time.perf_counter() - t0
    rows.append({"name": "sim_runtime_scenario",
                 "us_per_call": dt / 400 * 1e6,
                 "derived": f"400 cycles, 8 leechers in {dt:.2f}s wall"})
    if verbose:
        for r in rows:
            print(f"[sched] {r['name']}: {r['derived']}")
    return rows
