"""Benchmark-regression guard for the swarm perf trajectory.

Compares a freshly generated BENCH_swarm.json against the committed
baseline (BENCH_baseline.json) and fails if the batched engine got
meaningfully slower:

  * logical events/s at any swept N dropped more than --evps-drop
    (default 20%), or
  * a Scenario VII makespan / full-replication time regressed more than
    --makespan-drift (default 10%), or
  * a row's cross-ISP bytes grew more than --cross-isp-drift (default
    10%) or its p99 node-completion time drifted past --makespan-drift
    (the Scenario IX P4P economics; virtual-time, machine-independent), or
  * a checkpoint flash-crowd row's p99 time-to-ready (``ttr_p99_s``) or
    origin egress (``origin_egress_bytes``) regressed past the same
    bands (the Scenario XI swarm-served-checkpoint economics), or
  * a delta-upgrade row's total wire bytes (``upgrade_traffic_bytes``)
    or convergence time (``upgrade_makespan_s``) regressed past the same
    bands (the Scenario X versioned-manifest economics; zero-baseline
    rows are skipped like every other key), or
  * a profiled sweep row's per-tick host-Python cost
    (``host_ms_per_tick``) grew past --evps-drop — the wall-clock band,
    since it is machine-dependent — guarding the array-ledger fused
    tick's host-time-sublinear-in-N property.

Only rows present in BOTH files are compared (a CI smoke sweep that
stops at N=500 is judged against the matching baseline rows only), so
the full committed curve can extend beyond what CI re-runs.  Throughput
is wall-clock dependent; the 20% band absorbs machine noise while still
catching real algorithmic regressions.
"""
from __future__ import annotations

import argparse
import json
import sys


def _rows(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    return {r["name"]: r.get("metrics", {}) for r in doc.get("rows", [])}


def check(baseline_path: str, current_path: str, evps_drop: float = 0.20,
          makespan_drift: float = 0.10, cross_isp_drift: float = 0.10,
          verbose: bool = True) -> list:
    base, cur = _rows(baseline_path), _rows(current_path)
    failures = []
    shared = sorted(set(base) & set(cur))
    for name in shared:
        b, c = base[name], cur[name]
        for key, limit, higher_is_better in (
                ("events_per_sec", evps_drop, True),
                ("makespan_s", makespan_drift, False),
                ("full_replication_s", makespan_drift, False),
                ("p99_completion_s", makespan_drift, False),
                ("cross_isp_bytes", cross_isp_drift, False),
                ("ttr_p99_s", makespan_drift, False),
                ("origin_egress_bytes", cross_isp_drift, False),
                ("upgrade_traffic_bytes", cross_isp_drift, False),
                ("upgrade_makespan_s", makespan_drift, False),
                # ISSUE 10 profile keys: per-tick host-Python cost is the
                # quantity the fused tick pipeline exists to bound — use
                # the wall-clock band since it is machine-dependent
                ("host_ms_per_tick", evps_drop, False)):
            if key not in b or key not in c:
                continue
            bv, cv = float(b[key]), float(c[key])
            if bv <= 0:
                continue
            ratio = cv / bv
            bad = ratio < 1.0 - limit if higher_is_better \
                else ratio > 1.0 + limit
            tag = "FAIL" if bad else "ok"
            band = 1.0 - limit if higher_is_better else 1.0 + limit
            if verbose:
                print(f"[guard] {tag:4s} {name}.{key}: "
                      f"{bv:.6g} -> {cv:.6g} "
                      f"({ratio:.2f}x, band {band:.2f}x)")
            if bad:
                failures.append((name, key, bv, cv))
        # correctness riding along: a run that stopped replicating is a
        # regression no matter how fast it got
        for key in ("done", "replicated", "ready", "all_ready",
                    "chaos_ready", "upgraded", "no_stale"):
            if b.get(key) is True and c.get(key) is not True:
                failures.append((name, key, True, c.get(key)))
    if verbose:
        print(f"[guard] compared {len(shared)} shared rows; "
              f"{len(failures)} failure(s)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument("--current", default="BENCH_swarm.json")
    ap.add_argument("--evps-drop", type=float, default=0.20,
                    help="max fractional events/s drop per row")
    ap.add_argument("--makespan-drift", type=float, default=0.10,
                    help="max fractional makespan/replication increase")
    ap.add_argument("--cross-isp-drift", type=float, default=0.10,
                    help="max fractional cross-ISP bytes increase")
    args = ap.parse_args(argv)
    failures = check(args.baseline, args.current,
                     evps_drop=args.evps_drop,
                     makespan_drift=args.makespan_drift,
                     cross_isp_drift=args.cross_isp_drift)
    if failures:
        for name, key, bv, cv in failures:
            print(f"[guard] REGRESSION {name}.{key}: {bv} -> {cv}",
                  file=sys.stderr)
        return 1
    print("[guard] no regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
