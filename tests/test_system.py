"""End-to-end behaviour tests for the paper's tracker/agent system."""
import pytest

pytestmark = pytest.mark.protocol

from repro.core import (Agent, AgentConfig, SimRuntime, TrackerConfig,
                        TrackerServer, make_prime_app)
from repro.core.messages import Msg, RESULT


def build_cloud(n_leechers=2, parts=24, m_min=1, val_hook=None,
                timeout=200.0, overhead=0.0):
    rt = SimRuntime()
    server = TrackerServer(config=TrackerConfig(ping_interval_s=2.0))
    rt.add_node(server)
    host = Agent("host", config=AgentConfig(work_timeout_s=timeout,
                                            cycle_overhead_s=overhead),
                 val_hook=val_hook)
    rt.add_node(host, speed=1.0)
    app = make_prime_app("app", "host", 3, 24_000, n_parts=parts,
                         m_min=m_min, sim_time_per_number=1e-4)
    host.host_app(app)
    leechers = []
    for i in range(n_leechers):
        a = Agent(f"L{i}", config=AgentConfig(work_timeout_s=timeout,
                                              cycle_overhead_s=overhead))
        rt.add_node(a, speed=1.0)
        leechers.append(a)
    return rt, server, host, app, leechers


def test_application_completes_and_validates():
    rt, server, host, app, leechers = build_cloud()
    rt.run(until=3600, stop_when=lambda: app.done)
    assert app.done
    # every part validated exactly once, results are actual primes
    assert all(len(p.results) >= 1 for p in app.parts)
    total = sum(l.completed_cycles["app"] for l in leechers)
    assert total >= len(app.parts)
    # the winning results really are primes
    r0 = app.parts[0].results[0][1]
    assert 3 in r0 and 4 not in r0 and 5 in r0


def test_work_splits_roughly_evenly():
    rt, server, host, app, leechers = build_cloud(n_leechers=2, parts=40)
    rt.run(until=3600, stop_when=lambda: app.done)
    c = [l.completed_cycles["app"] for l in leechers]
    assert abs(c[0] - c[1]) <= 6, c


def test_metrics_published_to_server():
    rt, server, host, app, _ = build_cloud()
    rt.run(until=3600, stop_when=lambda: app.done)
    rt.run(until=rt.now() + 10)
    row = server.app_list.get("app")
    assert row is not None
    m = host.metrics["app"]
    assert row.p == m.p == len(app.parts)
    assert row.d == m.d > 0
    assert row.w == pytest.approx(m.w)


def test_host_death_drops_application():
    rt, server, host, app, leechers = build_cloud(parts=400)
    rt.run(until=20)              # some progress
    # kill the host: stop answering pings
    del rt.nodes["host"]
    rt.run(until=rt.now() + 60)
    assert "app" not in server.app_list
    # leechers eventually STOP the app (dropped from their lists)
    assert all("app" in l.stopped_apps for l in leechers)


def test_tail_timeout_redistributes_leases():
    rt, server, host, app, leechers = build_cloud(parts=30, timeout=30.0)
    rt.run(until=10)
    # one leecher dies mid-work
    dead = leechers[0]
    del rt.nodes[dead.node_id]
    rt.run(until=3600 * 5, stop_when=lambda: app.done)
    assert app.done  # survivor finished everything despite lost leases


def test_majority_voting_rejects_malicious():
    # m_min=2: every part must be computed twice and agree
    rt, server, host, app, leechers = build_cloud(n_leechers=3, parts=12,
                                                  m_min=2)
    rt.run(until=3600 * 5, stop_when=lambda: app.done)
    assert app.done
    assert all(len(p.results) >= 2 for p in app.parts)
    # m_min scaling of eq (4): p counts every replicated execution
    assert host.metrics["app"].m_min >= 2


def test_val_hook_discards_bad_results():
    calls = {}

    def val_hook(part_id, result):
        # reject the first submission of part 0 (simulated corruption)
        if part_id == 0 and "seen" not in calls:
            calls["seen"] = True
            return False
        return True

    rt, server, host, app, leechers = build_cloud(val_hook=val_hook, parts=8)
    rt.run(until=3600 * 2, stop_when=lambda: app.done)
    assert app.done
    assert calls.get("seen")
    # part 0 required a re-execution
    assert len(app.parts[0].results) >= 1


def test_all_23_procedures_exist():
    server_procs = ["PING", "PUSH", "RECV", "VAL", "INIT", "INFO", "WRITE",
                    "READ"]
    agent_procs = ["RECV", "SEND", "EVAL", "DIST", "STAT", "VAL", "TAIL",
                   "REQ", "SCAN", "RUN", "TIME", "COLLECT", "SAVE", "LOAD",
                   "STOP"]
    assert len(server_procs) + len(agent_procs) == 23
    for p in server_procs:
        assert callable(getattr(TrackerServer, p)), p
    for p in agent_procs:
        assert callable(getattr(Agent, p)), p


def test_agent_directory_layout(tmp_path):
    rt = SimRuntime()
    rt.add_node(TrackerServer())
    host = Agent("h", config=AgentConfig(root_dir=str(tmp_path)))
    rt.add_node(host)
    app = make_prime_app("a1", "h", 3, 4000, n_parts=4,
                         sim_time_per_number=1e-4)
    host.host_app(app)
    leech = Agent("l", config=AgentConfig(root_dir=str(tmp_path)))
    rt.add_node(leech)
    rt.run(until=3600, stop_when=lambda: app.done)
    assert app.done
    assert (tmp_path / "h" / "Seed" / "App" / "a1" / "app.bin").exists()
    assert (tmp_path / "h" / "Seed" / "App" / "a1" / "Data" / "Tracker"
            ).exists()
    assert (tmp_path / "h" / "Seed" / "App" / "a1" / "Result" / "0.res"
            ).exists()
    assert (tmp_path / "l" / "Leech" / "App" / "a1" / "Data" / "Time"
            ).exists()


def test_thread_runtime_runs_real_primes(tmp_path):
    from repro.core import ThreadRuntime
    rt = ThreadRuntime(n_workers=2)
    rt.add_node(TrackerServer(config=TrackerConfig(ping_interval_s=0.2)))
    host = Agent("h", config=AgentConfig(work_timeout_s=10.0,
                                         status_interval_s=0.2,
                                         retry_s=0.1))
    rt.add_node(host)
    app = make_prime_app("a1", "h", 3, 3000, n_parts=6)
    host.host_app(app)
    for i in range(2):
        rt.add_node(Agent(f"l{i}", config=AgentConfig(
            work_timeout_s=10.0, status_interval_s=0.2, retry_s=0.1)))
    rt.run(until_s=30.0, stop_when=lambda: app.done)
    assert app.done
    primes = sorted(set(sum((r for _, r, _ in
                             (res for p in app.parts for res in [p.results[0]]
                              )), [])))
    assert primes[:5] == [3, 5, 7, 11, 13]
