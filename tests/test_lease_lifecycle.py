"""Lease lifecycle: drop_volunteer, TAIL expiry re-DIST, BYE reclamation."""
import pytest

pytestmark = pytest.mark.protocol

from repro.core import (Agent, AgentConfig, LeaseTable, SimRuntime,
                        TrackerConfig, TrackerServer, make_prime_app)


# --------------------------- LeaseTable unit --------------------------- #
def test_drop_volunteer_frees_leases():
    tail = LeaseTable(timeout_s=60.0)
    tail.grant(0, "a", now=0.0)
    tail.grant(1, "a", now=0.0)
    tail.grant(1, "b", now=0.0)
    freed = tail.drop_volunteer("a")
    assert sorted(freed) == [0, 1]
    active = tail.active()
    assert 0 not in active
    assert [l.volunteer_id for l in active[1]] == ["b"]
    # dropping an unknown volunteer is a no-op
    assert tail.drop_volunteer("zz") == []


def test_lease_expiry_and_release():
    tail = LeaseTable(timeout_s=10.0)
    tail.grant(3, "a", now=0.0)
    assert tail.expired(5.0) == []
    exp = tail.expired(10.0)
    assert [l.part_id for l in exp] == [3]
    assert tail.release(3, "a")
    assert not tail.release(3, "a")      # already released


# ------------------------- protocol behaviours ------------------------- #
def build_cloud(n_leechers=2, parts=24, timeout=200.0, tmp=None,
                max_missed=3, per_number=1e-4):
    rt = SimRuntime()
    server = TrackerServer(config=TrackerConfig(ping_interval_s=2.0,
                                                max_missed=max_missed))
    rt.add_node(server)
    host = Agent("host", config=AgentConfig(work_timeout_s=timeout,
                                            root_dir=tmp))
    rt.add_node(host)
    app = make_prime_app("app", "host", 3, 24_000, n_parts=parts,
                         sim_time_per_number=per_number)
    host.host_app(app)
    leechers = []
    for i in range(n_leechers):
        a = Agent(f"L{i}", config=AgentConfig(work_timeout_s=timeout))
        rt.add_node(a)
        leechers.append(a)
    return rt, server, host, app, leechers


def test_tail_expiry_redistributes_to_other_volunteer(tmp_path):
    # slow parts (~8s each) and death detection disabled (max_missed huge):
    # TAIL expiry is the only mechanism recovering the dead node's lease
    rt, server, host, app, leechers = build_cloud(parts=30, timeout=30.0,
                                                  tmp=str(tmp_path),
                                                  max_missed=10**9,
                                                  per_number=1e-2)
    rt.run(until=5)
    dead = leechers[0]
    # silent death: no BYE — only TAIL expiry can recover its leases
    del rt.nodes[dead.node_id]
    rt.run(until=3600 * 5, stop_when=lambda: app.done)
    assert app.done
    assert all(p.done for p in app.parts)
    # the survivor picked up real work, including parts originally leased
    # to the dead volunteer
    assert leechers[1].completed_cycles["app"] > 0
    survivor = {leechers[1].node_id}
    assert any(v in survivor for p in app.parts for v, _, _ in p.results)
    log = (tmp_path / "host" / "Seed" / "App" / "app" / "Data" /
           "Tracker").read_text()
    # a lease visibly expired via TAIL and the part was re-DISTed
    assert "lease" in log
    assert "timeout" in log


def test_bye_reclaims_leases_immediately():
    # long timeout: if BYE did not reclaim, the app could not finish soon
    rt, server, host, app, leechers = build_cloud(parts=20, timeout=3000.0)
    rt.run(until=3)
    quitter = leechers[0]
    quitter.shutdown()                  # sends BYE
    del rt.nodes[quitter.node_id]
    rt.run(until=rt.now() + 5)
    # server dropped the member and the host freed its leases
    assert quitter.node_id not in server.members
    active = host.tails["app"].active()
    for leases in active.values():
        assert all(l.volunteer_id != quitter.node_id for l in leases)
    rt.run(until=2000, stop_when=lambda: app.done)
    assert app.done
    assert rt.now() < 2000.0            # far sooner than the 3000s timeout


def test_missed_pings_broadcast_peer_gone():
    rt, server, host, app, leechers = build_cloud(parts=40, timeout=3000.0)
    rt.run(until=3)
    dead = leechers[0]
    del rt.nodes[dead.node_id]          # silent death, no BYE
    # after (max_missed + 1) pings the tracker declares it gone and the
    # host reclaims the leases well before the 3000s TAIL timeout
    rt.run(until=rt.now() + 15)
    assert dead.node_id not in server.members
    active = host.tails["app"].active()
    for leases in active.values():
        assert all(l.volunteer_id != dead.node_id for l in leases)
