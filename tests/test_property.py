"""Property-based tests (hypothesis) on system invariants."""
import collections

import numpy as np
import pytest

pytestmark = pytest.mark.protocol

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.core.metrics import AppMetrics
from repro.core.swarm import naive_rounds, plan_broadcast, rounds_of
from repro.core.validation import VotingPool, majority_vote
from repro.core.workunit import Application, LeaseTable, Part, find_primes


# ---------------------------------------------------------------------- #
@given(st.lists(st.integers(0, 3), min_size=1, max_size=15),
       st.integers(1, 5))
def test_majority_vote_winner_has_majority(results, quorum):
    winner, ok = majority_vote(results, quorum=quorum)
    if ok:
        counts = collections.Counter(results)
        assert counts[winner] * 2 > len(results) or len(results) == 1
        assert len(results) >= quorum
    else:
        assert winner is None


@given(st.integers(2, 24), st.integers(2, 24), st.integers(1, 3),
       st.integers(0, 23))
@settings(max_examples=60, deadline=None)
def test_swarm_plan_complete_and_beats_naive(n_nodes, n_pieces, fanout,
                                             seeder):
    seeder = seeder % n_nodes
    plan = plan_broadcast(n_nodes, n_pieces, fanout=fanout, seeder=seeder)
    have = [set() for _ in range(n_nodes)]
    have[seeder] = set(range(n_pieces))
    last_round = 0
    per_round_up = collections.Counter()
    for t in sorted(plan, key=lambda t: t.round):
        assert t.piece in have[t.src], "sender must hold the piece"
        have[t.dst].add(t.piece)
        per_round_up[(t.round, t.src)] += 1
        last_round = max(last_round, t.round)
    assert all(h == set(range(n_pieces)) for h in have), "must complete"
    assert all(v <= fanout for v in per_round_up.values()), "fanout cap"
    if n_nodes > 2:
        assert last_round <= naive_rounds(n_nodes, n_pieces, fanout)


@given(st.lists(st.tuples(st.floats(0.1, 100.0), st.integers(100, 10_000)),
                min_size=1, max_size=50),
       st.integers(1, 4))
def test_metrics_equations(cycles, m_min):
    m = AppMetrics(d_app_bytes=4096, m_min=m_min)
    for t, b in cycles:
        m.record_cycle(b, t)
    n = len(cycles)
    # eq (1) + (4): d = m_min * (sum d_app + sum d_data)
    assert m.d == pytest.approx(m_min * (4096 * n + sum(b for _, b in cycles)))
    # eq (2) + (4)
    assert m.p == m_min * n
    # eq (3): w = m_min * sum(t) / p  == mean(t)  (m_min cancels)
    assert m.w == pytest.approx(sum(t for t, _ in cycles) / n)


@given(st.integers(1, 50), st.integers(1, 5), st.floats(1.0, 100.0))
def test_lease_table_exclusive_and_expiring(n_parts, m, timeout):
    lt = LeaseTable(timeout)
    for pid in range(n_parts):
        for v in range(m):
            lt.grant(pid, f"v{v}", now=0.0)
    active = lt.active()
    assert sum(len(v) for v in active.values()) == n_parts * m
    # all expire exactly at timeout
    assert len(lt.expired(timeout + 1e-6)) == n_parts * m
    assert len(lt.expired(timeout - 1e-3)) == 0
    # dropping one volunteer releases exactly its leases
    parts = lt.drop_volunteer("v0")
    assert len(parts) == n_parts
    assert sum(len(v) for v in lt.active().values()) == n_parts * (m - 1)


@given(st.integers(2, 2000), st.integers(2, 2000))
@settings(max_examples=30, deadline=None)
def test_find_primes_correct(a, b):
    lo, hi = min(a, b), max(a, b)
    out = find_primes(lo, hi)
    for n in out:
        assert n >= 2 and all(n % i for i in range(2, int(n ** 0.5) + 1))
    # spot-check completeness
    for n in range(lo, min(hi, lo + 50)):
        is_p = n >= 2 and all(n % i for i in range(2, int(n ** 0.5) + 1))
        assert (n in out) == is_p


@given(st.integers(1, 3), st.integers(1, 3))
def test_voting_pool_quorum(extra, m_min):
    m_max = m_min + extra
    pool = VotingPool(m_min=m_min, m_max=m_max)
    verdict = None
    for i in range(m_min):
        verdict = pool.offer("k", f"voter{i}", 42)
    assert verdict is not None
    winner, unanimous = verdict
    assert winner == 42 and unanimous


def test_voting_pool_flags_minority():
    pool = VotingPool(m_min=3, m_max=3)
    assert pool.offer("k", "a", 1) is None
    assert pool.offer("k", "b", 1) is None
    winner, unanimous = pool.offer("k", "c", 2)
    assert winner == 1 and not unanimous


# ---------------------------------------------------------------------- #
from repro.cluster.coordinator import JobCoordinator


@given(st.integers(1, 30), st.integers(1, 4))
@settings(max_examples=40, deadline=None)
def test_coordinator_exactly_once(n_items, n_members):
    clock = {"t": 0.0}
    coord = JobCoordinator(lease_timeout_s=10.0, clock=lambda: clock["t"])
    for m in range(n_members):
        coord.join(f"m{m}")
    ids = [coord.submit("data", {"i": i}) for i in range(n_items)]
    done = []
    rounds = 0
    while coord.outstanding and rounds < 10 * n_items:
        rounds += 1
        for m in range(n_members):
            item = coord.request(f"m{m}")
            if item is not None:
                ok = coord.complete(f"m{m}", item.item_id, elapsed_s=1.0)
                if ok:
                    done.append(item.item_id)
        clock["t"] += 1.0
    assert sorted(done) == sorted(ids)          # exactly once each
    assert coord.outstanding == 0


def test_coordinator_lease_expiry_redispatch():
    clock = {"t": 0.0}
    coord = JobCoordinator(lease_timeout_s=5.0, clock=lambda: clock["t"])
    coord.join("a")
    coord.join("b")
    iid = coord.submit("data", {})
    item = coord.request("a")
    assert item.item_id == iid
    # "a" dies; lease expires; "b" can pick it up
    clock["t"] = 6.0
    assert coord.expire_leases() == [iid]
    item2 = coord.request("b")
    assert item2.item_id == iid
    assert coord.complete("b", iid)


def test_heartbeat_t_f_semantics():
    from repro.cluster.heartbeat import HeartbeatMonitor, MemberState
    clock = {"t": 0.0}
    dead = []
    hb = HeartbeatMonitor(t_interval_s=1.0, f_max_missed=3,
                          on_dead=dead.append, clock=lambda: clock["t"])
    hb.register("x")
    clock["t"] = 2.5
    hb.sweep()
    assert hb.members["x"].state == MemberState.SUSPECT
    hb.beat("x")
    hb.sweep()
    assert hb.members["x"].state == MemberState.ALIVE
    clock["t"] = 2.5 + 4.5   # > f*t since last beat
    assert hb.sweep() == ["x"]
    assert dead == ["x"]
