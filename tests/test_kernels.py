"""Kernel validation: Pallas (interpret=True) and jnp twins vs pure oracles,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.jax_slow

from jax.experimental.pallas import tpu as pltpu

# The kernels fall back to the old pltpu.TPUCompilerParams spelling when
# the renamed CompilerParams is absent (jax <=0.4.37), so the Pallas paths
# build on both spellings; skip only if pallas exposes neither.
_HAS_PALLAS_COMPILER_PARAMS = (hasattr(pltpu, "CompilerParams")
                               or hasattr(pltpu, "TPUCompilerParams"))
needs_pallas = pytest.mark.skipif(
    not _HAS_PALLAS_COMPILER_PARAMS,
    reason="pallas lacks CompilerParams/TPUCompilerParams on this jax")

from repro.kernels.flash_attention.kernel import flash_fwd_pallas
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import mha_reference
from repro.kernels.ssd.kernel import ssd_pallas
from repro.kernels.ssd.ref import ssd_naive
from repro.models.ssm import ssd_scan

FLASH_CASES = [
    # B, Sq, Skv, Hq, Hkv, D, causal, window
    (2, 128, 128, 4, 2, 32, True, 0),
    (1, 100, 100, 4, 4, 16, True, 0),       # ragged seq
    (2, 128, 128, 8, 2, 32, True, 24),      # sliding window
    (2, 64, 128, 4, 2, 16, False, 0),       # cross attention
    (1, 256, 256, 2, 1, 64, True, 0),
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_jnp_matches_reference(case, dtype):
    B, Sq, Skv, Hq, Hkv, D, causal, window = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, D), dtype)
    ref = mha_reference(q, k, v, causal=causal, window=window)
    out = flash_attention(q, k, v, causal, window, 32, 32, "jnp")
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    err = float(jnp.max(jnp.abs(ref.astype(jnp.float32)
                                - out.astype(jnp.float32))))
    assert err < tol, (case, dtype, err)


@needs_pallas
@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_pallas_matches_reference(case):
    B, Sq, Skv, Hq, Hkv, D, causal, window = case
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, D), jnp.float32)
    ref = mha_reference(q, k, v, causal=causal, window=window)
    out, lse = flash_fwd_pallas(q, k, v, causal=causal, window=window,
                                block_q=64, block_k=64)
    assert float(jnp.max(jnp.abs(ref - out))) < 2e-5
    # lse sanity: exp(lse) == softmax denominator > 0
    assert np.isfinite(np.asarray(lse)).all()


def test_flash_grads_match_reference():
    B, S, Hq, Hkv, D = 2, 96, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)

    def f_ref(q, k, v):
        return jnp.sum(jnp.sin(mha_reference(q, k, v, causal=True)))

    def f_fl(q, k, v):
        return jnp.sum(jnp.sin(flash_attention(q, k, v, True, 0, 32, 32,
                                               "jnp")))

    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    gf = jax.grad(f_fl, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gr, gf):
        assert float(jnp.max(jnp.abs(a - b))) < 2e-5


SSD_CASES = [
    # B, S, H, P, G, N, chunk
    (2, 64, 4, 16, 1, 16, 16),
    (1, 96, 2, 32, 1, 8, 32),
    (2, 128, 4, 16, 2, 16, 64),
    (1, 50, 2, 16, 1, 16, 16),   # ragged
]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_scan_and_pallas_match_naive(case):
    B, S, H, P, G, N, chunk = case
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.5
    y0, s0 = ssd_naive(x, dt, A, Bm, Cm)
    pairs = [ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)]
    if _HAS_PALLAS_COMPILER_PARAMS:
        pairs.append(ssd_pallas(x, dt, A, Bm, Cm, chunk=chunk))
    for y, s in pairs:
        assert float(jnp.max(jnp.abs(y0 - y))) < 1e-3
        assert float(jnp.max(jnp.abs(s0 - s))) < 1e-3


def test_ssd_decode_step_matches_scan():
    """Single-token recurrence == chunked scan, step by step."""
    B, S, H, P, G, N = 1, 12, 2, 8, 1, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.5
    y_ref, final_ref = ssd_naive(x, dt, A, Bm, Cm)
    # sequential recurrence
    st = jnp.zeros((B, H, P, N))
    ys = []
    Bh = jnp.repeat(Bm, H // G, 2)
    Ch = jnp.repeat(Cm, H // G, 2)
    for t in range(S):
        dA = jnp.exp(dt[:, t] * A)                      # (B,H)
        upd = jnp.einsum("bh,bhp,bhn->bhpn", dt[:, t], x[:, t], Bh[:, t])
        st = st * dA[..., None, None] + upd
        ys.append(jnp.einsum("bhpn,bhn->bhp", st, Ch[:, t]))
    y_seq = jnp.stack(ys, 1)
    assert float(jnp.max(jnp.abs(y_seq - y_ref))) < 1e-4
    assert float(jnp.max(jnp.abs(st - final_ref))) < 1e-4


def test_ssd_init_state_threading():
    """Chunked scan with init state == one long scan split in two."""
    B, S, H, P, G, N = 1, 64, 2, 8, 1, 8
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.5
    y_all, s_all = ssd_scan(x, dt, A, Bm, Cm, chunk=16)
    half = S // 2
    y1, s1 = ssd_scan(x[:, :half], dt[:, :half], A, Bm[:, :half],
                      Cm[:, :half], chunk=16)
    y2, s2 = ssd_scan(x[:, half:], dt[:, half:], A, Bm[:, half:],
                      Cm[:, half:], chunk=16, init_state=s1)
    assert float(jnp.max(jnp.abs(jnp.concatenate([y1, y2], 1) - y_all))) < 1e-4
    assert float(jnp.max(jnp.abs(s2 - s_all))) < 1e-4
