import pytest

try:
    import jax  # noqa: F401
    _HAVE_JAX = True
except Exception:
    _HAVE_JAX = False

if not _HAVE_JAX:
    # the fast protocol CI job installs no jax: keep pytest from even
    # importing the jax-marked modules at collection time (-m deselection
    # alone still imports them and dies on the ImportError)
    collect_ignore = ["test_checkpoint_swarm.py", "test_infra.py",
                      "test_kernels.py", "test_models.py",
                      "test_parallel.py", "test_serving.py",
                      "test_trainer.py"]


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_makereport(item, call):
    """Print the chaos seed (with a one-line repro command) on any failing
    seed-parametrized test, so a CI failure is reproducible verbatim."""
    outcome = yield
    rep = outcome.get_result()
    if rep.when == "call" and rep.failed:
        seed = getattr(item, "funcargs", {}).get("seed")
        if seed is not None:
            rep.sections.append((
                "chaos seed",
                f"failing seed: {seed}\nrepro: PYTHONPATH=src python -m "
                f"repro.core.chaos --seed {seed} --check"))
