"""PieceExchange engine: choke scheduling, endgame cancels, real bytes."""
import threading
import time

import pytest

pytestmark = pytest.mark.protocol

from repro.core import (Agent, AgentConfig, LinkModel, Msg, PieceExchange,
                        PieceManifest, SimRuntime, ThreadRuntime,
                        TrackerConfig, TrackerServer, make_prime_app,
                        mask_nbytes, mask_of, pieces_of, rarest_first_order)
from repro.core.messages import (CHOKE, HAVE, INTERESTED, PIECE_CANCEL,
                                 PIECE_DATA, PIECE_REQ, UNCHOKE)
from repro.core.runtime import Node
from repro.core.workunit import PieceInventory


# --------------------------- bitmask helpers --------------------------- #
def test_mask_roundtrip_and_sizing():
    pieces = {0, 3, 17, 63}
    mask = mask_of(pieces)
    assert pieces_of(mask) == pieces
    assert mask_of(()) == 0 and pieces_of(0) == set()
    # 64 pieces fit in 8 bytes — announce cost no longer scales O(pieces)
    assert mask_nbytes(mask_of(range(64))) == 8
    assert mask_nbytes(0) == 0


def test_rarest_first_rotation_stable_under_completion():
    # equal availability: the tie-break rotation must not change as the
    # missing set shrinks (the old modulus was len(missing))
    avail = {p: 1 for p in range(8)}
    full = rarest_first_order(list(range(8)), avail, offset=5, n_pieces=8)
    shrunk = rarest_first_order([p for p in full if p != full[0]],
                                avail, offset=5, n_pieces=8)
    assert shrunk == full[1:]


# ----------------------- engine unit: choking -------------------------- #
def _engine(node_id="S", **over):
    cfg = AgentConfig(**over)
    log = []
    px = PieceExchange(node_id, cfg,
                       send=lambda dst, msg: log.append((dst, msg)),
                       now=lambda: 0.0, tracker_id="server")
    return px, log


def _interested(px, app_id, peer):
    px.on_interested(Msg(INTERESTED, peer, {"app_id": app_id}))


def test_choke_fairness_slow_leecher_cannot_monopolize_slots():
    px, log = _engine(upload_slots=2, optimistic_every=2)
    m = PieceManifest.synthetic("a", 64_000, 8_000)
    px.add_local_app("a", m)
    for peer in ("P0", "P1", "P2", "P3"):
        _interested(px, "a", peer)
    # startup fast path filled the free slots first-come-first-served
    assert len(px.unchoked["a"]) == 2
    # P2/P3 reciprocate (serve us bytes, credited through the rolling-rate
    # estimator the rechoke ranking reads); P0/P1 contribute nothing
    px._credit_from("P3", 5_000)
    px._credit_from("P2", 3_000)
    seen = []
    for _ in range(6):
        px.rechoke()
        seen.append(set(px.unchoked["a"]))
        assert len(px.unchoked["a"]) == 2
    # the best reciprocator holds a regular slot in every round…
    assert all("P3" in s for s in seen)
    # …while a zero-contributor can only ever ride the rotating optimistic
    # slot: no slow leecher appears in every round
    for slow in ("P0", "P1"):
        assert not all(slow in s for s in seen)


def test_optimistic_unchoke_rotates_through_choked_peers():
    px, log = _engine(upload_slots=1, optimistic_every=1)
    m = PieceManifest.synthetic("a", 8_000, 1_000)
    px.add_local_app("a", m)
    for peer in ("P0", "P1", "P2"):
        _interested(px, "a", peer)
    opts = []
    for _ in range(6):
        px.rechoke()
        opts.append(px.opt_unchoked["a"])
    # deterministic rotation cycles every choked candidate through the slot
    assert set(opts) == {"P0", "P1", "P2"}
    assert opts[:3] == opts[3:]          # stable cycle


def test_choked_request_is_refused_and_interest_grants_slots():
    px, log = _engine(upload_slots=1)
    m = PieceManifest.synthetic("a", 8_000, 1_000)
    px.add_local_app("a", m)
    _interested(px, "a", "P0")           # takes the only slot
    assert [d for d, msg in log if msg.kind == UNCHOKE] == ["P0"]
    # a non-endgame request from a choked peer bounces with CHOKE
    px.on_piece_req(Msg(PIECE_REQ, "P1", {"app_id": "a", "piece_id": 0}))
    assert (("P1", CHOKE) in [(d, msg.kind) for d, msg in log])
    assert not any(d == "P1" and msg.kind == PIECE_DATA for d, msg in log)
    # an unchoked peer is served
    px.on_piece_req(Msg(PIECE_REQ, "P0", {"app_id": "a", "piece_id": 0}))
    assert any(d == "P0" and msg.kind == PIECE_DATA for d, msg in log)


# ------------------- engine unit: endgame + cancels -------------------- #
def _wire(engines):
    """Deliver engine->engine messages through an inspectable queue."""
    history = []
    queue = []

    def mksend():
        return lambda dst, msg: queue.append((dst, msg))

    def pump():
        handlers = {PIECE_REQ: "on_piece_req", PIECE_DATA: "on_piece_data",
                    HAVE: "on_have", INTERESTED: "on_interested",
                    CHOKE: "on_choke", UNCHOKE: "on_unchoke",
                    PIECE_CANCEL: "on_piece_cancel"}
        while queue:
            dst, msg = queue.pop(0)
            history.append((dst, msg))
            eng = engines.get(dst)
            if eng is not None:
                getattr(eng, handlers[msg.kind])(msg)
    return mksend, pump, history


def test_endgame_duplicates_and_piece_cancel_reconciliation():
    engines = {}
    mksend, pump, history = _wire(engines)
    # two pieces: endgame engages for the tail piece once the first
    # verified (no duplication of a transfer's very first requests)
    m = PieceManifest.synthetic("a", 2_000, 1_000)
    L = PieceExchange("L", AgentConfig(endgame=True, endgame_dup=2),
                      send=mksend(), now=lambda: 0.0)
    A = PieceExchange("A", AgentConfig(choke=False),
                      send=mksend(), now=lambda: 0.0)
    B = PieceExchange("B", AgentConfig(upload_slots=1),
                      send=mksend(), now=lambda: 0.0)
    engines.update({"L": L, "A": A, "B": B})
    A.add_local_app("a", m)
    B.add_local_app("a", m)
    B.interested["a"].add("X")           # B's only upload slot is taken…
    B.unchoked["a"].add("X")
    done = []
    L.on_image_complete = lambda *args: done.append(args)
    L.join("a", m)
    L.note_full_seeders("a", {"A", "B"})
    L.pump("a")
    pump()       # full exchange: handshake, request, endgame dup, cancel
    # the missing piece went to A (first UNCHOKE); endgame duplicated the
    # request to B, flagged so B parks it in its choke queue
    endgame_reqs = [(d, msg) for d, msg in history
                    if msg.kind == PIECE_REQ and msg.payload.get("endgame")]
    assert [d for d, _ in endgame_reqs] == ["B"]
    assert not B.queued_reqs["a"].get("L")
    # A won the race: L cancelled the duplicate parked at B…
    assert L.cancels_sent == 1
    assert any(d == "B" and msg.kind == PIECE_CANCEL for d, msg in history)
    # …so B never transmitted the piece, even after X frees the slot
    B.unchoked["a"].discard("X")
    B._maybe_unchoke_now("a")
    pump()
    assert not any(msg.kind == PIECE_DATA and msg.src == "B"
                   for _, msg in history)
    assert done and done[0][0] == "a"    # image completed exactly once
    assert L.inventories["a"].complete


# ------------------ SimRuntime: downlink + cancel_work ----------------- #
def test_downlink_contention_serializes_bulk_ingress():
    got = []

    class Sink(Node):
        node_id = "sink"

        def on_message(self, msg):
            got.append((msg.payload["i"], self.rt.now()))

    link = LinkModel(uplink_Bps=None, downlink_Bps=1e6, base_latency_s=0.0,
                     bandwidth_Bps=1e9, bulk_threshold_bytes=1 << 16)
    rt = SimRuntime(link=link)
    rt.add_node(Sink())
    # 1MB from two different senders: both arrive via the sink's downlink
    rt.send("sink", Msg("X", "src1", {"i": 0}, size_bytes=1_000_000))
    rt.send("sink", Msg("X", "src2", {"i": 1}, size_bytes=1_000_000))
    rt.send("sink", Msg("X", "src3", {"i": 2}, size_bytes=64))
    rt.run()
    at = dict(got)
    assert at[0] == pytest.approx(1.0, rel=0.01)
    assert at[1] == pytest.approx(2.0, rel=0.01)   # queued at the ingress
    assert at[2] < 0.1                             # control msgs interleave


def test_sim_runtime_cancel_work_removes_job():
    done = []

    class W(Node):
        node_id = "w"

        def on_work_done(self, tag, result, elapsed_s):
            done.append((tag, self.rt.now()))

    rt = SimRuntime()
    w = W()
    rt.add_node(w)
    rt.submit_work("w", "t1", None, sim_duration_s=5.0)
    rt.submit_work("w", "t2", None, sim_duration_s=5.0)
    assert rt.cancel_work("w", "t1")
    assert not rt.cancel_work("w", "missing")
    rt.run()
    # t1 never completes; t2 reclaims the whole core (10s if t1 had stayed)
    assert [t for t, _ in done] == ["t2"]
    assert done[0][1] == pytest.approx(5.0, abs=0.2)


# -------------- integration: PART_CANCEL caps duplicates --------------- #
def _run_swarm_mmin2(endgame: bool):
    rt = SimRuntime(link=LinkModel(uplink_Bps=12.5e6))
    server = TrackerServer(config=TrackerConfig(ping_interval_s=2.0))
    rt.add_node(server)
    cfg = dict(work_timeout_s=600.0, endgame=endgame)
    host = Agent("host", config=AgentConfig(**cfg))
    rt.add_node(host)
    image = int(4e6)
    app = make_prime_app("app", "host", 3, 24_000, n_parts=16,
                         sim_time_per_number=5e-3, m_min=2, swarm=True,
                         app_bytes=image, piece_bytes=image // 8)
    host.host_app(app)
    agents = [host]
    for i in range(6):
        a = Agent(f"L{i}", config=AgentConfig(**cfg))
        # heterogeneous volunteers (cf. paper Scenario IV): staggered
        # completion times are what give cancels something to abort
        rt.add_node(a, speed=1.0 - 0.08 * i)
        agents.append(a)
    rt.run(until=4 * 3600, stop_when=lambda: app.done)
    assert app.done
    import collections
    execs = collections.Counter(part_id for a in agents
                                for (_, aid, part_id) in a.results_log
                                if aid == "app")
    return app, agents, execs


def test_part_cancel_caps_duplicate_executions():
    app, agents, execs = _run_swarm_mmin2(endgame=True)
    # endgame reconciliation: no part runs to completion more than
    # m_min + 1 times (one duplicate may slip through the cancel latency)
    assert max(execs.values()) <= app.m_min + 1
    # every part still reached its m_min quorum at its owner seeder
    # (results converge there; other seeders learn via PART_DONE gossip)
    copies = [c for a in agents
              for c in (a.apps.get("app"), a.replicas.get("app")) if c]
    for part in app.parts:
        assert part.done
        assert any(len(c.parts[part.part_id].results) >= app.m_min
                   for c in copies)
    dup_with = sum(max(0, n - app.m_min) for n in execs.values())
    _, _, execs_base = _run_swarm_mmin2(endgame=False)
    dup_without = sum(max(0, n - app.m_min) for n in execs_base.values())
    assert dup_with <= dup_without


def test_corrupt_piece_rerouted_to_other_holder_immediately():
    px, log = _engine("L")
    m = PieceManifest.synthetic("a", 1_000, 1_000)       # one piece
    px.join("a", m)
    px.note_full_seeders("a", {"A", "B"})
    px.unchoked_by["a"] |= {"A", "B"}
    px.pump("a")
    assert set(px.pending["a"][0]) == {"A"}              # least-loaded first
    # A serves garbage: the piece must re-enter missing and go to B now,
    # not stall until the recover() timeout
    px.on_piece_data(Msg(PIECE_DATA, "A",
                         {"app_id": "a", "piece_id": 0,
                          "proof": "garbage", "mask": 1}))
    assert "A" in px.bad_peers["a"]
    assert set(px.pending["a"][0]) == {"B"}
    reqs = [(d, msg) for d, msg in log if msg.kind == PIECE_REQ]
    assert [d for d, _ in reqs] == ["A", "B"]


def test_phantom_full_seeder_demoted_on_unchanged_snapshot():
    """Live-lock regression (scenario-x chaos overlay, hash-seed
    dependent): a crash-restarted seeder the tracker still advertises
    keeps refusing re-requests with an authoritative HAVE identical to
    the mask we already recorded.  The no-change early return in
    `_sync_peer_mask` used to skip the full-seeder demote, so `_holders`
    kept offering the phantom seeder and the REQ -> "don't have it" HAVE
    -> re-route -> REQ cycle spun at link latency while the heap grew."""
    px, log = _engine("L")
    m = PieceManifest.synthetic("a", 1_000, 1_000)       # one piece
    px.join("a", m)
    px.note_full_seeders("a", {"A"})                     # stale tracker row
    px.unchoked_by["a"].add("A")
    px.pump("a")
    assert [d for d, msg in log if msg.kind == PIECE_REQ] == ["A"]
    # A restarted empty: an authoritative snapshot (direct HAVE, no relay
    # hop) says it holds nothing — first contact records mask 0, and the
    # re-route still re-asks A because full_seeders vouches for it
    px.on_have(Msg(HAVE, "A", {"app_id": "a", "mask": 0, "v": m.version}))
    px.note_full_seeders("a", {"A"})                     # tracker re-push
    n_reqs = sum(1 for _, msg in log if msg.kind == PIECE_REQ)
    # the identical snapshot again: the demote must fire even though the
    # mask did not change, breaking the cycle on the second bounce
    px.on_have(Msg(HAVE, "A", {"app_id": "a", "mask": 0, "v": m.version}))
    assert "A" not in px.full_seeders["a"]
    assert px._holders("a", 0) == []
    assert sum(1 for _, msg in log if msg.kind == PIECE_REQ) == n_reqs
    assert 0 not in px.pending.get("a", {})


def test_recover_rerequests_stale_piece_from_alternate_holder():
    """The pending staleness sweep: a PIECE_DATA that never arrives is
    withdrawn after `stall_s` (PIECE_CANCEL to the silent holder, load
    released) and re-requested from an ALTERNATE holder — the silent one
    is shunned for that piece, so a black-holed link cannot capture the
    retries forever."""
    clock = [0.0]
    cfg = AgentConfig()
    log = []
    px = PieceExchange("L", cfg, send=lambda d, m: log.append((d, m)),
                       now=lambda: clock[0], tracker_id="server")
    m = PieceManifest.synthetic("a", 1_000, 1_000)       # one piece
    px.join("a", m)
    px.note_full_seeders("a", {"A", "B"})
    px.unchoked_by["a"] |= {"A", "B"}
    px.pump("a")
    assert set(px.pending["a"][0]) == {"A"}              # name tie-break
    assert px.peer_load["A"] == 1
    # A never answers: after the stall the request is withdrawn …
    clock[0] = 10.0
    px.recover("a", stall_s=5.0)
    assert [d for d, msg in log if msg.kind == PIECE_CANCEL] == ["A"]
    assert px.peer_load["A"] == 0
    # … and re-issued to B, not back to the silent A
    reqs = [d for d, msg in log if msg.kind == PIECE_REQ]
    assert reqs == ["A", "B"]
    assert set(px.pending["a"][0]) == {"B"}
    # B serves it: the piece completes and the stale history is dropped
    px.on_piece_data(Msg(PIECE_DATA, "B",
                         {"app_id": "a", "piece_id": 0,
                          "proof": m.piece_hashes[0], "mask": 1}))
    assert px.inventories["a"].complete
    assert 0 not in px.stalled_holders.get("a", {})


def test_recover_reannounces_when_no_holder_unchokes():
    """A leecher whose join HAVE died on the wire re-announces to the
    tracker from the staleness sweep, instead of waiting forever for a
    swarm that never learned it exists."""
    clock = [0.0]
    log = []
    px = PieceExchange("L", AgentConfig(),
                       send=lambda d, m: log.append((d, m)),
                       now=lambda: clock[0], tracker_id="server")
    m = PieceManifest.synthetic("a", 2_000, 1_000)
    px.join("a", m)
    assert [d for d, msg in log if msg.kind == HAVE] == ["server"]
    clock[0] = 30.0
    px.recover("a", stall_s=5.0)
    # no holder ever unchoked us -> interest cleared + HAVE re-announced
    assert [d for d, msg in log if msg.kind == HAVE] == ["server", "server"]


def test_repeated_interest_repeats_lost_unchoke():
    px, log = _engine(upload_slots=2)
    m = PieceManifest.synthetic("a", 8_000, 1_000)
    px.add_local_app("a", m)
    _interested(px, "a", "P0")
    assert [d for d, msg in log if msg.kind == UNCHOKE] == ["P0"]
    # P0 re-expresses interest (it never saw our UNCHOKE): repeat the
    # grant instead of silently keeping the slot allocated
    _interested(px, "a", "P0")
    assert [d for d, msg in log if msg.kind == UNCHOKE] == ["P0", "P0"]
    assert px.unchoked["a"] == {"P0"}


def test_rejected_result_does_not_spin_cached_resend_loop():
    # val_hook persistently rejects part 0: the volunteer's vote is
    # consumed (never re-granted by this seeder) and its cached result is
    # dropped, so no grant->cached-resend->reject livelock forms
    rt = SimRuntime()
    rt.add_node(TrackerServer(config=TrackerConfig(ping_interval_s=2.0)))
    host = Agent("host", config=AgentConfig(work_timeout_s=600.0),
                 val_hook=lambda part_id, result: part_id != 0)
    rt.add_node(host)
    app = make_prime_app("app", "host", 3, 6_000, n_parts=4,
                         sim_time_per_number=1e-3)
    host.host_app(app)
    vol = Agent("V0", config=AgentConfig(work_timeout_s=600.0))
    rt.add_node(vol)
    rt.run(until=120)
    # V0 executed each part at most once; part 0 stays unvalidated but the
    # protocol idles instead of spinning APP_DATA/RESULT traffic
    assert len(vol.results_log) <= len(app.parts)
    assert not app.parts[0].done
    assert all(p.done for p in app.parts[1:])
    assert rt.tx_bytes.get("host", 0) < 1_000_000


# ------------- ThreadRuntime: real bytes, two-seeder fetch ------------- #
def _mk_agent(node_id, tmp, **over):
    cfg = AgentConfig(work_timeout_s=5.0, status_interval_s=0.1,
                      rechoke_interval_s=0.2, root_dir=tmp, **over)
    return Agent(node_id, config=cfg)


def test_thread_runtime_reassembles_real_image_from_two_seeders(tmp_path):
    image = bytes((i * 31 + 7) % 256 for i in range(48_000))
    rt = ThreadRuntime(n_workers=2)
    rt.add_node(TrackerServer(config=TrackerConfig(ping_interval_s=0.2,
                                                   push_interval_s=0.1)))
    host = _mk_agent("h", str(tmp_path))
    app = make_prime_app("app", "h", 3, 1200, n_parts=4, swarm=True,
                         piece_bytes=8_192, image=image)
    host.host_app(app)
    rt.add_node(host)
    l1 = _mk_agent("L1", str(tmp_path))
    rt.add_node(l1)
    # phase 1: L1 fetches the full image from the origin, becomes replica
    rt.run(until_s=20.0, stop_when=lambda: "app" in l1.images)
    assert "app" in l1.images
    assert l1.px.assembled_image("app") == image
    # phase 2: L2 joins with TWO full seeders live and fetches from both
    l2 = _mk_agent("L2", str(tmp_path))
    rt.add_node(l2)
    rt.run(until_s=20.0, stop_when=lambda: "app" in l2.images)
    assert "app" in l2.images
    sources = {peer: n for peer, n in l2.px.pieces_from["app"].items()
               if n > 0}
    assert len(sources) >= 2, f"expected >=2 seeders, got {sources}"
    # byte-for-byte reassembly, re-verified against the manifest hash
    got = l2.px.assembled_image("app")
    assert got == image
    manifest = app.manifest
    assert PieceManifest.from_bytes("app", got,
                                    manifest.piece_bytes).manifest_hash \
        == manifest.manifest_hash
    # the reassembled Seed copy landed on disk (replica serving path)
    seed_copy = tmp_path / "L2" / "Seed" / "App" / "app" / "app.bin"
    assert seed_copy.read_bytes() == image


# ----------------- ThreadRuntime: timer drift regression ---------------- #
def test_thread_runtime_periodic_timer_no_drift_under_message_load():
    rt = ThreadRuntime(n_workers=1)
    fires = []

    class Flood(Node):
        node_id = "flood"

        def start(self, rt):
            super().start(rt)
            rt.set_timer("flood", "tick", 0.05, periodic=True)
            rt.send("flood", Msg("X", "flood"))

        def on_message(self, msg):
            time.sleep(0.04)             # heavy handler hogs the dispatcher
            self.rt.send("flood", Msg("X", "flood"))

        def on_timer(self, name):
            fires.append(self.rt.now())

    rt.add_node(Flood())
    rt.run(until_s=1.2)
    # deadline-aware dispatch + scheduled-time re-arm keep the 50ms grid:
    # ~24 fires expected; the old drift-per-period loop managed ~17
    assert len(fires) >= 20, f"only {len(fires)} fires: drift under load"


# ============ versioned manifests: delta + mixed-version ================ #
def test_manifest_chain_supersedes_and_delta():
    img1 = bytes(range(256)) * 16                    # 4096 bytes, 4 pieces
    m1 = PieceManifest.from_bytes("a", img1, 1024)
    img2 = bytearray(img1)
    img2[2048] ^= 0xFF                               # flip a byte in piece 2
    m2 = PieceManifest.from_bytes("a", bytes(img2), 1024, version=2, prev=m1)
    assert m2.prev_manifest_hash == m1.manifest_hash
    assert m2.manifest_hash != m1.manifest_hash      # hash folds the chain
    assert m2.delta(m1) == {2}
    assert m2.supersedes(m1) and not m1.supersedes(m2)
    assert not m1.supersedes(m1)                     # strictly newer only
    assert m2.supersedes(None)
    other = PieceManifest.from_bytes("b", img1, 1024, version=9)
    assert not other.supersedes(m1)                  # different app
    # incomparable manifests conservatively report everything changed
    coarse = PieceManifest.from_bytes("a", img1, 2048, version=2, prev=m1)
    assert coarse.delta(m1) == set(range(coarse.n_pieces))
    assert m2.delta(None) == {0, 1, 2, 3}


def test_manifest_degenerate_empty_and_exact_multiple():
    # empty image: a 0-piece manifest, trivially complete — no phantom
    # zero-byte piece that could never transfer or verify
    empty = PieceManifest.from_bytes("e", b"", 1024)
    assert empty.n_pieces == 0 and empty.total_bytes == 0
    assert empty.full_mask == 0
    assert PieceInventory(empty).complete
    assert PieceManifest.synthetic("e", 0, 1024).n_pieces == 0
    e2 = PieceManifest.from_bytes("e", b"", 1024, version=2, prev=empty)
    assert e2.supersedes(empty) and e2.delta(empty) == set()
    # exact multiple: no ragged tail piece — the last piece is full-sized
    # and no empty extra piece is appended
    img = bytes(4096)
    exact = PieceManifest.from_bytes("x", img, 1024)
    assert exact.n_pieces == 4
    assert [exact.piece_size(i) for i in range(4)] == [1024] * 4
    syn = PieceManifest.synthetic("x", 4096, 1024)
    assert syn.n_pieces == 4 and syn.piece_size(3) == 1024


def test_upgrade_reuses_unchanged_pieces_and_fetches_delta():
    img1 = bytes((i * 31 + 7) % 256 for i in range(4096))
    m1 = PieceManifest.from_bytes("a", img1, 1024)
    px, log = _engine("S")
    px.add_local_app("a", m1, image=img1)
    img2 = bytearray(img1)
    img2[1030] ^= 0xFF                               # piece 1 changes
    m2 = PieceManifest.from_bytes("a", bytes(img2), 1024, version=2, prev=m1)
    assert px.upgrade("a", m2)
    # the reuse rule carried over every unchanged piece (re-hashed), so
    # only the delta is left to fetch from the swarm
    inv = px.inventories["a"]
    assert inv.have == {0, 2, 3}
    assert px.reused_pieces == 3
    assert "a" in px.fetching and "a" not in px.complete
    # a stale/duplicate publish (not strictly newer) is refused
    assert not px.upgrade("a", m2)
    assert not px.upgrade("a", m1)
    # the missing piece completes the new image through the normal path
    assert inv.add(1, data=bytes(img2[1024:2048]))
    assert inv.complete


def test_stale_have_is_demoted_not_merged():
    m2 = PieceManifest.synthetic("a", 8_000, 1_000, version=2)
    px, log = _engine("S")
    px.add_local_app("a", m2)
    # a crash-restarted peer re-announces its full v1 mask after the
    # swarm moved to v2: it must be demoted, never pooled
    px.on_have(Msg(HAVE, "P1", {"app_id": "a", "mask": 255, "v": 1}))
    assert px.stale_have_demoted == 1
    assert not px.peer_masks.get("a", {}).get("P1", 0)
    # a peer AHEAD of us stops serving our revision: dropped from the
    # pool too, but not counted as a demotion
    px.on_have(Msg(HAVE, "P2", {"app_id": "a", "mask": 255, "v": 3}))
    assert px.stale_have_demoted == 1
    assert not px.peer_masks.get("a", {}).get("P2", 0)
    # the same mask tagged with the current version merges normally
    px.on_have(Msg(HAVE, "P1", {"app_id": "a", "mask": 255, "v": 2}))
    assert px.peer_masks["a"]["P1"] == 255


def test_stale_piece_req_refused_with_have():
    m2 = PieceManifest.synthetic("a", 8_000, 1_000, version=2)
    px, log = _engine("S")
    px.add_local_app("a", m2)
    _interested(px, "a", "P0")
    del log[:]
    px.on_piece_req(Msg(PIECE_REQ, "P0",
                        {"app_id": "a", "piece_id": 0, "v": 1}))
    assert px.stale_reqs_refused == 1
    # refused with our (version-tagged) HAVE so the straggler learns of
    # the new revision — never served stale-as-fresh, never banned
    assert not any(m.kind == PIECE_DATA for _, m in log)
    sent = [m for d, m in log if d == "P0" and m.kind == HAVE]
    assert sent and sent[-1].payload["v"] == 2
    assert "P0" not in px.bad_peers.get("a", set())
    px.on_piece_req(Msg(PIECE_REQ, "P0",
                        {"app_id": "a", "piece_id": 0, "v": 2}))
    assert any(m.kind == PIECE_DATA for _, m in log)


def test_stale_piece_data_discarded_without_ban():
    m1 = PieceManifest.synthetic("a", 8_000, 1_000, version=1)
    m2 = PieceManifest.synthetic("a", 8_000, 1_000, version=2,
                                 prev=m1, changed={0})
    px, log = _engine("L")
    px.join("a", m2)
    # piece 0 is the changed piece: its v1 proof is valid ONLY under v1 —
    # accepting it here is exactly the stale-as-fresh corruption the
    # version gate exists to stop
    px.on_piece_data(Msg(PIECE_DATA, "P0",
                         {"app_id": "a", "piece_id": 0, "v": 1,
                          "proof": m1.piece_hashes[0]}))
    assert px.stale_piece_data == 1 and px.stale_accepts == 0
    assert not px.inventories["a"].has(0)
    # not a ban: P0 is an honest v1 holder and stays usable once it
    # upgrades and re-announces under v2
    assert "P0" not in px.bad_peers.get("a", set())
    px.on_piece_data(Msg(PIECE_DATA, "P0",
                         {"app_id": "a", "piece_id": 0, "v": 2,
                          "proof": m2.piece_hashes[0]}))
    assert px.inventories["a"].has(0) and px.stale_accepts == 0


def test_intern_refcount_bounds_buffers_across_upgrades(monkeypatch):
    from repro.core import piece_exchange as pe
    monkeypatch.setattr(pe, "_IMAGE_INTERN_MAX", 2)
    px, log = _engine("S")
    img = bytes((i * 13 + 5) % 256 for i in range(8_192))
    m = PieceManifest.from_bytes("app", img, 1_024)
    px.add_local_app("app", m, image=img)
    base = pe.interned_image_count()
    for v in range(2, 7):                       # five successive upgrades
        img = bytes((b + 1) % 256 for b in img)
        m = PieceManifest.from_bytes("app", img, 1_024, version=v, prev=m)
        assert px.upgrade("app", m, image=img, full=True)
    # each upgrade released the superseded buffer's reference: the cache
    # holds the live revision plus at most the bounded LRU dedup tail —
    # NOT one buffer per revision ever published
    assert pe.interned_image_count() <= base + 1 + 2
    live = px._interned["app"]
    assert live == m.manifest_hash and pe._IMAGE_REFS[live] == 1
    px.drop_app("app")
    assert "app" not in px._interned
    assert live not in pe._IMAGE_REFS
