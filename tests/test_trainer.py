"""Trainer integration: convergence, checkpoint/restart, fault paths."""
import glob
import os

import jax
import numpy as np
import pytest

pytestmark = pytest.mark.jax_slow

from repro.cluster.elastic import plan_resize
from repro.cluster.sdc import SDCValidator, gradient_fingerprint
from repro.configs.base import get_config, reduced_config
from repro.optim.adamw import AdamWConfig
from repro.training.trainer import Trainer, TrainerConfig


def tiny_cfg():
    return reduced_config(get_config("granite-8b")).replace(
        vocab_size=64, d_model=32, num_heads=4, num_kv_heads=2, head_dim=8,
        d_ff=64)


def test_loss_decreases():
    cfg = tiny_cfg()
    tc = TrainerConfig(batch=8, seq=32, steps=30, log_every=0,
                       ckpt_every=1000)
    tr = Trainer(cfg, AdamWConfig(lr=3e-3, warmup_steps=5), tc)
    tr.init()
    hist = tr.run()
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.1, (first, last)


def test_checkpoint_restart_resumes_exactly(tmp_path):
    cfg = tiny_cfg()
    opt = AdamWConfig(lr=1e-3, warmup_steps=2)
    # run 1: 10 steps, checkpoint every 5
    tc = TrainerConfig(batch=4, seq=16, steps=10, ckpt_every=5,
                       ckpt_dir=str(tmp_path / "ckpt"), log_every=0)
    tr1 = Trainer(cfg, opt, tc)
    tr1.init(seed=7)
    tr1.run()
    state_10 = jax.tree_util.tree_map(np.asarray, tr1.state)

    # run 2: fresh process restores at step 10 and continues to 15
    tc2 = TrainerConfig(batch=4, seq=16, steps=15, ckpt_every=5,
                        ckpt_dir=str(tmp_path / "ckpt"), log_every=0)
    tr2 = Trainer(cfg, opt, tc2)
    tr2.init(seed=999)               # seed ignored on resume
    assert int(tr2.state["step"]) == 10
    for a, b in zip(jax.tree_util.tree_leaves(state_10),
                    jax.tree_util.tree_leaves(tr2.state)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))
    # pipeline state resumed (no batch replay)
    assert tr2.pipeline.state.next_piece == tr1.pipeline.state.next_piece
    hist = tr2.run()
    assert int(tr2.state["step"]) == 15


def test_deterministic_resume_equals_straight_run(tmp_path):
    """ckpt@5 -> resume -> 10 gives the same params as straight 10 steps."""
    cfg = tiny_cfg()
    opt = AdamWConfig(lr=1e-3, warmup_steps=2)
    straight = Trainer(cfg, opt, TrainerConfig(batch=4, seq=16, steps=10,
                                               ckpt_every=1000, log_every=0))
    straight.init(seed=3)
    straight.run()

    d = str(tmp_path / "c2")
    a = Trainer(cfg, opt, TrainerConfig(batch=4, seq=16, steps=5,
                                        ckpt_every=5, ckpt_dir=d,
                                        log_every=0))
    a.init(seed=3)
    a.run()
    b = Trainer(cfg, opt, TrainerConfig(batch=4, seq=16, steps=10,
                                        ckpt_every=5, ckpt_dir=d,
                                        log_every=0))
    b.init(seed=3)
    b.run()
    for x, y in zip(jax.tree_util.tree_leaves(straight.state["params"]),
                    jax.tree_util.tree_leaves(b.state["params"])):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-6)


def test_dead_member_triggers_redispatch_and_resize():
    cfg = tiny_cfg()
    tc = TrainerConfig(batch=4, seq=16, steps=3, log_every=0)
    tr = Trainer(cfg, AdamWConfig(), tc)
    tr.init()
    tr.run()
    plan = tr.on_member_dead("pod7", alive_pods=3)
    assert plan.new_pods == 2                 # largest pow2 <= 3
    assert plan.needs_restart and plan.reshard == "torrent"
    assert plan.mesh_shape == (2, 16, 16)


def test_sdc_flags_minority_replica():
    v = SDCValidator(m_min=3, m_max=3, every_steps=1)
    good = {"w": np.ones((4, 4), np.float32)}
    bad = {"w": np.ones((4, 4), np.float32) * 1.001}  # bitflip-ish
    assert v.offer(1, "podA", good) is None
    assert v.offer(1, "podB", good) is None
    rep = v.offer(1, "podC", bad)
    assert rep is not None and rep.agree
    assert rep.flagged == ["podC"]


def test_gradient_fingerprint_sensitivity():
    g = {"a": np.arange(32, dtype=np.float32).reshape(4, 8)}
    f1 = gradient_fingerprint(g)
    g2 = {"a": g["a"].copy()}
    g2["a"][2, 3] += 1e-3
    assert f1 != gradient_fingerprint(g2)
    assert f1 == gradient_fingerprint({"a": g["a"].copy()})


def test_elastic_plan_shapes():
    p1 = plan_resize(1)
    assert p1.mesh_shape == (16, 16) and p1.mesh_axes == ("data", "model")
    p8 = plan_resize(8, old_pods=8)
    assert p8.mesh_shape == (8, 16, 16) and not p8.needs_restart
    p5 = plan_resize(5, old_pods=8)
    assert p5.new_pods == 4 and p5.needs_restart
    assert p5.batch_scale == pytest.approx(0.5)


def test_grad_compression_trains_and_keeps_error_state():
    import jax
    import jax.numpy as jnp
    from repro.optim.compression import CompressionConfig
    from repro.training.train_state import init_train_state, make_train_step
    from repro.optim.adamw import AdamWConfig

    cfg = tiny_cfg()
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    k = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(k, (4, 32), 0, cfg.vocab_size),
             "labels": jax.random.randint(k, (4, 32), 0, cfg.vocab_size)}
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=3e-3, warmup_steps=2),
                                   compress=CompressionConfig(scheme="int8")))
    losses = []
    for _ in range(8):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert "err" in state
    # error-feedback state is non-trivial
    leaves = jax.tree_util.tree_leaves(state["err"])
    assert any(float(jnp.max(jnp.abs(l))) > 0 for l in leaves)
    assert losses[-1] < losses[0], losses
