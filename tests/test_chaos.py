"""Fault injection: FaultPlan determinism, loss/churn recovery, and the
seeded chaos invariant suite (convergence, quorum caps, availability).

Any failing chaos assertion prints its seed; reproduce with
  PYTHONPATH=src python -m repro.core.chaos --seed N --check
"""
import pytest

pytestmark = pytest.mark.protocol

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import (Agent, AgentConfig, ChaosScenario, Crash, FaultPlan,
                        LinkModel, LinkFault, Msg, Partition, SimRuntime,
                        TrackerConfig, TrackerServer, make_prime_app)
from repro.core.messages import PIECE_DATA
from repro.core.runtime import Node


# ---------------------- fault-layer unit semantics ---------------------- #
class _Sink(Node):
    def __init__(self, node_id="sink"):
        self.node_id = node_id
        self.got = []

    def on_message(self, msg):
        self.got.append((msg.payload.get("i"), round(self.rt.now(), 6)))


def test_drop_all_loses_messages_and_counts_them():
    plan = FaultPlan(seed=1, links={("src", "sink"): LinkFault(drop_p=1.0)})
    rt = SimRuntime(faults=plan)
    sink = _Sink()
    rt.add_node(sink)
    for i in range(5):
        rt.send("sink", Msg("X", "src", {"i": i}, size_bytes=64))
    rt.send("sink", Msg("X", "other", {"i": 99}, size_bytes=64))
    rt.run()
    assert [i for i, _ in sink.got] == [99]    # only the clean link works
    assert rt.dropped_msgs == 5


def test_duplication_delivers_twice():
    plan = FaultPlan(seed=1, link=LinkFault(dup_p=1.0))
    rt = SimRuntime(faults=plan)
    sink = _Sink()
    rt.add_node(sink)
    rt.send("sink", Msg("X", "src", {"i": 0}, size_bytes=64))
    rt.run()
    assert [i for i, _ in sink.got] == [0, 0]
    assert rt.dup_msgs == 1


def test_partition_cuts_inflight_messages_and_heals():
    plan = FaultPlan(partitions=[Partition(1.0, 2.0, (frozenset({"a"}),))])
    rt = SimRuntime(faults=plan)
    sink = _Sink("a")
    rt.add_node(sink)
    rt.send("a", Msg("X", "b", {"i": 0}, size_bytes=64))   # before: delivers
    rt.run(until=0.999)
    # sent before the cut but arriving inside it: lost in flight
    rt._at(0.9999, rt.send, ("a", Msg("X", "b", {"i": 1}, size_bytes=64)))
    # sent and delivered inside the partition: lost
    rt._at(1.5, rt.send, ("a", Msg("X", "b", {"i": 2}, size_bytes=64)))
    # after the heal: delivers again
    rt._at(2.5, rt.send, ("a", Msg("X", "b", {"i": 3}, size_bytes=64)))
    rt.run()
    assert [i for i, _ in sink.got] == [0, 3]
    assert rt.dropped_msgs == 2


def test_partition_same_island_and_rest_island_communicate():
    part = Partition(0.0, 10.0, ({"a", "b"}, {"c"}))
    assert not part.cuts("a", "b", 5.0)      # same island
    assert part.cuts("a", "c", 5.0)          # different islands
    assert part.cuts("a", "z", 5.0)          # island vs rest
    assert not part.cuts("y", "z", 5.0)      # rest vs rest
    assert not part.cuts("a", "c", 10.0)     # after the heal


def test_crash_kills_timers_work_and_delivery_until_restart():
    fired = []

    class Ticker(Node):
        node_id = "t"

        def start(self, rt):
            super().start(rt)
            rt.set_timer("t", "tick", 1.0, periodic=True)

        def on_timer(self, name):
            fired.append(self.rt.now())

        def on_message(self, msg):
            fired.append(("msg", self.rt.now()))

        def on_work_done(self, tag, result, elapsed_s):
            fired.append(("work", self.rt.now()))

    plan = FaultPlan(crashes=[Crash("t", at_s=2.5, restart_s=5.2)])
    rt = SimRuntime(faults=plan)
    rt.add_node(Ticker())
    rt.submit_work("t", "job", None, sim_duration_s=4.0)   # dies with crash
    rt._at(3.0, rt.send, ("t", Msg("X", "x", size_bytes=64)))
    rt.run(until=8.0)
    assert rt.crash_count == 1 and rt.restart_count == 1
    # ticks at 1, 2 — then the crash eats the timer, the in-flight work
    # and the message; restart re-arms from start(): ticks at 6.2, 7.2
    assert [f for f in fired if isinstance(f, tuple)] == []
    assert [round(t, 1) for t in fired] == [1.0, 2.0, 6.2, 7.2]


# ------------- differential: zero-fault plan is provably free ----------- #
class _TracingRuntime(SimRuntime):
    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self.trace = []

    def _deliver(self, dst, msg):
        self.trace.append((round(self._t, 9), dst, msg.kind, msg.src))
        super()._deliver(dst, msg)


def _run_swarm(faults):
    rt = _TracingRuntime(link=LinkModel(uplink_Bps=12.5e6,
                                        downlink_Bps=12.5e6), faults=faults)
    rt.add_node(TrackerServer(config=TrackerConfig(ping_interval_s=2.0)))
    host = Agent("host", config=AgentConfig(work_timeout_s=60.0))
    rt.add_node(host)
    image = int(4e6)
    app = make_prime_app("app", "host", 3, 24_000, n_parts=16,
                         sim_time_per_number=1e-3, m_min=2, swarm=True,
                         app_bytes=image, piece_bytes=image // 8)
    host.host_app(app)
    leechers = []
    for i in range(5):
        a = Agent(f"L{i}", config=AgentConfig(work_timeout_s=60.0))
        rt.add_node(a, speed=1.0 - 0.1 * i)
        leechers.append(a)
    rt.run(until=3600, stop_when=lambda: app.done)
    assert app.done
    rt.run(until=rt.now() + 30.0)        # drain post-completion traffic
    return rt, app, leechers


def test_zero_fault_plan_is_event_for_event_identical():
    """The fault layer must be provably free when disabled: a zero-fault
    FaultPlan yields the same trace as no plan at all."""
    bare, app_a, leech_a = _run_swarm(faults=None)
    zero, app_b, leech_b = _run_swarm(faults=FaultPlan(seed=123))
    assert zero.dropped_msgs == 0 and zero.dup_msgs == 0
    assert bare.events_processed == zero.events_processed
    assert bare.now() == zero.now()
    assert bare.trace == zero.trace      # event-for-event identical
    assert bare.tx_bytes == zero.tx_bytes
    for a, b in zip(leech_a, leech_b):
        assert a.px.bitfield_mask("app") == b.px.bitfield_mask("app")
        assert a.inventories["app"].have == b.inventories["app"].have
        assert a.completed_cycles == b.completed_cycles


# ----------- dropped PIECE_DATA: staleness sweep re-requests ------------ #
def test_dropped_piece_data_rerequested_and_completes():
    """Regression for the pending-request staleness sweep: a PIECE_DATA
    lost on the wire must be re-requested (here from a swarm with an
    alternate holder) instead of stalling the fetch forever."""
    plan = FaultPlan(drop_next={("host", "L1", PIECE_DATA): 2})
    rt = SimRuntime(link=LinkModel(uplink_Bps=12.5e6), faults=plan)
    rt.add_node(TrackerServer(config=TrackerConfig(ping_interval_s=2.0)))
    cfg = dict(work_timeout_s=60.0, status_interval_s=0.5,
               piece_timeout_s=3.0)
    host = Agent("host", config=AgentConfig(**cfg))
    rt.add_node(host)
    image = int(2e6)
    app = make_prime_app("app", "host", 3, 12_000, n_parts=8,
                         sim_time_per_number=1e-3, swarm=True,
                         app_bytes=image, piece_bytes=image // 8)
    host.host_app(app)
    l0 = Agent("L0", config=AgentConfig(**cfg))
    rt.add_node(l0)
    # phase 1: L0 replicates cleanly (its links are not in drop_next)
    rt.run(until=600, stop_when=lambda: "app" in l0.images)
    assert "app" in l0.images
    # phase 2: L1 joins; its first two PIECE_DATA from the origin die on
    # the wire — the sweep re-requests and the image still completes
    l1 = Agent("L1", config=AgentConfig(**cfg))
    rt.add_node(l1)
    rt.run(until=rt.now() + 600, stop_when=lambda: "app" in l1.images)
    assert rt.dropped_msgs == 2
    assert "app" in l1.images
    assert l1.inventories["app"].complete
    # at least one piece was fetched from the replica, not the origin
    assert sum(l1.px.pieces_from["app"].values()) == 8


# ------------- crash-restart: disk piece cache survives ----------------- #
def test_crash_restart_rescans_piece_cache(tmp_path):
    incarnations = []

    def mk_agent():
        a = Agent("V0", config=AgentConfig(
            work_timeout_s=20.0, status_interval_s=0.5, piece_timeout_s=3.0,
            replicate_completed=True, root_dir=str(tmp_path)))
        incarnations.append(a)
        return a

    rt = SimRuntime(link=LinkModel(uplink_Bps=2.5e6, downlink_Bps=2.5e6))
    rt.add_node(TrackerServer(config=TrackerConfig(ping_interval_s=1.0)))
    host = Agent("host", config=AgentConfig(work_timeout_s=20.0))
    rt.add_node(host)
    image = bytes((i * 37 + 5) % 256 for i in range(320_000))
    app = make_prime_app("app", "host", 3, 8_000, n_parts=8,
                         sim_time_per_number=1e-3, swarm=True,
                         piece_bytes=len(image) // 16, image=image)
    host.host_app(app)
    rt.add_node(mk_agent())
    rt.restart_factory["V0"] = mk_agent
    # run until V0 holds a few pieces (but not all), then crash it
    rt.run(until=600, stop_when=lambda: len(
        incarnations[0].px.inventories.get("app").have) >= 4
        if incarnations[0].px.inventories.get("app") else False)
    cached = len(incarnations[0].px.inventories["app"].have)
    assert 4 <= cached < 16
    rt.crash("V0")
    rt.run(until=rt.now() + 5.0)
    rt.restart("V0")
    rt.run(until=rt.now() + 600,
           stop_when=lambda: "app" in incarnations[-1].images)
    v0 = incarnations[-1]
    assert v0 is not incarnations[0]     # a fresh incarnation took over
    assert "app" in v0.images
    # the on-disk cache was rescanned: only the missing pieces re-fetched
    refetched = sum(v0.px.pieces_from["app"].values())
    assert refetched <= 16 - cached
    assert v0.px.assembled_image("app") == image


# ---------- checkpoint flash crowd: crash-restart + origin death -------- #
def test_checkpoint_crowd_survives_replica_crash_and_origin_death(tmp_path):
    """The Scenario XI chaos overlay in miniature: replicas cold-start
    from a zero-part (pure replication) checkpoint app; one replica
    crashes mid-restore and resumes from its on-disk piece cache, the
    origin dies once the swarm is self-sufficient, and every replica
    still reaches ready (complete verified piece set)."""
    from repro.core import Application

    incarnations = []

    def mk_r0():
        a = Agent("R0", config=AgentConfig(
            work_timeout_s=60.0, status_interval_s=0.5, piece_timeout_s=3.0,
            replicate_completed=True, root_dir=str(tmp_path)))
        incarnations.append(a)
        return a

    rt = SimRuntime(link=LinkModel(uplink_Bps=2.5e6, downlink_Bps=2.5e6))
    rt.add_node(TrackerServer(config=TrackerConfig(ping_interval_s=1.0)))
    cfg = dict(work_timeout_s=60.0, status_interval_s=0.5,
               piece_timeout_s=3.0, replicate_completed=True)
    origin = Agent("origin", config=AgentConfig(**cfg))
    rt.add_node(origin)
    image = bytes((i * 89 + 17) % 256 for i in range(256_000))
    app = Application("ckpt", "origin", app_bytes=len(image), parts=[],
                      swarm=True, piece_bytes=len(image) // 16, image=image)
    origin.host_app(app)
    rt.add_node(mk_r0())
    rt.restart_factory["R0"] = mk_r0
    others = [Agent(f"R{i}", config=AgentConfig(**cfg)) for i in (1, 2)]
    for a in others:
        rt.add_node(a)
    # crash R0 once it holds a partial piece set
    rt.run(until=600, stop_when=lambda: len(
        incarnations[0].px.inventories.get("ckpt").have) >= 4
        if incarnations[0].px.inventories.get("ckpt") else False)
    cached = len(incarnations[0].px.inventories["ckpt"].have)
    assert 4 <= cached < 16
    rt.crash("R0")
    # origin dies the moment any surviving replica is ready: the rest of
    # the crowd (including the restarted R0) must finish peer-to-peer
    rt.run(until=rt.now() + 600,
           stop_when=lambda: any("ckpt" in a.images for a in others))
    rt.nodes.pop("origin", None)
    rt.restart("R0")
    rt.run(until=rt.now() + 600,
           stop_when=lambda: "ckpt" in incarnations[-1].images
           and all("ckpt" in a.images for a in others))
    r0 = incarnations[-1]
    assert r0 is not incarnations[0]
    assert all("ckpt" in a.images for a in [r0] + others)
    # the cache resume did real work: R0's refetch skipped held pieces
    assert sum(r0.px.pieces_from["ckpt"].values()) <= 16 - cached
    # ready means bytes: every replica reassembles the exact image
    for a in [r0] + others:
        assert a.px.assembled_image("ckpt") == image


# --------------- tracker: silent-death row re-verification -------------- #
def test_tracker_reverifies_rows_and_reelects_host():
    sent = []

    class _RT:
        def now(self):
            return 0.0

        def send(self, dst, msg):
            sent.append((dst, msg))

    server = TrackerServer()
    server.rt = _RT()
    from repro.core.messages import AppInfo
    server.members = {"s2", "s3", "v"}
    server.app_list["a"] = AppInfo("a", "dead-host",
                                   seeders=("dead-host", "s1", "s2", "s3"))
    server.app_list["b"] = AppInfo("b", "gone", seeders=("gone",))
    server.seeder_load["a"] = {"s2": 4, "s3": 1}
    server._reverify_rows()
    row = server.app_list["a"]
    # dead seeders pruned, least-loaded live replica promoted to host
    assert row.host_id == "s3"
    assert set(row.seeders) == {"s2", "s3"}
    # a row with no live seeder left is dropped and announced
    assert "b" not in server.app_list
    assert any(msg.kind == "DROP_APP" and msg.payload["app_ids"] == ["b"]
               for _, msg in sent)


# ------------------- seeded chaos invariant suite ----------------------- #
# Scenario: N=12 volunteers, 10% loss, 2% duplication, 200ms jitter, 25%
# churn (crash + restart as fresh incarnations), one timed partition.
@pytest.mark.parametrize("seed", range(20))
def test_chaos_invariants(seed):
    sc = ChaosScenario(seed=seed).run()
    sc.check_invariants()
    r = sc.report()
    assert r["replicated"], f"seed={seed}: {r}"
    assert r["dropped_msgs"] > 0          # the plan actually bit
    assert r["restarts"] == r["crashes"] > 0


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 10_000),
           loss=st.floats(0.0, 0.30),
           churn=st.floats(0.0, 0.5),
           n_partitions=st.integers(0, 2))
    def test_chaos_property_random_plans(seed, loss, churn, n_partitions):
        """Random small FaultPlans (loss <= 30%, <= 2 partitions, <= N/2
        crashes) preserve the convergence + quorum + availability
        invariants; the failing seed prints as a one-line repro."""
        sc = ChaosScenario(seed=seed, n_volunteers=8, n_pieces=8,
                           n_parts=16, loss=loss, churn=churn,
                           n_partitions=n_partitions).run()
        sc.check_invariants()


# ------- versioned manifests: gossip must never wait on the limiter ----- #
def test_manifest_update_push_bypasses_seeder_update_limiter():
    """The SEEDER_UPDATE broadcast is rate-limited (one APP_LIST per
    push_interval_s) to stop O(N^2) storms; MANIFEST_UPDATE must NOT sit
    behind that budget — every tick of delay is a window where volunteers
    serve (and accept) superseded pieces as fresh.  Pins the max added
    staleness of version gossip at zero."""
    from repro.core import PieceManifest
    from repro.core.messages import (APP_LIST, AppInfo, MANIFEST_UPDATE,
                                     SEEDER_UPDATE)
    sent = []

    class _RT:
        t = 0.0

        def now(self):
            return self.t

        def send(self, dst, msg):
            sent.append((self.t, dst, msg))

    server = TrackerServer()
    rt = server.rt = _RT()
    server.members = {"host", "v1", "v2"}
    m1 = PieceManifest.synthetic("a", 8_000, 1_000)
    server.app_list["a"] = AppInfo("a", "host", seeders=("host",),
                                   manifest=m1)
    # t=0: a completion spends the one-per-interval broadcast budget
    server.RECV(Msg(SEEDER_UPDATE, "v1",
                    {"app_id": "a", "seeder": "v1",
                     "manifest_hash": m1.manifest_hash}))
    assert any(m.kind == APP_LIST for _, _, m in sent)
    # t=0.5 (inside push_interval_s=1.0): a second completion is relayed
    # but correctly NOT broadcast — the limiter is live
    rt.t = 0.5
    n0 = len(sent)
    server.RECV(Msg(SEEDER_UPDATE, "v2",
                    {"app_id": "a", "seeder": "v2",
                     "manifest_hash": m1.manifest_hash}))
    assert not any(m.kind == APP_LIST for _, _, m in sent[n0:])
    # t=0.6 (budget still spent): the host publishes v2 — the manifest
    # relay AND the APP_LIST broadcast go out THIS instant regardless
    rt.t = 0.6
    n1 = len(sent)
    m2 = PieceManifest.synthetic("a", 8_000, 1_000, version=2, prev=m1)
    server.RECV(Msg(MANIFEST_UPDATE, "host",
                    {"app_id": "a", "manifest": m2}))
    new = sent[n1:]
    relayed = {d for _, d, m in new if m.kind == MANIFEST_UPDATE}
    assert relayed == {"v1", "v2"}          # old seeders, minus publisher
    pushes = [(t, d) for t, d, m in new if m.kind == APP_LIST]
    assert pushes, "MANIFEST_UPDATE was delayed by the push limiter"
    assert all(t == 0.6 for t, _ in pushes)  # zero added staleness
    assert {d for _, d in pushes} == server.members
    # the row snapped to the new revision: seeders reset to the publisher
    row = server.app_list["a"]
    assert row.manifest is m2 and row.seeders == ("host",)
