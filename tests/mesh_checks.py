"""Multi-device correctness checks, run in a subprocess with 8 host devices.

Each check compares a sharded computation on a (2, 4) ("data", "model") mesh
against its single-device reference.  Invoked by tests/test_parallel.py via
``python tests/mesh_checks.py <check>`` with XLA_FLAGS set by the parent —
the main test process must keep seeing exactly 1 device.
"""
import os
import sys

if __name__ == "__main__":
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")

import numpy as np


def _mesh():
    import jax
    return jax.make_mesh((2, 4), ("data", "model"))


def check_train_step_sharded_matches_single():
    import jax, jax.numpy as jnp
    from repro.configs.base import get_config, reduced_config
    from repro.optim.adamw import AdamWConfig
    from repro.training.train_state import (init_train_state,
                                            make_train_step)
    cfg = reduced_config(get_config("internlm2-20b")).replace(
        dtype="float32", d_model=64, num_heads=8, num_kv_heads=4)
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    k = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(k, (4, 32), 0, cfg.vocab_size),
             "labels": jax.random.randint(k, (4, 32), 0, cfg.vocab_size)}
    s_ref, m_ref = jax.jit(make_train_step(cfg, AdamWConfig()))(state, batch)
    mesh = _mesh()
    with mesh:
        s_sh, m_sh = jax.jit(make_train_step(cfg, AdamWConfig(), mesh))(
            state, batch)
    assert abs(float(m_ref["loss"]) - float(m_sh["loss"])) < 1e-4, \
        (float(m_ref["loss"]), float(m_sh["loss"]))
    l_ref = jax.tree_util.tree_leaves(s_ref["params"])
    l_sh = jax.tree_util.tree_leaves(s_sh["params"])
    err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(l_ref, l_sh))
    assert err < 5e-4, err
    print("OK train_step sharded==single, err", err)


def check_moe_sharded_matches_single():
    import jax, jax.numpy as jnp
    from repro.configs.base import get_config, reduced_config
    from repro.models.moe import moe_block
    from repro.models.model import model_param_specs
    from repro.parallel.sharding import (DEFAULT_RULES, init_params,
                                         sharding_ctx)
    from repro.models import moe as moe_lib
    from repro.parallel.sharding import ParamSpec
    cfg = reduced_config(get_config("qwen3-moe-30b-a3b")).replace(
        dtype="float32", d_model=32, num_experts=8, experts_per_token=2,
        moe_d_ff=16)
    specs = moe_lib.moe_specs(cfg)
    params = init_params(jax.random.PRNGKey(0), specs)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32), jnp.float32)

    def f_single(params, x):
        out, aux = moe_block(params, x, cfg)
        return jnp.sum(out * jnp.cos(out)) + aux

    ref_val, ref_grads = jax.value_and_grad(f_single)(params, x)

    mesh = _mesh()

    def f_sharded(params, x):
        with sharding_ctx(mesh, DEFAULT_RULES):
            out, aux = moe_block(params, x, cfg)
            return jnp.sum(out * jnp.cos(out)) + aux

    with mesh:
        sh_val, sh_grads = jax.jit(jax.value_and_grad(f_sharded))(params, x)
    assert abs(float(ref_val) - float(sh_val)) < 1e-3, \
        (float(ref_val), float(sh_val))
    for a, b in zip(jax.tree_util.tree_leaves(ref_grads),
                    jax.tree_util.tree_leaves(sh_grads)):
        err = float(jnp.max(jnp.abs(a - b)))
        assert err < 1e-3, err
    print("OK moe sharded==single")


def check_embed_sharded_matches_take():
    import jax, jax.numpy as jnp
    from repro.configs.base import get_config, reduced_config
    from repro.models.layers import embed_tokens
    from repro.parallel.sharding import DEFAULT_RULES, sharding_ctx
    cfg = reduced_config(get_config("internlm2-20b")).replace(
        dtype="float32", vocab_size=64, d_model=32)
    emb = jax.random.normal(jax.random.PRNGKey(0), (64, 32), jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, 64)
    params = {"embedding": emb}
    ref = jnp.take(emb, toks, axis=0)
    mesh = _mesh()

    def f(params, toks):
        with sharding_ctx(mesh, DEFAULT_RULES):
            return embed_tokens(params, toks, cfg)

    with mesh:
        out = jax.jit(f)(params, toks)
    err = float(jnp.max(jnp.abs(ref - out)))
    assert err < 1e-6, err

    # gradient stays correct through the shard_map
    def g_ref(emb):
        return jnp.sum(jnp.sin(jnp.take(emb, toks, axis=0)))

    def g_sh(emb):
        with sharding_ctx(mesh, DEFAULT_RULES):
            return jnp.sum(jnp.sin(embed_tokens({"embedding": emb}, toks,
                                                cfg)))

    with mesh:
        ge = jax.jit(jax.grad(g_sh))(emb)
    gr = jax.grad(g_ref)(emb)
    err = float(jnp.max(jnp.abs(ge - gr)))
    assert err < 1e-5, err
    print("OK embed sharded==take (+grads)")


def check_decode_flash_sharded():
    import jax, jax.numpy as jnp
    from repro.models.attention import decode_attention
    from repro.parallel.sharding import INFERENCE_RULES, sharding_ctx
    B, S, Hq, Hkv, D = 4, 64, 8, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, 1, Hq, D), jnp.float32)
    kc = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    vc = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    t = jnp.asarray([10, 20, 30, 63], jnp.int32)
    ref = decode_attention(q, kc, vc, t)          # mesh-free path
    mesh = _mesh()

    def f(q, kc, vc, t):
        with sharding_ctx(mesh, INFERENCE_RULES):
            return decode_attention(q, kc, vc, t)

    with mesh:
        out = jax.jit(f)(q, kc, vc, t)
    err = float(jnp.max(jnp.abs(ref - out)))
    assert err < 1e-5, err
    print("OK sharded flash-decode == local, err", err)


def check_torrent_broadcast():
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.parallel.weight_torrent import torrent_broadcast_pieces
    mesh = jax.make_mesh((4, 2), ("pod", "data"))
    n, Pn, L = 4, 8, 32
    rng = np.random.RandomState(0)
    views = rng.randn(n, Pn, L).astype(np.float32)
    arr = jax.device_put(jnp.asarray(views),
                         NamedSharding(mesh, P("pod", None, None)))
    out = np.asarray(torrent_broadcast_pieces(arr, mesh, axis="pod",
                                              seeder=2))
    assert all(np.allclose(out[i], views[2]) for i in range(n))
    print("OK torrent broadcast")


def check_dryrun_cell_small():
    """The dry-run machinery itself on an 8-device mesh."""
    import jax
    from repro.configs.base import get_config, reduced_config, ShapeConfig
    from repro.launch.dryrun import lower_cell
    import repro.launch.dryrun as dr
    from repro.launch import hlo_analysis
    mesh = _mesh()
    import repro.configs.base as cb
    cfg = reduced_config(get_config("granite-8b"))
    cb._REGISTRY["granite-tiny"] = cfg
    shape = ShapeConfig("t", 64, 8, "train")
    cb.SHAPES["tiny_train"] = shape
    lowered, compiled = lower_cell("granite-tiny", "tiny_train", mesh)
    hlo = hlo_analysis.analyze_hlo(compiled.as_text(), n_devices=mesh.size)
    assert hlo["flops"] > 0 and hlo["collective_bytes"] > 0
    print("OK dryrun cell small:", hlo["flops"], hlo["collective_bytes"])




def check_tp_sp_and_pad_match_baseline():
    import jax, jax.numpy as jnp
    from repro.configs.base import get_config, reduced_config
    from repro.optim.adamw import AdamWConfig
    from repro.training.train_state import init_train_state, make_train_step
    # 12 heads % 4 != 0 when shrunk to 6 -> exercises padding on model=4
    cfg = reduced_config(get_config("qwen3-14b")).replace(
        dtype="float32", d_model=64, num_heads=6, num_kv_heads=2,
        head_dim=16, vocab_size=256)
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    k = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(k, (4, 32), 0, cfg.vocab_size),
             "labels": jax.random.randint(k, (4, 32), 0, cfg.vocab_size)}
    mesh = _mesh()
    with mesh:
        s0, m0 = jax.jit(make_train_step(cfg, AdamWConfig(), mesh))(
            state, batch)
        cfg_opt = cfg.replace(tp_sp=True, pad_attn_heads=True)
        s1, m1 = jax.jit(make_train_step(cfg_opt, AdamWConfig(), mesh))(
            state, batch)
    assert abs(float(m0["loss"]) - float(m1["loss"])) < 1e-4, \
        (float(m0["loss"]), float(m1["loss"]))
    for a, b in zip(jax.tree_util.tree_leaves(s0["params"]),
                    jax.tree_util.tree_leaves(s1["params"])):
        err = float(jnp.max(jnp.abs(a - b)))
        assert err < 5e-4, err
    print("OK tp_sp + head padding match baseline")




def check_moe_int8_a2a_close_to_exact():
    import jax, jax.numpy as jnp
    from repro.configs.base import get_config, reduced_config
    from repro.models.moe import moe_block
    from repro.models import moe as moe_lib
    from repro.parallel.sharding import (DEFAULT_RULES, init_params,
                                         sharding_ctx)
    cfg = reduced_config(get_config("qwen3-moe-30b-a3b")).replace(
        dtype="float32", d_model=32, num_experts=8, experts_per_token=2,
        moe_d_ff=16)
    specs = moe_lib.moe_specs(cfg)
    params = init_params(jax.random.PRNGKey(0), specs)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32), jnp.float32)
    mesh = _mesh()

    def run(c):
        def f(params, x):
            with sharding_ctx(mesh, DEFAULT_RULES):
                out, aux = moe_block(params, x, c)
                return jnp.sum(out * jnp.cos(out)) + aux
        with mesh:
            return jax.jit(jax.value_and_grad(f))(params, x)

    v0, g0 = run(cfg)
    v1, g1 = run(cfg.replace(moe_a2a_int8=True))
    rel = abs(float(v0) - float(v1)) / max(abs(float(v0)), 1e-9)
    assert rel < 0.05, rel      # int8 dispatch noise is bounded
    # gradients flow (straight-through) and stay finite
    import numpy as np
    for g in jax.tree_util.tree_leaves(g1):
        assert np.isfinite(np.asarray(g)).all()
    print("OK moe int8 a2a, rel err", rel)




def check_pipeline_parallel_matches_sequential():
    import jax, jax.numpy as jnp
    from repro.parallel.pipeline import pipeline_apply
    mesh = jax.make_mesh((4, 2), ("pod", "data"))
    L, M, B, D = 4, 6, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    ws = jax.random.normal(ks[0], (L, D, D), jnp.float32) * 0.3
    xs = jax.random.normal(ks[1], (M, B, D), jnp.float32)

    def stage(w, x):
        return jnp.tanh(x @ w)

    # sequential reference
    ref = []
    for m in range(M):
        h = xs[m]
        for l in range(L):
            h = stage(ws[l], h)
        ref.append(h)
    ref = jnp.stack(ref)
    with mesh:
        out = jax.jit(lambda w, x: pipeline_apply(stage, w, x, mesh,
                                                  axis="pod"))(ws, xs)
    err = float(jnp.max(jnp.abs(ref - out)))
    assert err < 1e-5, err
    print("OK pipeline parallel == sequential, err", err)


CHECKS = {k[6:]: v for k, v in list(globals().items())
          if k.startswith("check_")}

if __name__ == "__main__":
    name = sys.argv[1]
    CHECKS[name]()
