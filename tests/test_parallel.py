"""Sharded-vs-single-device equivalence, via 8-host-device subprocesses
(the main test process must keep seeing 1 device)."""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.jax_slow

HERE = os.path.dirname(os.path.abspath(__file__))
SRC = os.path.join(os.path.dirname(HERE), "src")

CHECKS = [
    "train_step_sharded_matches_single",
    "moe_sharded_matches_single",
    "embed_sharded_matches_take",
    "decode_flash_sharded",
    "torrent_broadcast",
    "dryrun_cell_small",
    "tp_sp_and_pad_match_baseline",
    "moe_int8_a2a_close_to_exact",
    "pipeline_parallel_matches_sequential",
]


@pytest.mark.parametrize("check", CHECKS)
def test_mesh_check(check):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, os.path.join(HERE, "mesh_checks.py"), check],
        capture_output=True, text=True, timeout=900, env=env)
    assert proc.returncode == 0, \
        f"{check} failed:\n{proc.stdout[-2000:]}\n{proc.stderr[-4000:]}"
    assert "OK" in proc.stdout
