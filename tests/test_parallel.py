"""Sharded-vs-single-device equivalence, via 8-host-device subprocesses
(the main test process must keep seeing 1 device)."""
import functools
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.jax_slow

HERE = os.path.dirname(os.path.abspath(__file__))
SRC = os.path.join(os.path.dirname(HERE), "src")


def _mesh_env():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return env


@functools.lru_cache(maxsize=1)
def _has_8_host_devices():
    """True iff a subprocess can actually see 8 forced host devices.

    Probed lazily, once per session, so images where jax is missing or
    ignores the host-device flag skip the mesh checks instead of
    erroring nine times — and collection with -m "not jax_slow" never
    pays the probe's jax import.
    """
    try:
        proc = subprocess.run(
            [sys.executable, "-c",
             "import jax; print(jax.device_count())"],
            capture_output=True, text=True, timeout=120, env=_mesh_env())
    except (OSError, subprocess.SubprocessError):
        return False
    return proc.returncode == 0 and proc.stdout.strip() == "8"


CHECKS = [
    "train_step_sharded_matches_single",
    "moe_sharded_matches_single",
    "embed_sharded_matches_take",
    "decode_flash_sharded",
    "torrent_broadcast",
    "dryrun_cell_small",
    "tp_sp_and_pad_match_baseline",
    "moe_int8_a2a_close_to_exact",
    "pipeline_parallel_matches_sequential",
]


@pytest.mark.parametrize("check", CHECKS)
def test_mesh_check(check):
    if not _has_8_host_devices():
        pytest.skip("jax cannot provide 8 forced host devices here")
    proc = subprocess.run(
        [sys.executable, os.path.join(HERE, "mesh_checks.py"), check],
        capture_output=True, text=True, timeout=900, env=_mesh_env())
    assert proc.returncode == 0, \
        f"{check} failed:\n{proc.stdout[-2000:]}\n{proc.stderr[-4000:]}"
    assert "OK" in proc.stdout
