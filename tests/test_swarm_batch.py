"""Array-native batched swarm engine (core/swarm_arrays + swarm_kernels):
kernel differentials against the scalar PieceExchange, request-for-request
trace equivalence via SwarmHub.mirror_scalar, mixed-mode event-heap
determinism (run vs run_batched), batched flash-crowd smoke, and the chaos
overlay on the batched path."""
import random

import numpy as np
import pytest

pytestmark = pytest.mark.protocol

from repro.core import (Agent, AgentConfig, LinkModel, Msg, PieceManifest,
                        SimRuntime, SwarmHub, TrackerConfig, TrackerServer,
                        make_prime_app, rarest_first_order_np)
from repro.core import swarm_kernels as sk
from repro.core.messages import HAVE, PIECE_REQ, UNCHOKE
from tests.test_exchange_scaling import _engine


# ===================== kernel differentials ============================= #
def test_rarest_orders_matches_scalar_per_row():
    """Batched rarest-first keys reproduce `rarest_first_order_np` (itself
    differentially tied to the scalar `rarest_first_order`) row by row
    over randomized counts / missing sets / tie-break offsets."""
    rng = random.Random(11)
    for _ in range(40):
        n_pieces = rng.randrange(1, 100)
        n_rows = rng.randrange(1, 12)
        counts = np.array([rng.randrange(0, 7) for _ in range(n_pieces)],
                          dtype=np.int32)
        missing = np.zeros((n_rows, n_pieces), dtype=bool)
        offsets = np.zeros(n_rows, dtype=np.int64)
        for r in range(n_rows):
            missing[r, rng.sample(range(n_pieces),
                                  rng.randrange(0, n_pieces + 1))] = True
            offsets[r] = rng.randrange(0, 900)
        orders = sk.rarest_orders(missing, counts, offsets, n_pieces)
        assert orders.shape == (n_rows, n_pieces)
        for r in range(n_rows):
            k = int(missing[r].sum())
            want = rarest_first_order_np(
                sorted(np.nonzero(missing[r])[0].tolist()), counts,
                offset=int(offsets[r]), n_pieces=n_pieces)
            assert orders[r, :k].tolist() == want, f"row {r}"


def test_choke_order_matches_scalar_ranking():
    """Batched choke ranking reproduces `_rechoke_app`'s
    sorted(key=(-rate_from, -rate_to, name)) for every holder at once,
    including rate ties broken by the lexicographic name."""
    rng = random.Random(5)
    rates = [0.0, 0.0, 1.5, 7.25, 7.25, 100.0]
    for _ in range(40):
        n_cols = rng.randrange(1, 20)
        n_holders = rng.randrange(1, 10)
        names = sorted(f"N{rng.randrange(1000):03d}-{i}"
                       for i in range(n_cols))
        ranks = np.arange(n_cols, dtype=np.int64)
        recv = np.array([[rng.choice(rates) for _ in range(n_cols)]
                         for _ in range(n_holders)], dtype=np.float32)
        sent = np.array([[rng.choice(rates) for _ in range(n_cols)]
                         for _ in range(n_holders)], dtype=np.float32)
        cand = np.array([[rng.random() < 0.6 for _ in range(n_cols)]
                         for _ in range(n_holders)], dtype=bool)
        order = sk.choke_order_np(recv, sent, cand, ranks)
        for h in range(n_holders):
            cs = [j for j in range(n_cols) if cand[h, j]]
            want = sorted(cs, key=lambda j: (-recv[h, j], -sent[h, j],
                                             names[j]))
            got = order[h, :len(cs)].tolist()
            assert got == want, f"holder {h}"


@pytest.mark.jax_slow
def test_kernel_backends_agree_with_numpy():
    """jax (and pallas, when present) backends produce bit-identical
    rarest orders and choke rankings to the numpy reference."""
    backends = [b for b in sk.available_backends() if b != "numpy"]
    if not backends:
        pytest.skip("no jax backends available")
    rng = random.Random(31)
    for _ in range(10):
        n_pieces = rng.randrange(1, 300)
        n_rows = rng.randrange(1, 20)
        counts = np.array([rng.randrange(0, 9) for _ in range(n_pieces)],
                          dtype=np.int32)
        missing = np.array([[rng.random() < 0.5 for _ in range(n_pieces)]
                            for _ in range(n_rows)], dtype=bool)
        offsets = np.array([rng.randrange(0, 2000)
                            for _ in range(n_rows)], dtype=np.int64)
        ref = sk.rarest_orders(missing, counts, offsets, n_pieces,
                               backend="numpy")
        for b in backends:
            got = sk.rarest_orders(missing, counts, offsets, n_pieces,
                                   backend=b)
            assert got.tolist() == ref.tolist(), b
        recv = np.array([[rng.choice([0.0, 3.5, 9.0])
                          for _ in range(n_rows)]
                         for _ in range(n_rows)], dtype=np.float32)
        sent = recv.T.copy()
        cand = np.array([[rng.random() < 0.5 for _ in range(n_rows)]
                         for _ in range(n_rows)], dtype=bool)
        ranks = np.arange(n_rows, dtype=np.int64)
        cref = sk.choke_order_np(recv, sent, cand, ranks)
        for b in backends:
            got = sk.choke_order(recv, sent, cand, ranks, backend=b)
            assert got.tolist() == cref.tolist(), b


# ============== trace differential: hub vs scalar pump ================== #
def test_batched_requests_match_scalar_over_seeded_trace():
    """320-event seeded trace: after every event, a hub mirroring the
    scalar engine's exact information set must predict the scalar pump's
    PIECE_REQ decisions request-for-request (piece, holder, order), and
    its endgame bridge must predict the scalar endgame duplicates."""
    n_pieces = 64
    manifest = PieceManifest.synthetic("a", n_pieces * 1000, 1000)
    px, log = _engine(piece_pipeline=6)
    rng = random.Random(97)
    peers = [f"P{i}" for i in range(16)]
    px.join("a", manifest)
    px.note_full_seeders("a", set(peers[:2]))
    compared = 0
    for step in range(320):
        # apply the event with pump disabled so the mirror sees the
        # pre-decision state the scalar engine is about to act on
        orig_pump, px.pump = px.pump, lambda app_id: None
        roll = rng.random()
        if roll < 0.5:
            px.on_have(Msg(HAVE, rng.choice(peers),
                           {"app_id": "a",
                            "mask": rng.getrandbits(n_pieces)}))
        elif roll < 0.8:
            px.on_unchoke(Msg(UNCHOKE, rng.choice(peers), {"app_id": "a"}))
        else:
            px.on_peer_gone(rng.choice(peers))
        px.pump = orig_pump
        hub = SwarmHub.mirror_scalar(px, "a")
        want = hub.decide_requests("a", "L", now=0.0)
        want_eg = hub.decide_endgame("a", "L", now=0.0)
        n0 = len(log)
        px.pump("a")
        got = [(m.payload["piece_id"], d) for d, m in log[n0:]
               if m.kind == PIECE_REQ and not m.payload.get("endgame")]
        got_eg = [(m.payload["piece_id"], d) for d, m in log[n0:]
                  if m.kind == PIECE_REQ and m.payload.get("endgame")]
        assert got == want, f"step {step}"
        assert got_eg == want_eg, f"step {step} (endgame)"
        compared += len(got)
    assert compared > 10          # the trace actually exercised matching


# ================= mixed-mode event-heap determinism ==================== #
def _mini_flash(n_leechers=4):
    rt = SimRuntime(link=LinkModel(uplink_Bps=12.5e6,
                                   downlink_Bps=12.5e6))
    rt.add_node(TrackerServer(config=TrackerConfig(ping_interval_s=2.0)))
    host = Agent("host", config=AgentConfig(work_timeout_s=600.0))
    rt.add_node(host)
    app = make_prime_app("mm-app", "host", 3, 6_000, n_parts=6,
                         sim_time_per_number=1e-4, swarm=True,
                         app_bytes=262_144, piece_bytes=32_768)
    host.host_app(app)
    leech = [Agent(f"L{i}", config=AgentConfig(work_timeout_s=600.0))
             for i in range(n_leechers)]
    for a in leech:
        rt.add_node(a)
    done = lambda: all("mm-app" in a.images for a in leech)
    return rt, host, leech, done


def test_run_batched_without_ticks_is_event_identical_to_run():
    """`run_batched` shares the heap, the monotonic `_seq` counter and
    `events_processed` with `run`; with no tick callback it must drain
    the same scenario pop-for-pop: same event count, same sequence
    watermark, same virtual clock, same per-node traffic."""
    a_rt, a_host, a_leech, a_done = _mini_flash()
    b_rt, b_host, b_leech, b_done = _mini_flash()
    a_rt.run(until=3_600, stop_when=a_done)
    b_rt.run_batched(until=3_600, stop_when=b_done, tick_s=0.25)
    assert a_done() and b_done()
    assert a_rt.events_processed == b_rt.events_processed
    assert repr(a_rt._seq) == repr(b_rt._seq)   # same push watermark
    assert a_rt.now() == b_rt.now()
    assert a_rt.tx_bytes == b_rt.tx_bytes
    assert a_host.completed_at == b_host.completed_at


def test_run_batched_resumes_mixed_with_run():
    """Mixed-mode regression: a scenario driven part-way by `run`, then
    finished by `run_batched` (and vice versa) lands in the same final
    state — the shared seq counter keeps FIFO order across the seam."""
    final = []
    for order in ((0, 1), (1, 0)):
        rt, host, leech, done = _mini_flash()
        runners = [lambda u: rt.run(until=u, stop_when=done),
                   lambda u: rt.run_batched(until=u, stop_when=done,
                                            tick_s=0.5)]
        runners[order[0]](1.5)
        assert not done()
        runners[order[1]](3_600)
        assert done()
        final.append((rt.events_processed, repr(rt._seq), rt.now(),
                      dict(rt.tx_bytes)))
    assert final[0] == final[1]


# ==================== batched end-to-end scenarios ====================== #
def test_scenario_vii_batched_smoke():
    """Small batched flash crowd completes and fully replicates; the hub
    actually carried the decisions (batch_ops) and coalesced the control
    plane (logical > heap events)."""
    from benchmarks.paper_tables import scenario_vii
    res = scenario_vii(verbose=False, n_volunteers=8, image_mb=4.0,
                       n_pieces=8, batched=True)
    assert res["done"] and res["replicated"] and res["replicas"] == 8
    assert res["batch_ops"] > 0
    assert res["logical_events"] > res["events"] > 0
    assert res["full_replication_s"] >= res["makespan_s"] > 0
    assert res["backend"] in sk.available_backends()


def test_chaos_overlay_on_batched_path():
    """Seeded FaultPlan over the batched engine: loss / dup / jitter /
    churn / a partition, with the PR-4 convergence, quorum and
    hub-consistency invariants asserted by check_invariants()."""
    from repro.core.chaos import ChaosScenario
    sc = ChaosScenario(seed=3, n_volunteers=8, n_pieces=12, n_parts=16,
                       image_bytes=96_000, real_image=False,
                       batched=True).run()
    sc.check_invariants()
    rep = sc.report()
    assert rep["replicated"] and rep["done"]
    assert rep["batch_ops"] > 0


@pytest.mark.jax_slow
def test_scenario_vii_batched_large_n_converges():
    """N=500 batched flash crowd (the CI sweep ceiling) fully replicates
    and clearly outruns the per-message path's historical event rate."""
    from benchmarks.paper_tables import scenario_vii
    res = scenario_vii(verbose=False, n_volunteers=500, batched=True)
    assert res["done"] and res["replicated"] and res["replicas"] == 500
    assert res["wall_s"] < 120
    assert res["events_per_sec"] > 500_000


# ====== ISSUE 10: fused request matching / endgame top-k kernels ======== #
def _match_requests_scalar(orders, n_walk, budgets, cand, cand_ok,
                           cand_key, have, full):
    """Pure-Python greedy walk — the semantics `match_requests_np`
    vectorizes: per row, for each order position in turn, pick the
    lowest-keyed usable candidate that holds the piece, mark it busy,
    burn one budget unit."""
    R, P = orders.shape
    C = cand.shape[1]
    picks = np.full((R, P), -1, dtype=np.int32)
    for r in range(R):
        taken = {c for c in range(C) if not cand_ok[r, c]}
        budget = int(budgets[r])
        for k in range(min(int(n_walk[r]), P)):
            if budget <= 0 or len(taken) == C:
                break
            p = int(orders[r, k])
            best = None
            for c in range(C):
                if c in taken:
                    continue
                j = int(cand[r, c])
                if not (full[j] or have[j, p]):
                    continue
                if best is None or cand_key[r, c] < cand_key[r, best]:
                    best = c
            if best is not None:
                picks[r, k] = int(cand[r, best])
                taken.add(best)
                budget -= 1
    return picks


def _holder_topk_scalar(keys, k):
    """Per-column sorted selection of the K cheapest valid holders."""
    n, p = keys.shape
    out = np.full((k, p), -1, dtype=np.int32)
    for col in range(p):
        valid = sorted((int(keys[r, col]), r) for r in range(n)
                       if keys[r, col] < sk.KEY_INF32)
        for s, (_, r) in enumerate(valid[:k]):
            out[s, col] = r
    return out


def _random_match_case(rng):
    R = rng.randrange(1, 10)
    P = rng.randrange(1, 24)
    N = rng.randrange(1, 16)
    C = rng.randrange(1, min(N, 8) + 1)
    orders = np.array([rng.sample(range(P), P) for _ in range(R)],
                      dtype=np.int32)
    n_walk = np.array([rng.randrange(0, P + 1) for _ in range(R)],
                      dtype=np.int32)
    budgets = np.array([rng.randrange(0, 7) for _ in range(R)],
                       dtype=np.int32)
    cand = np.full((R, C), -1, dtype=np.int32)
    cand_ok = np.zeros((R, C), dtype=bool)
    cand_key = np.full((R, C), sk.KEY_INF32, dtype=np.int32)
    for r in range(R):
        rows = rng.sample(range(N), rng.randrange(0, C + 1))
        keys = rng.sample(range(1 << 20), len(rows))   # unique per row
        for c, (j, key) in enumerate(zip(rows, keys)):
            cand[r, c] = j
            cand_ok[r, c] = rng.random() < 0.85
            cand_key[r, c] = key
    have = np.array([[rng.random() < 0.45 for _ in range(P)]
                     for _ in range(N)], dtype=bool)
    full = np.array([rng.random() < 0.15 for _ in range(N)], dtype=bool)
    return orders, n_walk, budgets, cand, cand_ok, cand_key, have, full


def test_match_requests_matches_scalar_reference():
    """The fused holder-match kernel reproduces the pure-Python greedy
    walk over randomized rows/candidates/budgets (numpy path: this is
    the reference the jax/pallas backends are then held to)."""
    rng = random.Random(23)
    picked = 0
    for _ in range(60):
        case = _random_match_case(rng)
        got = sk.match_requests_np(*case)
        want = _match_requests_scalar(*case)
        assert got.tolist() == want.tolist()
        picked += int((got >= 0).sum())
    assert picked > 100            # the cases actually exercised matching


def test_holder_topk_matches_scalar_reference():
    """The endgame shortlist kernel returns exactly the K cheapest valid
    holders per piece, ascending, -1 padded (keys unique per column, as
    the hub guarantees by embedding the name rank)."""
    rng = random.Random(29)
    filled = 0
    for _ in range(60):
        n = rng.randrange(1, 14)
        p = rng.randrange(1, 20)
        k = rng.randrange(1, 8)
        keys = np.full((n, p), sk.KEY_INF32, dtype=np.int32)
        for col in range(p):
            rows = rng.sample(range(n), rng.randrange(0, n + 1))
            vals = rng.sample(range(1 << 27), len(rows))
            for r, v in zip(rows, vals):
                keys[r, col] = v
        got = sk.holder_topk_np(keys, k)
        want = _holder_topk_scalar(keys, k)
        assert got.shape == (k, p)
        assert got.tolist() == want.tolist()
        filled += int((got >= 0).sum())
    assert filled > 100


@pytest.mark.jax_slow
def test_fused_kernel_backends_agree_with_numpy():
    """jax (and pallas, when present) produce bit-identical request
    matches and endgame shortlists to the numpy reference."""
    backends = [b for b in sk.available_backends() if b != "numpy"]
    if not backends:
        pytest.skip("no jax backends available")
    rng = random.Random(41)
    for _ in range(12):
        case = _random_match_case(rng)
        ref = sk.match_requests(*case, backend="numpy")
        for b in backends:
            got = sk.match_requests(*case, backend=b)
            assert got.tolist() == ref.tolist(), b
        n = rng.randrange(1, 20)
        p = rng.randrange(1, 24)
        k = rng.randrange(1, 9)
        keys = np.full((n, p), sk.KEY_INF32, dtype=np.int32)
        for col in range(p):
            rows = rng.sample(range(n), rng.randrange(0, n + 1))
            vals = rng.sample(range(1 << 27), len(rows))
            for r, v in zip(rows, vals):
                keys[r, col] = v
        tref = sk.holder_topk(keys, k, backend="numpy")
        for b in backends:
            got = sk.holder_topk(keys, k, backend=b)
            assert got.tolist() == tref.tolist(), b


# ========= ISSUE 10: array ledger vs scalar pending differential ======== #
def _assert_ledger_matches_dicts(hub):
    """Every hub state's in-flight ledger must be entry-for-entry
    identical to its engines' scalar `px.pending` dicts: same pieces,
    same holders, same request timestamps, same budget counters."""
    entries = 0
    max_dup = 0
    for st in hub.states.values():
        for name, i in st.row.items():
            px = st.clients[i]
            if px is None or not st.alive[i]:
                continue
            pending = px.pending.get(st.app_id, {})
            assert int(st.pend_n[i]) == len(pending), name
            assert int(st.pipeline[i]) == int(px.cfg.piece_pipeline)
            total = 0
            for p, asked in pending.items():
                cnt = int(st.pend_cnt[i, p])
                assert cnt == len(asked), (name, p)
                max_dup = max(max_dup, cnt)
                named = {}
                rowless = []
                for s in range(cnt):
                    j = int(st.pend_holder[i, p, s])
                    t = float(st.pend_t[i, p, s])
                    if j >= 0:
                        named[st.names[j]] = t
                    else:
                        assert j == -2, (name, p, s)
                        rowless.append(t)
                assert named == {h: float(t) for h, t in asked.items()
                                 if h in st.row}, (name, p)
                assert sorted(rowless) == sorted(
                    float(t) for h, t in asked.items()
                    if h not in st.row), (name, p)
                total += cnt
                entries += cnt
            # no ledger entries exist outside the dict's pieces
            assert int(st.pend_cnt[i].astype(np.int64).sum()) == total, name
    return entries, max_dup


def test_array_ledger_matches_scalar_pending_over_trace():
    """Seeded >=500-event batched flash crowd: after EVERY hub tick, the
    array ledger (pend_holder/pend_t/pend_cnt/pend_n) is entry-for-entry
    identical to the scalar `px.pending` dicts — requests, endgame
    duplicates, cancels and budget counters all flow through the same
    funnel and may never drift."""
    rt = SimRuntime(link=LinkModel(uplink_Bps=12.5e6,
                                   downlink_Bps=12.5e6))
    rt.add_node(TrackerServer(config=TrackerConfig(ping_interval_s=2.0)))
    hub = SwarmHub()
    host = Agent("host", config=AgentConfig(work_timeout_s=600.0),
                 hub=hub)
    rt.add_node(host)
    app = make_prime_app("lg-app", "host", 3, 6_000, n_parts=8,
                         sim_time_per_number=1e-4, swarm=True,
                         app_bytes=16 * 32_768, piece_bytes=32_768)
    host.host_app(app)
    leech = [Agent(f"L{i}", config=AgentConfig(work_timeout_s=600.0),
                   hub=hub) for i in range(6)]
    for a in leech:
        rt.add_node(a)
    rt.crash_hooks.append(hub.node_gone)
    done = lambda: all("lg-app" in a.images for a in leech)
    stats = {"checks": 0, "entries": 0, "max_dup": 0}

    def on_tick(now):
        hub.tick(now)
        entries, max_dup = _assert_ledger_matches_dicts(hub)
        stats["checks"] += 1
        stats["entries"] += entries
        stats["max_dup"] = max(stats["max_dup"], max_dup)

    rt.run_batched(until=3_600, stop_when=done, tick_s=0.5,
                   on_tick=on_tick)
    assert done()
    _assert_ledger_matches_dicts(hub)
    assert rt.events_processed >= 500     # the trace is big enough to count
    assert stats["checks"] > 0 and stats["entries"] > 0
    assert hub.ledger_ops > 0             # the ledger was kept incrementally
    # cancels were exercised: endgame duplicates appeared in the ledger
    # and their losers were cancelled on the winning PIECE_DATA
    cancels = sum(px.cancels_sent for a in leech + [host]
                  for px in [a.px])
    assert stats["max_dup"] >= 2 or cancels > 0


# =========== ISSUE 10: single-pass SwarmState row growth ================ #
def test_swarm_state_growth_single_pass_covers_every_row_array():
    """Capacity growth reallocates every per-row buffer in ONE registry
    walk: any (cap, ...) ndarray on SwarmState must be listed in
    _ROW_ARRAYS (else _grow would silently orphan it), fills must follow
    _ROW_FILL, and existing data must survive a doubling."""
    from repro.core.swarm_arrays import SwarmState
    m = PieceManifest.synthetic("g", 8_000, 1_000)     # P=8 != cap=4
    st = SwarmState("g", m, capacity=4)
    cap = st.have.shape[0]
    assert cap == 4 and st.P == 8
    per_row = {name for name, a in vars(st).items()
               if isinstance(a, np.ndarray) and a.ndim >= 1
               and a.shape[0] == cap}
    assert per_row == set(SwarmState._ROW_ARRAYS)
    assert set(SwarmState._ROW_FILL) <= set(SwarmState._ROW_ARRAYS)
    # populate all four rows, then grow past capacity
    for i in range(4):
        st.ensure_row(f"N{i}")
    st.have[2, 5] = True
    st.have_n[2] = 1
    st.pend_holder[1, 3, 0] = 2
    st.pend_t[1, 3, 0] = 7.25
    st.pend_cnt[1, 3] = 1
    st.pend_n[1] = 1
    st.pipeline[:4] = 6
    st.opt_peer[3] = 1
    st.uc_rows[0, 0] = 3
    st.uc_n[0] = 1
    st.busy_rows[1, 0] = 2
    st.busy_n[1] = 1
    i4 = st.ensure_row("N4")
    assert i4 == 4 and st.have.shape[0] == 8
    for name in SwarmState._ROW_ARRAYS:
        assert getattr(st, name).shape[0] == 8, name
    # old data intact
    assert st.have[2, 5] and int(st.have_n[2]) == 1
    assert int(st.pend_holder[1, 3, 0]) == 2
    assert float(st.pend_t[1, 3, 0]) == 7.25
    assert int(st.pend_cnt[1, 3]) == 1 and int(st.pend_n[1]) == 1
    assert st.pipeline[:4].tolist() == [6] * 4
    assert int(st.opt_peer[3]) == 1
    assert int(st.uc_rows[0, 0]) == 3 and int(st.busy_rows[1, 0]) == 2
    # new rows carry the registered fills
    assert not st.have[5:].any() and not st.alive[5:].any()
    assert (st.opt_peer[5:] == -1).all()
    assert (st.pend_holder[5:] == -1).all()
    assert (st.uc_rows[5:] == -1).all()
    assert (st.ub_rows[5:] == -1).all()
    assert (st.busy_rows[5:] == -1).all()
    assert int(st.pend_cnt[5:].sum()) == 0


# ---------- versioned manifests: (app_id, version) state keying --------- #
def _hub_engine(node_id, hub, **over):
    from repro.core import PieceExchange
    cfg = AgentConfig(**over)
    px = PieceExchange(node_id, cfg, send=lambda dst, msg: None,
                       now=lambda: 0.0, tracker_id="server", hub=hub)
    return px


def test_hub_states_keyed_by_version_never_cross_masks():
    hub = SwarmHub()
    m1 = PieceManifest.synthetic("a", 8_000, 1_000, version=1)
    m2 = PieceManifest.synthetic("a", 8_000, 1_000, version=2, prev=m1,
                                 changed={0})
    seeder = _hub_engine("S", hub)
    seeder.add_local_app("a", m1)
    leech = _hub_engine("L", hub)
    leech.join("a", m2)
    # one state per (app_id, version): the v1 seeder's full mask lives in
    # a different state than the v2 leecher's row — mixed-version swarms
    # can never merge availability
    assert set(hub.states) == {("a", 1), ("a", 2)}
    st2 = hub.states[("a", 2)]
    assert "S" not in st2.row and int(st2.counts.sum()) == 0
    assert hub.has_row("a", "S") and hub.has_row("a", "L")
    # decide_requests for the v2 leecher sees zero holders — it cannot be
    # steered at the v1 seeder
    st1 = hub.states[("a", 1)]
    assert st1.full[st1.row["S"]]


def test_hub_retire_detaches_row_and_prunes_empty_state():
    hub = SwarmHub()
    m1 = PieceManifest.synthetic("a", 8_000, 1_000, version=1)
    m2 = PieceManifest.synthetic("a", 8_000, 1_000, version=2, prev=m1,
                                 changed={0})
    a = _hub_engine("A", hub)
    b = _hub_engine("B", hub)
    a.add_local_app("a", m1)
    b.add_local_app("a", m1)
    assert hub.states[("a", 1)].n_alive == 2
    # A upgrades: its engine retires the v1 row and re-registers under v2
    # (the synthetic publisher path carries no image bytes)
    assert a.upgrade("a", m2, full=True)
    st1 = hub.states[("a", 1)]
    assert st1.n_alive == 1 and not st1.alive[st1.row["A"]]
    assert st1.full[st1.row["B"]]                   # only B's claim remains
    assert set(hub.states) == {("a", 1), ("a", 2)}
    # the last v1 holder upgrading prunes the superseded state entirely
    assert b.upgrade("a", m2, full=True)
    assert set(hub.states) == {("a", 2)}
    assert hub.states[("a", 2)].n_alive == 2
