"""Serving engine: continuous batching correctness + (d,p,w) publication."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.jax_slow

from repro.configs.base import get_config, reduced_config
from repro.models import model as M
from repro.parallel.sharding import init_params
from repro.serving.engine import ServeConfig, ServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config(get_config("granite-8b")).replace(
        dtype="float32", vocab_size=128, d_model=32, num_heads=4,
        num_kv_heads=2, head_dim=8, d_ff=64)
    params = init_params(jax.random.PRNGKey(0), M.model_param_specs(cfg))
    return cfg, params


def greedy_reference(cfg, params, prompt, max_new):
    """Direct full-forward greedy decoding (no cache)."""
    toks = list(map(int, prompt))
    out = []
    for _ in range(max_new):
        logits, _, _ = M.forward(
            cfg, params, {"tokens": jnp.asarray([toks], jnp.int32)},
            mode="train")
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        toks.append(nxt)
    return out


def test_engine_matches_reference_greedy(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, ServeConfig(slots=2, max_len=64))
    prompt = np.array([5, 9, 2, 17], np.int32)
    rid = eng.submit(prompt, max_new=6)
    reqs = {rid: prompt}
    done = {}
    for _ in range(200):
        eng.step()
        if not eng.queue and not eng.active:
            break
    # find the request output (engine keeps finished out_tokens on requests;
    # re-submit pattern: collect from history)
    # simplest: run again tracking the object
    eng2 = ServingEngine(cfg, params, ServeConfig(slots=2, max_len=64))
    rid2 = eng2.submit(prompt, max_new=6)
    req_obj = eng2.queue[0]
    for _ in range(200):
        eng2.step()
        if req_obj.done:
            break
    ref = greedy_reference(cfg, params, prompt, 6)
    assert req_obj.out_tokens == ref, (req_obj.out_tokens, ref)


def test_engine_drains_many_requests_and_publishes_units(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, ServeConfig(slots=3, max_len=64))
    rng = np.random.RandomState(0)
    objs = []
    for i in range(7):
        p = rng.randint(0, cfg.vocab_size, size=rng.randint(2, 9))
        eng.submit(p.astype(np.int32), max_new=4)
    objs = list(eng.queue)
    for _ in range(500):
        eng.step()
        if not eng.queue and not eng.active:
            break
    assert all(r.done for r in objs)
    units = eng.published_units()
    assert units, "must publish (d,p,w) rows"
    for b, row in units.items():
        assert row["p"] >= 1 and row["d"] > 0 and row["w"] >= 0


def test_continuous_batching_interleaves(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, ServeConfig(slots=2, max_len=64))
    a = eng.submit(np.array([1, 2, 3], np.int32), max_new=8)
    b = eng.submit(np.array([4, 5], np.int32), max_new=2)
    c = eng.submit(np.array([6], np.int32), max_new=2)
    objs = list(eng.queue)
    ticks = 0
    while (eng.queue or eng.active) and ticks < 300:
        eng.step()
        ticks += 1
    assert all(r.done for r in objs)
    # slot reuse happened: 3 requests > 2 slots
    assert ticks < 300
