"""Topology layer (core/topology + runtime WAN leg + P4P selection):
flat-topology trace identity (run and run_batched), `_topo_delay` send
semantics (cross-ISP accounting, WAN latency, trunk serialisation),
cost-kernel differentials (uniform plane == rarest-first, cost dominance,
island availability vs naive loops), scalar and batched peer-selection
preference with shun-dominates-cost decay, tracker COST_MAP delivery,
island-aligned chaos overlay, and the bench_guard cross-ISP keys."""
import json
import random

import numpy as np
import pytest

pytestmark = pytest.mark.protocol

from repro.core import (Agent, AgentConfig, LinkModel, Msg, PieceManifest,
                        SimRuntime, SwarmHub, Topology, TrackerConfig,
                        TrackerServer, make_prime_app)
from repro.core import swarm_kernels as sk
from repro.core.messages import HAVE, PIECE_REQ, UNCHOKE
from repro.core.runtime import Node
from tests.test_exchange_scaling import _engine


# ==================== flat-topology trace identity ====================== #
def _mini_flash(n_leechers=4, topology=None):
    rt = SimRuntime(link=LinkModel(uplink_Bps=12.5e6, downlink_Bps=12.5e6),
                    topology=topology)
    rt.add_node(TrackerServer(config=TrackerConfig(ping_interval_s=2.0)))
    host = Agent("host", config=AgentConfig(work_timeout_s=600.0))
    rt.add_node(host)
    app = make_prime_app("mm-app", "host", 3, 6_000, n_parts=6,
                         sim_time_per_number=1e-4, swarm=True,
                         app_bytes=262_144, piece_bytes=32_768)
    host.host_app(app)
    leech = [Agent(f"L{i}", config=AgentConfig(work_timeout_s=600.0))
             for i in range(n_leechers)]
    for a in leech:
        rt.add_node(a)
    done = lambda: all("mm-app" in a.images for a in leech)
    return rt, host, leech, done


def _trace(rt, host):
    return (rt.events_processed, repr(rt._seq), rt.now(),
            dict(rt.tx_bytes), rt.cross_isp_bytes, host.completed_at)


def test_flat_topology_is_event_identical_to_none():
    """`topology=None`, `Topology.flat(...)` and a hand-built one-island
    zero-latency topology must drain the same scenario pop-for-pop: same
    event count, same push watermark, same clock, same per-node bytes —
    and the flat runs never count a cross-ISP byte.  This is the
    transport-layer invariant (the tracker is deliberately given no
    topology here: COST_MAP is a protocol change, not a transport one)."""
    ids = ["server", "host"] + [f"L{i}" for i in range(4)]
    topos = [None, Topology.flat(ids),
             Topology({n: 0 for n in ids}, 1, [[0.0]])]
    traces = []
    for topo in topos:
        rt, host, _, done = _mini_flash(topology=topo)
        rt.run(until=3_600, stop_when=done)
        assert done()
        traces.append(_trace(rt, host))
    assert traces[0] == traces[1] == traces[2]
    assert traces[0][4] == 0                       # cross_isp_bytes


def test_flat_topology_run_batched_identical_to_none():
    """Same invariant on the batched driver: the tick loop shares the
    heap with `run`, so a flat topology must be equally inert there."""
    a_rt, a_host, _, a_done = _mini_flash()
    b_rt, b_host, _, b_done = _mini_flash(
        topology=Topology.flat(["server", "host"]
                               + [f"L{i}" for i in range(4)]))
    a_rt.run_batched(until=3_600, stop_when=a_done, tick_s=0.25)
    b_rt.run_batched(until=3_600, stop_when=b_done, tick_s=0.25)
    assert a_done() and b_done()
    assert _trace(a_rt, a_host) == _trace(b_rt, b_host)


# ======================= _topo_delay send semantics ===================== #
class _Sink(Node):
    def __init__(self, node_id):
        self.node_id = node_id
        self.got = []                              # (virtual_t, msg)

    def on_message(self, msg):
        self.got.append((self.rt.now(), msg))


def _wan_pair(topology, link=None):
    rt = SimRuntime(link=link or LinkModel(), topology=topology)
    sinks = {n: _Sink(n) for n in ("a", "b", "c")}
    for s in sinks.values():
        rt.add_node(s)
    return rt, sinks


def test_cross_island_send_adds_latency_and_counts_bytes():
    topo = Topology({"a": 0, "b": 1, "c": 0}, 2,
                    [[0.0, 0.05], [0.05, 0.0]])
    rt, sinks = _wan_pair(topo)
    flat, fsinks = _wan_pair(None)
    for r in (rt, flat):
        r.send("b", Msg("X", "a", {}, size_bytes=1000))   # cross
        r.send("c", Msg("X", "a", {}, size_bytes=500))    # intra
        r.run(until=10.0)
    t_cross, t_intra = sinks["b"].got[0][0], sinks["c"].got[0][0]
    f_cross, f_intra = fsinks["b"].got[0][0], fsinks["c"].got[0][0]
    assert t_cross == pytest.approx(f_cross + 0.05)   # one-way WAN leg
    assert t_intra == f_intra                         # intra untouched
    assert rt.cross_isp_bytes == 1000                 # intra not counted
    assert flat.cross_isp_bytes == 0


def test_cross_island_bulk_serialises_through_trunk():
    """Two bulk transfers from different island-0 sources into island 1
    queue behind each other on the shared (0, 1) trunk pipe, while the
    same sends with no trunk matrix land at independent times."""
    size = 1 << 17                                 # > bulk threshold
    lat = [[0.0, 0.01], [0.01, 0.0]]
    islands = {"a": 0, "c": 0, "b": 1}
    trunk = 1e6
    topo = Topology(islands, 2, lat,
                    bandwidth_Bps=[[None, trunk], [trunk, None]])
    free = Topology(islands, 2, lat)
    t_times, f_times = [], []
    for topology, times in ((topo, t_times), (free, f_times)):
        rt, sinks = _wan_pair(topology)
        rt.send("b", Msg("X", "a", {}, size_bytes=size))
        rt.send("b", Msg("X", "c", {}, size_bytes=size))
        rt.run(until=60.0)
        times.extend(t for t, _ in sinks["b"].got)
    assert len(t_times) == len(f_times) == 2
    # no trunk: both cross sends see only the WAN latency -> same arrival
    assert f_times[0] == f_times[1]
    # trunk: the second transfer starts where the first left the pipe
    assert t_times[1] - t_times[0] == pytest.approx(size / trunk)


# ========================= cost kernels ================================= #
def test_island_has_and_min_cost_match_naive_loops():
    rng = random.Random(13)
    for _ in range(30):
        n, p, k = (rng.randrange(1, 40), rng.randrange(1, 60),
                   rng.randrange(1, 8))
        have = np.array([[rng.random() < 0.3 for _ in range(p)]
                         for _ in range(n)], dtype=bool)
        island = np.array([rng.randrange(k) for _ in range(n)])
        member = np.zeros((k, n), dtype=bool)
        member[island, np.arange(n)] = True
        avail = sk.island_has(have, member)
        cost = np.array([[0 if i == j else rng.randrange(1, 16)
                          for j in range(k)] for i in range(k)],
                        dtype=np.int64)
        plane = sk.min_island_cost(avail, cost)
        assert avail.shape == (k, p) and plane.shape == (k, p)
        for ki in range(k):
            for pi in range(p):
                holders = [i for i in range(n) if have[i, pi]]
                want = any(island[i] == ki for i in holders)
                assert avail[ki, pi] == want
                costs = [cost[ki, island[i]] for i in holders]
                assert plane[ki, pi] == (min(costs) if costs
                                         else sk.COST_NONE)


def test_cost_orders_uniform_plane_equals_rarest_orders():
    """A uniform cost plane shifts every composite key by the same
    amount: the P4P order must be bit-identical to plain rarest-first —
    the decay-to-rarity property the chaos overlay relies on."""
    rng = random.Random(29)
    for _ in range(20):
        n_pieces, n_rows = rng.randrange(1, 80), rng.randrange(1, 10)
        counts = np.array([rng.randrange(0, 7) for _ in range(n_pieces)],
                          dtype=np.int32)
        missing = np.array([[rng.random() < 0.5 for _ in range(n_pieces)]
                            for _ in range(n_rows)], dtype=bool)
        offsets = np.array([rng.randrange(0, 500) for _ in range(n_rows)],
                           dtype=np.int64)
        level = rng.randrange(0, 16)
        plane = np.full((n_rows, n_pieces), level, dtype=np.int64)
        got = sk.cost_orders(missing, counts, offsets, plane, n_pieces)
        want = sk.rarest_orders(missing, counts, offsets, n_pieces)
        assert got.tolist() == want.tolist()


def test_cost_orders_cost_dominates_rarity():
    """A piece held on a cheap island outranks a strictly rarer piece
    only reachable across an expensive trunk; within one cost level the
    rarest-first order is preserved."""
    counts = np.array([1, 5, 3, 5], dtype=np.int32)   # 0 is the rarest
    missing = np.ones((1, 4), dtype=bool)
    offsets = np.zeros(1, dtype=np.int64)
    plane = np.array([[9, 0, 0, 0]], dtype=np.int64)  # rare but far
    order = sk.cost_orders(missing, counts, offsets, plane, 4)
    assert order[0].tolist() == [2, 1, 3, 0]          # cost, then rarity


@pytest.mark.jax_slow
def test_cost_kernels_backends_agree_with_numpy():
    backends = [b for b in sk.available_backends() if b != "numpy"]
    if not backends:
        pytest.skip("no jax backends available")
    rng = random.Random(41)
    for _ in range(8):
        n, p, k = (rng.randrange(1, 60), rng.randrange(1, 200),
                   rng.randrange(1, 9))
        have = np.array([[rng.random() < 0.4 for _ in range(p)]
                         for _ in range(n)], dtype=bool)
        island = np.array([rng.randrange(k) for _ in range(n)])
        member = np.zeros((k, n), dtype=bool)
        member[island, np.arange(n)] = True
        ref = sk.island_has(have, member, backend="numpy")
        counts = np.array([rng.randrange(0, 9) for _ in range(p)],
                          dtype=np.int32)
        missing = np.array([[rng.random() < 0.5 for _ in range(p)]
                            for _ in range(3)], dtype=bool)
        offsets = np.array([rng.randrange(0, 999) for _ in range(3)],
                           dtype=np.int64)
        plane = np.array([[rng.randrange(0, 16) for _ in range(p)]
                          for _ in range(3)], dtype=np.int64)
        oref = sk.cost_orders(missing, counts, offsets, plane, p,
                              backend="numpy")
        for b in backends:
            assert sk.island_has(have, member,
                                 backend=b).tolist() == ref.tolist(), b
            assert sk.cost_orders(missing, counts, offsets, plane, p,
                                  backend=b).tolist() == oref.tolist(), b


# =================== scalar P4P selection preference ==================== #
def _loaded_engine(n_pieces=1, holders=("A", "B", "C")):
    px, log = _engine()
    manifest = PieceManifest.synthetic("a", n_pieces * 1000, 1000)
    px.join("a", manifest)
    orig_pump, px.pump = px.pump, lambda app_id: None
    full = (1 << n_pieces) - 1
    for h in holders:
        px.on_have(Msg(HAVE, h, {"app_id": "a", "mask": full}))
        px.on_unchoke(Msg(UNCHOKE, h, {"app_id": "a"}))
    px.pump = orig_pump
    return px, log


def _reqs(log, n0=0):
    return [(dst, m.payload["piece_id"], bool(m.payload.get("endgame")))
            for dst, m in log[n0:] if m.kind == PIECE_REQ]


def test_scalar_pump_prefers_cheapest_island_holder():
    px, log = _loaded_engine()
    # L sits on island 0 with A; B and C are 5 and 2 away
    px.set_cost_map(0, [0, 5, 2], {"A": 0, "B": 1, "C": 2})
    px.pump("a")
    assert _reqs(log) == [("A", 0, False)]


def test_scalar_pump_shun_dominates_cost():
    """A shunned same-island holder loses to a clean remote one: the P4P
    bias decays to plain availability when the cheap holders starve."""
    px, log = _loaded_engine()
    px.set_cost_map(0, [0, 5, 2], {"A": 0, "B": 1, "C": 2})
    px.stalled_holders["a"] = {0: {"A", "C"}}
    px.pump("a")
    assert _reqs(log) == [("B", 0, False)]


def test_scalar_endgame_duplicates_cheapest_first():
    px, log = _loaded_engine()
    px.set_cost_map(0, [0, 5, 2], {"A": 0, "B": 1, "C": 2})
    px.pump("a")                                   # piece 0 -> A
    n0 = len(log)
    px._endgame("a")                               # duplicate to B and C
    assert _reqs(log, n0) == [("C", 0, True), ("B", 0, True)]


def test_scalar_without_cost_map_is_order_neutral():
    """No COST_MAP received: `_peer_cost` is identically 0 and the pump
    falls back to the historical (load, name) tie-break."""
    px, log = _loaded_engine()
    assert px._peer_cost("A") == px._peer_cost("ZZZ") == 0
    px.pump("a")
    assert _reqs(log) == [("A", 0, False)]         # name order, as before


# =================== batched hub selection preference =================== #
def test_batched_hub_prefers_same_island_holder():
    topo = Topology({"L": 0, "A": 0, "B": 1}, 2, [[0.0, 0.05],
                                                  [0.05, 0.0]])
    flipped = Topology({"L": 1, "A": 0, "B": 1}, 2, [[0.0, 0.05],
                                                     [0.05, 0.0]])
    for topology, want in ((None, "A"), (topo, "A"), (flipped, "B")):
        px, _ = _loaded_engine(n_pieces=4, holders=("A", "B"))
        hub = SwarmHub.mirror_scalar(px, "a")
        if topology is not None:
            hub.set_topology(topology)
        got = hub.decide_requests("a", "L", now=0.0)
        assert got, topology
        assert got[0][1] == want, topology


def test_batched_hub_cost_map_roundtrip():
    """set_topology(None) restores the flat decision set bit-identically
    (the cost matrix and per-row islands are fully cleared)."""
    px, _ = _loaded_engine(n_pieces=6, holders=("A", "B"))
    hub = SwarmHub.mirror_scalar(px, "a")
    flat = hub.decide_requests("a", "L", now=0.0)
    hub.set_topology(Topology({"L": 1, "A": 0, "B": 1}, 2,
                              [[0.0, 0.08], [0.08, 0.0]]))
    hub.set_topology(None)
    assert hub.decide_requests("a", "L", now=0.0) == flat


# ================== tracker COST_MAP + end-to-end ======================= #
def test_tracker_serves_cost_map_on_register():
    ids = ["server", "host"] + [f"L{i}" for i in range(4)]
    topo = Topology.make(ids, 2, seed=7)
    rt = SimRuntime(link=LinkModel(uplink_Bps=12.5e6,
                                   downlink_Bps=12.5e6),
                    topology=topo)
    rt.add_node(TrackerServer(config=TrackerConfig(ping_interval_s=2.0),
                              topology=topo))
    host = Agent("host", config=AgentConfig(work_timeout_s=600.0))
    rt.add_node(host)
    app = make_prime_app("mm-app", "host", 3, 6_000, n_parts=6,
                         sim_time_per_number=1e-4, swarm=True,
                         app_bytes=262_144, piece_bytes=32_768)
    host.host_app(app)
    leech = [Agent(f"L{i}", config=AgentConfig(work_timeout_s=600.0))
             for i in range(4)]
    for a in leech:
        rt.add_node(a)
    done = lambda: all("mm-app" in a.images for a in leech)
    rt.run(until=3_600, stop_when=done)
    assert done()
    assert rt.cross_isp_bytes > 0
    for a in leech:
        isl = topo.island_of(a.node_id)
        assert a.px.my_island == isl
        assert a.px.island_costs == topo.cost_row(isl)
        assert a.px.peer_islands == topo.islands


# =================== island-aligned chaos overlay ======================= #
@pytest.mark.parametrize("batched", [False, True])
def test_chaos_with_islands_still_replicates(batched):
    """Seeded FaultPlan whose partitions cut along island boundaries, on
    top of WAN latency + P4P selection: the swarm must still fully
    replicate (the cost bias decays to rarity when every same-island
    holder is cut or starved) and the run must see cross-ISP traffic."""
    from repro.core.chaos import ChaosScenario
    sc = ChaosScenario(seed=3, n_volunteers=8, n_pieces=12, n_parts=16,
                       image_bytes=96_000, real_image=False,
                       batched=batched, n_islands=3,
                       island_partitions=True).run()
    sc.check_invariants()
    rep = sc.report()
    assert rep["replicated"] and rep["done"]
    assert rep["cross_isp_bytes"] > 0


# ========================= bench_guard keys ============================= #
def test_bench_guard_flags_cross_isp_and_p99_regressions(tmp_path):
    from benchmarks.bench_guard import check

    def doc(cross, p99):
        return {"rows": [
            {"name": "ix_p4p", "metrics": {"cross_isp_bytes": cross,
                                           "p99_completion_s": p99,
                                           "done": True,
                                           "replicated": True}},
            {"name": "flat", "metrics": {"cross_isp_bytes": 0,
                                         "makespan_s": 10.0}}]}

    base, cur = tmp_path / "base.json", tmp_path / "cur.json"
    base.write_text(json.dumps(doc(1000, 50.0)))
    cur.write_text(json.dumps(doc(1200, 50.0)))     # +20% cross-ISP
    fails = check(str(base), str(cur), verbose=False)
    assert [(f[0], f[1]) for f in fails] == [("ix_p4p",
                                              "cross_isp_bytes")]
    cur.write_text(json.dumps(doc(1000, 60.0)))     # +20% p99
    fails = check(str(base), str(cur), verbose=False)
    assert [(f[0], f[1]) for f in fails] == [("ix_p4p",
                                              "p99_completion_s")]
    # a zero-valued baseline row (flat topology) is never compared
    cur.write_text(json.dumps(doc(1050, 52.0)))     # inside the band
    assert check(str(base), str(cur), verbose=False) == []


# ===================== Scenario IX economics smoke ====================== #
@pytest.mark.jax_slow
def test_scenario_ix_smoke_cuts_cross_isp_traffic():
    """N=64 / 4 islands: P4P selection must cut cross-ISP bytes by a
    wide margin without losing full replication (the CI-guarded
    acceptance numbers come from the benchmark rows; this pins the
    mechanism end-to-end in-process)."""
    from benchmarks.paper_tables import scenario_ix
    res = scenario_ix(verbose=False, n_volunteers=64, n_islands=4,
                      image_mb=8.0)
    assert res["naive"]["replicated"] and res["p4p"]["replicated"]
    assert res["cross_isp_reduction"] >= 5.0
    assert res["makespan_ratio"] <= 1.05
