"""Per-architecture smoke tests (reduced configs, CPU) + serve consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.jax_slow

from repro.configs.base import ARCH_IDS, get_config, reduced_config
from repro.models import model as M
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import init_params
from repro.training.train_state import init_train_state, make_train_step


def tiny_batch(cfg, B=2, S=32, key=0):
    k = jax.random.PRNGKey(key)
    batch = {}
    if cfg.is_encdec:
        St = max(S // cfg.encdec_tgt_ratio, 4)
        batch = {"enc_embeds": jax.random.normal(
                     k, (B, S, cfg.d_model), cfg.act_dtype) * 0.02,
                 "tokens": jax.random.randint(k, (B, St), 0, cfg.vocab_size),
                 "labels": jax.random.randint(k, (B, St), 0, cfg.vocab_size)}
    else:
        batch["labels"] = jax.random.randint(k, (B, S), 0, cfg.vocab_size)
        if cfg.input_kind == "embeds":
            batch["embeds"] = jax.random.normal(
                k, (B, S, cfg.d_model), cfg.act_dtype) * 0.02
        else:
            batch["tokens"] = jax.random.randint(k, (B, S), 0,
                                                 cfg.vocab_size)
    if cfg.mrope:
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_train_step_smoke(arch):
    cfg = reduced_config(get_config(arch))
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    batch = tiny_batch(cfg)
    step = jax.jit(make_train_step(cfg, AdamWConfig(warmup_steps=2)))
    state2, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), (arch, loss)
    # roughly ln(vocab) at random init
    assert 0.5 * np.log(cfg.vocab_size) < loss < 3.0 * np.log(cfg.vocab_size)
    assert int(state2["step"]) == 1
    # params actually moved
    p0 = jax.tree_util.tree_leaves(state["params"])[1]
    p1 = jax.tree_util.tree_leaves(state2["params"])[1]
    assert not np.allclose(np.asarray(p0), np.asarray(p1))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_microbatched_matches_plain(arch):
    cfg = reduced_config(get_config(arch)).replace(dtype="float32")
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    batch = tiny_batch(cfg, B=4, S=16)
    s1, m1 = jax.jit(make_train_step(cfg, AdamWConfig()))(state, batch)
    cfg2 = cfg.replace(micro_steps=2)
    s2, m2 = jax.jit(make_train_step(cfg2, AdamWConfig()))(state, batch)
    # microbatched grad == mean of micro grads; losses match closely
    assert float(m1["nll"]) == pytest.approx(float(m2["nll"]), rel=1e-4)
    l1 = jax.tree_util.tree_leaves(s1["params"])
    l2 = jax.tree_util.tree_leaves(s2["params"])
    err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(l1, l2))
    assert err < 5e-4, (arch, err)


@pytest.mark.parametrize("arch", ["internlm2-20b", "gemma3-12b",
                                  "mamba2-2.7b", "seamless-m4t-medium"])
def test_prefill_decode_matches_forward(arch):
    cfg = reduced_config(get_config(arch)).replace(dtype="float32")
    params = init_params(jax.random.PRNGKey(1), M.model_param_specs(cfg))
    B, S_total, S_prompt = 2, 12, 5
    key = jax.random.PRNGKey(2)
    toks = jax.random.randint(key, (B, S_total), 0, cfg.vocab_size)
    batch_full = {"tokens": toks}
    src = 8
    if cfg.is_encdec:
        batch_full["enc_embeds"] = jax.random.normal(
            key, (B, src, cfg.d_model), jnp.float32) * 0.1
    logits_full, _, _ = M.forward(cfg, params, dict(batch_full), mode="train")
    caches = init_params(jax.random.PRNGKey(0), M.cache_specs_tree(
        cfg, B, S_total, src_len=(src if cfg.is_encdec else S_total)))
    pb = {"tokens": toks[:, :S_prompt]}
    if cfg.is_encdec:
        pb["enc_embeds"] = batch_full["enc_embeds"]
    last, caches = M.prefill(cfg, params, pb, caches)
    errs = [float(jnp.max(jnp.abs(last - logits_full[:, S_prompt - 1])))]
    for i in range(S_prompt, S_total):
        lg, caches = M.decode_step(cfg, params, {"tokens": toks[:, i:i + 1]},
                                   caches)
        errs.append(float(jnp.max(jnp.abs(lg - logits_full[:, i]))))
    scale = float(jnp.max(jnp.abs(logits_full)))
    assert max(errs) / scale < 2e-3, (arch, errs)


def test_decode_with_per_slot_positions():
    """Continuous batching: two sequences at different positions."""
    cfg = reduced_config(get_config("internlm2-20b")).replace(dtype="float32")
    params = init_params(jax.random.PRNGKey(1), M.model_param_specs(cfg))
    B, S = 2, 10
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                              cfg.vocab_size)
    logits_full, _, _ = M.forward(cfg, params, {"tokens": toks},
                                  mode="train")
    caches = init_params(jax.random.PRNGKey(0),
                         M.cache_specs_tree(cfg, B, S))
    # row 0 prefilled to 4, row 1 prefilled to 7, via masked writes
    for i in range(7):
        idx = jnp.asarray([min(i, 4), min(i, 7)], jnp.int32)
        caches["index"] = idx
        step_toks = jnp.stack([toks[0, min(i, 4)], toks[1, min(i, 7)]])[:, None]
        lg, caches = M.decode_step(cfg, params, {"tokens": step_toks}, caches)
    # after the loop row0 is at 5... simply verify no NaN and shapes
    assert np.isfinite(np.asarray(lg)).all()


def test_param_counts_roughly_match_nameplates():
    import repro.models.model as MM
    expect = {"internlm2-20b": 20e9, "granite-8b": 8e9, "qwen3-14b": 14e9,
              "gemma3-12b": 12e9, "mamba2-2.7b": 2.7e9, "zamba2-7b": 7e9,
              "qwen2-vl-2b": 2e9}
    for arch, n in expect.items():
        cfg = get_config(arch)
        got = MM.count_params(cfg)
        assert 0.55 * n < got < 1.75 * n, (arch, got, n)


def test_moe_active_params():
    import repro.models.model as MM
    cfg = get_config("qwen3-moe-30b-a3b")
    total = MM.count_params(cfg)
    active = MM.count_params(cfg, active_only=True)
    assert 24e9 < total < 36e9, total
    assert active < 0.2 * total, (active, total)
