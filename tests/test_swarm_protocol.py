"""Piece-wise multi-seeder distribution through the live protocol (§V)."""
import pytest

pytestmark = pytest.mark.protocol

from repro.core import (Agent, AgentConfig, PieceInventory, PieceManifest,
                        SimRuntime, TrackerConfig, TrackerServer,
                        make_prime_app, register_executable,
                        resolve_executable)
from repro.core.runtime import LinkModel
from repro.core.swarm import rarest_first_order


# ----------------------- manifest / inventory unit --------------------- #
def test_piece_manifest_synthetic_and_sizes():
    m = PieceManifest.synthetic("a", total_bytes=10_000, piece_bytes=4096)
    assert m.n_pieces == 3
    assert m.piece_size(0) == 4096
    assert m.piece_size(2) == 10_000 - 2 * 4096
    assert len(set(m.piece_hashes)) == 3
    # identical params -> identical info hash; different app -> different
    assert m.manifest_hash == PieceManifest.synthetic(
        "a", 10_000, 4096).manifest_hash
    assert m.manifest_hash != PieceManifest.synthetic(
        "b", 10_000, 4096).manifest_hash


def test_piece_manifest_from_bytes_verifies():
    data = bytes(range(256)) * 40
    m = PieceManifest.from_bytes("x", data, piece_bytes=1024)
    inv = PieceInventory(m)
    assert not inv.complete
    # content-hashed manifest: the hashes are public metainfo, so a bare
    # proof (even the correct one) proves nothing — bytes are required
    assert m.content_hashed
    assert not inv.add(0, m.piece_hashes[0])
    assert not inv.add(1, "bogus-proof")
    # real byte slices verify by content re-hash; bogus bytes rejected
    assert inv.add(1, data=data[1024:2048])
    assert not inv.add(2, data=b"evil" * 256)
    assert 2 in inv.missing()
    for i in inv.missing():
        assert inv.add(i, data=data[i * 1024:(i + 1) * 1024])
    assert inv.complete
    assert inv.bitfield() == (1 << m.n_pieces) - 1   # compact int bitmask
    # synthetic manifests keep the proof path (simulation)
    s = PieceManifest.synthetic("x", 4096, 1024)
    assert not s.content_hashed
    assert PieceInventory(s).add(0, s.piece_hashes[0])


def test_rarest_first_order_policy():
    order = rarest_first_order([0, 1, 2, 3], {0: 5, 1: 1, 2: 3, 3: 1})
    assert order[:2] == [1, 3]           # rarest first
    assert order[-1] == 0                # most common last
    # offset staggers only tie-breaks
    shifted = rarest_first_order([0, 1, 2, 3], {0: 5, 1: 1, 2: 3, 3: 1},
                                 offset=2)
    assert set(shifted[:2]) == {1, 3}


def test_executable_registry_keyed_by_manifest_hash():
    register_executable("h123", run_fn=lambda p: p * 2,
                        cost_fn=lambda p, s: 1.0)
    entry = resolve_executable("h123")
    assert entry is not None and entry.run_fn(4) == 8
    assert resolve_executable("nope") is None
    # the old back-door into the runtime's node table is gone
    assert not hasattr(Agent, "_resolve_app")


# --------------------------- live protocol ----------------------------- #
def build_swarm(n_leechers=4, parts=24, image_mb=8.0, n_pieces=8,
                uplink_mbps=100.0, timeout=120.0):
    image = int(image_mb * 1e6)
    rt = SimRuntime(link=LinkModel(uplink_Bps=uplink_mbps * 1e6 / 8))
    server = TrackerServer(config=TrackerConfig(ping_interval_s=2.0))
    rt.add_node(server)
    host = Agent("host", config=AgentConfig(work_timeout_s=timeout))
    rt.add_node(host)
    app = make_prime_app("app", "host", 3, 24_000, n_parts=parts,
                         sim_time_per_number=1e-4, swarm=True,
                         app_bytes=image, piece_bytes=image // n_pieces)
    host.host_app(app)
    leechers = []
    for i in range(n_leechers):
        a = Agent(f"L{i}", config=AgentConfig(work_timeout_s=timeout))
        rt.add_node(a)
        leechers.append(a)
    return rt, server, host, app, leechers


def test_swarm_app_completes_with_replica_seeders():
    rt, server, host, app, leechers = build_swarm()
    rt.run(until=3600, stop_when=lambda: app.done)
    assert app.done
    # every leecher fetched + verified the full image and became a replica
    for l in leechers:
        assert "app" in l.images
        assert "app" in l.replicas
        inv = l.inventories["app"]
        assert inv.complete
    # tracker advertises the full seeder set, not just the origin
    row = server.app_list["app"]
    assert set(row.seeders) == {"host"} | {l.node_id for l in leechers}
    # results really are primes
    r0 = app.parts[0].results[0][1]
    assert 3 in r0 and 4 not in r0 and 5 in r0


def test_swarm_reduces_origin_uplink_vs_monolithic():
    def origin_bytes(swarm):
        image = int(8e6)
        rt = SimRuntime(link=LinkModel(uplink_Bps=12.5e6))
        rt.add_node(TrackerServer(config=TrackerConfig(ping_interval_s=2.0)))
        host = Agent("host", config=AgentConfig(work_timeout_s=600.0))
        rt.add_node(host)
        app = make_prime_app("app", "host", 3, 24_000, n_parts=24,
                             sim_time_per_number=1e-4, swarm=swarm,
                             app_bytes=image, piece_bytes=image // 8)
        host.host_app(app)
        for i in range(4):
            rt.add_node(Agent(f"L{i}",
                              config=AgentConfig(work_timeout_s=600.0)))
        rt.run(until=3600 * 4, stop_when=lambda: app.done)
        assert app.done
        return rt.tx_bytes.get("host", 0), rt.now()

    mono_bytes, mono_t = origin_bytes(swarm=False)
    swarm_bytes, swarm_t = origin_bytes(swarm=True)
    # the monolithic host re-ships the image per part; the swarm ships it
    # roughly once plus piece/protocol overheads
    assert swarm_bytes < mono_bytes / 4
    assert swarm_t <= mono_t


def test_origin_death_failover_to_replicas():
    rt, server, host, app, leechers = build_swarm(n_leechers=4, parts=30)
    # wait until at least one replica seeder formed
    rt.run(until=3600, stop_when=lambda: any(
        "app" in l.replicas for l in leechers))
    assert any("app" in l.replicas for l in leechers)
    del rt.nodes["host"]                 # origin dies mid-run
    rt.run(until=3600 * 4, stop_when=lambda: any(
        a.apps.get("app") and a.apps["app"].done for a in leechers))
    # the tracker promoted a replica instead of dropping the app …
    row = server.app_list.get("app")
    assert row is not None and row.host_id != "host"
    assert "host" not in row.seeders
    # … and the application completed under the new host
    promoted = [a for a in leechers if "app" in a.apps]
    assert promoted and promoted[0].apps["app"].done
    # leechers never STOPped the app
    assert all("app" not in l.stopped_apps for l in leechers)


def test_monolithic_app_still_dropped_on_host_death():
    # no replicas (swarm off): seed semantics preserved — host death kills
    rt = SimRuntime()
    server = TrackerServer(config=TrackerConfig(ping_interval_s=2.0))
    rt.add_node(server)
    host = Agent("host", config=AgentConfig(work_timeout_s=200.0))
    rt.add_node(host)
    app = make_prime_app("app", "host", 3, 500_000, n_parts=400,
                         sim_time_per_number=1e-4)
    host.host_app(app)
    leechers = [Agent(f"L{i}", config=AgentConfig(work_timeout_s=200.0))
                for i in range(2)]
    for a in leechers:
        rt.add_node(a)
    rt.run(until=20)
    del rt.nodes["host"]
    rt.run(until=rt.now() + 60)
    assert "app" not in server.app_list
    assert all("app" in l.stopped_apps for l in leechers)


def test_corrupt_piece_peer_is_ignored():
    rt, server, host, app, leechers = build_swarm(n_leechers=3)
    evil = leechers[0]

    def corrupt(msg):
        # serve garbage proofs for everything we hold
        from repro.core.messages import PIECE_DATA, Msg
        app_id = msg.payload["app_id"]
        piece_id = msg.payload["piece_id"]
        evil.swarm_peers[app_id].add(msg.src)
        evil.SEND(msg.src, Msg(PIECE_DATA, evil.node_id,
                               {"app_id": app_id, "piece_id": piece_id,
                                "proof": "garbage",
                                "mask": evil._our_bitfield(app_id)},
                               size_bytes=96))
    evil._on_piece_req = corrupt
    rt.run(until=3600, stop_when=lambda: app.done)
    assert app.done
    # honest leechers verified every piece against the manifest
    for l in leechers[1:]:
        inv = l.inventories["app"]
        assert inv.complete
        for pid in inv.have:
            assert l.manifests["app"].piece_hashes[pid] \
                == inv.manifest.piece_hashes[pid]


def test_tracker_orders_seeders_by_load():
    server = TrackerServer()

    class _RT:
        def now(self):
            return 0.0
    server.rt = _RT()
    from repro.core.messages import AppInfo
    row = AppInfo("a", "h", seeders=("s1", "s2", "s3"))
    server.app_list["a"] = row
    server.seeder_load["a"] = {"s1": 9, "s2": 0, "s3": 4}
    rows = server.READ()
    assert rows[0].seeders == ("s2", "s3", "s1")


def test_uplink_contention_serializes_bulk_only():
    from repro.core.messages import Msg
    from repro.core.runtime import Node

    got = []

    class Sink(Node):
        node_id = "sink"

        def on_message(self, msg):
            got.append((msg.payload["i"], self.rt.now()))

    link = LinkModel(uplink_Bps=1e6, base_latency_s=0.0,
                     bulk_threshold_bytes=1 << 16)
    rt = SimRuntime(link=link)
    rt.add_node(Sink())
    # two 1MB bulk sends from the same node serialise: ~1s and ~2s
    rt.send("sink", Msg("X", "src", {"i": 0}, size_bytes=1_000_000))
    rt.send("sink", Msg("X", "src", {"i": 1}, size_bytes=1_000_000))
    # a tiny control message bypasses the queue
    rt.send("sink", Msg("X", "src", {"i": 2}, size_bytes=64))
    rt.run()
    at = dict(got)
    assert at[0] == pytest.approx(1.0, rel=0.01)
    assert at[1] == pytest.approx(2.0, rel=0.01)
    assert at[2] < 0.1
    assert rt.tx_bytes["src"] == 2_000_064


# -------------- versioned manifests: tracker-side guards ---------------- #
def _tracker(members):
    server = TrackerServer()

    class _RT:
        def now(self):
            return 0.0

        def send(self, dst, msg):
            pass
    server.rt = _RT()
    server.members = set(members)
    return server


def test_tracker_write_never_rolls_back_manifest_revision():
    from repro.core.messages import AppInfo
    server = _tracker({"h", "s1"})
    m1 = PieceManifest.synthetic("a", 8_000, 1_000)
    m2 = PieceManifest.synthetic("a", 8_000, 1_000, version=2, prev=m1)
    server.WRITE(AppInfo("a", "h", seeders=("h",), manifest=m2))
    server.app_list["a"].seeders = ("h", "s1")
    # a stale upsert (a STATUS that raced the upgrade) carries v1: the
    # row keeps the v2 metainfo and the merged seeder set
    server.WRITE(AppInfo("a", "h", seeders=("h",), manifest=m1))
    row = server.app_list["a"]
    assert row.manifest is m2
    assert set(row.seeders) == {"h", "s1"}
    # the host republishing a NEWER revision via plain upsert resets the
    # seeder set — everyone else holds the superseded image
    m3 = PieceManifest.synthetic("a", 8_000, 1_000, version=3, prev=m2)
    server.WRITE(AppInfo("a", "h", seeders=("h",), manifest=m3))
    row = server.app_list["a"]
    assert row.manifest is m3 and row.seeders == ("h",)


def test_tracker_rejects_stale_revision_completion():
    from repro.core.messages import AppInfo, Msg, SEEDER_UPDATE
    server = _tracker({"h", "v1"})
    m1 = PieceManifest.synthetic("a", 8_000, 1_000)
    m2 = PieceManifest.synthetic("a", 8_000, 1_000, version=2, prev=m1)
    server.app_list["a"] = AppInfo("a", "h", seeders=("h",), manifest=m2)
    # v1 finished the OLD image just as the upgrade landed: admitting it
    # would route leechers to a node serving superseded pieces
    server.RECV(Msg(SEEDER_UPDATE, "v1",
                    {"app_id": "a", "seeder": "v1",
                     "manifest_hash": m1.manifest_hash}))
    assert server.app_list["a"].seeders == ("h",)
    # the same volunteer completing the CURRENT revision is admitted
    server.RECV(Msg(SEEDER_UPDATE, "v1",
                    {"app_id": "a", "seeder": "v1",
                     "manifest_hash": m2.manifest_hash}))
    assert set(server.app_list["a"].seeders) == {"h", "v1"}
