"""Checkpoint store, data pipeline, compression, HLO analyzer."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.jax_slow

from repro.checkpoint.store import CheckpointStore, async_save
from repro.data.pipeline import (LeasedBatchPipeline, SyntheticTokens,
                                 TokenFileStore)
from repro.launch import hlo_analysis
from repro.optim.compression import (CompressionConfig, compress_tree,
                                     compression_ratio)


# ------------------------------ checkpoint ----------------------------- #
def test_checkpoint_roundtrip(tmp_path):
    store = CheckpointStore(str(tmp_path), piece_bytes=1024)
    tree = {"a": np.arange(12, dtype=np.float32).reshape(3, 4),
            "b": {"c": np.ones((100,), np.int32),
                  "d": np.float32(3.5)}}
    store.save(3, tree, extra={"note": "hi"})
    out, extra = store.restore(tree)
    assert extra["note"] == "hi"
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_latest(tmp_path):
    store = CheckpointStore(str(tmp_path), keep_last=2)
    tree = {"x": np.zeros(4, np.float32)}
    for s in (1, 2, 3, 4):
        store.save(s, tree)
    assert store.steps() == [3, 4]
    assert store.latest_step() == 4


def test_checkpoint_async_and_uncommitted_ignored(tmp_path):
    store = CheckpointStore(str(tmp_path))
    tree = {"x": np.ones(8, np.float32)}
    th = async_save(store, 7, tree)
    th.join(30)
    assert store.latest_step() == 7
    # a torn write (no COMMITTED marker) must be invisible
    os.makedirs(tmp_path / "step_00000009")
    assert store.latest_step() == 7


# ------------------------------ data ----------------------------------- #
def test_synthetic_tokens_deterministic():
    src = SyntheticTokens(vocab_size=100, seed=1)
    a = src.piece(5, 2, 8)
    b = src.piece(5, 2, 8)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_token_file_store_roundtrip(tmp_path):
    store = TokenFileStore(str(tmp_path))
    toks = np.arange(1000, dtype=np.uint32)
    store.write_shard(0, toks)
    out = store.read_shard(0)
    np.testing.assert_array_equal(toks, out)
    piece = store.piece(0, 2, 8, vocab_size=500)
    assert piece["tokens"].shape == (2, 8)


def test_pipeline_resume_no_replay():
    src = SyntheticTokens(vocab_size=50)
    p1 = LeasedBatchPipeline(src, batch=2, seq=8)
    seen = []
    for _ in range(5):
        iid, b = p1.next_batch()
        seen.append(b["tokens"][0, 0])
        p1.complete(iid)
    sd = p1.state_dict()
    p2 = LeasedBatchPipeline(src, batch=2, seq=8)
    p2.load_state_dict(sd)
    iid, b6 = p2.next_batch()
    # continues from piece 5, not replaying piece 0
    ref = src.piece(5, 2, 8)
    np.testing.assert_array_equal(b6["tokens"], ref["tokens"])


# ------------------------------ compression ---------------------------- #
def test_int8_compression_error_feedback_converges():
    cfg = CompressionConfig(scheme="int8")
    g = jnp.asarray(np.random.RandomState(0).randn(64, 64), jnp.float32)
    err = None
    acc_true = np.zeros_like(g)
    acc_comp = np.zeros_like(g)
    for _ in range(20):
        comp, err = compress_tree(g, err, cfg)
        acc_true += np.asarray(g)
        acc_comp += np.asarray(comp)
    # with error feedback the accumulated sums track closely
    rel = np.max(np.abs(acc_true - acc_comp)) / np.max(np.abs(acc_true))
    assert rel < 0.02, rel
    assert compression_ratio(cfg) == 4.0


def test_topk_compression_keeps_largest():
    cfg = CompressionConfig(scheme="topk", topk_frac=0.1,
                            error_feedback=False)
    g = jnp.asarray(np.random.RandomState(1).randn(100), jnp.float32)
    comp, _ = compress_tree(g, None, cfg)
    comp = np.asarray(comp)
    kept = np.nonzero(comp)[0]
    assert 5 <= len(kept) <= 15
    thresh = np.sort(np.abs(np.asarray(g)))[-len(kept)]
    assert np.all(np.abs(np.asarray(g))[kept] >= thresh - 1e-6)


# ------------------------------ hlo analyzer --------------------------- #
def test_hlo_trip_count_aware_flops():
    def f(x):
        def body(c, _):
            return jnp.tanh(c @ x), None
        y, _ = jax.lax.scan(body, x, None, length=9)
        return y.sum()

    x = jnp.ones((64, 64), jnp.float32)
    compiled = jax.jit(f).lower(x).compile()
    res = hlo_analysis.analyze_hlo(compiled.as_text())
    # 9 matmuls of 2*64^3, vs cost_analysis' body-once count
    expect = 9 * 2 * 64 ** 3
    assert res["dot_flops"] == pytest.approx(expect, rel=0.01), res
    ca = compiled.cost_analysis()
    if isinstance(ca, list):           # jax 0.4.x returns [dict]
        ca = ca[0] if ca else {}
    xla_flops = ca.get("flops", 0)
    assert xla_flops < res["dot_flops"]   # the very bug we correct


def test_hlo_collective_accounting():
    import subprocess, sys, os
    # collectives need >1 device: subprocess with 4 host devices
    code = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch import hlo_analysis
mesh = jax.make_mesh((4,), ("d",))
x = jax.ShapeDtypeStruct((64, 64), jnp.float32,
                         sharding=NamedSharding(mesh, P("d", None)))
def f(x):
    return jnp.sum(x)
compiled = jax.jit(f).lower(x).compile()
res = hlo_analysis.analyze_hlo(compiled.as_text(), n_devices=4)
assert res["collective_bytes"] > 0, res
print("OK", res["collectives"])
'''
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300, env=env)
    assert p.returncode == 0 and "OK" in p.stdout, p.stderr[-2000:]
