"""Swarm-served checkpoints: image codec, restore fidelity, from_swarm.

The tentpole loop: `CheckpointStore.save` emits a packed step image +
piece manifest, an origin agent hosts it as a pure-replication swarm
Application, replicas leech it through the ordinary PieceExchange, and
`restore_from_agent` / `ServingEngine.from_swarm` reassemble, content-
verify and restore a tree byte-identical to an origin disk restore.
"""
import os

import pytest

jax = pytest.importorskip("jax")
import numpy as np

pytestmark = pytest.mark.jax_slow

from repro.checkpoint.store import (IMAGE_MAGIC, CheckpointStore,
                                    async_save, pack_step_image,
                                    unpack_step_image)
from repro.checkpoint.swarm_restore import (checkpoint_application,
                                            restore_from_agent,
                                            restore_image, verify_image)
from repro.core import (Agent, AgentConfig, LinkModel, PieceInventory,
                        PieceManifest, SimRuntime, TrackerConfig,
                        TrackerServer)


def _tree(seed: int = 0):
    rng = np.random.default_rng(seed)
    return {
        "wte": rng.standard_normal((64, 16)).astype(np.float32),
        "block": {"w1": rng.standard_normal((16, 32)).astype(np.float32),
                  "b1": np.zeros((32,), np.float32),
                  "scale": rng.standard_normal((16,)).astype(np.float16)},
        "step_count": np.asarray(7, np.int32),
    }


def _trees_equal(a, b) -> bool:
    fa = jax.tree_util.tree_leaves(a)
    fb = jax.tree_util.tree_leaves(b)
    if len(fa) != len(fb):
        return False
    for x, y in zip(fa, fb):
        x, y = np.asarray(x), np.asarray(y)
        if x.shape != y.shape or x.dtype != y.dtype:
            return False
        if x.tobytes() != y.tobytes():
            return False
    return True


# ------------------------- image codec ---------------------------------- #
def test_step_image_roundtrip(tmp_path):
    store = CheckpointStore(str(tmp_path / "src"), swarm_piece_bytes=4096)
    tree = _tree()
    store.save(3, tree, extra={"lr": 0.1})
    image = store.pack_image(3)
    assert image.startswith(IMAGE_MAGIC)
    dest = str(tmp_path / "dst" / "step_00000003")
    files = unpack_step_image(image, dest)
    assert "manifest.json" in files
    restored, extra = CheckpointStore(str(tmp_path / "dst")).restore(
        tree, step=3)
    assert extra["lr"] == 0.1
    assert _trees_equal(tree, restored)


def test_unpack_rejects_malformed_images(tmp_path):
    store = CheckpointStore(str(tmp_path / "s"))
    store.save(0, _tree())
    image = store.pack_image(0)
    with pytest.raises(ValueError):
        unpack_step_image(b"NOTMAGIC" + image, str(tmp_path / "a"))
    with pytest.raises(ValueError):
        unpack_step_image(image[:-10], str(tmp_path / "b"))
    with pytest.raises(ValueError):
        unpack_step_image(image + b"junk", str(tmp_path / "c"))


def test_save_emits_swarm_manifest(tmp_path):
    store = CheckpointStore(str(tmp_path), swarm_piece_bytes=2048)
    store.save(5, _tree())
    assert os.path.exists(os.path.join(store.step_dir(5), "swarm.json"))
    pm = store.swarm_manifest(5)
    assert pm.content_hashed and pm.piece_bytes == 2048
    # the persisted metainfo matches a fresh re-hash of the packed image
    re = PieceManifest.from_bytes(pm.app_id, store.pack_image(5), 2048)
    assert re.manifest_hash == pm.manifest_hash
    # the image content-verifies against the manifest
    assert verify_image(store.pack_image(5), pm)


def test_async_save_then_swarm_manifest(tmp_path):
    store = CheckpointStore(str(tmp_path), swarm_piece_bytes=4096)
    tree = _tree(seed=2)
    th = async_save(store, 9, tree)
    th.join()
    pm = store.swarm_manifest(9)
    params, _ = restore_image(store.pack_image(9), pm, tree,
                              workdir=str(tmp_path / "w"))
    assert _trees_equal(tree, params)


# ---------------------- corruption rejection ----------------------------- #
def test_corrupt_piece_rejected_by_inventory(tmp_path):
    store = CheckpointStore(str(tmp_path), swarm_piece_bytes=1024)
    store.save(0, _tree())
    image = store.pack_image(0)
    pm = store.swarm_manifest(0)
    inv = PieceInventory(pm)
    good = bytes(image[:pm.piece_size(0)])
    bad = bytes([good[0] ^ 0xFF]) + good[1:]
    assert not inv.add(0, data=bad)          # content re-hash mismatch
    assert not inv.add(0, proof=pm.piece_hashes[0])  # bare proof refused
    assert inv.add(0, data=good)
    assert inv.has(0)


def test_restore_rejects_tampered_image(tmp_path):
    store = CheckpointStore(str(tmp_path), swarm_piece_bytes=1024)
    tree = _tree()
    store.save(0, tree)
    image = bytearray(store.pack_image(0))
    pm = store.swarm_manifest(0)
    image[len(image) // 2] ^= 0x01
    assert not verify_image(bytes(image), pm)
    with pytest.raises(ValueError, match="content verification"):
        restore_image(bytes(image), pm, tree, workdir=str(tmp_path / "w"))


# ------------------- fidelity through a real swarm ----------------------- #
def _swarm_fetch(store, tmp_path, n_replicas=2):
    """Origin hosts the committed step; replicas leech it. Returns the
    ready replica agents."""
    rt = SimRuntime(link=LinkModel(uplink_Bps=12.5e6, downlink_Bps=12.5e6))
    rt.add_node(TrackerServer(config=TrackerConfig(ping_interval_s=1.0)))
    cfg = dict(work_timeout_s=60.0, status_interval_s=0.5,
               piece_timeout_s=3.0, replicate_completed=True)
    origin = Agent("origin", config=AgentConfig(**cfg))
    rt.add_node(origin)
    app = checkpoint_application(store, host_id="origin")
    origin.host_app(app)
    replicas = [Agent(f"R{i}", config=AgentConfig(**cfg))
                for i in range(n_replicas)]
    for a in replicas:
        rt.add_node(a)
    rt.run(until=600,
           stop_when=lambda: all(app.app_id in a.images for a in replicas))
    assert all(app.app_id in a.images for a in replicas)
    return app, replicas


def test_swarm_restore_identical_to_origin_restore(tmp_path):
    store = CheckpointStore(str(tmp_path / "origin_store"),
                            swarm_piece_bytes=8192)
    tree = _tree(seed=3)
    store.save(12, tree, extra={"tokens_seen": 1 << 20})
    app, replicas = _swarm_fetch(store, tmp_path)
    origin_params, origin_extra = store.restore(tree, step=12)
    for i, rep in enumerate(replicas):
        params, extra = restore_from_agent(
            rep, app.app_id, tree, workdir=str(tmp_path / f"rep{i}"))
        assert extra == origin_extra
        assert _trees_equal(origin_params, params)
    # ready gate: an agent that never completed the set must be refused
    fresh = Agent("late", config=AgentConfig())
    with pytest.raises(RuntimeError, match="ready gate"):
        restore_from_agent(fresh, app.app_id, tree)


def test_serving_engine_from_swarm(tmp_path):
    from repro.configs.base import get_config, reduced_config
    from repro.models import model as M
    from repro.parallel.sharding import init_params
    from repro.serving.engine import ServeConfig, ServingEngine

    cfg = reduced_config(get_config("granite-8b")).replace(
        dtype="float32", vocab_size=128, d_model=32, num_heads=4,
        num_kv_heads=2, head_dim=8, d_ff=64)
    params = init_params(jax.random.PRNGKey(0), M.model_param_specs(cfg))
    store = CheckpointStore(str(tmp_path / "store"),
                            swarm_piece_bytes=16 << 10)
    store.save(1, params, extra={"step": 1})
    app, (replica, *_) = _swarm_fetch(store, tmp_path, n_replicas=1)
    eng = ServingEngine.from_swarm(
        cfg, params, ServeConfig(slots=2, max_len=64),
        agent=replica, app_id=app.app_id,
        workdir=str(tmp_path / "restore"))
    assert eng.restore_extra == {"step": 1}
    assert _trees_equal(params, eng.params)
