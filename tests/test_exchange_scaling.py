"""Bitmask-native swarm hot paths: incremental availability bookkeeping,
differential equivalence with the reference implementation, rolling-rate
choke ranking, piece-cache rescan, zero-copy images, timer versioning."""
import random

import numpy as np
import pytest

pytestmark = pytest.mark.protocol

from repro.core import (Agent, AgentConfig, LinkModel, Msg, PieceExchange,
                        PieceManifest, RollingRate, SimRuntime,
                        TrackerConfig, TrackerServer, iter_bits,
                        make_prime_app, rarest_first_order,
                        rarest_first_order_np)
from repro.core.directory import AgentDirs
from repro.core.messages import HAVE, PIECE_DATA, PIECE_REQ, UNCHOKE
from repro.core.runtime import Node


def _engine(node_id="L", incremental=True, clock=None, dirs=None, **over):
    cfg = AgentConfig(**over)
    log = []
    px = PieceExchange(node_id, cfg,
                       send=lambda dst, msg: log.append((dst, msg)),
                       now=(lambda: clock[0]) if clock else (lambda: 0.0),
                       tracker_id="server", dirs=dirs)
    px.use_incremental = incremental
    return px, log


# ------------------ differential: availability array ------------------- #
def _naive_avail(px, app_id, n_pieces):
    """Recompute availability from scratch out of the engine's raw state:
    full-seeder count plus per-piece partial-holder counts."""
    full = (1 << n_pieces) - 1
    avail = np.zeros(n_pieces, dtype=np.int32)
    for mask in px.peer_masks.get(app_id, {}).values():
        for p in iter_bits(mask & full):
            avail[p] += 1
    avail += np.int32(len(px.full_seeders.get(app_id, ())))
    return avail


def test_incremental_availability_matches_naive_recompute():
    """500 randomized HAVE / SEEDER_UPDATE / PEER_GONE events: the
    incrementally maintained count array stays byte-identical to a naive
    recompute after every single event."""
    n_pieces = 96
    px, _ = _engine()
    manifest = PieceManifest.synthetic("a", n_pieces * 500, 500)
    px.join("a", manifest)
    rng = random.Random(7)
    peers = [f"P{i}" for i in range(24)]
    for step in range(500):
        roll = rng.random()
        if roll < 0.70:
            # masks occasionally carry out-of-range bits (a buggy or
            # malicious announce); they must be ignored consistently
            mask = rng.getrandbits(n_pieces + 8)
            px.on_have(Msg(HAVE, rng.choice(peers),
                           {"app_id": "a", "mask": mask}))
        elif roll < 0.85:
            k = rng.randrange(0, 6)
            px.note_full_seeders("a", set(rng.sample(peers, k)))
        else:
            px.on_peer_gone(rng.choice(peers))
        got = px.avail_array("a")
        want = _naive_avail(px, "a", n_pieces)
        assert got.dtype == np.int32
        assert got.tobytes() == want.tobytes(), f"diverged at step {step}"


def test_pre_manifest_garbage_mask_survives_join_and_departure():
    """A HAVE can precede the manifest; its mask is stored untrimmed.
    Learning the manifest, promoting the peer, and the peer's departure
    must all ignore the out-of-range bits instead of corrupting (or
    crashing on) the availability counts."""
    n_pieces = 8
    manifest = PieceManifest.synthetic("a", n_pieces * 100, 100)
    px, _ = _engine()
    garbage = (1 << 40) | 0b101          # bits far beyond n_pieces
    px.on_have(Msg(HAVE, "P0", {"app_id": "a", "mask": garbage}))
    px.on_have(Msg(HAVE, "P1", {"app_id": "a",
                                "mask": (1 << 33) | manifest.full_mask}))
    px.interested["a"].add("P1")         # INTERESTED raced ahead too
    px.unchoked["a"].add("P1")
    px.join("a", manifest)
    # P1's in-range holdings are complete: promoted despite garbage bits,
    # and the late promotion still releases its upload slot
    assert "P1" in px.full_seeders["a"]
    assert "P1" not in px.interested["a"]
    assert "P1" not in px.unchoked["a"]
    want = np.zeros(n_pieces, dtype=np.int32)
    want[[0, 2]] += 1                    # P0's in-range bits
    want += 1                            # P1's partial-holder counts
    want += 1                            # …plus its full-seeder constant
    assert px.avail_array("a").tobytes() == want.tobytes()
    px.on_peer_gone("P0")                # must not IndexError
    px.on_peer_gone("P1")
    want = np.zeros(n_pieces, dtype=np.int32)
    assert px.avail_array("a").tobytes() == want.tobytes()
    # departed peers' rate estimators are dropped as well
    px._credit_from("P2", 1_000)
    px.on_peer_gone("P2")
    assert "P2" not in px.rate_from


def test_rarest_first_order_np_matches_scalar():
    rng = random.Random(3)
    for _ in range(50):
        n = rng.randrange(1, 120)
        counts = np.array([rng.randrange(0, 6) for _ in range(n)],
                          dtype=np.int32)
        missing = sorted(rng.sample(range(n), rng.randrange(0, n + 1)))
        off = rng.randrange(0, 300)
        avail = {p: int(counts[p]) for p in range(n)}
        assert rarest_first_order_np(missing, counts, offset=off,
                                     n_pieces=n) \
            == rarest_first_order(missing, avail, offset=off, n_pieces=n)


def test_fast_pump_issues_identical_requests_to_reference():
    """Drive two engines (incremental vs pre-optimization reference)
    through the same randomized event trace; every PIECE_REQ and the
    pending-request tables must match exactly."""
    n_pieces = 64
    manifest = PieceManifest.synthetic("a", n_pieces * 1000, 1000)
    fast, fast_log = _engine(incremental=True, piece_pipeline=6)
    ref, ref_log = _engine(incremental=False, piece_pipeline=6)
    rng = random.Random(23)
    peers = [f"P{i}" for i in range(16)]
    for px in (fast, ref):
        px.join("a", manifest)
        px.note_full_seeders("a", set(peers[:2]))
    for step in range(300):
        roll = rng.random()
        if roll < 0.5:
            ev = Msg(HAVE, rng.choice(peers),
                     {"app_id": "a", "mask": rng.getrandbits(n_pieces)})
            fast.on_have(ev)
            ref.on_have(ev)
        elif roll < 0.8:
            ev = Msg(UNCHOKE, rng.choice(peers), {"app_id": "a"})
            fast.on_unchoke(ev)
            ref.on_unchoke(ev)
        else:
            gone = rng.choice(peers)
            fast.on_peer_gone(gone)
            ref.on_peer_gone(gone)
        assert fast.pending["a"] == ref.pending["a"], f"step {step}"
        assert dict(fast.peer_load) == dict(ref.peer_load), f"step {step}"
    fast_reqs = [(d, m.payload) for d, m in fast_log if m.kind == PIECE_REQ]
    ref_reqs = [(d, m.payload) for d, m in ref_log if m.kind == PIECE_REQ]
    assert fast_reqs == ref_reqs and len(fast_reqs) > 10


def test_peer_load_cleared_when_loaded_peer_departs():
    px, log = _engine(piece_pipeline=2)
    manifest = PieceManifest.synthetic("a", 4_000, 1_000)
    px.join("a", manifest)
    px.note_full_seeders("a", {"A", "B"})
    px.unchoked_by["a"] |= {"A", "B"}
    px.pump("a")
    assert px.peer_load["A"] == 1 and px.peer_load["B"] == 1
    assert len(px.pending["a"]) == 2
    px.on_peer_gone("A")
    # the departed peer's load entry is gone, not just decremented …
    assert "A" not in px.peer_load
    # … and its in-flight request moved to the surviving holder
    assert all(set(asked) == {"B"} for asked in px.pending["a"].values())
    assert px.peer_load["B"] == 1


# ------------------- rolling-rate rechoke ranking ---------------------- #
def test_rolling_rate_estimator_decays_and_stays_bounded():
    rr = RollingRate(window_s=10.0)
    rr.add(0.0, 1000)
    assert rr.rate(1.0) == pytest.approx(100.0)
    assert rr.rate(9.9) == pytest.approx(100.0)
    assert rr.rate(10.1) == 0.0
    # pruning happens on add() too: an estimator that is only ever fed
    # (never ranked) must not retain one entry per transfer forever
    for i in range(1_000):
        rr.add(float(i), 10)
    assert len(rr._events) <= 11
    assert rr.rate(999.0) == pytest.approx(10.0 * 10 / 10.0)


def test_rechoke_prefers_recently_fast_peer_over_stale_fast_peer():
    """Regression for the ROADMAP open item: a peer that moved bytes long
    ago (old-fast) must lose its regular slot to one moving bytes now
    (new-slow-starter), which cumulative counters never allowed."""
    clock = [0.0]
    px, log = _engine("S", clock=clock, upload_slots=2, optimistic_every=99,
                      rate_window_s=20.0)
    manifest = PieceManifest.synthetic("a", 8_000, 1_000)
    px.add_local_app("a", manifest)
    for peer in ("OLD", "NEW", "IDLE"):
        px.on_interested(Msg("INTERESTED", peer, {"app_id": "a"}))
    # t=0: OLD serves us a lot; NEW nothing yet
    px._credit_from("OLD", 50_000)
    clock[0] = 1.0
    px.rechoke()
    regular = px.unchoked["a"] - {px.opt_unchoked.get("a")}
    assert regular == {"OLD"}
    # t=100: OLD went idle (outside the 20s window); NEW serves a little
    clock[0] = 100.0
    px._credit_from("NEW", 2_000)
    px.rechoke()
    regular = px.unchoked["a"] - {px.opt_unchoked.get("a")}
    assert regular == {"NEW"}
    # cumulative totals still favour OLD — the ranking must not
    assert px.bytes_from["OLD"] > px.bytes_from["NEW"]


# --------------------- piece-cache rescan on restart ------------------- #
def test_piece_cache_rescan_restores_partial_and_drops_corrupt(tmp_path):
    image = bytes((i * 13 + 5) % 256 for i in range(8_192))
    manifest = PieceManifest.from_bytes("app", image, piece_bytes=2_048)
    assert manifest.n_pieces == 4
    dirs = AgentDirs(str(tmp_path), "A1")
    # a previous run cached pieces 0 and 2 intact, wrote garbage for 1,
    # and left a foreign file behind
    dirs.save_piece("app", 0, image[:2_048])
    dirs.save_piece("app", 1, b"\xff" * 2_048)            # corrupt
    dirs.save_piece("app", 2, image[4_096:6_144])
    dirs.save_piece("app", 9, b"junk")                    # out of range
    px, log = _engine(dirs=dirs)
    px.join("app", manifest)
    inv = px.inventories["app"]
    # intact pieces restored without any network fetch; bad ones dropped
    assert inv.have == {0, 2}
    assert dirs.load_piece("app", 1) is None
    assert dirs.load_piece("app", 9) is None
    # the join announce advertises the restored holdings
    have = [m for d, m in log if m.kind == HAVE and d == "server"]
    assert have and have[0].payload["mask"] == 0b101
    # only the genuinely missing pieces are fetched; completion reuses the
    # cached pieces byte-for-byte
    px.note_full_seeders("app", {"S"})
    px.unchoked_by["app"].add("S")
    px.pump("app")
    # serve each request as it is issued (one in flight per holder)
    for _ in range(4):
        if inv.complete:
            break
        reqs = [m.payload["piece_id"] for d, m in log
                if m.kind == PIECE_REQ]
        px.on_piece_data(Msg(PIECE_DATA, "S", {
            "app_id": "app", "piece_id": reqs[-1],
            "data": image[reqs[-1] * 2_048:(reqs[-1] + 1) * 2_048]}))
    assert inv.complete
    asked = {m.payload["piece_id"] for d, m in log if m.kind == PIECE_REQ}
    assert asked == {1, 3}               # cached pieces never re-fetched
    assert px.assembled_image("app") == image


def test_piece_cache_rescan_full_cache_completes_without_fetch(tmp_path):
    image = bytes(range(256)) * 16
    manifest = PieceManifest.from_bytes("app2", image, piece_bytes=1_024)
    dirs = AgentDirs(str(tmp_path), "A2")
    for pid in range(manifest.n_pieces):
        dirs.save_piece("app2", pid,
                        image[pid * 1_024:(pid + 1) * 1_024])
    px, log = _engine(dirs=dirs)
    done = []
    px.on_image_complete = lambda *a: done.append(a)
    px.join("app2", manifest)
    assert done and done[0][0] == "app2"
    assert "app2" in px.complete and "app2" not in px.fetching
    assert not any(m.kind == PIECE_REQ for _, m in log)
    assert px.assembled_image("app2") == image


# ------------------- zero-copy shared image buffers -------------------- #
def test_sim_real_image_replicas_share_one_interned_buffer():
    image = bytes((i * 31 + 7) % 256 for i in range(262_144))
    rt = SimRuntime(link=LinkModel(uplink_Bps=12.5e6))
    rt.add_node(TrackerServer(config=TrackerConfig(ping_interval_s=2.0)))
    host = Agent("host", config=AgentConfig(work_timeout_s=600.0))
    rt.add_node(host)
    app = make_prime_app("zc-app", "host", 3, 6_000, n_parts=6,
                         sim_time_per_number=1e-4, swarm=True,
                         piece_bytes=32_768, image=image)
    host.host_app(app)
    leechers = [Agent(f"L{i}", config=AgentConfig(work_timeout_s=600.0))
                for i in range(3)]
    for a in leechers:
        rt.add_node(a)
    rt.run(until=3600, stop_when=lambda: all(
        "zc-app" in a.images for a in leechers))
    base = host.px.image_bytes("zc-app")
    assert isinstance(base, memoryview)
    for l in leechers:
        mv = l.px.image_bytes("zc-app")
        # every replica's image is a view over the SAME buffer object —
        # sim memory stays O(image), not O(N·image)
        assert mv.obj is base.obj
        assert l.px.assembled_image("zc-app") == image
    # pieces served from the origin were zero-copy slices as well
    payload = host.px._piece_payload("zc-app", 1)
    assert isinstance(payload, memoryview) and payload.obj is base.obj


# ----------------------- timer version counters ------------------------ #
def test_sim_timer_latest_set_wins_and_cancel_is_bounded():
    rt = SimRuntime()
    fires = []

    class T(Node):
        node_id = "t"

        def on_timer(self, name):
            fires.append((name, self.rt.now()))

    rt.add_node(T())
    # re-setting the same one-shot supersedes the earlier arm
    rt.set_timer("t", "x", 1.0)
    rt.set_timer("t", "x", 2.0)
    rt.run()
    assert fires == [("x", 2.0)]
    # cancellation
    fires.clear()
    rt.set_timer("t", "y", 1.0)
    rt.cancel_timer("t", "y")
    rt.run()
    assert fires == []
    # a periodic timer stops after cancel, and repeated set/cancel cycles
    # keep exactly one bookkeeping entry per key (no tombstone growth)
    for _ in range(50):
        rt.set_timer("t", "z", 0.5, periodic=True)
        rt.cancel_timer("t", "z")
    assert len(rt._timer_ver) == 3      # keys x, y, z — not 50 tombstones
    fires.clear()
    rt.set_timer("t", "z", 0.5, periodic=True)
    rt.run(until=rt.now() + 1.6)
    assert len(fires) == 3
    rt.cancel_timer("t", "z")
    n = len(fires)
    rt.run(until=rt.now() + 5.0)
    assert len(fires) == n


# ------------------------- scenario VII smoke -------------------------- #
def test_scenario_vii_flash_crowd_smoke():
    from benchmarks.paper_tables import scenario_vii
    res = scenario_vii(verbose=False, n_volunteers=8, image_mb=4.0,
                       n_pieces=8)
    assert res["done"] and res["replicated"]
    assert res["replicas"] == 8
    assert res["events"] > 0 and res["events_per_sec"] > 0
    assert res["peak_rss_mb"] > 0
    assert res["full_replication_s"] >= res["makespan_s"] > 0
