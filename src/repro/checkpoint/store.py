"""Sharded checkpointing with torrent-style restore.

Layout:
  <root>/step_<n>/manifest.json       tree structure, shapes, dtypes, pieces
  <root>/step_<n>/piece_<i>.npz       flat-chunked payload pieces
  <root>/step_<n>/COMMITTED           write barrier marker

Pieces (not per-tensor files) are the unit of both I/O and swarm exchange:
on restore in a multi-pod job only the seeder pod reads from the store;
every other pod receives pieces over the interconnect via
parallel/weight_torrent (ppermute ring) or host-side via core/swarm's
rarest-first plan.  `async_save` runs serialisation off-thread so the train
loop never blocks (the step's arrays are snapshotted to host first).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


class CheckpointStore:
    def __init__(self, root: str, piece_bytes: int = 64 << 20,
                 keep_last: int = 3):
        self.root = root
        self.piece_bytes = piece_bytes
        self.keep_last = keep_last
        os.makedirs(root, exist_ok=True)

    # ------------------------------------------------------------------ #
    def save(self, step: int, tree, extra: Optional[dict] = None) -> str:
        d = os.path.join(self.root, f"step_{step:08d}")
        tmp = d + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        entries = _flatten_with_paths(tree)
        manifest = {"step": step, "extra": extra or {}, "leaves": [],
                    "pieces": []}
        # pack leaves into pieces
        piece, piece_sz, piece_idx = {}, 0, 0
        for key, leaf in entries:
            arr = np.asarray(leaf)
            manifest["leaves"].append({
                "key": key, "shape": list(arr.shape), "dtype": str(arr.dtype),
                "piece": piece_idx, "name": f"a{len(piece)}"})
            piece[f"a{len(piece)}"] = arr
            piece_sz += arr.nbytes
            if piece_sz >= self.piece_bytes:
                np.savez(os.path.join(tmp, f"piece_{piece_idx:05d}.npz"),
                         **piece)
                manifest["pieces"].append(piece_idx)
                piece, piece_sz = {}, 0
                piece_idx += 1
        if piece:
            np.savez(os.path.join(tmp, f"piece_{piece_idx:05d}.npz"), **piece)
            manifest["pieces"].append(piece_idx)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, "COMMITTED"), "w") as f:
            f.write(str(time.time()))
        if os.path.isdir(d):
            shutil.rmtree(d)
        os.rename(tmp, d)
        self._gc()
        return d

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.root, f"step_{s:08d}"),
                          ignore_errors=True)

    def steps(self) -> List[int]:
        out = []
        for fn in sorted(os.listdir(self.root)):
            d = os.path.join(self.root, fn)
            if fn.startswith("step_") and \
                    os.path.exists(os.path.join(d, "COMMITTED")):
                out.append(int(fn[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # ------------------------------------------------------------------ #
    def restore(self, template, step: Optional[int] = None
                ) -> Tuple[Any, dict]:
        """Restore into the structure of `template` (pytree of arrays or
        ShapeDtypeStructs)."""
        step = step if step is not None else self.latest_step()
        assert step is not None, "no committed checkpoint found"
        d = os.path.join(self.root, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        pieces: Dict[int, Any] = {}
        values: Dict[str, np.ndarray] = {}
        for leaf in manifest["leaves"]:
            pid = leaf["piece"]
            if pid not in pieces:
                pieces[pid] = np.load(
                    os.path.join(d, f"piece_{pid:05d}.npz"))
            values[leaf["key"]] = pieces[pid][leaf["name"]]
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        out = []
        for path, leaf in flat:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            arr = values[key]
            want = getattr(leaf, "dtype", None)
            if want is not None and str(arr.dtype) != str(want):
                arr = arr.astype(want)
            out.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, out)
        return tree, manifest["extra"]

    def restore_distributed(self, template, mesh, step: Optional[int] = None,
                            pod_axis: str = "pod"):
        """Torrent restore: seeder pod reads, pieces ride the ring.

        On the single-controller CPU stand-in this demonstrates the
        collective path (weight_torrent); a multi-controller deployment
        would gate the `restore()` call on pod rank.
        """
        tree, extra = self.restore(template, step)
        if mesh is not None and pod_axis in mesh.shape:
            from repro.parallel.weight_torrent import torrent_broadcast
            tree = torrent_broadcast(tree, mesh, axis=pod_axis)
        return tree, extra


def async_save(store: CheckpointStore, step: int, tree,
               extra: Optional[dict] = None) -> threading.Thread:
    """Snapshot to host, then serialise in a background thread."""
    host_tree = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)
    th = threading.Thread(target=store.save, args=(step, host_tree, extra),
                          daemon=True)
    th.start()
    return th
