"""Sharded checkpointing with torrent-style restore.

Layout:
  <root>/step_<n>/manifest.json       tree structure, shapes, dtypes, pieces
  <root>/step_<n>/piece_<i>.npz       flat-chunked payload pieces
  <root>/step_<n>/COMMITTED           write barrier marker

Pieces (not per-tensor files) are the unit of both I/O and swarm exchange:
on restore in a multi-pod job only the seeder pod reads from the store;
every other pod receives pieces over the interconnect via
parallel/weight_torrent (ppermute ring) or host-side via core/swarm's
rarest-first plan.  `async_save` runs serialisation off-thread so the train
loop never blocks (the step's arrays are snapshotted to host first).

Every committed step also carries `swarm.json`: a `PieceManifest` (the
torrent metainfo) over the step's canonical *image* — manifest.json plus
the piece files packed into one byte stream by `pack_step_image` — so a
checkpoint can be advertised to the volunteer swarm as a regular
piece-wise Application and serving replicas can cold-start from peers
(`checkpoint/swarm_restore.py`) instead of hammering this store.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.workunit import PieceManifest

# canonical step-image framing: magic + json file table + file bytes
IMAGE_MAGIC = b"CKPTIMG1\n"


def _image_files(d: str) -> List[str]:
    """Canonical file order for a step's swarm image: the tree manifest
    first, then the payload pieces (COMMITTED and swarm.json are framing,
    not content, and stay out of the image)."""
    pieces = sorted(fn for fn in os.listdir(d)
                    if fn.startswith("piece_") and fn.endswith(".npz"))
    return ["manifest.json"] + pieces


def pack_step_image(d: str) -> bytes:
    """Pack a committed step directory into the canonical image bytes the
    swarm manifest hashes: magic, a json file table, then the files'
    bytes concatenated in table order."""
    files = _image_files(d)
    blobs = []
    table = []
    for fn in files:
        with open(os.path.join(d, fn), "rb") as f:
            b = f.read()
        table.append({"name": fn, "size": len(b)})
        blobs.append(b)
    header = json.dumps({"files": table}, sort_keys=True).encode() + b"\n"
    return IMAGE_MAGIC + header + b"".join(blobs)


def unpack_step_image(image, dest_dir: str) -> List[str]:
    """Inverse of `pack_step_image`: write the step's files into
    `dest_dir` (plus a fresh COMMITTED marker) and return the file names.
    Callers verify the image against its PieceManifest *before* calling
    this — the framing here is trusted only after the content re-hash."""
    mv = memoryview(image)
    if bytes(mv[:len(IMAGE_MAGIC)]) != IMAGE_MAGIC:
        raise ValueError("not a checkpoint step image (bad magic)")
    ofs = len(IMAGE_MAGIC)
    end = ofs
    while end < len(mv) and mv[end] != 0x0A:        # newline-terminated
        end += 1
    header = json.loads(bytes(mv[ofs:end]).decode())
    ofs = end + 1
    os.makedirs(dest_dir, exist_ok=True)
    names = []
    for ent in header["files"]:
        n = int(ent["size"])
        with open(os.path.join(dest_dir, ent["name"]), "wb") as f:
            f.write(mv[ofs:ofs + n])
        ofs += n
        names.append(ent["name"])
    if ofs != len(mv):
        raise ValueError("trailing bytes after the declared file table")
    with open(os.path.join(dest_dir, "COMMITTED"), "w") as f:
        f.write(str(time.time()))
    return names


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


class CheckpointStore:
    def __init__(self, root: str, piece_bytes: int = 64 << 20,
                 keep_last: int = 3, swarm_piece_bytes: int = 4 << 20):
        self.root = root
        self.piece_bytes = piece_bytes
        self.keep_last = keep_last
        # granularity of the *swarm* manifest over the packed step image;
        # smaller than the I/O piece size so a flash crowd of replicas
        # disperses across many holders instead of queueing on whole shards
        self.swarm_piece_bytes = swarm_piece_bytes
        os.makedirs(root, exist_ok=True)

    def step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    # ------------------------------------------------------------------ #
    def save(self, step: int, tree, extra: Optional[dict] = None) -> str:
        d = os.path.join(self.root, f"step_{step:08d}")
        tmp = d + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        entries = _flatten_with_paths(tree)
        manifest = {"step": step, "extra": extra or {}, "leaves": [],
                    "pieces": []}
        # pack leaves into pieces
        piece, piece_sz, piece_idx = {}, 0, 0
        for key, leaf in entries:
            arr = np.asarray(leaf)
            manifest["leaves"].append({
                "key": key, "shape": list(arr.shape), "dtype": str(arr.dtype),
                "piece": piece_idx, "name": f"a{len(piece)}"})
            piece[f"a{len(piece)}"] = arr
            piece_sz += arr.nbytes
            if piece_sz >= self.piece_bytes:
                np.savez(os.path.join(tmp, f"piece_{piece_idx:05d}.npz"),
                         **piece)
                manifest["pieces"].append(piece_idx)
                piece, piece_sz = {}, 0
                piece_idx += 1
        if piece:
            np.savez(os.path.join(tmp, f"piece_{piece_idx:05d}.npz"), **piece)
            manifest["pieces"].append(piece_idx)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        # emit the swarm metainfo: a PieceManifest (content-hashed, like a
        # .torrent) over the step's canonical packed image, so replicas
        # can join the distribution swarm straight off the step directory.
        # Successive committed steps form a revision chain (version +
        # prev_manifest_hash): a replica holding v(k) seeds its v(k+1)
        # inventory from the pieces the delta left unchanged.
        prev_pm = None
        prior = [s for s in self.steps() if s < step]
        if prior:
            try:
                prev_pm = self.swarm_manifest(prior[-1])
            except Exception:
                prev_pm = None
        pm = PieceManifest.from_bytes(
            self.swarm_app_id(step), pack_step_image(tmp),
            self.swarm_piece_bytes,
            version=(prev_pm.version + 1 if prev_pm is not None else 1),
            prev=prev_pm)
        with open(os.path.join(tmp, "swarm.json"), "w") as f:
            json.dump({"app_id": pm.app_id, "piece_bytes": pm.piece_bytes,
                       "total_bytes": pm.total_bytes,
                       "piece_hashes": list(pm.piece_hashes),
                       "version": pm.version,
                       "prev_manifest_hash": pm.prev_manifest_hash,
                       "manifest_hash": pm.manifest_hash}, f)
        with open(os.path.join(tmp, "COMMITTED"), "w") as f:
            f.write(str(time.time()))
        if os.path.isdir(d):
            shutil.rmtree(d)
        os.rename(tmp, d)
        self._gc()
        return d

    # ------------------------------------------------------------------ #
    def swarm_app_id(self, step: int) -> str:
        """The Application id a step is advertised under in the swarm."""
        return f"ckpt-{os.path.basename(os.path.normpath(self.root))}" \
               f"-step{step:08d}"

    def pack_image(self, step: Optional[int] = None) -> bytes:
        """The committed step's canonical swarm image bytes."""
        step = step if step is not None else self.latest_step()
        assert step is not None, "no committed checkpoint found"
        return pack_step_image(self.step_dir(step))

    def swarm_manifest(self, step: Optional[int] = None) -> PieceManifest:
        """The PieceManifest `save` emitted for a committed step
        (rebuilt from the files for pre-swarm.json step dirs)."""
        step = step if step is not None else self.latest_step()
        assert step is not None, "no committed checkpoint found"
        path = os.path.join(self.step_dir(step), "swarm.json")
        if not os.path.exists(path):
            return PieceManifest.from_bytes(self.swarm_app_id(step),
                                            self.pack_image(step),
                                            self.swarm_piece_bytes)
        with open(path) as f:
            doc = json.load(f)
        pm = PieceManifest(doc["app_id"], int(doc["piece_bytes"]),
                           int(doc["total_bytes"]),
                           tuple(doc["piece_hashes"]), content_hashed=True,
                           version=int(doc.get("version", 1)),
                           prev_manifest_hash=doc.get("prev_manifest_hash"))
        assert pm.manifest_hash == doc["manifest_hash"], \
            "swarm.json does not match its own metainfo"
        return pm

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.root, f"step_{s:08d}"),
                          ignore_errors=True)

    def steps(self) -> List[int]:
        out = []
        for fn in sorted(os.listdir(self.root)):
            d = os.path.join(self.root, fn)
            if fn.startswith("step_") and \
                    os.path.exists(os.path.join(d, "COMMITTED")):
                out.append(int(fn[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # ------------------------------------------------------------------ #
    def restore(self, template, step: Optional[int] = None
                ) -> Tuple[Any, dict]:
        """Restore into the structure of `template` (pytree of arrays or
        ShapeDtypeStructs)."""
        step = step if step is not None else self.latest_step()
        assert step is not None, "no committed checkpoint found"
        d = os.path.join(self.root, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        pieces: Dict[int, Any] = {}
        values: Dict[str, np.ndarray] = {}
        for leaf in manifest["leaves"]:
            pid = leaf["piece"]
            if pid not in pieces:
                pieces[pid] = np.load(
                    os.path.join(d, f"piece_{pid:05d}.npz"))
            values[leaf["key"]] = pieces[pid][leaf["name"]]
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        out = []
        for path, leaf in flat:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            arr = values[key]
            want = getattr(leaf, "dtype", None)
            if want is not None and str(arr.dtype) != str(want):
                arr = arr.astype(want)
            out.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, out)
        return tree, manifest["extra"]

    def restore_distributed(self, template, mesh, step: Optional[int] = None,
                            pod_axis: str = "pod"):
        """Torrent restore: seeder pod reads, pieces ride the ring.

        On the single-controller CPU stand-in this demonstrates the
        collective path (weight_torrent); a multi-controller deployment
        would gate the `restore()` call on pod rank.
        """
        tree, extra = self.restore(template, step)
        if mesh is not None and pod_axis in mesh.shape:
            from repro.parallel.weight_torrent import torrent_broadcast
            tree = torrent_broadcast(tree, mesh, axis=pod_axis)
        return tree, extra


def async_save(store: CheckpointStore, step: int, tree,
               extra: Optional[dict] = None) -> threading.Thread:
    """Snapshot to host, then serialise in a background thread."""
    host_tree = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)
    th = threading.Thread(target=store.save, args=(step, host_tree, extra),
                          daemon=True)
    th.start()
    return th
