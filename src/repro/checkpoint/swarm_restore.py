"""Checkpoint cold-start over the volunteer swarm (the jax<->swarm loop).

A committed `CheckpointStore` step is a regular piece-wise Application:
`checkpoint_application` wraps the step's canonical packed image and the
`swarm.json` PieceManifest into an `Application` the origin agent hosts
(`host_app`), and every serving replica joins as a leecher-then-seeder
through the ordinary `Agent`/`PieceExchange` machinery (hub mode scales
the flash crowd; `AgentConfig.replicate_completed=True` lets replicas
join an app that carries no work parts).

The restore side closes the loop: `restore_from_agent` takes a replica
whose piece set completed, re-hashes the assembled image against the
manifest (content verification — the framing header is trusted only
after this), unpacks the step directory and restores the parameter tree
through `CheckpointStore.restore`, byte-identical to an origin restore.
`ServingEngine.from_swarm` builds an engine straight from that, with
`parallel/weight_torrent`'s ppermute ring as the intra-pod fan-out once
one host in a pod holds the bytes.
"""
from __future__ import annotations

import os
import tempfile
from dataclasses import replace
from typing import Any, Optional, Tuple

from repro.checkpoint.store import CheckpointStore, unpack_step_image
from repro.core.workunit import Application, PieceManifest


def checkpoint_application(store: CheckpointStore,
                           step: Optional[int] = None, *,
                           host_id: str = "origin",
                           app_id: Optional[str] = None) -> Application:
    """The committed step as a swarm Application: real image bytes, the
    store's emitted manifest, and no work parts (pure replication)."""
    step = step if step is not None else store.latest_step()
    assert step is not None, "no committed checkpoint found"
    manifest = store.swarm_manifest(step)
    if app_id is not None and app_id != manifest.app_id:
        # advertise under a caller-chosen id: rebuild the metainfo so the
        # manifest hash still binds (app_id, piece size, content); the
        # revision chain (version, prev hash) rides along unchanged
        image = store.pack_image(step)
        manifest = replace(
            PieceManifest.from_bytes(app_id, image, manifest.piece_bytes),
            version=manifest.version,
            prev_manifest_hash=manifest.prev_manifest_hash)
    else:
        image = store.pack_image(step)
    return Application(manifest.app_id, host_id, app_bytes=len(image),
                      parts=[], swarm=True,
                      piece_bytes=manifest.piece_bytes,
                      manifest=manifest, image=image)


def verify_image(image, manifest: PieceManifest) -> bool:
    """Content re-hash of an assembled image against its metainfo."""
    if image is None or len(image) != manifest.total_bytes:
        return False
    rehash = replace(
        PieceManifest.from_bytes(manifest.app_id, image,
                                 manifest.piece_bytes),
        version=manifest.version,
        prev_manifest_hash=manifest.prev_manifest_hash)
    return rehash.manifest_hash == manifest.manifest_hash


def restore_image(image, manifest: PieceManifest, template,
                  workdir: Optional[str] = None) -> Tuple[Any, dict]:
    """Verify + unpack an assembled step image and restore `template`."""
    if not verify_image(image, manifest):
        raise ValueError(
            f"image failed content verification against manifest "
            f"{manifest.manifest_hash[:12]} ({manifest.app_id})")
    workdir = workdir or tempfile.mkdtemp(prefix="swarm_restore_")
    # the unpacked directory is a regular committed step: restore through
    # the store so dtype coercion/tree reassembly match an origin restore
    step_dir = os.path.join(workdir, "step_00000000")
    unpack_step_image(image, step_dir)
    return CheckpointStore(workdir).restore(template, step=0)


def restore_from_agent(agent, app_id: str, template,
                       workdir: Optional[str] = None) -> Tuple[Any, dict]:
    """Cold-start restore from a replica agent the moment its piece set
    for `app_id` completes (every piece verified by the inventory)."""
    if app_id not in agent.images:
        raise RuntimeError(
            f"{agent.node_id} has not completed the piece set for "
            f"{app_id}; ready gate is agent.images")
    manifest = agent.px.manifests.get(app_id)
    assert manifest is not None, f"{agent.node_id} holds no manifest"
    image = agent.px.assembled_image(app_id)
    return restore_image(image, manifest, template, workdir=workdir)
