from repro.checkpoint.store import (  # noqa: F401
    CheckpointStore,
    async_save,
)
