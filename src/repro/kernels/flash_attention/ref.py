"""Pure-jnp oracle for flash attention: materialised scores + mask.

Used by tests to validate both the custom-vjp jnp implementation (ops.py)
and the Pallas TPU kernel (kernel.py, interpret mode on CPU).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def mha_reference(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0,
                  softcap: float = 0.0) -> jax.Array:
    """q: (B, Sq, Hq, D); k/v: (B, Skv, Hkv, D) with Hq % Hkv == 0."""
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    q5 = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", q5.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)
