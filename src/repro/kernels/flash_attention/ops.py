"""FlashAttention-2 as a brick-scan with a custom VJP (jnp + Pallas backends).

Forward saves only (q, k, v, out, lse); backward re-walks the same statically
enumerated brick list accumulating (dq, dk, dv).  Peak memory is O(S·H·D) plus
one brick — no (S x S) score tensor, no per-step softmax residuals.  The brick
list enumerates only blocks alive under the causal/sliding-window mask, so
compiled dot FLOPs track the true masked cost (padding waste <= the diagonal
half-bricks), which is what the roofline's compute term sees.
"""
from __future__ import annotations

import functools
import math
from typing import List, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def brick_list(nq: int, nk: int, cq: int, ck: int, causal: bool, window: int,
               q_offset: int = 0) -> List[Tuple[int, int]]:
    """Statically enumerate (q-chunk, kv-chunk) bricks needed under the mask."""
    pairs = []
    for i in range(nq):
        q_lo, q_hi = q_offset + i * cq, q_offset + (i + 1) * cq - 1
        for j in range(nk):
            k_lo, k_hi = j * ck, (j + 1) * ck - 1
            if causal and k_lo > q_hi:
                continue
            if window and k_hi <= q_lo - window:
                continue
            pairs.append((i, j))
    return pairs


def _mask_for(i, j, cq, ck, Skv, causal, window, q_offset):
    qpos = q_offset + i * cq + jnp.arange(cq)[:, None]
    kpos = j * ck + jnp.arange(ck)[None, :]
    mask = kpos < Skv
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    return mask


def _pad_seq(x, c):
    pad = (-x.shape[1]) % c
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return x


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window: int = 0, cq: int = 1024,
                    ck: int = 1024, impl: str = "jnp") -> jax.Array:
    out, _ = _flash_fwd(q, k, v, causal, window, cq, ck, impl)
    return out


def _flash_fwd(q, k, v, causal, window, cq, ck, impl):
    if impl == "pallas":
        from repro.kernels.flash_attention.kernel import flash_fwd_pallas
        out, lse = flash_fwd_pallas(q, k, v, causal=causal, window=window,
                                    block_q=cq, block_k=ck)
        return out, (q, k, v, out, lse)
    out, lse = _flash_fwd_jnp(q, k, v, causal, window, cq, ck)
    return out, (q, k, v, out, lse)


def _flash_fwd_jnp(q, k, v, causal, window, cq, ck):
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    cq = min(cq, Sq)
    ck = min(ck, Skv)
    qp, kp, vp = _pad_seq(q, cq), _pad_seq(k, ck), _pad_seq(v, ck)
    nq, nk = qp.shape[1] // cq, kp.shape[1] // ck
    pairs = brick_list(nq, nk, cq, ck, causal, window)
    qc = qp.reshape(B, nq, cq, Hkv, G, D)
    kc = kp.reshape(B, nk, ck, Hkv, D)
    vc = vp.reshape(B, nk, ck, Hkv, D)
    scale = 1.0 / math.sqrt(D)

    acc0 = jnp.zeros((nq, B, cq, Hkv, G, D), jnp.float32)
    m0 = jnp.full((nq, B, cq, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((nq, B, cq, Hkv, G), jnp.float32)
    iis = jnp.asarray([p[0] for p in pairs], jnp.int32)
    jjs = jnp.asarray([p[1] for p in pairs], jnp.int32)

    def body(carry, ij):
        acc, m, l = carry
        i, j = ij
        qi = jax.lax.dynamic_index_in_dim(qc, i, 1, keepdims=False)
        kj = jax.lax.dynamic_index_in_dim(kc, j, 1, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vc, j, 1, keepdims=False)
        s = jnp.einsum("bqkgd,bskd->bqkgs", qi, kj).astype(jnp.float32) * scale
        qpos = i * cq + jnp.arange(cq)[:, None]
        kpos = j * ck + jnp.arange(ck)[None, :]
        mask = kpos < Skv
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask[:, None, None, :], s, NEG_INF)
        mi = jax.lax.dynamic_index_in_dim(m, i, 0, keepdims=False)
        li = jax.lax.dynamic_index_in_dim(l, i, 0, keepdims=False)
        ai = jax.lax.dynamic_index_in_dim(acc, i, 0, keepdims=False)
        m_new = jnp.maximum(mi, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(mi - m_new)
        l_new = li * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqkgs,bskd->bqkgd", p.astype(q.dtype), vj)
        a_new = ai * corr[..., None] + pv.astype(jnp.float32)
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, i, 0)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, i, 0)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, i, 0)
        return (acc, m, l), None

    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), (iis, jjs))
    out = acc / jnp.maximum(l[..., None], 1e-37)
    lse = m + jnp.log(jnp.maximum(l, 1e-37))              # (nq,B,cq,Hkv,G)
    out = jnp.transpose(out, (1, 0, 2, 3, 4, 5)).reshape(B, nq * cq, Hq, D)
    lse = jnp.transpose(lse, (1, 0, 2, 3, 4)).reshape(B, nq * cq, Hkv, G)
    return out[:, :Sq].astype(q.dtype), lse[:, :Sq]


def _flash_bwd(causal, window, cq, ck, impl, res, dout):
    q, k, v, out, lse = res
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    cq = min(cq, Sq)
    ck = min(ck, Skv)
    scale = 1.0 / math.sqrt(D)

    qp, kp, vp = _pad_seq(q, cq), _pad_seq(k, ck), _pad_seq(v, ck)
    dop = _pad_seq(dout, cq)
    outp = _pad_seq(out, cq)
    nq, nk = qp.shape[1] // cq, kp.shape[1] // ck
    lsep = jnp.pad(lse, ((0, 0), (0, nq * cq - Sq), (0, 0), (0, 0)),
                   constant_values=0.0)
    pairs = brick_list(nq, nk, cq, ck, causal, window)

    qc = qp.reshape(B, nq, cq, Hkv, G, D)
    kc = kp.reshape(B, nk, ck, Hkv, D)
    vc = vp.reshape(B, nk, ck, Hkv, D)
    doc = dop.reshape(B, nq, cq, Hkv, G, D)
    lsec = lsep.reshape(B, nq, cq, Hkv, G)
    # delta = rowsum(dO * O)
    delta = jnp.sum(dop.astype(jnp.float32) * outp.astype(jnp.float32),
                    axis=-1).reshape(B, nq, cq, Hkv, G)

    dq0 = jnp.zeros((nq, B, cq, Hkv, G, D), jnp.float32)
    dk0 = jnp.zeros((nk, B, ck, Hkv, D), jnp.float32)
    dv0 = jnp.zeros((nk, B, ck, Hkv, D), jnp.float32)
    iis = jnp.asarray([p[0] for p in pairs], jnp.int32)
    jjs = jnp.asarray([p[1] for p in pairs], jnp.int32)

    def body(carry, ij):
        dq, dk, dv = carry
        i, j = ij
        qi = jax.lax.dynamic_index_in_dim(qc, i, 1, keepdims=False)
        kj = jax.lax.dynamic_index_in_dim(kc, j, 1, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vc, j, 1, keepdims=False)
        doi = jax.lax.dynamic_index_in_dim(doc, i, 1, keepdims=False)
        lsei = jax.lax.dynamic_index_in_dim(lsec, i, 1, keepdims=False)
        di = jax.lax.dynamic_index_in_dim(delta, i, 1, keepdims=False)
        s = jnp.einsum("bqkgd,bskd->bqkgs", qi, kj).astype(jnp.float32) * scale
        qpos = i * cq + jnp.arange(cq)[:, None]
        kpos = j * ck + jnp.arange(ck)[None, :]
        mask = kpos < Skv
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask[:, None, None, :], s, NEG_INF)
        p = jnp.exp(s - lsei[..., None])                   # (B,cq,Hkv,G,ck)
        dvj = jnp.einsum("bqkgs,bqkgd->bskd", p.astype(dout.dtype), doi)
        dp = jnp.einsum("bqkgd,bskd->bqkgs", doi, vj).astype(jnp.float32)
        ds = p * (dp - di[..., None]) * scale              # (B,cq,Hkv,G,ck)
        dsq = ds.astype(q.dtype)
        dqi = jnp.einsum("bqkgs,bskd->bqkgd", dsq, kj)
        dkj = jnp.einsum("bqkgs,bqkgd->bskd", dsq, qi)
        dq = dq.at[i].add(dqi.astype(jnp.float32))
        dk = dk.at[j].add(dkj.astype(jnp.float32))
        dv = dv.at[j].add(dvj.astype(jnp.float32))
        return (dq, dk, dv), None

    (dq, dk, dv), _ = jax.lax.scan(body, (dq0, dk0, dv0), (iis, jjs))
    dq = jnp.transpose(dq, (1, 0, 2, 3, 4, 5)).reshape(B, nq * cq, Hq, D)
    dk = jnp.transpose(dk, (1, 0, 2, 3, 4)).reshape(B, nk * ck, Hkv, D)
    dv = jnp.transpose(dv, (1, 0, 2, 3, 4)).reshape(B, nk * ck, Hkv, D)
    return (dq[:, :Sq].astype(q.dtype), dk[:, :Skv].astype(k.dtype),
            dv[:, :Skv].astype(v.dtype))


flash_attention.defvjp(_flash_fwd, _flash_bwd)
