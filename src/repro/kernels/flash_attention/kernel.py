"""Pallas TPU FlashAttention-2 forward kernel.

Grid: (batch, q_heads, q_blocks, kv_blocks) with the kv axis sequential
("arbitrary") so the online-softmax state lives in VMEM scratch across kv
steps.  Blocks are MXU-aligned (block_q x head_dim and block_k x head_dim
tiles); GQA is handled in the k/v index_map (kv head = q head // group).

Causal/sliding-window masking is positional via iota; fully-masked kv blocks
are skipped with pl.when so the kernel does no dead MXU work beyond the
diagonal half-bricks.

Validated on CPU with interpret=True against ref.mha_reference and against
the custom-vjp jnp implementation in ops.py (which is also the TPU-side
fallback when use_pallas=False).
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed pltpu.TPUCompilerParams -> CompilerParams; accept either
# spelling so the kernel builds on both old (<=0.4.37) and new images
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams", None)

NEG_INF = -1e30


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref,
                m_scr, l_scr, acc_scr, *,
                scale: float, causal: bool, window: int,
                block_q: int, block_k: int, n_kv: int, seq_kv: int):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_lo = i * block_q
    k_lo = j * block_k
    # skip blocks fully outside the mask
    live = True
    if causal:
        live = k_lo <= q_lo + block_q - 1
    if window:
        live = jnp.logical_and(live, k_lo + block_k - 1 > q_lo - window) \
            if causal else (k_lo + block_k - 1 > q_lo - window)

    @pl.when(live if not isinstance(live, bool) else True)
    def _body():
        q = q_ref[0, :, 0, :].astype(jnp.float32)          # (bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < seq_kv
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == n_kv - 1)
    def _emit():
        l = jnp.maximum(l_scr[...], 1e-37)
        o_ref[0, :, 0, :] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0, :, 0] = m_scr[...] + jnp.log(l)


def flash_fwd_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     causal: bool = True, window: int = 0,
                     block_q: int = 128, block_k: int = 128,
                     interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """q: (B, Sq, Hq, D); k/v: (B, Skv, Hkv, D) -> (out, lse)."""
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    pad_q = (-Sq) % block_q
    pad_k = (-Skv) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq = (Sq + pad_q) // block_q
    nk = (Skv + pad_k) // block_k

    kernel = functools.partial(
        _fwd_kernel, scale=1.0 / math.sqrt(D), causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_kv=nk, seq_kv=Skv)

    out, lse = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, D),
                         lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, block_k, 1, D),
                         lambda b, h, i, j, G=G: (b, j, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, D),
                         lambda b, h, i, j, G=G: (b, j, h // G, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, 1, D),
                         lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, block_q, 1),
                         lambda b, h, i, j: (b, i, h)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Sq + pad_q, Hq, D), q.dtype),
            jax.ShapeDtypeStruct((B, Sq + pad_q, Hq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq], lse[:, :Sq]
