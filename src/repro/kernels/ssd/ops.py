"""Jitted wrapper for the SSD kernel with jnp fallback."""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("chunk", "impl", "interpret"))
def ssd(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
        Cm: jax.Array, chunk: int = 128, impl: str = "pallas",
        interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    if impl == "pallas":
        from repro.kernels.ssd.kernel import ssd_pallas
        return ssd_pallas(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)
    from repro.models.ssm import ssd_scan
    return ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
