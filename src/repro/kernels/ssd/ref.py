"""Pure-jnp oracles for the Mamba2 SSD kernel.

`ssd_naive` is the O(S^2) quadratic form (direct semiseparable matmul) —
slow but obviously correct; `repro.models.ssm.ssd_scan` is the chunked
production implementation.  Both serve as references for the Pallas kernel.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def ssd_naive(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
              Cm: jax.Array, init_state: Optional[jax.Array] = None
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,H,P); dt: (B,S,H); A: (H,); Bm/Cm: (B,S,G,N).

    y[t] = sum_{s<=t} C_t . (prod_{r in (s,t]} exp(dtA_r)) dt_s x_s B_s
    Returns (y, final_state)."""
    Bsz, S, H, Pd = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2).astype(jnp.float32)    # (B,S,H,N)
    Ch = jnp.repeat(Cm, rep, axis=2).astype(jnp.float32)
    dtA = dt.astype(jnp.float32) * A.astype(jnp.float32)    # (B,S,H)
    cum = jnp.cumsum(dtA, axis=1)                           # (B,S,H)
    # decay(s->t) = exp(cum[t]-cum[s]) for t >= s
    dec = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # (B,T,S,H)
    tri = jnp.tril(jnp.ones((S, S), bool))[None, :, :, None]
    dec = jnp.where(tri, dec, 0.0)
    cb = jnp.einsum("bthn,bshn->btsh", Ch, Bh)
    m = cb * dec
    xdt = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]
    y = jnp.einsum("btsh,bshp->bthp", m, xdt)
    if init_state is not None:
        dec0 = jnp.exp(cum)                                  # (B,S,H)
        y = y + jnp.einsum("bshn,bhpn,bsh->bshp", Ch,
                           init_state.astype(jnp.float32), dec0)
    # final state
    decT = jnp.exp(cum[:, -1:, :] - cum)                     # (B,S,H)
    state = jnp.einsum("bshn,bsh,bshp->bhpn", Bh, decT, xdt)
    if init_state is not None:
        state = state + init_state.astype(jnp.float32) * \
            jnp.exp(cum[:, -1])[:, :, None, None]
    return y.astype(x.dtype), state
