"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

Grid: (batch, heads, chunks) with the chunk axis sequential ("arbitrary");
the (P, N) recurrent state lives in VMEM scratch across chunk steps.  Within
a chunk everything is dense matmul work for the MXU:

   y_diag = ((C B^T) .* decay_tril) (dt x)         intra-chunk
   y_off  = (C state_in) .* decay_from_start       inter-chunk
   state  = state_in * chunk_decay + (B dt x decay_to_end)

The hardware-adaptation choice (vs the paper-adjacent Triton kernel): TPU
prefers one sequential grid axis + VMEM-resident state over warp-level
pipelining, and L=chunk x N/P tiles sized to MXU multiples.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed pltpu.TPUCompilerParams -> CompilerParams; accept either
# spelling so the kernel builds on both old (<=0.4.37) and new images
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams", None)


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, fin_ref,
                state_scr, *, n_chunks: int, chunk: int):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)        # (L, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # (L,)
    a = a_ref[0].astype(jnp.float32)                 # ()
    Bm = b_ref[0, :, 0, :].astype(jnp.float32)       # (L, N)
    Cm = c_ref[0, :, 0, :].astype(jnp.float32)       # (L, N)

    dtA = dt * a                                     # (L,)
    cum = jnp.cumsum(dtA)                            # (L,)
    xdt = x * dt[:, None]                            # (L, P)

    # intra-chunk
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, L)
    diff = cum[:, None] - cum[None, :]
    li = jax.lax.broadcasted_iota(jnp.int32, cb.shape, 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, cb.shape, 1)
    dec = jnp.where(li >= lj, jnp.exp(diff), 0.0)
    y = jax.lax.dot_general(cb * dec, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (L, P)

    # inter-chunk using incoming state
    state_in = state_scr[...]                        # (P, N)
    dec0 = jnp.exp(cum)                              # (L,)
    y = y + (jax.lax.dot_general(Cm, state_in, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
             * dec0[:, None])

    # state update
    decT = jnp.exp(cum[-1] - cum)                    # (L,)
    upd = jax.lax.dot_general(xdt * decT[:, None], Bm,
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (P, N)
    state_scr[...] = state_in * jnp.exp(cum[-1]) + upd

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(c_idx == n_chunks - 1)
    def _emit():
        fin_ref[0, 0, :, :] = state_scr[...]


def ssd_pallas(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
               Cm: jax.Array, chunk: int = 128, interpret: bool = True
               ) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,H,P); dt: (B,S,H); A: (H,); Bm/Cm: (B,S,G,N) with G | H.

    Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    Bsz, S, H, Pd = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        # pad dt with zeros => decay 1, no state contribution
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk

    kernel = functools.partial(_ssd_kernel, n_chunks=nc, chunk=chunk)
    y, fin = pl.pallas_call(
        kernel,
        grid=(Bsz, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, Pd), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, 1, N),
                         lambda b, h, c, rep=rep: (b, c, h // rep, 0)),
            pl.BlockSpec((1, chunk, 1, N),
                         lambda b, h, c, rep=rep: (b, c, h // rep, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, Pd), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, Pd, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, Sp, H, Pd), x.dtype),
            jax.ShapeDtypeStruct((Bsz, H, Pd, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((Pd, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
    return y[:, :S], fin
