"""Mamba2-2.7B — attention-free SSD (state-space duality) [arXiv:2405.21060].

Pure SSM stack: 64 layers, d_model 2560, d_state 128, expand 2, head_dim 64.
Sub-quadratic by construction — the 500k decode shape runs (constant-size
recurrent state).
"""
from repro.configs.base import ModelConfig, dense_groups, register

CONFIG = register(ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    d_model=2560,
    num_heads=0,
    num_kv_heads=1,
    head_dim=0,
    d_ff=0,                       # Mamba2 block has no separate MLP
    vocab_size=50280,
    groups=dense_groups(64, mixer="ssd", mlp="none"),
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_ngroups=1,
    ssm_conv_width=4,
    subquadratic=True,
))
