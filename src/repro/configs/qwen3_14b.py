"""Qwen3-14B — dense GQA with qk-norm [hf:Qwen/Qwen3-8B family]."""
from repro.configs.base import ModelConfig, dense_groups, register

CONFIG = register(ModelConfig(
    name="qwen3-14b",
    family="dense",
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151936,
    groups=dense_groups(40),
    qk_norm=True,
    rope_theta=1_000_000.0,
))
