"""Qwen3-MoE-30B-A3B — 128 experts, top-8, qk-norm [hf:Qwen/Qwen3-30B-A3B]."""
from repro.configs.base import ModelConfig, dense_groups, register

CONFIG = register(ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,            # decoupled from d_model/num_heads, as in HF config
    d_ff=768,                # per-expert width (assignment value)
    vocab_size=151936,
    groups=dense_groups(48, mlp="moe"),
    num_experts=128,
    experts_per_token=8,
    moe_d_ff=768,
    qk_norm=True,
    rope_theta=1_000_000.0,
))
