"""Llama-4 Scout 17B-A16E — MoE 16 experts top-1 + shared expert, early fusion.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].  Every layer is MoE
(interleave step 1 for Scout) with a shared expert of the same width as the
routed experts.  Early-fusion multimodal frontend is a stub: ``input_specs()``
provides precomputed embeddings.
"""
from repro.configs.base import ModelConfig, dense_groups, register

CONFIG = register(ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,              # routed-expert width (assignment value)
    vocab_size=202048,
    groups=dense_groups(48, mlp="moe"),
    num_experts=16,
    experts_per_token=1,
    moe_d_ff=8192,
    shared_expert=True,
    rope_theta=500_000.0,
    input_kind="embeds",    # early fusion: embeddings arrive fused
))
