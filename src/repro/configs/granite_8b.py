"""Granite-8B (code) — llama-architecture dense GQA [arXiv:2405.04324]."""
from repro.configs.base import ModelConfig, dense_groups, register

CONFIG = register(ModelConfig(
    name="granite-8b",
    family="dense",
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=49152,
    groups=dense_groups(36),
    rope_theta=10_000_000.0,
))
