"""InternLM2-20B — dense GQA transformer [arXiv:2403.17297]."""
from repro.configs.base import ModelConfig, dense_groups, register

CONFIG = register(ModelConfig(
    name="internlm2-20b",
    family="dense",
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92544,
    groups=dense_groups(48),
    rope_theta=1_000_000.0,
))
