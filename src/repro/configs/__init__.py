from repro.configs.base import (  # noqa: F401
    ARCH_IDS,
    ARCH_MODULES,
    SHAPES,
    GroupSpec,
    LayerSpec,
    ModelConfig,
    ShapeConfig,
    get_config,
    list_archs,
    reduced_config,
    register,
)
