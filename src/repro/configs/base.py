"""Model/config system.

A model is described by a sequence of *layer groups*; each group is a tuple of
``LayerSpec`` repeated ``repeat`` times.  Groups are executed with
``jax.lax.scan`` over the repeats (params stacked on a leading axis), which
keeps HLO size and CPU compile time bounded for 48+-layer models.

Every assigned architecture maps onto this one substrate:

  mixer: "attn"        full causal self attention (GQA, optional qk-norm)
         "attn_local"  sliding-window causal attention
         "ssd"         Mamba2 state-space-duality block
         "none"        no mixer (pure-MLP layer; unused by assigned archs)
  mlp:   "dense" | "moe" | "none"
  shared_attn: bool    Zamba2-style weight-tied global attention applied after
                       the mixer (params shared across all applications).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class LayerSpec:
    mixer: str = "attn"           # "attn" | "attn_local" | "ssd" | "none"
    mlp: str = "dense"            # "dense" | "moe" | "none"
    shared_attn: bool = False     # apply the weight-tied shared attention block


@dataclass(frozen=True)
class GroupSpec:
    layers: Tuple[LayerSpec, ...]
    repeat: int

    @property
    def num_layers(self) -> int:
        return len(self.layers) * self.repeat


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    groups: Tuple[GroupSpec, ...]

    # --- attention options -------------------------------------------------
    window_size: int = 1024       # for "attn_local"
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    mrope: bool = False           # multimodal 3D RoPE (Qwen2-VL)
    mrope_sections: Tuple[int, ...] = (16, 24, 24)  # t,h,w splits of head_dim/2
    attn_logit_softcap: float = 0.0

    # --- MoE ---------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    shared_expert: bool = False   # llama4-style shared expert alongside routed
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001

    # --- SSD / Mamba2 ------------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_ngroups: int = 1
    ssm_conv_width: int = 4
    ssd_chunk: int = 256

    # --- shared attention (Zamba2) ------------------------------------------
    shared_attn_heads: int = 0    # 0 => num_heads
    shared_attn_kv_heads: int = 0

    # --- encoder/decoder ----------------------------------------------------
    is_encdec: bool = False
    encoder_groups: Tuple[GroupSpec, ...] = ()
    # ratio tgt_len = seq_len // tgt_ratio for encdec shapes
    encdec_tgt_ratio: int = 4

    # --- input modality ----------------------------------------------------
    # "tokens": int32 token ids.  "embeds": the modality frontend is a stub and
    # inputs arrive as precomputed (B, S, d_model) embeddings (VLM/audio).
    input_kind: str = "tokens"

    # --- numerics / substrate ----------------------------------------------
    dtype: str = "bfloat16"       # activation/compute dtype
    param_dtype: str = "float32"  # master params (training)
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    use_pallas: bool = False      # Pallas kernels (TPU); CPU dry-run uses jnp ref
    remat: str = "full"           # "none" | "full" | "dots" activation ckpt
    attn_impl: str = "auto"       # "auto" | "flash" | "brick" | "full"
    loss_chunk: int = 1024        # seq-chunked cross-entropy (0 = unchunked)
    micro_steps: int = 1          # gradient-accumulation microbatches
    # --- beyond-paper perf knobs (see EXPERIMENTS.md §Perf) ---------------
    tp_sp: bool = False           # explicit reduce-scatter row-parallel projs
    pad_attn_heads: bool = False  # pad GQA q-head groups to TP multiple
    moe_a2a_int8: bool = False    # quantize MoE all-to-all dispatch buffers
    attn_chunk_q: int = 1024      # blocked-attention query chunk (jnp path)
    attn_chunk_kv: int = 1024     # blocked-attention kv chunk (jnp path)
    # Sub-quadratic capable: safe to lower 500k-token decode.
    subquadratic: bool = False

    # ------------------------------------------------------------------ #
    @property
    def num_layers(self) -> int:
        n = sum(g.num_layers for g in self.groups)
        if self.is_encdec:
            n += sum(g.num_layers for g in self.encoder_groups)
        return n

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def act_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def master_dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # Parameter count (token-embedding excluded flag for 6ND accounting).
    def param_count(self, include_embed: bool = True) -> int:
        from repro.models.registry import count_params  # lazy, avoids cycle
        return count_params(self, include_embed=include_embed)

    def active_param_count(self) -> int:
        from repro.models.registry import count_params
        return count_params(self, include_embed=True, active_only=True)


# --------------------------------------------------------------------------- #
# Input shapes assigned to every LM architecture.
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def dense_groups(n_layers: int, mixer: str = "attn", mlp: str = "dense"
                 ) -> Tuple[GroupSpec, ...]:
    return (GroupSpec((LayerSpec(mixer=mixer, mlp=mlp),), n_layers),)


# --------------------------------------------------------------------------- #
# Registry
# --------------------------------------------------------------------------- #
_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    # import the modules so they self-register
    from repro import configs as _c  # noqa: F401
    import importlib
    if name not in _REGISTRY:
        try:
            mod = name.replace("-", "_").replace(".", "_")
            importlib.import_module(f"repro.configs.{mod}")
        except ImportError:
            pass
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list:
    import importlib
    for m in ARCH_MODULES:
        importlib.import_module(f"repro.configs.{m}")
    return sorted(_REGISTRY)


ARCH_MODULES = [
    "internlm2_20b",
    "gemma3_12b",
    "granite_8b",
    "qwen3_14b",
    "qwen2_vl_2b",
    "llama4_scout_17b_a16e",
    "qwen3_moe_30b_a3b",
    "mamba2_2_7b",
    "zamba2_7b",
    "seamless_m4t_medium",
]

ARCH_IDS = [
    "internlm2-20b",
    "gemma3-12b",
    "granite-8b",
    "qwen3-14b",
    "qwen2-vl-2b",
    "llama4-scout-17b-a16e",
    "qwen3-moe-30b-a3b",
    "mamba2-2.7b",
    "zamba2-7b",
    "seamless-m4t-medium",
]


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    def shrink_groups(groups):
        out = []
        for g in groups:
            out.append(GroupSpec(g.layers, repeat=min(g.repeat, 2)))
        return tuple(out)

    kw = dict(
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        groups=shrink_groups(cfg.groups),
        window_size=min(cfg.window_size, 32),
        attn_chunk_q=16,
        attn_chunk_kv=32,
        ssd_chunk=16,
        remat="none",
    )
    if cfg.num_experts:
        kw.update(num_experts=min(cfg.num_experts, 4),
                  experts_per_token=min(cfg.experts_per_token, 2),
                  moe_d_ff=64)
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_head_dim=16)
    if cfg.mrope:
        kw.update(mrope_sections=(2, 3, 3))   # sums to head_dim/2 = 8
    if cfg.is_encdec:
        kw.update(encoder_groups=shrink_groups(cfg.encoder_groups))
    if cfg.shared_attn_heads:
        kw.update(shared_attn_heads=4, shared_attn_kv_heads=2)
    return cfg.replace(**kw)
