"""Gemma3-12B — dense GQA, 5:1 local:global attention, 128k context.

[hf:google/gemma-3-*-pt; unverified].  48 layers arranged as 8 groups of
(5 x sliding-window local + 1 x global).  Sliding-window attention makes the
model sub-quadratic-dominated, so the 500k decode shape is lowered for it.
"""
from repro.configs.base import GroupSpec, LayerSpec, ModelConfig, register

_LOCAL = LayerSpec(mixer="attn_local", mlp="dense")
_GLOBAL = LayerSpec(mixer="attn", mlp="dense")

CONFIG = register(ModelConfig(
    name="gemma3-12b",
    family="dense",
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=240,
    d_ff=15360,
    vocab_size=262144,
    groups=(GroupSpec((_LOCAL,) * 5 + (_GLOBAL,), 8),),
    window_size=1024,
    qk_norm=True,
    rope_theta=1_000_000.0,
    subquadratic=True,   # sliding-window dominated; 500k decode allowed
))
