"""SeamlessM4T-medium — encoder-decoder, multimodal [arXiv:2308.11596].

Transformer backbone only: the speech frontend is a stub and the encoder
consumes precomputed frame embeddings (B, S_src, d_model).  12 encoder layers
(bidirectional) + 12 decoder layers (causal self-attn + cross-attn).  Decode
shapes lower the *decoder* step (self-KV cache of seq_len, cross-attn to
seq_len//4 encoder states).  500k decode is skipped: full attention and no
long-context use-case for a speech model.
"""
from repro.configs.base import ModelConfig, dense_groups, register

CONFIG = register(ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    groups=dense_groups(12),            # decoder
    is_encdec=True,
    encoder_groups=dense_groups(12),    # encoder
    encdec_tgt_ratio=4,
    input_kind="embeds",                # speech frames arrive pre-embedded
))
