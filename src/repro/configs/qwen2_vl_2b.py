"""Qwen2-VL-2B — VLM backbone with M-RoPE [arXiv:2409.12191].

Backbone only (per assignment): the vision frontend is a stub and
``input_specs()`` provides precomputed patch/text embeddings of shape
(B, S, d_model); position ids are 3D (t, h, w) for M-RoPE.
"""
from repro.configs.base import ModelConfig, dense_groups, register

CONFIG = register(ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    groups=dense_groups(28),
    mrope=True,
    mrope_sections=(16, 24, 24),   # halves of head_dim/2 = 64 -> t/h/w splits
    rope_theta=1_000_000.0,
    input_kind="embeds",
))
