"""Zamba2-7B — hybrid Mamba2 backbone + weight-tied shared attention block.

[arXiv:2411.15242; unverified].  81 Mamba2 layers; a single *shared* (weight-
tied) global-attention block is applied every 6th layer (13 applications over
the first 78 layers, then a 3-layer SSD tail).  Hybrid => the 500k decode shape
runs (SSD state is constant-size; attention KV is sharded over the mesh).
"""
from repro.configs.base import GroupSpec, LayerSpec, ModelConfig, register

_SSD = LayerSpec(mixer="ssd", mlp="none")
_SSD_ATTN = LayerSpec(mixer="ssd", mlp="none", shared_attn=True)

CONFIG = register(ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14336,                  # width of the shared block's MLP
    vocab_size=32000,
    groups=(
        GroupSpec((_SSD,) * 5 + (_SSD_ATTN,), 13),   # 78 layers, 13 shared-attn hits
        GroupSpec((_SSD,), 3),                        # tail
    ),
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_ngroups=1,
    shared_attn_heads=32,
    shared_attn_kv_heads=32,
    subquadratic=True,
))
