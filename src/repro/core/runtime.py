"""Execution runtimes for the tracker/agent protocol.

Two interchangeable runtimes drive the same Node code:

  * SimRuntime    — deterministic discrete-event simulation on a virtual
                    clock.  Work durations come from each application's
                    cost_fn and per-node speed factors; message latency from a
                    simple base+bytes/bw model.  Used to reproduce the paper's
                    Tables I-IV at full scale in milliseconds of wall time.
  * ThreadRuntime — a real-time event loop (dispatcher thread + worker pool).
                    RUN executes the actual application function (the prime
                    search really runs).  Used by examples and integration
                    tests at reduced scale.

Nodes are event-driven: the runtime calls ``on_message`` and ``on_timer``.
"""
from __future__ import annotations

import heapq
import itertools
import queue
import random
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Set, Tuple

from repro.core.faults import FaultPlan
from repro.core.messages import Msg
from repro.core.topology import Topology


class Node:
    node_id: str = "?"

    def start(self, rt: "Runtime") -> None:
        self.rt = rt

    def on_message(self, msg: Msg) -> None:  # pragma: no cover - interface
        raise NotImplementedError

    def on_timer(self, name: str) -> None:
        pass

    def on_work_done(self, tag: Any, result: Any, elapsed_s: float) -> None:
        pass


@dataclass
class LinkModel:
    base_latency_s: float = 0.002
    bandwidth_Bps: float = 100e6 / 8 * 0.9   # ~100BASE-TX payload rate
    # per-node uplink capacity; when set, a node's *bulk* sends serialise
    # through its egress pipe (so a seeder fanning out to N leechers pays N
    # transfer times, which is what makes swarm vs single-seeder
    # measurable).  Control messages below the threshold interleave with
    # bulk transfers, as packets do on a real link — otherwise a seeder's
    # PONGs would queue behind multi-MB pieces and the tracker would
    # declare it dead.
    uplink_Bps: Optional[float] = None
    # per-node downlink capacity, mirroring the uplink model: bulk
    # transfers *into* a node serialise through its ingress pipe.  Without
    # it an unchoked seeder could fan N pieces into one leecher "for free"
    # and choking would not be measurable.
    downlink_Bps: Optional[float] = None
    bulk_threshold_bytes: int = 1 << 16

    def latency(self, size_bytes: int) -> float:
        return self.base_latency_s + size_bytes / self.bandwidth_Bps

    def tx_time(self, size_bytes: int) -> float:
        return size_bytes / (self.uplink_Bps or self.bandwidth_Bps)

    def rx_time(self, size_bytes: int) -> float:
        return size_bytes / (self.downlink_Bps or self.bandwidth_Bps)


class Runtime:
    def now(self) -> float:
        raise NotImplementedError

    def send(self, dst: str, msg: Msg) -> None:
        raise NotImplementedError

    def set_timer(self, node_id: str, name: str, delay_s: float,
                  periodic: bool = False) -> None:
        raise NotImplementedError

    def cancel_timer(self, node_id: str, name: str) -> None:
        raise NotImplementedError

    def submit_work(self, node_id: str, tag: Any, fn: Callable[[], Any],
                    sim_duration_s: Optional[float] = None) -> None:
        raise NotImplementedError

    def cancel_work(self, node_id: str, tag: Any) -> bool:
        """Best-effort abort of submitted-but-unfinished work.  Returns True
        when the job was removed before completing (its ``on_work_done``
        will never fire); False when it already ran or cannot be stopped —
        the caller must then discard the eventual result itself."""
        return False


# sentinel result delivered by ThreadRuntime for work cancelled after its
# queue pop could no longer be prevented; nodes must discard it
CANCELLED = object()


# --------------------------------------------------------------------------- #
class SimRuntime(Runtime):
    """Deterministic discrete-event simulator.

    An optional `FaultPlan` (core.faults) injects seeded, reproducible
    chaos: per-link loss/duplication/jitter, timed partitions and node
    crash/restart schedules.  All fault randomness comes from one
    `random.Random(plan.seed)` and is only drawn when the effective fault
    is non-trivial, so a zero-fault plan leaves the event trace untouched.

    An optional `Topology` (core.topology) layers a WAN over the flat
    LinkModel: messages crossing island (ISP) boundaries pay the
    inter-island latency, bulk transfers additionally serialise through
    the shared inter-island trunk pipe (when the topology carries a
    bandwidth matrix), and every cross-island byte is accounted in
    `cross_isp_bytes` — the metric Scenario IX's P4P selection exists to
    cut.  `topology=None` (or a flat single-island topology) leaves the
    trace event-for-event identical, like a zero-fault plan.
    """

    def __init__(self, link: Optional[LinkModel] = None,
                 faults: Optional[FaultPlan] = None,
                 topology: Optional[Topology] = None):
        self.nodes: Dict[str, Node] = {}
        self.link = link or LinkModel()
        self._t = 0.0
        self._seq = itertools.count()
        # event heap entries are (time, seq, bound_method, args) tuples —
        # no per-event closure allocation on the send/timer hot paths
        self._heap: List[Tuple[float, int, Callable, tuple]] = []
        # timer cancellation by version counter: the scheduled event
        # carries the version it was armed with and fires only while it is
        # still current.  Unlike the old tombstone set (which grew with
        # every cancel until the same timer was re-armed), this stays at
        # one dict entry per live (node, name) key.
        self._timer_ver: Dict[Tuple[str, str], int] = {}
        self.speed: Dict[str, float] = {}
        # total events executed by run() — simulator-throughput metric
        self.events_processed = 0
        # run_batched wall split: message-burst drains vs on_tick passes
        self.batched_drain_s = 0.0
        self.batched_tick_s = 0.0
        # per-node egress accounting and uplink/downlink-contention state
        self.tx_bytes: Dict[str, int] = {}
        self._uplink_free: Dict[str, float] = {}
        self._downlink_free: Dict[str, float] = {}
        # processor-sharing executor state (per node): jobs share the core,
        # like the paper's clients running two app processes on one-core VMs
        self._ps_jobs: Dict[str, Dict[int, list]] = {}
        self._ps_last: Dict[str, float] = {}
        self._ps_event: Dict[str, int] = {}
        # called with the node id on every crash() — the authoritative
        # liveness signal for batched-mode swarm state (PEER_GONE relays
        # can arrive after a restart and must not wipe the fresh state)
        self.crash_hooks: List[Callable[[str], None]] = []
        # --- WAN topology (core.topology) ------------------------------ #
        self.topology = topology
        # cross-island egress accounting — Scenario IX's headline metric
        self.cross_isp_bytes = 0
        # (src_island, dst_island) -> time the shared trunk frees up
        self._xlink_free: Dict[Tuple[int, int], float] = {}
        # --- fault injection (core.faults) ----------------------------- #
        self.faults = faults
        self._rng = random.Random(faults.seed) if faults is not None else None
        # private copy: drop_next counters are consumed as messages match
        self._drop_next: Dict[Tuple[str, str, str], int] = \
            dict(faults.drop_next) if faults is not None else {}
        self.crashed: Set[str] = set()
        # node_id -> factory building a fresh incarnation on restart; when
        # absent the old object is resumed with its memory intact
        self.restart_factory: Dict[str, Callable[[], Node]] = {}
        self._crashed_nodes: Dict[str, Tuple[Node, float]] = {}
        self.dropped_msgs = 0
        self.dup_msgs = 0
        self.crash_count = 0
        self.restart_count = 0
        if faults is not None:
            for c in faults.crashes:
                self._at(c.at_s, self.crash, (c.node,))
                if c.restart_s is not None:
                    self._at(c.restart_s, self.restart, (c.node,))

    def add_node(self, node: Node, speed: float = 1.0) -> None:
        self.nodes[node.node_id] = node
        self.speed[node.node_id] = speed
        node.start(self)

    def now(self) -> float:
        return self._t

    def _at(self, t: float, fn: Callable, args: tuple = ()) -> None:
        heapq.heappush(self._heap, (t, next(self._seq), fn, args))

    def send(self, dst: str, msg: Msg) -> None:
        src = msg.src
        self.tx_bytes[src] = self.tx_bytes.get(src, 0) + msg.size_bytes
        bulk = msg.size_bytes >= self.link.bulk_threshold_bytes
        if bulk and (self.link.uplink_Bps is not None
                     or self.link.downlink_Bps is not None):
            # the endpoint pipes replace the generic shared-bandwidth term
            # (they ARE the transfer-time model for bulk messages): first
            # serialise through the sender's uplink, then through the
            # receiver's downlink, so concurrent seeders fanning into one
            # node queue behind each other at its ingress
            t = self._t
            if self.link.uplink_Bps is not None:
                start = max(t, self._uplink_free.get(src, 0.0))
                t = start + self.link.tx_time(msg.size_bytes)
                self._uplink_free[src] = t
            if self.link.downlink_Bps is not None:
                start = max(t, self._downlink_free.get(dst, 0.0))
                t = start + self.link.rx_time(msg.size_bytes)
                self._downlink_free[dst] = t
            at = t + self.link.base_latency_s
        else:
            at = self._t + self.link.latency(msg.size_bytes)
        if self.topology is not None:
            at = self._topo_delay(src, dst, msg, bulk, at)
        if self.faults is not None:
            # loss/dup/jitter apply past the pipe model: the bytes were
            # transmitted (and accounted), the network lost them.  RNG is
            # drawn only for non-trivial faults so a zero-fault plan
            # leaves the trace untouched.
            key = (src, dst, msg.kind)
            n = self._drop_next.get(key, 0)
            if n > 0:
                self._drop_next[key] = n - 1
                self.dropped_msgs += 1
                return
            fault = self.faults.link_fault(src, dst)
            if fault:
                if fault.drop_p and self._rng.random() < fault.drop_p:
                    self.dropped_msgs += 1
                    return
                if fault.jitter_s:
                    at += self._rng.random() * fault.jitter_s
                if fault.dup_p and self._rng.random() < fault.dup_p:
                    # duplicate delivery, independently jittered (payloads
                    # are treated read-only by receivers, so sharing the
                    # Msg is safe — same convention as tracker relays)
                    self.dup_msgs += 1
                    extra = (self._rng.random() * fault.jitter_s
                             if fault.jitter_s else self.link.base_latency_s)
                    self._at(at + extra, self._deliver, (dst, msg))
        self._at(at, self._deliver, (dst, msg))

    def _topo_delay(self, src: str, dst: str, msg: Msg,
                    bulk: bool, at: float) -> float:
        """WAN leg of a transfer.  Intra-island messages pass through
        untouched (a zero latency is never added, so a flat topology is
        event-for-event identical to no topology).  Cross-island bulk
        transfers additionally serialise through the shared per-island-pair
        trunk pipe when the topology carries a bandwidth matrix."""
        topo = self.topology
        si = topo.island_of(src)
        di = topo.island_of(dst)
        if si != di:
            self.cross_isp_bytes += msg.size_bytes
            if bulk:
                bw = topo.trunk_Bps(si, di)
                if bw is not None:
                    start = max(at, self._xlink_free.get((si, di), 0.0))
                    at = start + msg.size_bytes / bw
                    self._xlink_free[(si, di)] = at
        extra = topo.latency(si, di)
        if extra:
            at += extra
        return at

    def _deliver(self, dst: str, msg: Msg) -> None:
        if self.faults is not None \
                and self.faults.cut(msg.src, dst, self._t):
            # partitions cut at delivery time, so in-flight messages
            # crossing the cut are lost too
            self.dropped_msgs += 1
            return
        node = self.nodes.get(dst)
        if node is not None:
            node.on_message(msg)

    # ---- crash / restart (fault injection) ---------------------------- #
    def crash(self, node_id: str) -> None:
        """Kill a node: it stops receiving messages, all its timers and
        in-flight work die.  In-flight messages it already sent still
        deliver (they are in the network, not the process)."""
        node = self.nodes.pop(node_id, None)
        if node is None:
            return
        self.crashed.add(node_id)
        self._crashed_nodes[node_id] = (node, self.speed.get(node_id, 1.0))
        self.crash_count += 1
        for hook in self.crash_hooks:
            hook(node_id)
        for key in [k for k in self._timer_ver if k[0] == node_id]:
            self._timer_ver[key] += 1        # every armed timer dies
        self._ps_jobs.pop(node_id, None)
        self._ps_last.pop(node_id, None)
        self._ps_event.pop(node_id, None)    # scheduled _ps_fire is stale

    def restart(self, node_id: str) -> None:
        """Bring a crashed node back.  A registered `restart_factory`
        builds a fresh incarnation (volatile state lost, only disk
        survives — the realistic crash model); without one the old object
        resumes with its memory intact (suspend/resume).  Either way the
        node's start() runs again, so agents re-register with the
        tracker."""
        if node_id not in self.crashed:
            return
        self.crashed.discard(node_id)
        old, speed = self._crashed_nodes.pop(node_id)
        factory = self.restart_factory.get(node_id)
        node = factory() if factory is not None else old
        self.restart_count += 1
        self.add_node(node, speed=speed)

    def set_timer(self, node_id: str, name: str, delay_s: float,
                  periodic: bool = False) -> None:
        key = (node_id, name)
        ver = self._timer_ver.get(key, 0) + 1    # latest set supersedes
        self._timer_ver[key] = ver
        self._at(self._t + delay_s, self._fire_timer,
                 (key, ver, delay_s, periodic))

    def cancel_timer(self, node_id: str, name: str) -> None:
        key = (node_id, name)
        self._timer_ver[key] = self._timer_ver.get(key, 0) + 1

    def _fire_timer(self, key: Tuple[str, str], ver: int, delay_s: float,
                    periodic: bool) -> None:
        if self._timer_ver.get(key) != ver:
            return                   # cancelled, or superseded by a re-set
        node = self.nodes.get(key[0])
        if node is None:
            return
        node.on_timer(key[1])
        if periodic and self._timer_ver.get(key) == ver:
            self._at(self._t + delay_s, self._fire_timer,
                     (key, ver, delay_s, periodic))

    # ---- processor-sharing work executor ------------------------------ #
    def _ps_advance(self, node_id: str) -> None:
        jobs = self._ps_jobs.setdefault(node_id, {})
        last = self._ps_last.get(node_id, self._t)
        if jobs and self._t > last:
            rate = self.speed.get(node_id, 1.0) / len(jobs)
            dt = self._t - last
            for j in jobs.values():
                j[0] -= dt * rate          # remaining work units
        self._ps_last[node_id] = self._t

    def _ps_schedule(self, node_id: str) -> None:
        jobs = self._ps_jobs.get(node_id, {})
        token = next(self._seq)
        self._ps_event[node_id] = token
        if not jobs:
            return
        rate = self.speed.get(node_id, 1.0) / len(jobs)
        jid, job = min(jobs.items(), key=lambda kv: kv[1][0])
        eta = self._t + max(job[0], 0.0) / rate
        self._at(eta, self._ps_fire, (node_id, token))

    def _ps_fire(self, node_id: str, token: int) -> None:
        if self._ps_event.get(node_id) != token:
            return                          # superseded by a newer event
        self._ps_advance(node_id)
        jobs = self._ps_jobs.get(node_id, {})
        done = [k for k, j in jobs.items() if j[0] <= 1e-9]
        for k in done:
            work, tag, fn, t0 = jobs.pop(k)
            node = self.nodes.get(node_id)
            if node is not None:
                result = fn() if fn is not None else None
                node.on_work_done(tag, result, self._t - t0)
        self._ps_schedule(node_id)

    def submit_work(self, node_id: str, tag: Any, fn: Callable[[], Any],
                    sim_duration_s: Optional[float] = None) -> None:
        """Processor sharing: concurrent jobs on a node split its core, like
        the paper's clients running one process per leeched application."""
        dur = sim_duration_s if sim_duration_s is not None else 0.0
        self._ps_advance(node_id)
        jid = next(self._seq)
        # [remaining_work_units, tag, fn, started_at]
        self._ps_jobs.setdefault(node_id, {})[jid] = [dur, tag, fn, self._t]
        self._ps_schedule(node_id)

    def cancel_work(self, node_id: str, tag: Any) -> bool:
        """Remove an unfinished job from the processor-sharing executor; the
        remaining jobs immediately reclaim its share of the core."""
        jobs = self._ps_jobs.get(node_id)
        if not jobs:
            return False
        for jid, job in list(jobs.items()):
            if job[1] == tag:
                self._ps_advance(node_id)
                jobs.pop(jid, None)
                self._ps_schedule(node_id)
                return True
        return False

    def run(self, until: Optional[float] = None,
            stop_when: Optional[Callable[[], bool]] = None,
            max_events: int = 50_000_000) -> float:
        n = 0
        heap = self._heap
        while heap and n < max_events:
            if until is not None and heap[0][0] > until:
                break
            t, _, fn, args = heapq.heappop(heap)
            self._t = t
            fn(*args)
            n += 1
            if stop_when is not None and n % 64 == 0 and stop_when():
                break
        self.events_processed += n
        return self._t

    def run_batched(self, until: Optional[float] = None,
                    stop_when: Optional[Callable[[], bool]] = None,
                    tick_s: float = 0.25,
                    on_tick: Optional[Callable[[float], None]] = None,
                    max_events: int = 50_000_000) -> float:
        """Batched-delivery mode: drain every due event up to the next
        tick boundary in one burst, then call `on_tick(now)` (the
        SwarmHub's batched decision pass) at the boundary.

        Shares `run()`'s heap, its single monotonic `_seq` counter and
        the `events_processed` total, so the two modes can interleave
        freely — same-tick events keep their insertion order no matter
        which mode pops them, and with `on_tick=None` this produces a
        trace identical to `run()` pop for pop (the mixed-mode
        determinism regression test asserts exactly that).

        Events scheduled *during* a burst at times inside the current
        tick are drained in the same burst, so intra-tick message
        cascades behave as in per-message mode; only the on_tick hook
        itself runs at quantized times.

        Wall time is split into `batched_drain_s` (message bursts: the
        per-event host-Python cost) and `batched_tick_s` (the on_tick
        decision passes) so `swarm_bench --profile` can report where a
        batched run actually spends its time."""
        n = 0
        heap = self._heap
        tick = max(float(tick_s), 1e-9)
        stop = False
        perf = time.perf_counter
        while heap and n < max_events and not stop:
            t0 = heap[0][0]
            if until is not None and t0 > until:
                break
            boundary = t0 + tick
            if until is not None:
                boundary = min(boundary, until)
            w0 = perf()
            while heap and heap[0][0] <= boundary and n < max_events:
                t, _, fn, args = heapq.heappop(heap)
                self._t = t
                fn(*args)
                n += 1
                if stop_when is not None and n % 64 == 0 and stop_when():
                    stop = True
                    break
            self.batched_drain_s += perf() - w0
            if stop:
                break
            if on_tick is not None:
                self._t = max(self._t, boundary)
                w0 = perf()
                on_tick(self._t)
                self.batched_tick_s += perf() - w0
                if stop_when is not None and stop_when():
                    break
        self.events_processed += n
        return self._t


# --------------------------------------------------------------------------- #
class ThreadRuntime(Runtime):
    """Real-time event loop: one dispatcher thread + a worker pool."""

    def __init__(self, n_workers: int = 4):
        self.nodes: Dict[str, Node] = {}
        self._q: "queue.Queue" = queue.Queue()
        # (due, seq, (node, name), delay, periodic, version)
        self._timers: List[Tuple[float, int, Tuple[str, str], float,
                                 bool, int]] = []
        self._timer_lock = threading.Lock()
        # version-counter cancellation (see SimRuntime): one entry per
        # live timer key instead of an ever-growing tombstone set
        self._timer_ver: Dict[Tuple[str, str], int] = {}
        self._seq = itertools.count()
        self._stop = threading.Event()
        self._work_q: "queue.Queue" = queue.Queue()
        self._cancelled_work: set = set()
        self._work_lock = threading.Lock()
        self._threads: List[threading.Thread] = []
        self.n_workers = n_workers
        self._t0 = time.monotonic()
        # run-generation token: threads spawned by an earlier run() exit
        # when a newer run starts, instead of surviving a timed-out join
        # and double-consuming the queues
        self._gen = 0

    def add_node(self, node: Node, speed: float = 1.0) -> None:
        self.nodes[node.node_id] = node
        node.start(self)

    def now(self) -> float:
        return time.monotonic() - self._t0

    def send(self, dst: str, msg: Msg) -> None:
        self._q.put(("msg", dst, msg))

    def set_timer(self, node_id: str, name: str, delay_s: float,
                  periodic: bool = False) -> None:
        key = (node_id, name)
        with self._timer_lock:
            ver = self._timer_ver.get(key, 0) + 1
            self._timer_ver[key] = ver
            heapq.heappush(self._timers,
                           (self.now() + delay_s, next(self._seq), key,
                            delay_s, periodic, ver))

    def cancel_timer(self, node_id: str, name: str) -> None:
        key = (node_id, name)
        with self._timer_lock:
            self._timer_ver[key] = self._timer_ver.get(key, 0) + 1

    def submit_work(self, node_id: str, tag: Any, fn: Callable[[], Any],
                    sim_duration_s: Optional[float] = None) -> None:
        self._work_q.put((node_id, tag, fn))

    def cancel_work(self, node_id: str, tag: Any) -> bool:
        """Mark queued work cancelled.  A worker that pops a cancelled job
        skips execution and delivers the CANCELLED sentinel instead; work
        already executing cannot be stopped.  Always returns False — the
        caller must discard the eventual (sentinel or real) result."""
        with self._work_lock:
            self._cancelled_work.add((node_id, tag))
        return False

    # -- loop --------------------------------------------------------------
    def _worker(self, gen: int):
        while not self._stop.is_set() and gen == self._gen:
            try:
                node_id, tag, fn = self._work_q.get(timeout=0.05)
            except queue.Empty:
                continue
            with self._work_lock:
                cancelled = (node_id, tag) in self._cancelled_work
                self._cancelled_work.discard((node_id, tag))
            if cancelled:
                self._q.put(("done", node_id, (tag, CANCELLED, 0.0)))
                continue
            t0 = self.now()
            result = fn() if fn is not None else None
            with self._work_lock:
                # consume a cancel that arrived mid-execution: the mark
                # must not outlive this job and falsely cancel a future
                # submission reusing the same tag
                self._cancelled_work.discard((node_id, tag))
            self._q.put(("done", node_id, (tag, result, self.now() - t0)))

    def _fire_due_timers(self) -> None:
        fired = []
        with self._timer_lock:
            while self._timers and self._timers[0][0] <= self.now():
                t, _, key, delay, periodic, ver = heapq.heappop(
                    self._timers)
                if self._timer_ver.get(key) != ver:
                    continue        # cancelled or superseded by a re-set
                fired.append(key)
                if periodic:
                    # re-arm from the *scheduled* time, not the (late) fire
                    # time, so periodic timers keep their grid instead of
                    # drifting by the handling latency every period; when
                    # overloaded past a full period, skip the missed slots
                    # (re-arming at <= now would re-fire in this same pass)
                    nt = t + delay
                    if nt <= self.now():
                        nt = self.now() + delay
                    heapq.heappush(self._timers,
                                   (nt, next(self._seq), key,
                                    delay, periodic, ver))
        for nid, name in fired:
            node = self.nodes.get(nid)
            if node:
                node.on_timer(name)

    def _dispatch(self, gen: int):
        while not self._stop.is_set() and gen == self._gen:
            # deadline-aware wait: block on the message queue only until
            # the next timer is due, and re-check timers after every
            # message, so a loaded queue cannot starve or drift timers
            self._fire_due_timers()
            with self._timer_lock:
                deadline = self._timers[0][0] if self._timers else None
            wait = 0.05 if deadline is None else deadline - self.now()
            if wait <= 0.0:
                continue
            try:
                kind, dst, data = self._q.get(timeout=min(wait, 0.05))
            except queue.Empty:
                continue
            node = self.nodes.get(dst)
            if node is None:
                continue
            if kind == "msg":
                node.on_message(data)
            else:
                tag, result, dt = data
                node.on_work_done(tag, result, dt)

    def run(self, until_s: float = 30.0,
            stop_when: Optional[Callable[[], bool]] = None) -> None:
        """Drive the loop for up to `until_s`.  Re-entrant: a second call
        restarts the worker/dispatcher threads, so tests can run phases
        (e.g. seed an image, add a node, run again)."""
        for th in self._threads:         # previous phase's threads
            th.join(timeout=1.0)
        self._gen += 1                   # orphans (stuck in a long fn)
        gen = self._gen                  # exit once their job finishes
        self._stop.clear()
        self._threads = []
        for _ in range(self.n_workers):
            th = threading.Thread(target=self._worker, args=(gen,),
                                  daemon=True)
            th.start()
            self._threads.append(th)
        disp = threading.Thread(target=self._dispatch, args=(gen,),
                                daemon=True)
        disp.start()
        self._threads.append(disp)
        deadline = time.monotonic() + until_s
        while time.monotonic() < deadline:
            if stop_when is not None and stop_when():
                break
            time.sleep(0.02)
        self._stop.set()
        for th in self._threads:
            th.join(timeout=1.0)
