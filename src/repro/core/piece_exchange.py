"""PieceExchange: the swarm transfer engine behind the agent (paper §V).

Everything about moving application-image *pieces* between volunteers lives
here, extracted from core/agent.py so the transfer scheduler is a layer of
its own (the way BitTorrent separates the peer wire protocol from piece-
selection policy, and the way BOINC separates its transitioner from the
science app).  The Agent keeps only protocol glue: it routes PIECE_*/HAVE/
CHOKE messages into the engine and reacts to the engine's callbacks.

The engine owns, per application:

  * peer state     — who is in the swarm, which pieces each peer holds
                     (HAVE bitmasks, stored as ints), which full seeders
                     exist;
  * selection      — rarest-first piece ordering (core.swarm policy) with a
                     deterministic per-node tie-break rotation, one in-
                     flight request per holder, bounded pipeline;
  * choke scheduling (seeder side) — a fixed number of upload slots;
                     leechers announce INTERESTED, the engine UNCHOKEs the
                     best reciprocators (rolling-window byte *rates*, not
                     lifetime totals) plus one optimistic slot rotated
                     deterministically so newcomers bootstrap; requests
                     from choked peers are refused with CHOKE so the
                     requester re-routes;
  * endgame        — when every missing piece is already in flight, the
                     outstanding requests are duplicated to all other
                     holders (flagged `endgame`, queued by choked holders
                     instead of refused) and reconciled with PIECE_CANCEL
                     the moment the first copy verifies;
  * real bytes     — when the application image is real (Application.image)
                     PIECE_DATA carries the actual payload slice, verified
                     by re-hashing; verified pieces are cached on disk via
                     AgentDirs and reassembled into the replica's Seed copy
                     on completion.  Synthetic (simulation) images move as
                     hash proofs over the identical code path.

Scaling (bitmask-native hot paths).  All per-pump bookkeeping is
incremental so a node's cost per scheduling decision is O(P log P) in the
piece count and *independent of swarm size*:

  * a per-app numpy int32 availability-count array is updated on HAVE
    bitmask deltas, seeder-set changes and PEER_GONE instead of being
    rebuilt O(P·N) on every pump;
  * a per-piece holder index and a cached holder pool replace the per-piece
    O(N) peer rescans;
  * full seeders contribute the same constant to every piece's
    availability, so rarest-first sorts on the partial-holder counts alone
    (`rarest_first_order_np`, an argsort over the count array);
  * real piece payloads are zero-copy `memoryview` slices over one shared
    image buffer, and completed images are interned by manifest hash so N
    replicas cost O(image) memory, not O(N·image).

The pre-optimization paths are kept (`_pump_reference`, `_avail_naive`,
`_holders_naive`) as the reference implementation: differential tests
assert the fast path issues identical requests, and
benchmarks/exchange_bench.py measures the speedup against them.
"""
from __future__ import annotations

import collections
from typing import Any, Callable, Dict, List, Optional, Set

import numpy as np

from repro.core.messages import (CHOKE, HAVE, INTERESTED, PIECE_CANCEL,
                                 PIECE_DATA, PIECE_REQ, UNCHOKE, Msg)
from repro.core.swarm import rarest_first_order, rarest_first_order_np
from repro.core.workunit import PieceInventory, PieceManifest, mask_nbytes


def iter_bits(mask: int):
    """Yield the set bit positions of an int bitmask, lowest first."""
    while mask:
        low = mask & -mask
        yield low.bit_length() - 1
        mask ^= low


class RollingRate:
    """Sliding-window byte-rate estimator for the rechoke ranking.

    `add(t, n)` records a transfer; `rate(now)` returns bytes/sec over the
    trailing `window_s` seconds.  Replaces the cumulative byte counters in
    choke ranking so a peer that moved bytes long ago stops outranking
    peers that are moving bytes *now* (stale-transfer dominance in
    long-lived swarms was a ROADMAP open item)."""

    __slots__ = ("window_s", "_events", "_total")

    def __init__(self, window_s: float):
        self.window_s = max(window_s, 1e-9)
        self._events: collections.deque = collections.deque()
        self._total = 0

    def add(self, t: float, nbytes: int) -> None:
        self._events.append((t, nbytes))
        self._total += nbytes
        # prune on write as well as read: an estimator that is fed but
        # never ranked (e.g. a seeder we download from but never serve)
        # must not retain one entry per piece forever
        self._prune(t)

    def rate(self, now: float) -> float:
        self._prune(now)
        return self._total / self.window_s

    def _prune(self, now: float) -> None:
        cutoff = now - self.window_s
        ev = self._events
        while ev and ev[0][0] <= cutoff:
            self._total -= ev.popleft()[1]


# Completed real images interned by manifest hash: every node that holds the
# same verified image shares ONE immutable bytes buffer, so a simulation
# with N replicas costs O(image) memory instead of O(N·image).  The keys are
# content-derived (the info-hash covers the per-piece content hashes), so a
# cache hit carries exactly the trust piece verification already
# established.
#
# Entries are REFCOUNTED: every engine that maps an app to the buffer holds
# a reference (acquired in add_local_app/_complete_fetch, released by
# upgrade()/drop_app()).  With versioned manifests each upgrade retires a
# whole image under a hash nobody will ever intern again — without the
# release, 5 upgrades leak 5 full buffers per app.  Unreferenced entries
# are kept as a small LRU dedup tail (a late joiner completing v(k) right
# after everyone upgraded still dedups) bounded by _IMAGE_INTERN_MAX;
# referenced entries are never evicted.
_IMAGE_INTERN: "collections.OrderedDict[str, bytes]" = collections.OrderedDict()
_IMAGE_REFS: Dict[str, int] = {}
_IMAGE_INTERN_MAX = 8


def _evict_unreferenced() -> None:
    excess = sum(1 for mh in _IMAGE_INTERN if mh not in _IMAGE_REFS) \
        - _IMAGE_INTERN_MAX
    if excess <= 0:
        return
    for mh in [m for m in _IMAGE_INTERN if m not in _IMAGE_REFS][:excess]:
        del _IMAGE_INTERN[mh]


def intern_image(manifest_hash: str, image) -> bytes:
    """Insert (or dedup against) the shared buffer AND acquire one
    reference; pair every call with a release_image."""
    cached = _IMAGE_INTERN.get(manifest_hash)
    if cached is None:
        cached = bytes(image) if isinstance(image, memoryview) else image
        _IMAGE_INTERN[manifest_hash] = cached
    else:
        _IMAGE_INTERN.move_to_end(manifest_hash)
    _IMAGE_REFS[manifest_hash] = _IMAGE_REFS.get(manifest_hash, 0) + 1
    _evict_unreferenced()
    return cached


def acquire_image(manifest_hash: str) -> Optional[bytes]:
    """Acquire a reference on an already-interned buffer (None on miss)."""
    cached = _IMAGE_INTERN.get(manifest_hash)
    if cached is not None:
        _IMAGE_INTERN.move_to_end(manifest_hash)
        _IMAGE_REFS[manifest_hash] = _IMAGE_REFS.get(manifest_hash, 0) + 1
    return cached


def release_image(manifest_hash: str) -> None:
    n = _IMAGE_REFS.get(manifest_hash, 0)
    if n <= 1:
        _IMAGE_REFS.pop(manifest_hash, None)
        _evict_unreferenced()
    else:
        _IMAGE_REFS[manifest_hash] = n - 1


def interned_image_count() -> int:
    """Number of interned buffers currently held (the RSS proxy the
    intern-growth regression test bounds across upgrades)."""
    return len(_IMAGE_INTERN)


class PieceExchange:
    """Per-agent swarm transfer engine.

    `send(dst, msg)` and `now()` come from the owning agent; `tracker_id`
    is where join/HAVE announces go for relay.  `on_image_complete(app_id,
    manifest_hash, image_bytes)` fires once per verified image;
    `on_bytes(app_id, n)` accounts received piece payload.
    """

    def __init__(self, node_id: str, cfg, *,
                 send: Callable[[str, Msg], None],
                 now: Callable[[], float],
                 tracker_id: str = "server",
                 dirs=None,
                 on_image_complete: Optional[Callable] = None,
                 on_bytes: Optional[Callable[[str, int], None]] = None,
                 hub=None):
        self.node_id = node_id
        self.cfg = cfg
        self.send = send
        self.now = now
        self.tracker_id = tracker_id
        self.dirs = dirs
        self.on_image_complete = on_image_complete
        self.on_bytes = on_bytes
        # hub mode (core/swarm_arrays.SwarmHub): decisions come from the
        # shared arrays' batched per-tick passes instead of per-message
        # pumps, and the control plane (HAVE fan-out, INTERESTED,
        # UNCHOKE/CHOKE) is applied through the arrays instead of the
        # wire.  Piece traffic stays on the simulated wire either way.
        self.hub = hub
        # False switches pump to the pre-optimization reference path
        # (kept for differential tests and the exchange micro-benchmark)
        self.use_incremental = True
        # --- image / holdings state ------------------------------------- #
        self.manifests: Dict[str, PieceManifest] = {}
        self.inventories: Dict[str, PieceInventory] = {}
        self.complete: Set[str] = set()          # full verified images held
        self.fetching: Set[str] = set()          # apps being leeched
        # real image payloads, as views over the interned shared buffer
        self.image_src: Dict[str, memoryview] = {}
        self.store: Dict[str, Dict[int, Any]] = \
            collections.defaultdict(dict)        # real piece payload views
        # --- swarm peer state -------------------------------------------- #
        self.full_seeders: Dict[str, Set[str]] = collections.defaultdict(set)
        # app -> peer -> HAVE bitmask (bit p set <=> peer holds piece p)
        self.peer_masks: Dict[str, Dict[str, int]] = \
            collections.defaultdict(dict)
        self.swarm_peers: Dict[str, Set[str]] = collections.defaultdict(set)
        self.bad_peers: Dict[str, Set[str]] = collections.defaultdict(set)
        # piece -> {holder: asked_at}; >1 holder only in endgame
        self.pending: Dict[str, Dict[int, Dict[str, float]]] = \
            collections.defaultdict(dict)
        self.peer_load: Dict[str, int] = collections.defaultdict(int)
        # app -> holder -> pieces for which it is the SOLE pending holder
        # (the only requests a CHOKE must re-route); maintained by the
        # _req_* funnel so on_choke touches one holder, not the whole set
        self._sole_pending: Dict[str, Dict[str, Set[int]]] = {}
        # app -> piece -> holders whose request for it went stale
        # (recover()): the re-request prefers an *alternate* holder, so a
        # black-holed link cannot capture a piece's retries forever.
        # Cleared per piece the moment a copy verifies.
        self.stalled_holders: Dict[str, Dict[int, Set[str]]] = {}
        # --- ALTO cost map (tracker COST_MAP; P4P holder preference) ------ #
        # None until a COST_MAP arrives; then holder tie-breaks prefer
        # cheap (same-island) peers.  Shun/stall signals always dominate
        # the cost, so the bias decays when same-island holders starve.
        self.my_island = 0
        self.island_costs: Optional[List[int]] = None
        self.peer_islands: Dict[str, int] = {}
        # --- incremental availability (tentpole) -------------------------- #
        # per-app int32 array: how many *partial* holders have each piece
        # (full seeders add a uniform constant tracked by len(full_seeders))
        self._counts: Dict[str, np.ndarray] = {}
        # per-app, per-piece set of partial holders (the holder index)
        self._piece_holders: Dict[str, List[Set[str]]] = {}
        # cached holder pool; dropped on any membership change
        self._pool_cache: Dict[str, Set[str]] = {}
        # apps whose holder pool is unchanged since the last INTERESTED pass
        self._interest_clean: Set[str] = set()
        # --- choke scheduler (serving side) ------------------------------ #
        self.interested: Dict[str, Set[str]] = collections.defaultdict(set)
        self.unchoked: Dict[str, Set[str]] = collections.defaultdict(set)
        self.opt_unchoked: Dict[str, str] = {}
        self._opt_idx: Dict[str, int] = collections.defaultdict(int)
        self._rechoke_round = 0
        # app -> peer -> queued endgame piece requests (served on unchoke)
        self.queued_reqs: Dict[str, Dict[str, Set[int]]] = \
            collections.defaultdict(dict)
        # --- choke view (leeching side) ---------------------------------- #
        self.unchoked_by: Dict[str, Set[str]] = collections.defaultdict(set)
        self.interest_sent: Dict[str, Set[str]] = collections.defaultdict(set)
        # --- accounting --------------------------------------------------- #
        self.bytes_from: Dict[str, int] = collections.defaultdict(int)
        self.bytes_to: Dict[str, int] = collections.defaultdict(int)
        self._rate_window = float(getattr(cfg, "rate_window_s", 20.0))
        self.rate_from: Dict[str, RollingRate] = {}
        self.rate_to: Dict[str, RollingRate] = {}
        self.pieces_from: Dict[str, Dict[str, int]] = \
            collections.defaultdict(lambda: collections.defaultdict(int))
        self.cancels_sent = 0
        self.dup_piece_data = 0
        # --- versioned-manifest (delta distribution) accounting ----------- #
        # app_id -> manifest_hash of the interned buffer this engine holds
        # a reference on (released on upgrade/drop)
        self._interned: Dict[str, str] = {}
        self.upgrades = 0                # revisions applied locally
        self.reused_pieces = 0           # pieces carried over re-verified
        self.stale_piece_data = 0        # version-mismatched PIECE_DATA
        #                                  discarded (NOT a ban — honest
        #                                  peers on the old revision)
        self.stale_reqs_refused = 0      # version-mismatched PIECE_REQ
        self.stale_have_demoted = 0      # old-version HAVEs that demoted
        #                                  the announcing peer
        # tripwire for the mixed-version invariant: a version-mismatched
        # payload must NEVER reach the inventory.  Incremented only if the
        # discard gate is bypassed; chaos scenarios assert it stays 0.
        self.stale_accepts = 0

    # ======================== ALTO cost map (P4P) ======================= #
    def set_cost_map(self, island: int, costs: List[int],
                     islands: Optional[Dict[str, int]] = None) -> None:
        """Install the tracker's COST_MAP: this node's island, its
        endpoint-cost row (cost to every island), and the peer->island
        directory.  Idempotent; a re-REGISTER just refreshes it."""
        self.my_island = int(island)
        self.island_costs = list(costs)
        if islands:
            self.peer_islands.update(islands)

    def _peer_cost(self, peer: str) -> int:
        """ALTO cost to a peer; 0 before any COST_MAP arrives (flat
        world), and pessimistically the most expensive known cost for
        peers the directory does not list."""
        if self.island_costs is None:
            return 0
        isl = self.peer_islands.get(peer)
        if isl is None or not 0 <= isl < len(self.island_costs):
            return max(self.island_costs)
        return self.island_costs[isl]

    # ===================== lifecycle / membership ======================= #
    def add_local_app(self, app_id: str, manifest: PieceManifest,
                      image=None) -> None:
        """Register an app whose full image this node already holds (origin
        seeder, or a replica restored from disk)."""
        self.manifests[app_id] = manifest
        self.complete.add(app_id)
        if image is not None:
            if manifest.content_hashed:
                image = intern_image(manifest.manifest_hash, image)
                self._track_intern(app_id, manifest.manifest_hash)
            self.image_src[app_id] = memoryview(image)
        if self.hub is not None:
            self.hub.register_seed(self, app_id, manifest)

    def _track_intern(self, app_id: str, manifest_hash: str) -> None:
        """Record that this engine holds one intern reference for the app,
        releasing any reference it held for a previous revision."""
        old = self._interned.get(app_id)
        if old == manifest_hash:
            release_image(manifest_hash)     # already held: keep one ref
            return
        if old is not None:
            release_image(old)
        self._interned[app_id] = manifest_hash

    def join(self, app_id: str, manifest: PieceManifest) -> None:
        """Start leeching an app image piece-wise; announces the bitfield
        to the tracker so swarm members discover each other.  An intact
        on-disk piece cache (an agent restarting mid-download) is re-hashed
        into the inventory first, so only the genuinely missing pieces are
        fetched."""
        self.manifests.setdefault(app_id, manifest)
        inv = self.inventories.setdefault(app_id, PieceInventory(manifest))
        self.fetching.add(app_id)
        if self.hub is not None:
            # hub mode: the shared arrays replace the tracker announce +
            # HAVE relay discovery loop; cache-restored pieces are folded
            # into the swarm-wide availability directly
            self.hub.register_leech(self, app_id, manifest)
            self._rescan_cache(app_id, inv)
            for piece_id in inv.have:
                self.hub.note_have(self, app_id, piece_id)
            if inv.complete:
                self._complete_fetch(app_id)
            return
        # build the availability index now: announces that arrived before
        # the manifest get folded in (and complete peers promoted) here
        self._arrays(app_id)
        self._rescan_cache(app_id, inv)
        self.send(self.tracker_id, self._have_msg(app_id))
        if inv.complete:
            self._complete_fetch(app_id)
        else:
            self.pump(app_id)

    def _rescan_cache(self, app_id: str, inv: PieceInventory) -> int:
        """Restart support (ROADMAP open item): verify pieces cached under
        Leech/App/<id>/Pieces back into the inventory instead of
        re-fetching everything.  Corrupt or foreign cache files are
        deleted so the pieces are fetched from the swarm.  Returns the
        number of pieces restored."""
        if self.dirs is None or inv.have or not inv.manifest.content_hashed:
            return 0
        restored = 0
        for piece_id in self.dirs.list_pieces(app_id):
            data = (self.dirs.load_piece(app_id, piece_id)
                    if 0 <= piece_id < inv.manifest.n_pieces else None)
            if data is not None and inv.add(piece_id, data=data):
                self.store[app_id][piece_id] = data
                restored += 1
            else:
                self.dirs.drop_piece(app_id, piece_id)
        return restored

    def note_full_seeders(self, app_id: str, seeders: Set[str]) -> None:
        seeders = set(seeders)
        if seeders != self.full_seeders.get(app_id):
            # guard: APP_LIST re-pushes the same set every refresh; only a
            # real change may invalidate the cached holder pool
            self.full_seeders[app_id] = seeders
            self._pool_changed(app_id)

    # ================== versioned manifests (delta path) ================= #
    def _reset_swarm_view(self, app_id: str) -> None:
        """Forget everything known about the swarm FOR THE PREVIOUS
        revision: masks, availability, seeder sets, in-flight requests and
        upload grants all describe v(k) holdings and must never leak into
        v(k+1) scheduling.  Swarm *membership* (who to announce to) is
        kept — the same nodes are upgrading with us."""
        self._req_drop_app(app_id)
        self.stalled_holders.pop(app_id, None)
        self.peer_masks.pop(app_id, None)
        self.full_seeders.pop(app_id, None)
        self._counts.pop(app_id, None)
        self._piece_holders.pop(app_id, None)
        self._pool_cache.pop(app_id, None)
        self._interest_clean.discard(app_id)
        self.interest_sent.pop(app_id, None)
        # upload grants belong to the old revision too; no CHOKE burst is
        # needed — our v(k+1) HAVE makes old-version peers drop us, and a
        # straggler's request bounces off the version gate with a HAVE
        self.interested.pop(app_id, None)
        self.unchoked.pop(app_id, None)
        self.opt_unchoked.pop(app_id, None)
        self.queued_reqs.pop(app_id, None)

    def _read_old_piece(self, app_id: str, old_manifest: PieceManifest,
                        old_image, old_store: Dict[int, Any], piece_id: int):
        """Bytes of a piece as held under the previous revision (shared
        image view, per-piece store, or the on-disk cache)."""
        if old_image is not None:
            lo = piece_id * old_manifest.piece_bytes
            return old_image[lo:lo + old_manifest.piece_bytes]
        data = old_store.get(piece_id)
        if data is None and self.dirs is not None:
            data = self.dirs.load_piece(app_id, piece_id)
        return data

    def upgrade(self, app_id: str, new_manifest: PieceManifest,
                image=None, full: bool = False) -> bool:
        """Move the app to a newer manifest revision (delta distribution).

        Pieces unchanged per `new_manifest.delta(old)` that this node
        already holds verified are carried over — re-read and re-HASHED
        for content-hashed manifests (the reuse rule: a reused piece is
        never trusted on faith) — so only the changed pieces are fetched
        from the swarm.  `full=True` is the publisher path: this node
        holds the complete new revision outright (`image` for real apps).
        Returns False for stale/duplicate updates (version not newer) or
        unknown apps."""
        old = self.manifests.get(app_id)
        if old is None or not new_manifest.supersedes(old):
            return False
        old_inv = self.inventories.get(app_id)
        if old_inv is None and app_id in self.complete:
            old_inv = PieceInventory(old, complete=True)
        self.upgrades += 1
        self._reset_swarm_view(app_id)
        if self.hub is not None:
            self.hub.retire(self, app_id, old)
        self.manifests[app_id] = new_manifest
        old_image = self.image_src.pop(app_id, None)
        old_store = self.store.pop(app_id, None) or {}
        self.complete.discard(app_id)
        if full:
            # publisher: complete new image by fiat (real bytes or a
            # synthetic revision), release the superseded interned buffer
            self.inventories.pop(app_id, None)
            self.fetching.discard(app_id)
            self.complete.add(app_id)
            if image is not None and new_manifest.content_hashed:
                image = intern_image(new_manifest.manifest_hash, image)
                self._track_intern(app_id, new_manifest.manifest_hash)
            else:
                mh = self._interned.pop(app_id, None)
                if mh is not None:
                    release_image(mh)
            if image is not None:
                self.image_src[app_id] = memoryview(image)
                if self.dirs is not None:
                    self.dirs.save_seed_image(app_id, bytes(image))
            if self.hub is not None:
                self.hub.register_seed(self, app_id, new_manifest)
            else:
                self.send(self.tracker_id, self._have_msg(app_id))
            return True
        # leecher: seed the new inventory from still-valid old pieces
        reads: Dict[int, Any] = {}

        def read_piece(piece_id: int):
            data = reads.get(piece_id)
            if data is None:
                data = self._read_old_piece(app_id, old, old_image,
                                            old_store, piece_id)
                if data is not None:
                    reads[piece_id] = data
            return data

        new_inv = PieceInventory(new_manifest)
        adopted = (new_inv.seed_from(old_inv, read_piece)
                   if old_inv is not None else set())
        self.reused_pieces += len(adopted)
        self.inventories[app_id] = new_inv
        if new_manifest.content_hashed:
            self.store[app_id] = {pid: reads[pid] for pid in adopted}
            if self.dirs is not None:
                for pid in self.dirs.list_pieces(app_id):
                    if pid not in adopted:
                        self.dirs.drop_piece(app_id, pid)
                for pid in adopted:
                    self.dirs.save_piece(app_id, pid, reads[pid])
        # the superseded buffer's intern slot is released now; adopted
        # slices keep the underlying bytes alive only until completion
        # reassembles (and interns) the new image
        mh = self._interned.pop(app_id, None)
        if mh is not None:
            release_image(mh)
        self.fetching.add(app_id)
        if self.hub is not None:
            self.hub.register_leech(self, app_id, new_manifest)
            for piece_id in new_inv.have:
                self.hub.note_have(self, app_id, piece_id)
            if new_inv.complete:
                self._complete_fetch(app_id)
            return True
        # one v(k+1) announce to the tracker and known swarm peers: seeds
        # the new availability plane AND demotes us from v(k) pools
        announce = self._have_msg(app_id)
        for target in sorted(self.swarm_peers.get(app_id, set()) -
                             {self.node_id}):
            self.send(target, announce)
        self.send(self.tracker_id, announce)
        if new_inv.complete:
            self._complete_fetch(app_id)
        else:
            self.pump(app_id)
        return True

    def drop_app(self, app_id: str, keep_image: bool = False) -> None:
        """Forget an app (STOP).  `keep_image` preserves the manifest and
        payload for apps this node still seeds as origin."""
        self._req_drop_app(app_id)
        self.fetching.discard(app_id)
        self.inventories.pop(app_id, None)
        self.stalled_holders.pop(app_id, None)
        self.peer_masks.pop(app_id, None)
        self._counts.pop(app_id, None)
        self._piece_holders.pop(app_id, None)
        self._pool_cache.pop(app_id, None)
        self._interest_clean.discard(app_id)
        self.swarm_peers.pop(app_id, None)
        self.full_seeders.pop(app_id, None)
        self.bad_peers.pop(app_id, None)
        self.interested.pop(app_id, None)
        self.unchoked.pop(app_id, None)
        self.opt_unchoked.pop(app_id, None)
        self.queued_reqs.pop(app_id, None)
        self.unchoked_by.pop(app_id, None)
        self.interest_sent.pop(app_id, None)
        if not keep_image:
            self.complete.discard(app_id)
            self.manifests.pop(app_id, None)
            self.image_src.pop(app_id, None)
            self.store.pop(app_id, None)
            mh = self._interned.pop(app_id, None)
            if mh is not None:
                release_image(mh)

    def on_peer_gone(self, node: str) -> None:
        # hub mode: the runtime's crash hook already reset the node's row
        # (PEER_GONE relays can trail a restart; acting on them here
        # would wipe the fresh incarnation's state) — only the local
        # per-engine bookkeeping below needs cleaning
        for app_id, masks in self.peer_masks.items():
            mask = masks.pop(node, None)
            if mask:
                counts = self._counts.get(app_id)
                if counts is not None:
                    holders = self._piece_holders[app_id]
                    # stored masks may carry out-of-range bits from
                    # announces that arrived before the manifest was
                    # known; the counts only ever covered valid pieces
                    for p in iter_bits(mask & ((1 << len(counts)) - 1)):
                        counts[p] -= 1
                        holders[p].discard(node)
                self._pool_changed(app_id)
        self.rate_from.pop(node, None)
        self.rate_to.pop(node, None)
        for app_id, peers in self.full_seeders.items():
            if node in peers:
                peers.discard(node)
                self._pool_changed(app_id)
        for peers in self.interested.values():
            peers.discard(node)
        for peers in self.unchoked.values():
            peers.discard(node)
        for peers in self.unchoked_by.values():
            peers.discard(node)
        for peers in self.interest_sent.values():
            peers.discard(node)
        for peers in self.swarm_peers.values():
            peers.discard(node)
        for queued in self.queued_reqs.values():
            queued.pop(node, None)
        self.peer_load.pop(node, None)
        for app_id in list(self.pending):
            pending = self.pending[app_id]
            stranded = [p for p, asked in pending.items() if node in asked]
            for piece in stranded:
                # the load counter is already gone wholesale (popped
                # above): don't let the decrement resurrect it at 0
                self._req_del(app_id, piece, node, dec_load=False)
            if stranded:
                self.pump(app_id)

    # ====================== queries for the agent ======================= #
    def bitfield_mask(self, app_id: str) -> int:
        if app_id in self.complete:
            manifest = self.manifests.get(app_id)
            return manifest.full_mask if manifest else 0
        inv = self.inventories.get(app_id)
        return inv.bitfield() if inv else 0

    def image_bytes(self, app_id: str) -> Optional[memoryview]:
        """Zero-copy view of the app's real image (None for synthetic)."""
        return self.image_src.get(app_id)

    def seed_load(self, app_id: str) -> int:
        """Upload pressure this node's choke scheduler sees for an app:
        granted slots plus endgame requests queued behind them.  Reported
        to the tracker (via STATUS loads) for least-loaded routing."""
        queued = sum(len(ps) for ps in
                     self.queued_reqs.get(app_id, {}).values())
        return len(self.unchoked.get(app_id, ())) + queued

    def assembled_image(self, app_id: str) -> Optional[bytes]:
        """Reassemble a completed real image from the in-memory store or
        the on-disk piece cache; None for synthetic images."""
        manifest = self.manifests.get(app_id)
        if manifest is None:
            return None
        src = self.image_src.get(app_id)
        if src is not None:
            return bytes(src)
        store = self.store.get(app_id, {})
        if len(store) == manifest.n_pieces:
            return b"".join(store[p] for p in range(manifest.n_pieces))
        if self.dirs is not None:
            return self.dirs.assemble_image(app_id, manifest.n_pieces)
        return None

    # ============ incremental availability / holder index =============== #
    def _pool_changed(self, app_id: str) -> None:
        """Swarm membership changed: drop the cached holder pool and allow
        a fresh INTERESTED pass toward any new holders."""
        self._pool_cache.pop(app_id, None)
        self._interest_clean.discard(app_id)

    def _ban(self, app_id: str, peer: str) -> None:
        self.bad_peers[app_id].add(peer)
        self._pool_changed(app_id)

    def _arrays(self, app_id: str):
        """The availability count array and per-piece holder index; built
        lazily (HAVE announces may precede the manifest) and maintained
        incrementally afterwards."""
        counts = self._counts.get(app_id)
        if counts is None:
            manifest = self.manifests.get(app_id)
            if manifest is None:
                return None, None
            n = manifest.n_pieces
            counts = np.zeros(n, dtype=np.int32)
            holders: List[Set[str]] = [set() for _ in range(n)]
            full = manifest.full_mask
            for peer, mask in self.peer_masks.get(app_id, {}).items():
                for p in iter_bits(mask & full):
                    counts[p] += 1
                    holders[p].add(peer)
                if mask & full == full:
                    # a peer whose completing announce arrived before the
                    # manifest was known is recognised as a seeder now —
                    # the per-announce promotion check only runs on deltas
                    self._promote_full_seeder(app_id, peer)
            self._counts[app_id] = counts
            self._piece_holders[app_id] = holders
        return counts, self._piece_holders.get(app_id)

    def avail_array(self, app_id: str) -> Optional[np.ndarray]:
        """Current per-piece availability (partial holders + full seeders)
        as int32 — the incrementally maintained structure the differential
        tests compare against `_avail_naive`."""
        counts, _ = self._arrays(app_id)
        if counts is None:
            return None
        return counts + np.int32(len(self.full_seeders.get(app_id, ())))

    def _avail_naive(self, app_id: str) -> Dict[int, int]:
        """Reference (pre-optimization) availability map: full O(P·N)
        rebuild from the stored peer masks."""
        n_full = len(self.full_seeders.get(app_id, ()))
        avail: Dict[int, int] = collections.defaultdict(lambda: 0)
        manifest = self.manifests.get(app_id)
        full = None
        if manifest is not None:
            full = manifest.full_mask
            for p in range(manifest.n_pieces):
                avail[p] = n_full
        for mask in self.peer_masks.get(app_id, {}).values():
            if full is not None:
                mask &= full
            for p in iter_bits(mask):
                avail[p] += 1
        return avail

    # ========================= piece selection ========================== #
    def _holder_pool(self, app_id: str) -> Set[str]:
        """Peers holding at least one piece (full seeders + partial
        holders), excluding ourselves and banned peers.  Cached until the
        membership changes; callers must not mutate the returned set."""
        pool = self._pool_cache.get(app_id)
        if pool is None:
            pool = set(self.full_seeders.get(app_id, ()))
            for peer, mask in self.peer_masks.get(app_id, {}).items():
                if mask:
                    pool.add(peer)
            pool.discard(self.node_id)
            pool -= self.bad_peers.get(app_id, set())
            if self.cfg.fetch_from:
                # origin-only mode: the whole request plane collapses to
                # the allow-listed peers (interest, pump and endgame all
                # draw their candidates from this pool or _holders)
                pool &= set(self.cfg.fetch_from)
            self._pool_cache[app_id] = pool
        return pool

    def _holders(self, app_id: str, piece_id: int) -> List[str]:
        """Peers this node may fetch `piece_id` from, via the per-piece
        holder index (full seeders hold everything by definition)."""
        if not self.use_incremental:
            return self._holders_naive(app_id, piece_id)
        cands = set(self.full_seeders.get(app_id, ()))
        _, holders = self._arrays(app_id)
        if holders is not None:
            cands |= holders[piece_id]
        cands.discard(self.node_id)
        bad = self.bad_peers.get(app_id)
        if bad:
            cands -= bad
        if self.cfg.fetch_from:
            cands &= set(self.cfg.fetch_from)
        return sorted(cands)

    def _holders_naive(self, app_id: str, piece_id: int) -> List[str]:
        """Reference holder scan: rebuilds the pool and tests each member
        for the piece, as the pre-index implementation did."""
        full = self.full_seeders.get(app_id, ())
        by_peer = self.peer_masks.get(app_id, {})
        pool = set(full)
        for peer, mask in by_peer.items():
            if mask:
                pool.add(peer)
        pool.discard(self.node_id)
        pool -= self.bad_peers.get(app_id, set())
        if self.cfg.fetch_from:
            pool &= set(self.cfg.fetch_from)
        return sorted(p for p in pool
                      if p in full or (by_peer.get(p, 0) >> piece_id) & 1)

    def _usable(self, app_id: str, peer: str) -> bool:
        """May we address a normal (non-endgame) request to `peer`?
        Choking is the HOLDER's policy, so this is gated on its UNCHOKE
        regardless of our own cfg.choke — requesting anyway would just
        bounce off a CHOKE and spin."""
        return peer in self.unchoked_by[app_id]

    def _express_interest(self, app_id: str) -> None:
        inv = self.inventories.get(app_id)
        if inv is None or inv.complete:
            return
        sent = self.interest_sent[app_id]
        for peer in sorted(self._holder_pool(app_id) - sent):
            sent.add(peer)
            self.send(peer, Msg(INTERESTED, self.node_id,
                                {"app_id": app_id}, size_bytes=64))

    # ===================== pending-request funnel ======================= #
    # Every mutation of the `pending` dicts goes through the four helpers
    # below.  They keep three things consistent in one place: the
    # per-holder load counters, the sole-pending-by-holder index that
    # on_choke re-routes from, and (hub mode) the batched engine's
    # array-native request ledger.

    def _sole_del(self, app_id: str, peer: str, piece_id: int) -> None:
        sp = self._sole_pending.get(app_id)
        held = sp.get(peer) if sp else None
        if held is not None:
            held.discard(piece_id)
            if not held:
                del sp[peer]

    def _req_add(self, app_id: str, piece_id: int, peer: str,
                 now: float) -> None:
        """Record an issued request (`peer` is not yet asked for the
        piece — pump/endgame guarantee that)."""
        pending = self.pending[app_id]
        asked = pending.get(piece_id)
        if asked is None:
            pending[piece_id] = {peer: now}
            self._sole_pending.setdefault(app_id, {}) \
                .setdefault(peer, set()).add(piece_id)
        else:
            if len(asked) == 1:
                # an endgame duplicate: the previous holder stops being
                # the sole one on the hook for this piece
                self._sole_del(app_id, next(iter(asked)), piece_id)
            asked[peer] = now
        self.peer_load[peer] += 1
        if self.hub is not None:
            self.hub.ledger_add(self, app_id, piece_id, peer, now)

    def _req_del(self, app_id: str, piece_id: int, peer: str,
                 dec_load: bool = True) -> bool:
        """Withdraw one (piece, holder) entry; True when it existed.
        `dec_load=False` for peers whose load counter was already
        dropped wholesale (on_peer_gone pops it first)."""
        pending = self.pending.get(app_id)
        asked = pending.get(piece_id) if pending else None
        if asked is None or peer not in asked:
            return False
        del asked[peer]
        if dec_load:
            self.peer_load[peer] = max(0, self.peer_load[peer] - 1)
        self._sole_del(app_id, peer, piece_id)
        if not asked:
            del pending[piece_id]
        elif len(asked) == 1:
            self._sole_pending.setdefault(app_id, {}) \
                .setdefault(next(iter(asked)), set()).add(piece_id)
        if self.hub is not None:
            self.hub.ledger_del(self, app_id, piece_id, peer)
        return True

    def _req_clear(self, app_id: str,
                   piece_id: int) -> Optional[Dict[str, float]]:
        """Drop a piece's whole pending entry (reconcile: the piece
        verified).  Returns the removed holder->asked_at dict so the
        caller can PIECE_CANCEL the losers."""
        pending = self.pending.get(app_id)
        asked = pending.pop(piece_id, None) if pending else None
        if not asked:
            return asked
        for holder in asked:
            self.peer_load[holder] = max(0, self.peer_load[holder] - 1)
            self._sole_del(app_id, holder, piece_id)
        if self.hub is not None:
            self.hub.ledger_clear(self, app_id, piece_id)
        return asked

    def _req_drop_app(self, app_id: str) -> None:
        """Forget every in-flight request for the app (STOP / revision
        reset)."""
        for asked in self.pending.pop(app_id, {}).values():
            for peer in asked:
                self.peer_load[peer] = max(0, self.peer_load[peer] - 1)
        self._sole_pending.pop(app_id, None)
        if self.hub is not None:
            self.hub.ledger_drop(self, app_id)

    def _route_choked(self, app_id: str, peer: str) -> None:
        """A CHOKE from `peer`: re-route the requests solely pending at
        it (endgame duplicates stay queued at the holder; a sole request
        must move elsewhere or the piece stalls).  The holder index makes
        this O(requests at peer), not O(whole pending set)."""
        held = self._sole_pending.get(app_id, {}).get(peer)
        if not held:
            return
        for piece_id in sorted(held):
            self._req_del(app_id, piece_id, peer)

    def pump(self, app_id: str) -> None:
        """Issue PIECE_REQs, rarest-first, to the least-loaded unchoked
        holders; fall into endgame when everything missing is in flight.

        Cost per call is O(P log P) (argsort of the maintained count
        array) plus O(1) per issued request — and O(1) outright when the
        pipeline is already full, which is the common case for the pumps
        triggered by every HAVE announce in a busy swarm."""
        if self.hub is not None:
            # hub mode: requests are matched in the next batched tick
            self.hub.mark_dirty(self, app_id)
            return
        if not self.use_incremental:
            return self._pump_reference(app_id)
        inv = self.inventories.get(app_id)
        if inv is None or inv.complete:
            return
        if app_id not in self._interest_clean:
            self._express_interest(app_id)
            self._interest_clean.add(app_id)
        pending = self.pending[app_id]
        n_pieces = inv.manifest.n_pieces
        if (len(pending) < self.cfg.piece_pipeline
                and n_pieces - len(inv.have) > len(pending)):
            # at most one in-flight request per holder: committing several
            # pieces to one uplink queues them behind each other while
            # other holders idle, and starves the seeder-egress reduction
            busy = {peer for asked in pending.values() for peer in asked}
            usable = (self.unchoked_by[app_id]
                      & self._holder_pool(app_id)) - busy
            if usable:
                missing = [p for p in inv.missing() if p not in pending]
                counts, holders = self._arrays(app_id)
                # stable per-node offset staggers tie-breaks so leechers
                # start on different pieces (random-first-piece,
                # deterministically)
                off = sum(ord(c) for c in self.node_id + app_id)
                # full seeders add the same constant to every piece's
                # availability, so sorting on partial counts alone
                # preserves the rarest-first order
                order = rarest_first_order_np(missing, counts, offset=off,
                                              n_pieces=n_pieces)
                usable_full = usable & self.full_seeders.get(app_id, set())
                stalled = self.stalled_holders.get(app_id, {})
                now = self.now()
                for piece_id in order:
                    if (len(pending) >= self.cfg.piece_pipeline
                            or not usable):
                        break
                    cands = usable_full | (usable & holders[piece_id])
                    if not cands:
                        continue
                    shun = stalled.get(piece_id, ())
                    # holder tie-break: never-shunned first, then cheapest
                    # island (P4P; 0 for everyone without a cost map, so
                    # the flat order is unchanged), then least loaded
                    peer = min(cands, key=lambda h: (
                        h in shun, self._peer_cost(h),
                        self.peer_load.get(h, 0), h))
                    self._req_add(app_id, piece_id, peer, now)
                    usable.discard(peer)
                    usable_full.discard(peer)
                    self._send_req(app_id, piece_id, peer)
        # endgame only once real progress exists AND everything still
        # missing is already in flight: duplicating the very first
        # requests (e.g. a one-piece image) would multiply seeder egress
        # for transfers that are not tail-latency bound at all
        if (self.cfg.endgame and pending and inv.have
                and n_pieces - len(inv.have) == len(pending)):
            self._endgame(app_id)

    def _pump_reference(self, app_id: str) -> None:
        """The pre-optimization pump: full availability rebuild and
        per-piece holder-pool rescans, O(P·N) per call.  Kept verbatim so
        the differential tests can assert the fast path issues identical
        requests and the micro-benchmark has an honest baseline."""
        inv = self.inventories.get(app_id)
        if inv is None or inv.complete:
            return
        self._express_interest(app_id)
        pending = self.pending[app_id]
        missing = [p for p in inv.missing() if p not in pending]
        off = sum(ord(c) for c in self.node_id + app_id)
        order = rarest_first_order(missing, self._avail_naive(app_id),
                                   offset=off,
                                   n_pieces=inv.manifest.n_pieces)
        now = self.now()
        busy = {peer for asked in pending.values() for peer in asked}
        for piece_id in order:
            if len(pending) >= self.cfg.piece_pipeline:
                break
            holders = [h for h in self._holders_naive(app_id, piece_id)
                       if h not in busy and self._usable(app_id, h)]
            if not holders:
                continue
            peer = min(holders, key=lambda h: (self.peer_load.get(h, 0), h))
            self._req_add(app_id, piece_id, peer, now)
            busy.add(peer)
            self._send_req(app_id, piece_id, peer)
        if (self.cfg.endgame and pending and inv.have and not
                [p for p in inv.missing() if p not in pending]):
            self._endgame(app_id)

    def _send_req(self, app_id: str, piece_id: int, peer: str,
                  endgame: bool = False) -> None:
        payload = {"app_id": app_id, "piece_id": piece_id}
        v = self._version(app_id)
        if v is not None:
            payload["v"] = v
        if endgame:
            payload["endgame"] = True
        self.send(peer, Msg(PIECE_REQ, self.node_id, payload, size_bytes=96))

    def _endgame(self, app_id: str) -> None:
        """Every missing piece is in flight: duplicate each outstanding
        request to other holders (choked ones queue it) so one slow uplink
        cannot stall completion; PIECE_CANCEL reconciles the losers.

        Holders whose earlier request for the piece went stale
        (`stalled_holders`) are skipped: with a deterministic holder order
        and a duplication cap, re-asking the same silent trio forever
        would pin the piece to peers that never deliver while willing
        seeders idle one name further down the list."""
        pending = self.pending[app_id]
        stalled = self.stalled_holders.get(app_id, {})
        now = self.now()
        cap = max(int(getattr(self.cfg, "endgame_dup", 3)), 1)
        for piece_id, asked in pending.items():
            if len(asked) >= cap:
                continue
            shun = stalled.get(piece_id, ())
            holders = self._holders(app_id, piece_id)
            if self.island_costs is not None:
                # P4P: duplicate to same-island holders first (shunned
                # ones are skipped below regardless of cost, so the bias
                # decays when the cheap holders starve)
                holders = sorted(holders,
                                 key=lambda h: (self._peer_cost(h), h))
            for holder in holders:
                if holder in asked or holder in shun:
                    continue
                self._req_add(app_id, piece_id, holder, now)
                self._send_req(app_id, piece_id, holder, endgame=True)
                if len(asked) >= cap:
                    break

    # ======================== message handlers ========================== #
    def _note_peer_mask(self, app_id: str, peer: str,
                        mask: Optional[int]) -> bool:
        """Merge a peer's HAVE bitmask into the swarm state, updating the
        availability counts and holder index incrementally.  Returns True
        when availability actually changed, so callers can skip redundant
        pumps on no-op announces."""
        if mask is None or peer == self.node_id:
            return False
        masks = self.peer_masks[app_id]
        old = masks.get(peer, 0)
        if old | mask == old:
            # no new bits — the common case once a swarm warms up; only
            # record first contact (a join announce with an empty mask)
            if peer not in masks:
                masks[peer] = old
            return False
        manifest = self.manifests.get(app_id)
        if manifest is not None:
            mask &= manifest.full_mask           # ignore out-of-range bits
        new = old | mask
        masks[peer] = new
        delta = new & ~old
        if not delta:
            return False
        counts = self._counts.get(app_id)
        if counts is not None:
            holders = self._piece_holders[app_id]
            for p in iter_bits(delta):
                counts[p] += 1
                holders[p].add(peer)
        if old == 0:
            self._pool_changed(app_id)           # a new holder appeared
        # promotion must ignore any out-of-range bits stored while the
        # manifest was still unknown
        if manifest is not None \
                and new & manifest.full_mask == manifest.full_mask:
            self._promote_full_seeder(app_id, peer)
        return True

    def _sync_peer_mask(self, app_id: str, peer: str, mask: int) -> bool:
        """Authoritative holdings snapshot, straight from the peer itself
        (a direct HAVE, not a relay): unlike the grow-only merge, bits the
        peer no longer announces are REMOVED.  A crash-restarted peer
        loses its pieces but keeps its node id — without reconciling
        downward, its stale full mask makes every leecher spin a
        request/refusal loop against a peer that holds nothing."""
        if mask is None or peer == self.node_id:
            return False
        manifest = self.manifests.get(app_id)
        masks = self.peer_masks[app_id]
        old = masks.get(peer)
        if manifest is None or old is None:
            # no manifest to validate against, or first contact: the
            # grow-only merge already does the right thing
            return self._note_peer_mask(app_id, peer, mask)
        new = mask & manifest.full_mask
        if new != manifest.full_mask \
                and peer in self.full_seeders.get(app_id, ()):
            # demote BEFORE the no-change early return: the peer itself
            # says it no longer holds everything.  A stale tracker row
            # (APP_LIST re-pushes the old seeder set every refresh) can
            # re-promote a crash-restarted seeder between two identical
            # snapshots — without re-demoting here, endgame re-requests
            # live-lock against the phantom seeder (REQ -> "don't have
            # it" HAVE -> re-route -> _holders offers it again via
            # full_seeders -> REQ ...) at link latency, and the heap
            # grows without sim time advancing.
            self.full_seeders[app_id].discard(peer)
            if not new and not old:
                # it was in the holder pool only as a seeder
                self._pool_changed(app_id)
            if new == old:
                return True          # availability changed: full -> partial
        if new == old:
            return False
        masks[peer] = new
        counts = self._counts.get(app_id)
        if counts is not None:
            holders = self._piece_holders[app_id]
            for p in iter_bits(old & ~new):
                counts[p] -= 1
                holders[p].discard(peer)
            for p in iter_bits(new & ~old):
                counts[p] += 1
                holders[p].add(peer)
        if (old == 0) != (new == 0):
            # the cached holder pool only tracks *membership*: invalidate
            # when the peer enters or leaves it, not on every mask delta
            # (the grow-only merge has the same rule — a per-announce
            # invalidation would put an O(N) pool rebuild back on the
            # HAVE hot path the PR 3 caching removed)
            self._pool_changed(app_id)
        if new == manifest.full_mask:
            self._promote_full_seeder(app_id, peer)
        return True

    def _drop_peer_pending(self, app_id: str, peer: str) -> bool:
        """Withdraw every in-flight request parked at `peer` for the app
        (it turned out to be on a different manifest revision).  Returns
        True when anything was dropped."""
        pending = self.pending.get(app_id)
        if not pending:
            return False
        dropped = False
        for piece_id in [p for p, asked in pending.items() if peer in asked]:
            self._req_del(app_id, piece_id, peer)
            dropped = True
        return dropped

    def _promote_full_seeder(self, app_id: str, peer: str) -> None:
        """The peer completed the image: it is a seeder now, not a
        leecher — release any upload slot it held."""
        if peer not in self.full_seeders[app_id]:
            self.full_seeders[app_id].add(peer)
            self._pool_changed(app_id)
        self.interested[app_id].discard(peer)
        self.unchoked[app_id].discard(peer)
        self.queued_reqs[app_id].pop(peer, None)

    def _version(self, app_id: str) -> Optional[int]:
        manifest = self.manifests.get(app_id)
        return manifest.version if manifest is not None else None

    def _have_msg(self, app_id: str, peer: Optional[str] = None) -> Msg:
        mask = self.bitfield_mask(app_id)
        payload = {"app_id": app_id, "mask": mask}
        v = self._version(app_id)
        if v is not None:
            payload["v"] = v
        if peer is not None:
            payload["peer"] = peer
        return Msg(HAVE, self.node_id, payload,
                   size_bytes=96 + mask_nbytes(mask))

    def _stale_version(self, app_id: str, v: Optional[int]) -> bool:
        """Does a message tagged with manifest version `v` mismatch the
        revision this node currently tracks?  Untagged messages (pre-
        versioning peers, unit harnesses) are treated as current."""
        if v is None:
            return False
        local = self._version(app_id)
        return local is not None and v != local

    def on_have(self, msg: Msg) -> None:
        payload = msg.payload
        app_id = payload["app_id"]
        # the tracker relays announces with the originating peer attached
        peer = payload.get("peer", msg.src)
        if peer == self.node_id:
            return
        self.swarm_peers[app_id].add(peer)
        if self._stale_version(app_id, payload.get("v")):
            # mixed-version isolation: a mask for a different revision of
            # the image must NEVER merge into this revision's availability.
            # A crash-restarted peer re-announcing its v(k) mask after the
            # swarm moved to v(k+1) is DEMOTED (its pieces are stale, its
            # full-seeder claim doubly so); a peer that is AHEAD of us is
            # removed from our pool too — it stopped serving our revision.
            v = payload.get("v")
            if v < (self._version(app_id) or 0):
                self.stale_have_demoted += 1
            changed = self._sync_peer_mask(app_id, peer, 0)
            rerouted = self._drop_peer_pending(app_id, peer)
            if (changed or rerouted) and app_id in self.fetching:
                self.pump(app_id)
            return
        if "peer" in payload:
            # relayed (extra hop, possibly stale): grow-only merge
            changed = self._note_peer_mask(app_id, peer,
                                           payload.get("mask", 0))
        else:
            # direct from the peer: authoritative snapshot — may shrink
            # (crash-restarted peers re-announce what they really hold)
            changed = self._sync_peer_mask(app_id, peer,
                                           payload.get("mask", 0))
        # requests outstanding at a peer that turns out to lack the piece
        # are re-routed right away
        pending = self.pending.get(app_id)
        rerouted = False
        if pending:
            known = self.peer_masks[app_id].get(peer, 0)
            for piece_id in [p for p, asked in pending.items()
                             if peer in asked and not (known >> p) & 1]:
                self._req_del(app_id, piece_id, peer)
                rerouted = True
        # a HAVE that changed nothing cannot change pump's decision either
        if (changed or rerouted) and app_id in self.fetching:
            self.pump(app_id)

    def on_interested(self, msg: Msg) -> None:
        app_id = msg.payload["app_id"]
        peer = msg.src
        self.swarm_peers[app_id].add(peer)
        if app_id not in self.manifests:
            return
        self.interested[app_id].add(peer)
        if not self.cfg.choke:
            # choking disabled: everyone is always welcome
            self.send(peer, Msg(UNCHOKE, self.node_id,
                                {"app_id": app_id}, size_bytes=64))
            return
        if peer in self.unchoked[app_id]:
            # the peer re-expressed interest while already holding a slot:
            # our earlier UNCHOKE was lost — repeat the grant (idempotent)
            self.send(peer, Msg(UNCHOKE, self.node_id,
                                {"app_id": app_id}, size_bytes=64))
            return
        self._maybe_unchoke_now(app_id)

    def _maybe_unchoke_now(self, app_id: str) -> None:
        """Fill free upload slots immediately (startup fast path); the
        periodic rechoke later re-ranks by reciprocal throughput."""
        unchoked = self.unchoked[app_id]
        for peer in sorted(self.interested[app_id] - unchoked):
            if len(unchoked) >= self.cfg.upload_slots:
                break
            self._unchoke(app_id, peer)

    def _unchoke(self, app_id: str, peer: str) -> None:
        if self.hub is not None and self.hub.grant(self, app_id, peer):
            return           # applied through the arrays, nothing on wire
        self.unchoked[app_id].add(peer)
        self.send(peer, Msg(UNCHOKE, self.node_id,
                            {"app_id": app_id}, size_bytes=64))
        queued = self.queued_reqs[app_id].pop(peer, None)
        if queued:
            for piece_id in sorted(queued):
                self._serve(app_id, peer, piece_id)

    def _choke(self, app_id: str, peer: str) -> None:
        if self.hub is not None and self.hub.choke(self, app_id, peer):
            return
        self.unchoked[app_id].discard(peer)
        self.send(peer, Msg(CHOKE, self.node_id,
                            {"app_id": app_id}, size_bytes=64))

    # --------------------- reciprocity accounting ----------------------- #
    def _credit_from(self, peer: str, nbytes: int) -> None:
        """Account verified piece payload received from `peer`."""
        self.bytes_from[peer] += nbytes
        est = self.rate_from.get(peer)
        if est is None:
            est = self.rate_from[peer] = RollingRate(self._rate_window)
        est.add(self.now(), nbytes)

    def _credit_to(self, peer: str, nbytes: int) -> None:
        """Account piece payload served to `peer`."""
        self.bytes_to[peer] += nbytes
        est = self.rate_to.get(peer)
        if est is None:
            est = self.rate_to[peer] = RollingRate(self._rate_window)
        est.add(self.now(), nbytes)

    def _rate(self, table: Dict[str, RollingRate], peer: str,
              now: float) -> float:
        est = table.get(peer)
        return est.rate(now) if est is not None else 0.0

    def rechoke(self) -> None:
        """Periodic re-choke: keep the best reciprocators (rolling-window
        byte rate received from the peer, then rate served to it — a
        seeder's proxy for the peer's drain rate) in the regular slots and
        rotate one optimistic unchoke through the rest so new peers can
        bootstrap.  Ranking on *rates* rather than lifetime totals means a
        historically fast but now-idle peer loses its slot within one
        window instead of dominating rechoke decisions forever."""
        if not self.cfg.choke:
            return
        if self.hub is not None:
            return           # the hub reranks every holder per tick batch
        self._rechoke_round += 1
        every = max(int(getattr(self.cfg, "optimistic_every", 3)), 1)
        rotate = self._rechoke_round % every == 0
        for app_id in list(self.interested):
            self._rechoke_app(app_id, rotate)

    def _rechoke_app(self, app_id: str, rotate: bool) -> None:
        cands = {p for p in self.interested[app_id] if p != self.node_id}
        slots = max(int(self.cfg.upload_slots), 1)
        if len(cands) <= slots:
            new = set(cands)
            self.opt_unchoked.pop(app_id, None)
        else:
            now = self.now()
            ranked = sorted(cands, key=lambda p: (
                -self._rate(self.rate_from, p, now),
                -self._rate(self.rate_to, p, now), p))
            new = set(ranked[:slots - 1])
            rest = sorted(cands - new)
            opt = self.opt_unchoked.get(app_id)
            if rotate or opt not in rest:
                self._opt_idx[app_id] += 1
                opt = rest[self._opt_idx[app_id] % len(rest)]
            self.opt_unchoked[app_id] = opt
            new.add(opt)
        old = self.unchoked.get(app_id, set())
        for peer in sorted(old - new):
            self._choke(app_id, peer)
        for peer in sorted(new - old):
            self._unchoke(app_id, peer)

    def on_choke(self, msg: Msg) -> None:
        app_id = msg.payload["app_id"]
        peer = msg.src
        self.unchoked_by[app_id].discard(peer)
        # re-route outstanding requests parked at the choking holder
        self._route_choked(app_id, peer)
        if app_id in self.fetching:
            self.pump(app_id)

    def on_unchoke(self, msg: Msg) -> None:
        app_id = msg.payload["app_id"]
        self.unchoked_by[app_id].add(msg.src)
        if app_id in self.fetching:
            self.pump(app_id)

    def on_piece_cancel(self, msg: Msg) -> None:
        app_id = msg.payload["app_id"]
        queued = self.queued_reqs.get(app_id, {}).get(msg.src)
        if queued is not None:
            queued.discard(msg.payload["piece_id"])
            if not queued:
                self.queued_reqs[app_id].pop(msg.src, None)

    def on_piece_req(self, msg: Msg) -> None:
        app_id = msg.payload["app_id"]
        piece_id = msg.payload["piece_id"]
        peer = msg.src
        self.swarm_peers[app_id].add(peer)
        manifest = self.manifests.get(app_id)
        inv = self.inventories.get(app_id)
        if self._stale_version(app_id, msg.payload.get("v")):
            # never serve across revisions: our pieces would verify against
            # a different manifest (or worse, collide on unchanged ids and
            # smuggle stale content in as fresh).  The HAVE reply carries
            # our version, so the requester demotes us from its pool.
            self.stale_reqs_refused += 1
            self.send(peer, self._have_msg(app_id))
            return
        holds = (app_id in self.complete
                 or (inv is not None and inv.has(piece_id)))
        if manifest is None or not holds:
            # tell the requester what we actually have so it re-routes
            self.send(peer, self._have_msg(app_id))
            return
        self.interested[app_id].add(peer)       # a request implies interest
        if self.cfg.choke and peer not in self.unchoked[app_id]:
            self._maybe_unchoke_now(app_id)
        if self.cfg.choke and peer not in self.unchoked[app_id]:
            if msg.payload.get("endgame"):
                # endgame duplicates wait for a slot instead of bouncing;
                # PIECE_CANCEL prunes them if another holder wins the race
                self.queued_reqs[app_id].setdefault(peer, set()).add(piece_id)
            else:
                self._choke(app_id, peer)
            return
        self._serve(app_id, peer, piece_id)

    def _piece_payload(self, app_id: str, piece_id: int):
        """The piece's payload as a zero-copy view over the shared image
        buffer (or the stored/cached slice for partial holders)."""
        image = self.image_src.get(app_id)
        if image is not None:
            manifest = self.manifests[app_id]
            lo = piece_id * manifest.piece_bytes
            return image[lo:lo + manifest.piece_bytes]
        data = self.store.get(app_id, {}).get(piece_id)
        if data is None and self.dirs is not None:
            data = self.dirs.load_piece(app_id, piece_id)
        return data

    def _serve(self, app_id: str, peer: str, piece_id: int) -> None:
        manifest = self.manifests[app_id]
        mask = self.bitfield_mask(app_id)
        payload = {"app_id": app_id, "piece_id": piece_id,
                   "proof": manifest.piece_hashes[piece_id], "mask": mask,
                   "v": manifest.version}
        data = self._piece_payload(app_id, piece_id)
        if data is not None:
            payload["data"] = data
        self._credit_to(peer, manifest.piece_size(piece_id))
        if self.hub is not None:
            self.hub.credit(self, app_id, peer,
                            manifest.piece_size(piece_id), received=False)
        self.send(peer, Msg(PIECE_DATA, self.node_id, payload,
                            size_bytes=96 + manifest.piece_size(piece_id)
                            + mask_nbytes(mask)))

    def on_piece_data(self, msg: Msg) -> None:
        app_id = msg.payload["app_id"]
        piece_id = msg.payload["piece_id"]
        peer = msg.src
        self.swarm_peers[app_id].add(peer)
        if self._stale_version(app_id, msg.payload.get("v")):
            # a payload for a different manifest revision: DISCARD, do not
            # verify, do not merge the attached mask.  This is NOT a ban —
            # the peer is an honest holder of the other revision (e.g. a
            # v1 seeder answering a request issued before our upgrade);
            # banning it would lose it for good once it upgrades too.
            self.stale_piece_data += 1
            if msg.payload.get("v", 0) < (self._version(app_id) or 0):
                self._sync_peer_mask(app_id, peer, 0)
            self._drop_peer_pending(app_id, peer)
            if app_id in self.fetching:
                self.pump(app_id)
            return
        self._note_peer_mask(app_id, peer, msg.payload.get("mask"))
        # answered: drop the in-flight entry (when it was the last holder
        # the piece re-enters `missing`, so a corrupt reply cannot stall
        # it until recover())
        self._req_del(app_id, piece_id, peer)
        inv = self.inventories.get(app_id)
        if inv is None or inv.complete or inv.has(piece_id):
            if inv is not None:
                self.dup_piece_data += 1     # endgame race lost by `peer`
            self._reconcile(app_id, piece_id)
            return
        data = msg.payload.get("data")
        if not inv.add(piece_id, msg.payload.get("proof"), data=data):
            # corrupt piece: never ask this peer again, fetch elsewhere
            self._ban(app_id, peer)
            self.unchoked_by[app_id].discard(peer)
            self.pump(app_id)
            return
        if self._stale_version(app_id, msg.payload.get("v")):
            # unreachable while the discard gate above holds; evaluated
            # again at the accept site so any future bypass of that gate
            # trips the chaos suites' stale_accepts == 0 assertion
            self.stale_accepts += 1
        manifest = inv.manifest
        nbytes = manifest.piece_size(piece_id)
        self._credit_from(peer, nbytes)
        if self.hub is not None:
            self.hub.credit(self, app_id, peer, nbytes, received=True)
        self.pieces_from[app_id][peer] += 1
        if data is not None:
            self.store[app_id][piece_id] = data
            if self.dirs is not None:
                self.dirs.save_piece(app_id, piece_id, data)
        if self.on_bytes is not None:
            self.on_bytes(app_id, nbytes)
        # endgame reconciliation: the race is decided, cancel the rest
        self._reconcile(app_id, piece_id)
        if self.hub is not None:
            # hub mode: one array write replaces the whole announce
            # fan-out (the hub counts the suppressed deliveries)
            self.hub.note_have(self, app_id, piece_id)
            if inv.complete:
                self._complete_fetch(app_id)
            return
        # announce to known peers directly AND via the tracker relay.  The
        # relay alone would suffice for reach, but the extra hop delays
        # rarity information enough to push measurably more piece traffic
        # back onto the origin; the ~bitmask-sized announces are cheap next
        # to the pieces they steer.  One Msg serves the whole burst — the
        # payload is identical for every target (receivers treat payloads
        # as read-only, like the tracker's relays).
        announce = self._have_msg(app_id)
        for target in sorted(self.swarm_peers[app_id] - {peer,
                                                         self.node_id}):
            self.send(target, announce)
        self.send(self.tracker_id, announce)
        if inv.complete:
            self._complete_fetch(app_id)
        else:
            self.pump(app_id)

    def _reconcile(self, app_id: str, piece_id: int) -> None:
        """Drop the pending entry for a piece we now hold and PIECE_CANCEL
        every other holder still racing to serve it."""
        stalled = self.stalled_holders.get(app_id)
        if stalled:
            stalled.pop(piece_id, None)      # decided: forget stale history
        if self.hub is not None:
            self.hub.mark_dirty(self, app_id)
        asked = self._req_clear(app_id, piece_id)
        if not asked:
            return
        for holder in sorted(asked):
            self.cancels_sent += 1
            self.send(holder, Msg(PIECE_CANCEL, self.node_id,
                                  {"app_id": app_id, "piece_id": piece_id},
                                  size_bytes=64))

    def _complete_fetch(self, app_id: str) -> None:
        """All pieces verified: reassemble real images, cache the Seed
        copy, and hand the agent the keys to the executable.  Real images
        are interned by manifest hash so every replica in a simulation
        shares one buffer instead of materialising its own copy."""
        inv = self.inventories[app_id]
        self.complete.add(app_id)
        self.fetching.discard(app_id)
        for piece_id in list(self.pending.get(app_id, {})):
            self._reconcile(app_id, piece_id)
        if self.hub is not None:
            self.hub.set_full(self, app_id)
        image = None
        if inv.manifest.content_hashed:
            mh = inv.manifest.manifest_hash
            image = acquire_image(mh)
            if image is None:
                assembled = self.assembled_image(app_id)  # store or disk
                if assembled is not None:
                    image = intern_image(mh, assembled)
            if image is not None:
                self._track_intern(app_id, mh)
                self.image_src[app_id] = memoryview(image)
                # the shared image supersedes the per-piece slices
                self.store.pop(app_id, None)
                if self.dirs is not None:
                    self.dirs.save_seed_image(app_id, image)
        if self.on_image_complete is not None:
            self.on_image_complete(app_id, inv.manifest.manifest_hash, image)

    # ========================== maintenance ============================= #
    def recover(self, app_id: str, stall_s: float) -> None:
        """Re-issue piece requests that went unanswered (e.g. the holder
        died before PEER_GONE propagated, or never unchoked us)."""
        now = self.now()
        pending = self.pending.get(app_id, {})
        for piece_id, asked in list(pending.items()):
            stale = [peer for peer, t in asked.items() if now - t > stall_s]
            for peer in stale:
                self._req_del(app_id, piece_id, peer)
                # shun the silent holder for this piece so the
                # re-request pump issues goes to an alternate one
                self.stalled_holders.setdefault(app_id, {}) \
                    .setdefault(piece_id, set()).add(peer)
                # the holder may have the request parked in its choke
                # queue (endgame): withdraw it, or it inflates the
                # load the holder reports to the tracker forever
                self.send(peer, Msg(PIECE_CANCEL, self.node_id,
                                    {"app_id": app_id,
                                     "piece_id": piece_id},
                                    size_bytes=64))
        # allow a fresh INTERESTED round toward holders that never answered
        if (self.hub is None and app_id in self.fetching
                and not self.unchoked_by[app_id]):
            self.interest_sent[app_id].clear()
            self._interest_clean.discard(app_id)
            # re-announce to the tracker: with no holder granting us a
            # slot, our join HAVE (or the tracker's relays) may have been
            # lost — without the announce the swarm never discovers us
            self.send(self.tracker_id, self._have_msg(app_id))
        self.pump(app_id)
