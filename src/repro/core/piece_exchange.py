"""PieceExchange: the swarm transfer engine behind the agent (paper §V).

Everything about moving application-image *pieces* between volunteers lives
here, extracted from core/agent.py so the transfer scheduler is a layer of
its own (the way BitTorrent separates the peer wire protocol from piece-
selection policy, and the way BOINC separates its transitioner from the
science app).  The Agent keeps only protocol glue: it routes PIECE_*/HAVE/
CHOKE messages into the engine and reacts to the engine's callbacks.

The engine owns, per application:

  * peer state     — who is in the swarm, which pieces each peer holds
                     (HAVE bitmasks), which full seeders exist;
  * selection      — rarest-first piece ordering (core.swarm policy) with a
                     deterministic per-node tie-break rotation, one in-
                     flight request per holder, bounded pipeline;
  * choke scheduling (seeder side) — a fixed number of upload slots;
                     leechers announce INTERESTED, the engine UNCHOKEs the
                     best reciprocators (bytes received from the peer, then
                     bytes served to it) plus one optimistic slot rotated
                     deterministically so newcomers bootstrap; requests
                     from choked peers are refused with CHOKE so the
                     requester re-routes;
  * endgame        — when every missing piece is already in flight, the
                     outstanding requests are duplicated to all other
                     holders (flagged `endgame`, queued by choked holders
                     instead of refused) and reconciled with PIECE_CANCEL
                     the moment the first copy verifies;
  * real bytes     — when the application image is real (Application.image)
                     PIECE_DATA carries the actual payload slice, verified
                     by re-hashing; verified pieces are cached on disk via
                     AgentDirs and reassembled into the replica's Seed copy
                     on completion.  Synthetic (simulation) images move as
                     hash proofs over the identical code path.
"""
from __future__ import annotations

import collections
from typing import Any, Callable, Dict, List, Optional, Set

from repro.core.messages import (CHOKE, HAVE, INTERESTED, PIECE_CANCEL,
                                 PIECE_DATA, PIECE_REQ, UNCHOKE, Msg)
from repro.core.swarm import rarest_first_order
from repro.core.workunit import (PieceInventory, PieceManifest, mask_nbytes,
                                 pieces_of)


class PieceExchange:
    """Per-agent swarm transfer engine.

    `send(dst, msg)` and `now()` come from the owning agent; `tracker_id`
    is where join/HAVE announces go for relay.  `on_image_complete(app_id,
    manifest_hash, image_bytes)` fires once per verified image;
    `on_bytes(app_id, n)` accounts received piece payload.
    """

    def __init__(self, node_id: str, cfg, *,
                 send: Callable[[str, Msg], None],
                 now: Callable[[], float],
                 tracker_id: str = "server",
                 dirs=None,
                 on_image_complete: Optional[Callable] = None,
                 on_bytes: Optional[Callable[[str, int], None]] = None):
        self.node_id = node_id
        self.cfg = cfg
        self.send = send
        self.now = now
        self.tracker_id = tracker_id
        self.dirs = dirs
        self.on_image_complete = on_image_complete
        self.on_bytes = on_bytes
        # --- image / holdings state ------------------------------------- #
        self.manifests: Dict[str, PieceManifest] = {}
        self.inventories: Dict[str, PieceInventory] = {}
        self.complete: Set[str] = set()          # full verified images held
        self.fetching: Set[str] = set()          # apps being leeched
        self.image_src: Dict[str, bytes] = {}    # real image payloads
        self.store: Dict[str, Dict[int, bytes]] = \
            collections.defaultdict(dict)        # real piece payloads
        # --- swarm peer state -------------------------------------------- #
        self.full_seeders: Dict[str, Set[str]] = collections.defaultdict(set)
        self.peer_pieces: Dict[str, Dict[str, Set[int]]] = \
            collections.defaultdict(dict)
        self.swarm_peers: Dict[str, Set[str]] = collections.defaultdict(set)
        self.bad_peers: Dict[str, Set[str]] = collections.defaultdict(set)
        # piece -> {holder: asked_at}; >1 holder only in endgame
        self.pending: Dict[str, Dict[int, Dict[str, float]]] = \
            collections.defaultdict(dict)
        self.peer_load: Dict[str, int] = collections.defaultdict(int)
        # --- choke scheduler (serving side) ------------------------------ #
        self.interested: Dict[str, Set[str]] = collections.defaultdict(set)
        self.unchoked: Dict[str, Set[str]] = collections.defaultdict(set)
        self.opt_unchoked: Dict[str, str] = {}
        self._opt_idx: Dict[str, int] = collections.defaultdict(int)
        self._rechoke_round = 0
        # app -> peer -> queued endgame piece requests (served on unchoke)
        self.queued_reqs: Dict[str, Dict[str, Set[int]]] = \
            collections.defaultdict(dict)
        # --- choke view (leeching side) ---------------------------------- #
        self.unchoked_by: Dict[str, Set[str]] = collections.defaultdict(set)
        self.interest_sent: Dict[str, Set[str]] = collections.defaultdict(set)
        # --- accounting --------------------------------------------------- #
        self.bytes_from: Dict[str, int] = collections.defaultdict(int)
        self.bytes_to: Dict[str, int] = collections.defaultdict(int)
        self.pieces_from: Dict[str, Dict[str, int]] = \
            collections.defaultdict(lambda: collections.defaultdict(int))
        self.cancels_sent = 0
        self.dup_piece_data = 0

    # ===================== lifecycle / membership ======================= #
    def add_local_app(self, app_id: str, manifest: PieceManifest,
                      image: Optional[bytes] = None) -> None:
        """Register an app whose full image this node already holds (origin
        seeder, or a replica restored from disk)."""
        self.manifests[app_id] = manifest
        self.complete.add(app_id)
        if image is not None:
            self.image_src[app_id] = image

    def join(self, app_id: str, manifest: PieceManifest) -> None:
        """Start leeching an app image piece-wise; announces the (empty)
        bitfield to the tracker so swarm members discover each other."""
        self.manifests.setdefault(app_id, manifest)
        self.inventories.setdefault(app_id, PieceInventory(manifest))
        self.fetching.add(app_id)
        self.send(self.tracker_id, self._have_msg(app_id))
        self.pump(app_id)

    def note_full_seeders(self, app_id: str, seeders: Set[str]) -> None:
        self.full_seeders[app_id] = set(seeders)

    def drop_app(self, app_id: str, keep_image: bool = False) -> None:
        """Forget an app (STOP).  `keep_image` preserves the manifest and
        payload for apps this node still seeds as origin."""
        for asked in self.pending.pop(app_id, {}).values():
            for peer in asked:
                self.peer_load[peer] = max(0, self.peer_load[peer] - 1)
        self.fetching.discard(app_id)
        self.inventories.pop(app_id, None)
        self.peer_pieces.pop(app_id, None)
        self.swarm_peers.pop(app_id, None)
        self.full_seeders.pop(app_id, None)
        self.bad_peers.pop(app_id, None)
        self.interested.pop(app_id, None)
        self.unchoked.pop(app_id, None)
        self.opt_unchoked.pop(app_id, None)
        self.queued_reqs.pop(app_id, None)
        self.unchoked_by.pop(app_id, None)
        self.interest_sent.pop(app_id, None)
        if not keep_image:
            self.complete.discard(app_id)
            self.manifests.pop(app_id, None)
            self.image_src.pop(app_id, None)
            self.store.pop(app_id, None)

    def on_peer_gone(self, node: str) -> None:
        for app_id in list(self.peer_pieces):
            self.peer_pieces[app_id].pop(node, None)
        for peers in self.swarm_peers.values():
            peers.discard(node)
        for peers in self.full_seeders.values():
            peers.discard(node)
        for peers in self.interested.values():
            peers.discard(node)
        for peers in self.unchoked.values():
            peers.discard(node)
        for peers in self.unchoked_by.values():
            peers.discard(node)
        for peers in self.interest_sent.values():
            peers.discard(node)
        for queued in self.queued_reqs.values():
            queued.pop(node, None)
        self.peer_load.pop(node, None)
        for app_id, pending in self.pending.items():
            dirty = False
            for piece, asked in list(pending.items()):
                if asked.pop(node, None) is not None:
                    dirty = True
                if not asked:
                    del pending[piece]
            if dirty:
                self.pump(app_id)

    # ====================== queries for the agent ======================= #
    def bitfield_mask(self, app_id: str) -> int:
        if app_id in self.complete:
            manifest = self.manifests.get(app_id)
            return (1 << manifest.n_pieces) - 1 if manifest else 0
        inv = self.inventories.get(app_id)
        return inv.bitfield() if inv else 0

    def image_bytes(self, app_id: str) -> Optional[bytes]:
        return self.image_src.get(app_id)

    def seed_load(self, app_id: str) -> int:
        """Upload pressure this node's choke scheduler sees for an app:
        granted slots plus endgame requests queued behind them.  Reported
        to the tracker (via STATUS loads) for least-loaded routing."""
        queued = sum(len(ps) for ps in
                     self.queued_reqs.get(app_id, {}).values())
        return len(self.unchoked.get(app_id, ())) + queued

    def assembled_image(self, app_id: str) -> Optional[bytes]:
        """Reassemble a completed real image from the in-memory store or
        the on-disk piece cache; None for synthetic images."""
        manifest = self.manifests.get(app_id)
        if manifest is None:
            return None
        if app_id in self.image_src:
            return self.image_src[app_id]
        store = self.store.get(app_id, {})
        if len(store) == manifest.n_pieces:
            return b"".join(store[p] for p in range(manifest.n_pieces))
        if self.dirs is not None:
            return self.dirs.assemble_image(app_id, manifest.n_pieces)
        return None

    # ========================= piece selection ========================== #
    def _avail(self, app_id: str) -> Dict[int, int]:
        n_full = len(self.full_seeders.get(app_id, ()))
        avail: Dict[int, int] = collections.defaultdict(lambda: 0)
        manifest = self.manifests.get(app_id)
        if manifest is not None:
            for p in range(manifest.n_pieces):
                avail[p] = n_full
        for have in self.peer_pieces.get(app_id, {}).values():
            for p in have:
                avail[p] += 1
        return avail

    def _holder_pool(self, app_id: str) -> Set[str]:
        """Peers holding at least one piece (full seeders + partial
        holders), excluding ourselves and banned peers."""
        pool = set(self.full_seeders.get(app_id, ()))
        for peer, have in self.peer_pieces.get(app_id, {}).items():
            if have:
                pool.add(peer)
        pool.discard(self.node_id)
        return pool - self.bad_peers.get(app_id, set())

    def _holders(self, app_id: str, piece_id: int) -> List[str]:
        full = self.full_seeders.get(app_id, ())
        by_peer = self.peer_pieces.get(app_id, {})
        return sorted(p for p in self._holder_pool(app_id)
                      if p in full or piece_id in by_peer.get(p, ()))

    def _usable(self, app_id: str, peer: str) -> bool:
        """May we address a normal (non-endgame) request to `peer`?
        Choking is the HOLDER's policy, so this is gated on its UNCHOKE
        regardless of our own cfg.choke — requesting anyway would just
        bounce off a CHOKE and spin."""
        return peer in self.unchoked_by[app_id]

    def _express_interest(self, app_id: str) -> None:
        inv = self.inventories.get(app_id)
        if inv is None or inv.complete:
            return
        sent = self.interest_sent[app_id]
        for peer in sorted(self._holder_pool(app_id) - sent):
            sent.add(peer)
            self.send(peer, Msg(INTERESTED, self.node_id,
                                {"app_id": app_id}, size_bytes=64))

    def pump(self, app_id: str) -> None:
        """Issue PIECE_REQs, rarest-first, to the least-loaded unchoked
        holders; fall into endgame when everything missing is in flight."""
        inv = self.inventories.get(app_id)
        if inv is None or inv.complete:
            return
        self._express_interest(app_id)
        pending = self.pending[app_id]
        missing = [p for p in inv.missing() if p not in pending]
        # stable per-node offset staggers tie-breaks so leechers start on
        # different pieces (random-first-piece, deterministically)
        off = sum(ord(c) for c in self.node_id + app_id)
        order = rarest_first_order(missing, self._avail(app_id), offset=off,
                                   n_pieces=inv.manifest.n_pieces)
        now = self.now()
        # at most one in-flight request per holder: committing several
        # pieces to one uplink queues them behind each other while other
        # holders idle, and starves the seeder-egress reduction
        busy = {peer for asked in pending.values() for peer in asked}
        for piece_id in order:
            if len(pending) >= self.cfg.piece_pipeline:
                break
            holders = [h for h in self._holders(app_id, piece_id)
                       if h not in busy and self._usable(app_id, h)]
            if not holders:
                continue
            peer = min(holders, key=lambda h: (self.peer_load[h], h))
            pending[piece_id] = {peer: now}
            busy.add(peer)
            self.peer_load[peer] += 1
            self._send_req(app_id, piece_id, peer)
        # endgame only once real progress exists AND everything still
        # missing is already in flight: duplicating the very first
        # requests (e.g. a one-piece image) would multiply seeder egress
        # for transfers that are not tail-latency bound at all
        if (self.cfg.endgame and pending and inv.have and not
                [p for p in inv.missing() if p not in pending]):
            self._endgame(app_id)

    def _send_req(self, app_id: str, piece_id: int, peer: str,
                  endgame: bool = False) -> None:
        payload = {"app_id": app_id, "piece_id": piece_id}
        if endgame:
            payload["endgame"] = True
        self.send(peer, Msg(PIECE_REQ, self.node_id, payload, size_bytes=96))

    def _endgame(self, app_id: str) -> None:
        """Every missing piece is in flight: duplicate each outstanding
        request to other holders (choked ones queue it) so one slow uplink
        cannot stall completion; PIECE_CANCEL reconciles the losers."""
        pending = self.pending[app_id]
        now = self.now()
        cap = max(int(getattr(self.cfg, "endgame_dup", 3)), 1)
        for piece_id, asked in pending.items():
            if len(asked) >= cap:
                continue
            for holder in self._holders(app_id, piece_id):
                if holder in asked:
                    continue
                asked[holder] = now
                self.peer_load[holder] += 1
                self._send_req(app_id, piece_id, holder, endgame=True)
                if len(asked) >= cap:
                    break

    # ======================== message handlers ========================== #
    def _note_peer_mask(self, app_id: str, peer: str,
                        mask: Optional[int]) -> None:
        if mask is None or peer == self.node_id:
            return
        known = self.peer_pieces[app_id].setdefault(peer, set())
        known |= pieces_of(mask)
        manifest = self.manifests.get(app_id)
        if manifest is not None and len(known) >= manifest.n_pieces:
            # the peer completed the image: it is a seeder now, not a
            # leecher — release any upload slot it held
            self.full_seeders[app_id].add(peer)
            self.interested[app_id].discard(peer)
            self.unchoked[app_id].discard(peer)
            self.queued_reqs[app_id].pop(peer, None)

    def _have_msg(self, app_id: str, peer: Optional[str] = None) -> Msg:
        mask = self.bitfield_mask(app_id)
        payload = {"app_id": app_id, "mask": mask}
        if peer is not None:
            payload["peer"] = peer
        return Msg(HAVE, self.node_id, payload,
                   size_bytes=96 + mask_nbytes(mask))

    def on_have(self, msg: Msg) -> None:
        app_id = msg.payload["app_id"]
        # the tracker relays announces with the originating peer attached
        peer = msg.payload.get("peer", msg.src)
        if peer == self.node_id:
            return
        self.swarm_peers[app_id].add(peer)
        self._note_peer_mask(app_id, peer, msg.payload.get("mask", 0))
        known = self.peer_pieces[app_id].get(peer, set())
        # requests outstanding at a peer that turns out to lack the piece
        # are re-routed right away
        pending = self.pending[app_id]
        for piece_id, asked in list(pending.items()):
            if peer in asked and piece_id not in known:
                del asked[peer]
                self.peer_load[peer] = max(0, self.peer_load[peer] - 1)
                if not asked:
                    del pending[piece_id]
        if app_id in self.fetching:
            self.pump(app_id)

    def on_interested(self, msg: Msg) -> None:
        app_id = msg.payload["app_id"]
        peer = msg.src
        self.swarm_peers[app_id].add(peer)
        if app_id not in self.manifests:
            return
        self.interested[app_id].add(peer)
        if not self.cfg.choke:
            # choking disabled: everyone is always welcome
            self.send(peer, Msg(UNCHOKE, self.node_id,
                                {"app_id": app_id}, size_bytes=64))
            return
        self._maybe_unchoke_now(app_id)

    def _maybe_unchoke_now(self, app_id: str) -> None:
        """Fill free upload slots immediately (startup fast path); the
        periodic rechoke later re-ranks by reciprocal throughput."""
        unchoked = self.unchoked[app_id]
        for peer in sorted(self.interested[app_id] - unchoked):
            if len(unchoked) >= self.cfg.upload_slots:
                break
            self._unchoke(app_id, peer)

    def _unchoke(self, app_id: str, peer: str) -> None:
        self.unchoked[app_id].add(peer)
        self.send(peer, Msg(UNCHOKE, self.node_id,
                            {"app_id": app_id}, size_bytes=64))
        queued = self.queued_reqs[app_id].pop(peer, None)
        if queued:
            for piece_id in sorted(queued):
                self._serve(app_id, peer, piece_id)

    def _choke(self, app_id: str, peer: str) -> None:
        self.unchoked[app_id].discard(peer)
        self.send(peer, Msg(CHOKE, self.node_id,
                            {"app_id": app_id}, size_bytes=64))

    def rechoke(self) -> None:
        """Periodic re-choke: keep the best reciprocators (bytes received
        from the peer, then bytes served to it — a seeder's proxy for the
        peer's drain rate) in the regular slots and rotate one optimistic
        unchoke through the rest so new peers can bootstrap."""
        if not self.cfg.choke:
            return
        self._rechoke_round += 1
        every = max(int(getattr(self.cfg, "optimistic_every", 3)), 1)
        rotate = self._rechoke_round % every == 0
        for app_id in list(self.interested):
            self._rechoke_app(app_id, rotate)

    def _rechoke_app(self, app_id: str, rotate: bool) -> None:
        cands = {p for p in self.interested[app_id] if p != self.node_id}
        slots = max(int(self.cfg.upload_slots), 1)
        if len(cands) <= slots:
            new = set(cands)
            self.opt_unchoked.pop(app_id, None)
        else:
            ranked = sorted(cands, key=lambda p: (-self.bytes_from[p],
                                                  -self.bytes_to[p], p))
            new = set(ranked[:slots - 1])
            rest = sorted(cands - new)
            opt = self.opt_unchoked.get(app_id)
            if rotate or opt not in rest:
                self._opt_idx[app_id] += 1
                opt = rest[self._opt_idx[app_id] % len(rest)]
            self.opt_unchoked[app_id] = opt
            new.add(opt)
        old = self.unchoked.get(app_id, set())
        for peer in sorted(old - new):
            self._choke(app_id, peer)
        for peer in sorted(new - old):
            self._unchoke(app_id, peer)

    def on_choke(self, msg: Msg) -> None:
        app_id = msg.payload["app_id"]
        peer = msg.src
        self.unchoked_by[app_id].discard(peer)
        # re-route outstanding requests parked at the choking holder
        pending = self.pending[app_id]
        for piece_id, asked in list(pending.items()):
            if peer in asked and len(asked) == 1:
                # endgame duplicates stay queued at the holder; a sole
                # request must move elsewhere or the piece stalls
                del asked[peer]
                self.peer_load[peer] = max(0, self.peer_load[peer] - 1)
                del pending[piece_id]
        if app_id in self.fetching:
            self.pump(app_id)

    def on_unchoke(self, msg: Msg) -> None:
        app_id = msg.payload["app_id"]
        self.unchoked_by[app_id].add(msg.src)
        if app_id in self.fetching:
            self.pump(app_id)

    def on_piece_cancel(self, msg: Msg) -> None:
        app_id = msg.payload["app_id"]
        queued = self.queued_reqs.get(app_id, {}).get(msg.src)
        if queued is not None:
            queued.discard(msg.payload["piece_id"])
            if not queued:
                self.queued_reqs[app_id].pop(msg.src, None)

    def on_piece_req(self, msg: Msg) -> None:
        app_id = msg.payload["app_id"]
        piece_id = msg.payload["piece_id"]
        peer = msg.src
        self.swarm_peers[app_id].add(peer)
        manifest = self.manifests.get(app_id)
        inv = self.inventories.get(app_id)
        holds = (app_id in self.complete
                 or (inv is not None and inv.has(piece_id)))
        if manifest is None or not holds:
            # tell the requester what we actually have so it re-routes
            self.send(peer, self._have_msg(app_id))
            return
        self.interested[app_id].add(peer)       # a request implies interest
        if self.cfg.choke and peer not in self.unchoked[app_id]:
            self._maybe_unchoke_now(app_id)
        if self.cfg.choke and peer not in self.unchoked[app_id]:
            if msg.payload.get("endgame"):
                # endgame duplicates wait for a slot instead of bouncing;
                # PIECE_CANCEL prunes them if another holder wins the race
                self.queued_reqs[app_id].setdefault(peer, set()).add(piece_id)
            else:
                self._choke(app_id, peer)
            return
        self._serve(app_id, peer, piece_id)

    def _piece_payload(self, app_id: str, piece_id: int) -> Optional[bytes]:
        image = self.image_src.get(app_id)
        if image is not None:
            manifest = self.manifests[app_id]
            lo = piece_id * manifest.piece_bytes
            return image[lo:lo + manifest.piece_bytes]
        data = self.store.get(app_id, {}).get(piece_id)
        if data is None and self.dirs is not None:
            data = self.dirs.load_piece(app_id, piece_id)
        return data

    def _serve(self, app_id: str, peer: str, piece_id: int) -> None:
        manifest = self.manifests[app_id]
        mask = self.bitfield_mask(app_id)
        payload = {"app_id": app_id, "piece_id": piece_id,
                   "proof": manifest.piece_hashes[piece_id], "mask": mask}
        data = self._piece_payload(app_id, piece_id)
        if data is not None:
            payload["data"] = data
        self.bytes_to[peer] += manifest.piece_size(piece_id)
        self.send(peer, Msg(PIECE_DATA, self.node_id, payload,
                            size_bytes=96 + manifest.piece_size(piece_id)
                            + mask_nbytes(mask)))

    def on_piece_data(self, msg: Msg) -> None:
        app_id = msg.payload["app_id"]
        piece_id = msg.payload["piece_id"]
        peer = msg.src
        self.swarm_peers[app_id].add(peer)
        self._note_peer_mask(app_id, peer, msg.payload.get("mask"))
        pending = self.pending[app_id]
        asked = pending.get(piece_id)
        if asked is not None and peer in asked:
            del asked[peer]
            self.peer_load[peer] = max(0, self.peer_load[peer] - 1)
            if not asked:
                # last outstanding request for the piece answered: the
                # piece must re-enter `missing` (pump skips pending keys),
                # or a corrupt reply would stall it until recover()
                del pending[piece_id]
        inv = self.inventories.get(app_id)
        if inv is None or inv.complete or inv.has(piece_id):
            if inv is not None:
                self.dup_piece_data += 1     # endgame race lost by `peer`
            self._reconcile(app_id, piece_id)
            return
        data = msg.payload.get("data")
        if not inv.add(piece_id, msg.payload.get("proof"), data=data):
            # corrupt piece: never ask this peer again, fetch elsewhere
            self.bad_peers[app_id].add(peer)
            self.unchoked_by[app_id].discard(peer)
            self.pump(app_id)
            return
        manifest = inv.manifest
        nbytes = manifest.piece_size(piece_id)
        self.bytes_from[peer] += nbytes
        self.pieces_from[app_id][peer] += 1
        if data is not None:
            self.store[app_id][piece_id] = data
            if self.dirs is not None:
                self.dirs.save_piece(app_id, piece_id, data)
        if self.on_bytes is not None:
            self.on_bytes(app_id, nbytes)
        # endgame reconciliation: the race is decided, cancel the rest
        self._reconcile(app_id, piece_id)
        # announce to known peers directly AND via the tracker relay.  The
        # relay alone would suffice for reach, but the extra hop delays
        # rarity information enough to push measurably more piece traffic
        # back onto the origin; the ~bitmask-sized announces are cheap next
        # to the pieces they steer.
        for target in sorted(self.swarm_peers[app_id] - {peer,
                                                         self.node_id}):
            self.send(target, self._have_msg(app_id))
        self.send(self.tracker_id, self._have_msg(app_id))
        if inv.complete:
            self._complete_fetch(app_id)
        else:
            self.pump(app_id)

    def _reconcile(self, app_id: str, piece_id: int) -> None:
        """Drop the pending entry for a piece we now hold and PIECE_CANCEL
        every other holder still racing to serve it."""
        asked = self.pending[app_id].pop(piece_id, None)
        if not asked:
            return
        for holder in sorted(asked):
            self.peer_load[holder] = max(0, self.peer_load[holder] - 1)
            self.cancels_sent += 1
            self.send(holder, Msg(PIECE_CANCEL, self.node_id,
                                  {"app_id": app_id, "piece_id": piece_id},
                                  size_bytes=64))

    def _complete_fetch(self, app_id: str) -> None:
        """All pieces verified: reassemble real images, cache the Seed
        copy, and hand the agent the keys to the executable."""
        inv = self.inventories[app_id]
        self.complete.add(app_id)
        self.fetching.discard(app_id)
        for piece_id in list(self.pending.get(app_id, {})):
            self._reconcile(app_id, piece_id)
        image = None
        if inv.manifest.content_hashed:
            image = self.assembled_image(app_id)   # store or disk cache
            if image is not None:
                self.image_src[app_id] = image
                # the joined image supersedes the per-piece slices
                self.store.pop(app_id, None)
                if self.dirs is not None:
                    self.dirs.save_seed_image(app_id, image)
        if self.on_image_complete is not None:
            self.on_image_complete(app_id, inv.manifest.manifest_hash, image)

    # ========================== maintenance ============================= #
    def recover(self, app_id: str, stall_s: float) -> None:
        """Re-issue piece requests that went unanswered (e.g. the holder
        died before PEER_GONE propagated, or never unchoked us)."""
        now = self.now()
        pending = self.pending.get(app_id, {})
        for piece_id, asked in list(pending.items()):
            for peer, t in list(asked.items()):
                if now - t > stall_s:
                    del asked[peer]
                    self.peer_load[peer] = max(0,
                                               self.peer_load[peer] - 1)
                    # the holder may have the request parked in its choke
                    # queue (endgame): withdraw it, or it inflates the
                    # load the holder reports to the tracker forever
                    self.send(peer, Msg(PIECE_CANCEL, self.node_id,
                                        {"app_id": app_id,
                                         "piece_id": piece_id},
                                        size_bytes=64))
            if not asked:
                del pending[piece_id]
        # allow a fresh INTERESTED round toward holders that never answered
        if app_id in self.fetching and not self.unchoked_by[app_id]:
            self.interest_sent[app_id].clear()
        self.pump(app_id)
