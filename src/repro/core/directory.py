"""Agent working-directory layout (paper §III.F, Fig. 3).

  <root>/<agent>/
    Seed/App/<app_id>/app.bin
    Seed/App/<app_id>/Data/Tracker        # TAIL's volunteer/lease log
    Seed/App/<app_id>/Result/<part>.res
    Leech/App/<app_id>/Data/Time          # TIME's working-time log
    Leech/App/<app_id>/Result/<part>.res  # temporary, dropped by STOP

All leech content is temporary: once an application finishes (or the host
vanishes), STOP removes the whole Leech/App/<app_id> subtree.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional


class AgentDirs:
    def __init__(self, root: str, agent_id: str):
        self.base = os.path.join(root, agent_id)
        os.makedirs(os.path.join(self.base, "Seed", "App"), exist_ok=True)
        os.makedirs(os.path.join(self.base, "Leech", "App"), exist_ok=True)

    # ---- seed side -------------------------------------------------------
    def seed_app(self, app_id: str, app_bytes: int,
                 image: Optional[bytes] = None) -> str:
        d = os.path.join(self.base, "Seed", "App", app_id)
        os.makedirs(os.path.join(d, "Data"), exist_ok=True)
        os.makedirs(os.path.join(d, "Result"), exist_ok=True)
        with open(os.path.join(d, "app.bin"), "wb") as f:
            f.write(image if image is not None
                    else b"\0" * min(app_bytes, 1 << 16))
        return d

    def save_seed_image(self, app_id: str, image: bytes) -> str:
        """Write a (reassembled) application image as this agent's Seed
        copy — the moment a leecher turns replica seeder."""
        return self.seed_app(app_id, len(image), image=image)

    def load_seed_image(self, app_id: str) -> Optional[bytes]:
        p = os.path.join(self.base, "Seed", "App", app_id, "app.bin")
        if not os.path.exists(p):
            return None
        with open(p, "rb") as f:
            return f.read()

    def tracker_log(self, app_id: str, line: str) -> None:
        d = os.path.join(self.base, "Seed", "App", app_id, "Data")
        os.makedirs(d, exist_ok=True)
        with open(os.path.join(d, "Tracker"), "a") as f:
            f.write(line + "\n")

    def save_seed_result(self, app_id: str, part_id: int, result: Any) -> None:
        d = os.path.join(self.base, "Seed", "App", app_id, "Result")
        os.makedirs(d, exist_ok=True)
        with open(os.path.join(d, f"{part_id}.res"), "w") as f:
            json.dump(result, f)

    # ---- piece cache (paper §V swarm extension) --------------------------
    # Verified image pieces live under Leech/App/<app_id>/Pieces so a
    # volunteer can re-seed them mid-download; once the image completes the
    # pieces are reassembled into the agent's Seed copy (save_seed_image).
    def save_piece(self, app_id: str, piece_id: int, data: bytes) -> None:
        d = os.path.join(self.base, "Leech", "App", app_id, "Pieces")
        os.makedirs(d, exist_ok=True)
        with open(os.path.join(d, f"{piece_id}.piece"), "wb") as f:
            f.write(data)

    def load_piece(self, app_id: str, piece_id: int) -> Optional[bytes]:
        p = os.path.join(self.base, "Leech", "App", app_id, "Pieces",
                         f"{piece_id}.piece")
        if not os.path.exists(p):
            return None
        with open(p, "rb") as f:
            return f.read()

    def drop_piece(self, app_id: str, piece_id: int) -> None:
        """Remove one cached piece (a corrupt or foreign file found while
        rescanning the cache on agent restart)."""
        p = os.path.join(self.base, "Leech", "App", app_id, "Pieces",
                         f"{piece_id}.piece")
        try:
            os.remove(p)
        except OSError:
            pass

    def list_pieces(self, app_id: str) -> list:
        d = os.path.join(self.base, "Leech", "App", app_id, "Pieces")
        if not os.path.isdir(d):
            return []
        return sorted(int(f.split(".")[0]) for f in os.listdir(d)
                      if f.endswith(".piece"))

    def assemble_image(self, app_id: str, n_pieces: int) -> Optional[bytes]:
        """Join the cached pieces into the full image (None if any piece is
        missing); content verification is the caller's job."""
        parts = []
        for piece_id in range(n_pieces):
            data = self.load_piece(app_id, piece_id)
            if data is None:
                return None
            parts.append(data)
        return b"".join(parts)

    # ---- leech side ------------------------------------------------------
    def time_log(self, app_id: str, line: str) -> None:
        d = os.path.join(self.base, "Leech", "App", app_id, "Data")
        os.makedirs(d, exist_ok=True)
        with open(os.path.join(d, "Time"), "a") as f:
            f.write(line + "\n")

    def save_leech_result(self, app_id: str, part_id: int, result: Any
                          ) -> None:
        d = os.path.join(self.base, "Leech", "App", app_id, "Result")
        os.makedirs(d, exist_ok=True)
        with open(os.path.join(d, f"{part_id}.res"), "w") as f:
            json.dump(result, f)

    def load_leech_result(self, app_id: str, part_id: int) -> Optional[Any]:
        p = os.path.join(self.base, "Leech", "App", app_id, "Result",
                         f"{part_id}.res")
        if not os.path.exists(p):
            return None
        with open(p) as f:
            return json.load(f)

    def drop_leech_app(self, app_id: str) -> None:
        d = os.path.join(self.base, "Leech", "App", app_id)
        if os.path.isdir(d):
            shutil.rmtree(d, ignore_errors=True)
