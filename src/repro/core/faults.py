"""Deterministic fault injection for the discrete-event runtime.

The paper's premise is that volunteer nodes are unreliable: they appear,
disappear and sit behind flaky consumer links (BOINC treats client churn
and result loss as the *default* operating condition).  This module is the
fault model the protocol is tested against — a declarative `FaultPlan`
that `SimRuntime` threads through `send`/`_deliver`/`run`:

  * `LinkFault`   — per-message drop probability, duplication probability
                    and reorder jitter, per link or as a default for every
                    link;
  * `Partition`   — timed network partitions: nodes in different islands
                    cannot exchange messages while the partition is up
                    (in-flight messages crossing the cut are lost);
  * `Crash`       — node crash/restart schedules: a crashed node loses its
                    timers, in-flight work and volatile state; on restart
                    it re-registers (a fresh agent incarnation when a
                    restart factory is registered, so only the disk piece
                    cache survives — the PR 3 rescan path);
  * `drop_next`   — drop the next n messages matching (src, dst, kind)
                    deterministically, no RNG draw (targeted tests).

Every random decision comes from one `random.Random(plan.seed)` owned by
the runtime and is only drawn when the effective fault is non-trivial, so
a zero-fault plan is *provably free*: it produces an event-for-event
identical trace to a runtime with no plan at all (differential-tested in
tests/test_chaos.py).  A chaos run is exactly reproducible from
``(seed, plan)`` within a process; across processes set PYTHONHASHSEED for
bit-identical traces (set iteration order over node ids depends on it).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Tuple


@dataclass(frozen=True)
class LinkFault:
    """Per-message fault rates on a (src, dst) link."""
    drop_p: float = 0.0          # P(message lost)
    dup_p: float = 0.0           # P(message delivered twice)
    jitter_s: float = 0.0        # extra delay ~ U(0, jitter_s) — reordering

    def __bool__(self) -> bool:
        return bool(self.drop_p or self.dup_p or self.jitter_s)


NO_FAULT = LinkFault()


@dataclass
class Partition:
    """A timed partition.  `islands` are disjoint node groups; every node
    not listed belongs to one implicit "rest" island.  While the partition
    is up, messages whose endpoints sit in different islands are lost at
    delivery time (so in-flight traffic crossing the cut dies too)."""
    start_s: float
    end_s: float
    islands: Tuple[FrozenSet[str], ...]

    def __post_init__(self):
        self.islands = tuple(frozenset(g) for g in self.islands)

    def _island(self, node: str) -> Optional[int]:
        for i, group in enumerate(self.islands):
            if node in group:
                return i
        return None                        # the implicit rest-island

    def cuts(self, src: str, dst: str, t: float) -> bool:
        if not (self.start_s <= t < self.end_s):
            return False
        return self._island(src) != self._island(dst)


@dataclass
class Crash:
    """Crash `node` at `at_s`; restart it at `restart_s` (None = stays
    dead).  Volatile state dies with the process; whether anything
    survives depends on the restart path — a registered restart factory
    builds a fresh node (only the on-disk piece cache survives), otherwise
    the old object is resumed with its memory intact."""
    node: str
    at_s: float
    restart_s: Optional[float] = None


@dataclass
class FaultPlan:
    """Everything the chaos layer may do to one run, reproducible from
    ``(seed, plan)``.  A default-constructed plan is the zero-fault plan:
    attaching it to a SimRuntime changes nothing, provably (see module
    docstring)."""
    seed: int = 0
    link: LinkFault = field(default_factory=LinkFault)   # every-link default
    links: Dict[Tuple[str, str], LinkFault] = field(default_factory=dict)
    partitions: List[Partition] = field(default_factory=list)
    crashes: List[Crash] = field(default_factory=list)
    # (src, dst, kind) -> drop the next n matching messages; deterministic
    # (no RNG draw), for targeted loss-recovery tests
    drop_next: Dict[Tuple[str, str, str], int] = field(default_factory=dict)
    # nodes whose links never lose/duplicate/jitter (partitions and
    # crashes still apply) — e.g. keep a reference observer clean
    protected: FrozenSet[str] = frozenset()

    def link_fault(self, src: str, dst: str) -> LinkFault:
        if src in self.protected or dst in self.protected:
            return NO_FAULT
        return self.links.get((src, dst), self.link)

    def cut(self, src: str, dst: str, t: float) -> bool:
        for p in self.partitions:
            if p.cuts(src, dst, t):
                return True
        return False
