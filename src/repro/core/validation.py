"""Majority-voting result validation (paper §III.D, after Sarmenta).

A part's result is accepted once at least `quorum` results agree by
majority; malicious/aberrant results are discarded and never reach the
server's status updates.
"""
from __future__ import annotations

import collections
from typing import Any, List, Optional, Tuple


def _canon(r: Any):
    if isinstance(r, (list, tuple)):
        return tuple(_canon(x) for x in r)
    if isinstance(r, dict):
        return tuple(sorted((k, _canon(v)) for k, v in r.items()))
    return r


def majority_vote(results: List[Any], quorum: int = 1
                  ) -> Tuple[Optional[Any], bool]:
    """Returns (winning_result, accepted)."""
    if len(results) < quorum:
        return None, False
    counts = collections.Counter(_canon(r) for r in results)
    winner, n = counts.most_common(1)[0]
    if n * 2 > len(results) or (len(results) == 1 and quorum == 1):
        for r in results:
            if _canon(r) == winner:
                return r, True
    return None, False


class VotingPool:
    """Standalone m_min/m_max voting pool (used by cluster/sdc.py)."""

    def __init__(self, m_min: int = 2, m_max: int = 3):
        assert m_max >= m_min >= 1
        self.m_min = m_min
        self.m_max = m_max
        self.votes: dict = {}

    def offer(self, key, voter: str, value) -> Optional[Tuple[Any, bool]]:
        """Add a vote; returns (winner, unanimous) once decidable else None."""
        slot = self.votes.setdefault(key, [])
        if any(v == voter for v, _ in slot):
            return None
        slot.append((voter, value))
        if len(slot) < self.m_min:
            return None
        winner, ok = majority_vote([x for _, x in slot], quorum=self.m_min)
        if ok:
            unanimous = len({_canon(x) for _, x in slot}) == 1
            return winner, unanimous
        if len(slot) >= self.m_max:
            return None, False
        return None
