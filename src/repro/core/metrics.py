"""Measurement units of the paper (§III.B, eqs. 1-4).

Every application `A` published to the tracker carries three units so
volunteers can judge it before leeching:

  d_A = sum_v d_app + sum_i d_data          (eq. 1)  — bytes moved
  p_A = sum_i frequency(A_i)                 (eq. 2)  — popularity (cycles run)
  w_A = sum_i time(A_i) / p_A                (eq. 3)  — avg working time
  under m_min-way validation all scale by m_min (eq. 4)

High d + low w  -> low complexity; high p and w + low d -> high complexity
(§III.B).  The same units drive the framework's scheduler cost model
(heterogeneity-aware placement) — see cluster/coordinator.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List


@dataclass
class AppMetrics:
    """Accumulates (d, p, w) for one application."""
    d_app_bytes: int = 0                 # size of the application file
    d_data_bytes: float = 0.0            # sum of data part sizes transferred
    app_downloads: int = 0               # REQ re-downloads the app each cycle
    cycles: int = 0                      # p numerator (frequency)
    total_time_s: float = 0.0            # sum of per-cycle working time
    m_min: int = 1                       # validation replication (eq. 4)

    # -- updates ----------------------------------------------------------
    def record_cycle(self, data_bytes: float, time_s: float,
                     app_downloaded: bool = True) -> None:
        self.cycles += 1
        self.d_data_bytes += data_bytes
        if app_downloaded:
            self.app_downloads += 1
        self.total_time_s += time_s

    # -- units ------------------------------------------------------------
    @property
    def d(self) -> float:
        """eq. (1) scaled by m_min per eq. (4)."""
        return self.m_min * (self.d_app_bytes * self.app_downloads
                             + self.d_data_bytes)

    @property
    def p(self) -> float:
        """eq. (2) scaled by m_min per eq. (4)."""
        return self.m_min * self.cycles

    @property
    def w(self) -> float:
        """eq. (3); note eq. (4) scales the numerator sum, and p carries its
        own m_min, so w is m_min-invariant in the paper's formulation."""
        if self.cycles == 0:
            return 0.0
        return self.m_min * self.total_time_s / self.p

    def as_dict(self) -> Dict[str, float]:
        return {"d": self.d, "p": self.p, "w": self.w}


def complexity_hint(d: float, p: float, w: float,
                    d_scale: float = 1 << 20, w_scale: float = 10.0) -> str:
    """The paper's §III.B heuristic, as a volunteer-facing hint."""
    high_d = d > d_scale
    high_w = w > w_scale
    high_p = p > 100
    if high_d and not high_w:
        return "low"
    if high_p and high_w and not high_d:
        return "high"
    return "medium"
