"""Batched swarm decision kernels: rarest-first scoring and choke ranking
for ALL nodes in one vectorized pass (ROADMAP: "N=2000+ flash crowds via
batched, array-native simulation").

The scalar `PieceExchange` engine makes every decision one Python call at
a time: `rarest_first_order_np` sorts one node's missing pieces, and
`_rechoke_app` ranks one holder's candidates.  At N=2000 those calls —
not the protocol — dominate the simulation wall-clock.  This module
computes the same decisions for a whole swarm as array programs over the
`SwarmState` layout (core/swarm_arrays.py):

  * `rarest_keys` / `rarest_orders`  — per-(node, piece) composite sort
    keys reproducing `rarest_first_order_np`'s lexsort order
    ``(counts, (p + offset) % n, p)`` exactly, argsorted per row;
  * `choke_order` — per-holder candidate ranking reproducing
    `_rechoke_app`'s ``sorted(cands, key=(-rate_from, -rate_to, name))``
    via a chain of stable argsorts.

Three interchangeable backends hide behind the same API, mirroring the
repo's kernel discipline (`repro.kernels.ssd.ops`: reference impl +
differential tests + selectable fast path):

  * ``numpy``  — always available, the default on CPU-only images;
  * ``jax``    — jitted `jnp` version of the same math;
  * ``pallas`` — the rarest-first scoring inner loop as a Pallas kernel
    (interpret mode on CPU, compiled on TPU), argsort staying in XLA.

`set_backend` / the ``REPRO_SWARM_BACKEND`` env var select globally;
every function also takes an explicit ``backend=``.  Unknown or
unavailable backends fall back to numpy, so CPU-only CI never needs jax.
Differential tests (tests/test_swarm_batch.py) assert all backends
reproduce the scalar decisions bit-for-bit.
"""
from __future__ import annotations

import os
from typing import List, Optional, Sequence

import numpy as np

try:  # CPU-only protocol CI installs no jax; everything degrades to numpy
    import jax
    import jax.numpy as jnp
    _HAVE_JAX = True
except Exception:  # pragma: no cover - exercised on the no-jax CI image
    jax = None
    jnp = None
    _HAVE_JAX = False

# sentinel key for pieces a row must not request (held, pending, invalid):
# larger than any real composite key so they argsort to the back
KEY_INF = np.int64(2 ** 62)

# int32-safe sentinel for the fused request-matching / endgame top-k
# kernels (the jax backend runs without x64): holder keys there are
# cost * 2^20 + rank < 2^27, so 2^30 is strictly above any real key
KEY_INF32 = np.int32(2 ** 30)

_backend = os.environ.get("REPRO_SWARM_BACKEND", "numpy")


def available_backends() -> List[str]:
    return ["numpy"] + (["jax", "pallas"] if _HAVE_JAX else [])


def set_backend(name: str) -> str:
    """Select the default backend; unavailable ones fall back to numpy."""
    global _backend
    _backend = name if name in available_backends() else "numpy"
    return _backend


def get_backend(backend: Optional[str] = None) -> str:
    b = backend if backend is not None else _backend
    return b if b in available_backends() else "numpy"


# ====================== rarest-first scoring ============================ #
# The scalar order (swarm.rarest_first_order_np) is
#     np.lexsort((m, (m + offset) % n, counts[m]))
# i.e. sort missing piece ids by (availability, rotated id, raw id).  With
# counts < COUNT_CAP and piece ids < n the three keys pack losslessly into
# one int64:  key = (counts * n + rot) * n + p  — one argsort per row then
# reproduces the lexsort order for ALL rows at once.

def rarest_keys_np(counts: np.ndarray, offsets: np.ndarray,
                   n_pieces: int) -> np.ndarray:
    """(R, P) int64 composite keys; rows are nodes, columns pieces."""
    n = max(int(n_pieces), 1)
    p = np.arange(n, dtype=np.int64)
    rot = (p[None, :] + np.asarray(offsets, dtype=np.int64)[:, None]) % n
    return (counts.astype(np.int64)[None, :] * n + rot) * n + p[None, :]


if _HAVE_JAX:
    from functools import partial

    @partial(jax.jit, static_argnames=("n_pieces", "impl", "interpret"))
    def _rarest_keys_jax(counts, offsets, n_pieces: int,
                         impl: str = "jnp", interpret: bool = True):
        # int32 throughout (jax runs without x64 here): the composite key
        # needs counts * n^2 < 2^31, which holds for every simulated
        # swarm (counts <= N; see _rarest_keys_pallas)
        if impl == "pallas":
            return _rarest_keys_pallas(counts, offsets, n_pieces,
                                       interpret=interpret)
        n = max(int(n_pieces), 1)
        p = jnp.arange(n, dtype=jnp.int32)
        rot = (p[None, :] + offsets.astype(jnp.int32)[:, None]) % n
        return (counts.astype(jnp.int32)[None, :] * n + rot) * n + p[None, :]

    def _rarest_keys_pallas(counts, offsets, n_pieces: int,
                            interpret: bool = True):
        """Pallas scoring kernel: the fused multiply-add + rotated-modulo
        inner loop of the rarest-first key computation, one grid row per
        node block.  int32 on-chip (TPU-native); the (counts * n * n)
        product must stay below 2^31, which holds for every simulated
        swarm (counts <= N, N * P^2 < 2^31 up to N=2000, P=1024)."""
        import jax.experimental.pallas as pl

        n = max(int(n_pieces), 1)
        rows = offsets.shape[0]

        def kernel(counts_ref, off_ref, out_ref):
            c = counts_ref[...].astype(jnp.int32)            # (1, n)
            off = off_ref[...].astype(jnp.int32)             # (1, 1)
            p = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)
            rot = jax.lax.rem(p + off, jnp.int32(n))
            out_ref[...] = (c * n + rot) * n + p

        return pl.pallas_call(
            kernel,
            grid=(rows,),
            in_specs=[
                pl.BlockSpec((1, n), lambda i: (0, 0)),
                pl.BlockSpec((1, 1), lambda i: (i, 0)),
            ],
            out_specs=pl.BlockSpec((1, n), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((rows, n), jnp.int32),
            interpret=interpret,
        )(counts.astype(jnp.int32)[None, :],
          offsets.astype(jnp.int32)[:, None])


def rarest_keys(counts: np.ndarray, offsets: np.ndarray, n_pieces: int,
                backend: Optional[str] = None) -> np.ndarray:
    """Composite rarest-first sort keys for many nodes at once.

    ``counts``  — (P,) availability counts (partial holders; a uniform
                  full-seeder constant cannot change the order);
    ``offsets`` — (R,) per-node tie-break rotations (the scalar engine's
                  ``sum(ord(c) for c in node_id + app_id)``).
    Returns (R, P) int64 keys; ``argsort(keys[r])`` is exactly
    ``rarest_first_order_np(range(P), counts, offsets[r], P)``.
    """
    b = get_backend(backend)
    if b == "numpy":
        return rarest_keys_np(counts, offsets, n_pieces)
    impl = "pallas" if b == "pallas" else "jnp"
    out = _rarest_keys_jax(jnp.asarray(np.asarray(counts)),
                           jnp.asarray(np.asarray(offsets)),
                           int(n_pieces), impl=impl)
    return np.asarray(out, dtype=np.int64)


def rarest_orders(missing: np.ndarray, counts: np.ndarray,
                  offsets: np.ndarray, n_pieces: int,
                  backend: Optional[str] = None) -> np.ndarray:
    """Batched `rarest_first_order_np`: full piece order per node.

    ``missing`` is (R, P) bool — True where the node may request the
    piece.  Returns (R, P) int32 piece ids; row r's first
    ``missing[r].sum()`` entries are that node's missing pieces in
    rarest-first order (non-missing pieces sort to the back via KEY_INF).
    """
    keys = rarest_keys(counts, offsets, n_pieces, backend=backend)
    keys = np.where(np.asarray(missing, dtype=bool), keys, KEY_INF)
    return np.argsort(keys, axis=1, kind="stable").astype(np.int32)


# ================== topology-aware (P4P) scoring ======================== #
# Cost-aware piece selection (ISSUE 7): each node ranks its missing pieces
# by (network cost of the cheapest holder island, rarity, rotated id, id).
# Cost is PRIMARY: a piece held on the node's own island always beats one
# only available across an ISP boundary, which is what cuts cross-ISP
# bytes.  When every piece has the same cheapest-holder cost — one island,
# or all same-island holders starved away — the cost plane is uniform and
# the order degrades to exactly `rarest_orders` (the decay-to-rarity
# property the chaos overlay test pins).
#
# The backend-differentiated work is `island_has`: a (K, P) island-level
# availability reduction over the (N, P) have-matrix, computed as a
# onehot(K, N) @ have(N, P) matmul (MXU-shaped on TPU).  The final
# cost ⊕ rarity combine happens host-side in int64 over the backend's
# int32/int64 base keys — same discipline as the masking + argsort in
# `rarest_orders`, and it sidesteps the int32 headroom the jax/pallas
# base keys already exhaust (counts * P^2 < 2^31 leaves no room for a
# cost multiplier).

# sentinel "no holder anywhere" cost: above any real ALTO cost (<= 15)
COST_NONE = np.int64(64)


def island_has_np(have: np.ndarray, member: np.ndarray) -> np.ndarray:
    """(K, P) bool: does any alive node of island k hold piece p?

    ``have``   — (N, P) bool/int piece-holding matrix (alive holders only;
                 the caller zeroes dead/irrelevant rows);
    ``member`` — (K, N) bool island membership (onehot of island index).
    """
    m = np.asarray(member, dtype=np.int32)
    h = np.asarray(have, dtype=np.int32)
    return (m @ h) > 0


if _HAVE_JAX:
    @jax.jit
    def _island_has_jax(have, member):
        return (member.astype(jnp.int32) @ have.astype(jnp.int32)) > 0

    def _island_has_pallas(have, member, interpret: bool = True):
        """Pallas island-availability kernel: one grid step per island,
        reducing that island's member rows over the have-matrix as a
        (1, N) x (N, P) dot — the MXU-native shape of the reduction."""
        import jax.experimental.pallas as pl

        k, n = member.shape
        p = have.shape[1]

        def kernel(member_ref, have_ref, out_ref):
            m = member_ref[...].astype(jnp.float32)          # (1, n)
            h = have_ref[...].astype(jnp.float32)            # (n, p)
            out_ref[...] = jnp.dot(
                m, h, preferred_element_type=jnp.float32) > 0

        return pl.pallas_call(
            kernel,
            grid=(k,),
            in_specs=[
                pl.BlockSpec((1, n), lambda i: (i, 0)),
                pl.BlockSpec((n, p), lambda i: (0, 0)),
            ],
            out_specs=pl.BlockSpec((1, p), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((k, p), jnp.bool_),
            interpret=interpret,
        )(member.astype(jnp.int32), have.astype(jnp.int32))


def island_has(have: np.ndarray, member: np.ndarray,
               backend: Optional[str] = None) -> np.ndarray:
    """Backend-selectable island-level availability reduction."""
    b = get_backend(backend)
    if b == "numpy":
        return island_has_np(have, member)
    hj = jnp.asarray(np.asarray(have, dtype=np.int32))
    mj = jnp.asarray(np.asarray(member, dtype=np.int32))
    if b == "pallas":
        out = _island_has_pallas(hj, mj)
    else:
        out = _island_has_jax(hj, mj)
    return np.asarray(out, dtype=bool)


def min_island_cost(avail: np.ndarray, cost: np.ndarray) -> np.ndarray:
    """(K, P) per-source-island cheapest-holder cost plane.

    ``avail`` — (K, P) bool island availability (from `island_has`);
    ``cost``  — (K, K) ALTO cost matrix (row = source island).
    Entry [s, p] is the minimum cost from island s to any island holding
    piece p; pieces nobody holds get COST_NONE (they are masked out of
    requests anyway, but the sentinel keeps the key finite and uniform).
    Plain numpy on purpose: K x K x P is tiny next to the N x P reduction,
    and sharing one implementation keeps every backend bit-identical.
    """
    a = np.asarray(avail, dtype=bool)                       # (K, P)
    c = np.asarray(cost, dtype=np.int64)                    # (K, K)
    # broadcast: plane[s, k, p] = cost[s, k] where island k holds p
    plane = np.where(a[None, :, :], c[:, :, None], COST_NONE)
    return plane.min(axis=1)                                # (K, P)


def cost_rarest_keys(counts: np.ndarray, offsets: np.ndarray,
                     piece_cost: np.ndarray, n_pieces: int,
                     backend: Optional[str] = None) -> np.ndarray:
    """Cost-primary composite keys: (R, P) int64
    ``key = piece_cost * span + rarest_key`` with
    ``span = (max_count + 1) * n^2`` so the cost strictly dominates and
    the within-cost order is exactly the rarest-first order.

    ``piece_cost`` — (R, P) per-(node, piece) cheapest-holder cost (the
    node's island row of the `min_island_cost` plane).  A uniform cost
    plane shifts every key by the same amount: ordering identical to
    `rarest_keys` (decay-to-rarity, differential-tested).
    """
    base = rarest_keys(counts, offsets, n_pieces, backend=backend)
    n = max(int(n_pieces), 1)
    max_count = int(np.asarray(counts).max()) if np.asarray(counts).size \
        else 0
    span = np.int64(max_count + 1) * n * n
    return np.asarray(piece_cost, dtype=np.int64) * span \
        + base.astype(np.int64)


def cost_orders(missing: np.ndarray, counts: np.ndarray,
                offsets: np.ndarray, piece_cost: np.ndarray,
                n_pieces: int,
                backend: Optional[str] = None) -> np.ndarray:
    """Batched cost-aware piece order per node (the P4P `rarest_orders`).

    Same contract as `rarest_orders` plus ``piece_cost`` (R, P): row r's
    first ``missing[r].sum()`` entries are node r's missing pieces ordered
    by (cheapest-holder cost, rarity, rotated id, id).
    """
    keys = cost_rarest_keys(counts, offsets, piece_cost, n_pieces,
                            backend=backend)
    keys = np.where(np.asarray(missing, dtype=bool), keys, KEY_INF)
    return np.argsort(keys, axis=1, kind="stable").astype(np.int32)


# ========================= choke ranking ================================ #
def choke_order_np(recv: np.ndarray, sent: np.ndarray, cand: np.ndarray,
                   ranks: np.ndarray) -> np.ndarray:
    """Rank every holder's unchoke candidates in one pass.

    Reproduces `_rechoke_app`'s ``sorted(cands, key=lambda p:
    (-rate_from[p], -rate_to[p], p))`` for all holders at once via a
    chain of stable argsorts (last key applied last is primary).
    ``ranks`` maps column -> lexicographic rank of the node name, which
    is what the scalar string tie-break sorts by; a 2-D (H, C) ranks
    matrix gives every holder row its own tie-break key (P4P mode packs
    the ALTO cost above the name rank).  Non-candidate columns are
    pushed to the back.  Returns (H, C) int32 column indices.
    """
    cand = np.asarray(cand, dtype=bool)
    # non-candidates must lose every comparison: real rates are >= 0
    r1 = np.where(cand, recv, -1.0)
    r2 = np.where(cand, sent, -1.0)
    rk = np.asarray(ranks)
    if rk.ndim == 1:
        rk = rk[None, :]
    nm = np.where(cand, rk, rk.max() + 1 if rk.size
                  else 1).astype(np.int64)
    # stable multi-key sort: name (tie-break), then -sent, then -recv
    order = np.argsort(nm, axis=1, kind="stable")
    for key in (-r2, -r1):
        k = np.take_along_axis(key, order, axis=1)
        order = np.take_along_axis(order,
                                   np.argsort(k, axis=1, kind="stable"),
                                   axis=1)
    return order.astype(np.int32)


if _HAVE_JAX:
    @jax.jit
    def _choke_order_jax(recv, sent, cand, ranks):
        r1 = jnp.where(cand, recv, -1.0)
        r2 = jnp.where(cand, sent, -1.0)
        # int32 keys (jax runs without x64): callers packing cost above
        # the name rank must keep cost * shift + rank < 2^31
        rk = ranks if ranks.ndim == 2 else ranks[None, :]
        maxr = jnp.max(rk) + 1 if rk.size else 1
        nm = jnp.where(cand, rk, maxr).astype(jnp.int32)
        order = jnp.argsort(nm, axis=1, stable=True)
        for key in (-r2, -r1):
            k = jnp.take_along_axis(key, order, axis=1)
            order = jnp.take_along_axis(
                order, jnp.argsort(k, axis=1, stable=True), axis=1)
        return order.astype(jnp.int32)


def choke_order(recv: np.ndarray, sent: np.ndarray, cand: np.ndarray,
                ranks: np.ndarray,
                backend: Optional[str] = None) -> np.ndarray:
    b = get_backend(backend)
    if b == "numpy":
        return choke_order_np(recv, sent, cand, ranks)
    # the pallas backend shares the jax ranking path: the scoring kernel
    # only covers the rarest-first inner loop, where it wins
    out = _choke_order_jax(jnp.asarray(np.asarray(recv, dtype=np.float32)),
                           jnp.asarray(np.asarray(sent, dtype=np.float32)),
                           jnp.asarray(np.asarray(cand, dtype=bool)),
                           jnp.asarray(np.asarray(ranks, dtype=np.int32)))
    return np.asarray(out, dtype=np.int32)


# ==================== fused request matching ============================ #
# The array-native ledger (ISSUE 10) lets the hub's pump stage stop
# walking per-node dicts: every selected row's holder choice becomes one
# greedy walk over its piece order, executed for ALL rows as a loop over
# order POSITIONS (at most P vectorized steps, independent of N — the
# "host time sublinear in N" property).  Each step k picks, for every
# still-active row, the lowest-keyed usable candidate holding that row's
# k-th rarest piece, marks the holder busy (one in-flight request per
# holder) and burns one pipeline-budget unit — exactly the scalar
# `_match_row` walk.
#
# Keys are the int32-safe encoding ``cost * 2^20 + rank`` (< 2^27): it
# orders identically to the scalar engine's ``rank + cost * 2^32`` —
# both are the lexicographic (cost, rank) order, since rank < 2^20 —
# but fits the x64-less jax backend.  Rows with shunned or banned
# holders stay on the scalar `_match_row` slow path, so the kernel never
# needs the shun plane.

def match_requests_np(orders: np.ndarray, n_walk: np.ndarray,
                      budgets: np.ndarray, cand: np.ndarray,
                      cand_ok: np.ndarray, cand_key: np.ndarray,
                      have: np.ndarray, full: np.ndarray) -> np.ndarray:
    """Greedy holder-match for many rows at once.

    ``orders``   — (R, P) int piece ids, each row's request order;
    ``n_walk``   — (R,) how many order positions row r may walk
                   (its missing-piece count);
    ``budgets``  — (R,) pipeline budget (requests row r may issue);
    ``cand``     — (R, C) int32 candidate holder rows, -1 padded;
    ``cand_ok``  — (R, C) bool: candidate is usable (valid, alive,
                   holder-ish, not self, not already busy for the row);
    ``cand_key`` — (R, C) int32 preference key, lower wins
                   (``cost * 2^20 + name_rank``);
    ``have``     — (N, P) bool piece-holding matrix; ``full`` — (N,) bool.

    Returns (R, P) int32 picks: ``picks[r, k]`` is the holder row chosen
    for piece ``orders[r, k]``, or -1.  A row stops when its budget is
    exhausted, its walk ends, or all its candidates are busy.
    """
    orders = np.asarray(orders)
    R, P = orders.shape
    picks = np.full((R, P), -1, dtype=np.int32)
    C = cand.shape[1] if cand.ndim == 2 else 0
    if R == 0 or C == 0:
        return picks
    safe = np.where(cand >= 0, cand, 0)
    hv = np.asarray(have, dtype=bool)[safe] \
        | np.asarray(full, dtype=bool)[safe][:, :, None]     # (R, C, P)
    taken = ~np.asarray(cand_ok, dtype=bool)
    budget = np.asarray(budgets, dtype=np.int64).copy()
    walk = np.asarray(n_walk, dtype=np.int64)
    key = np.asarray(cand_key, dtype=np.int64)
    ridx = np.arange(R)
    kmax = int(min(max(int(walk.max(initial=0)), 0), P))
    for k in range(kmax):
        act = (budget > 0) & (k < walk) & ~taken.all(axis=1)
        if not act.any():
            break
        p = orders[:, k].astype(np.int64)
        okk = ~taken & hv[ridx, :, p] & act[:, None]         # (R, C)
        sel = okk.any(axis=1)
        c = np.argmin(np.where(okk, key, np.int64(KEY_INF32)), axis=1)
        picks[sel, k] = cand[sel, c[sel]]
        taken[sel, c[sel]] = True
        budget[sel] -= 1
    return picks


if _HAVE_JAX:
    @jax.jit
    def _match_requests_jax(orders, n_walk, budgets, cand, cand_ok,
                            cand_key, have, full):
        R, P = orders.shape
        safe = jnp.where(cand >= 0, cand, 0)
        hv = have[safe] | full[safe][:, :, None]             # (R, C, P)
        inf = jnp.int32(KEY_INF32)
        key0 = jnp.where(cand_ok, cand_key.astype(jnp.int32), inf)
        ridx = jnp.arange(R)

        def body(k, carry):
            picks, taken, budget = carry
            act = (budget > 0) & (k < n_walk) & ~jnp.all(taken, axis=1)
            p = orders[:, k]
            col = jnp.take_along_axis(
                hv, p[:, None, None], axis=2)[:, :, 0]       # (R, C)
            okk = ~taken & col & act[:, None]
            sel = okk.any(axis=1)
            c = jnp.argmin(jnp.where(okk, key0, inf), axis=1)
            val = jnp.take_along_axis(cand, c[:, None], axis=1)[:, 0]
            picks = picks.at[:, k].set(
                jnp.where(sel, val, picks[:, k]))
            taken = taken.at[ridx, c].set(taken[ridx, c] | sel)
            budget = budget - sel.astype(budget.dtype)
            return picks, taken, budget

        picks0 = jnp.full((R, P), -1, dtype=jnp.int32)
        picks, _, _ = jax.lax.fori_loop(
            0, P, body,
            (picks0, ~cand_ok, budgets.astype(jnp.int32)))
        return picks

    def _match_requests_pallas(orders, n_walk, budgets, cand, cand_ok,
                               cand_key, have, full,
                               interpret: bool = True):
        """Pallas request-matching kernel: one grid program per row walks
        that row's piece order with the (candidate-availability, key,
        busy-mask) state resident in the program — the per-row greedy
        inner loop the numpy/jax paths vectorize across rows."""
        import jax.experimental.pallas as pl

        R, P = orders.shape
        C = cand.shape[1]
        safe = jnp.where(cand >= 0, cand, 0)
        hv = (have[safe] | full[safe][:, :, None]).astype(jnp.int32)
        inf = int(KEY_INF32)  # plain int: pallas kernels can't capture arrays

        def kernel(ord_ref, walk_ref, bud_ref, cand_ref, ok_ref,
                   key_ref, hv_ref, out_ref):
            order = ord_ref[...]                             # (1, P)
            okrow = ok_ref[...][0] != 0                      # (C,)
            keyrow = jnp.where(okrow, key_ref[...][0], inf)  # (C,)
            hvrow = hv_ref[...][0]                           # (C, P)
            candrow = cand_ref[...][0]                       # (C,)
            walk = walk_ref[...][0, 0]

            def body(k, carry):
                out, taken, bud = carry
                act = (bud > 0) & (k < walk) & jnp.any(~taken)
                p = order[0, k]
                col = jax.lax.dynamic_index_in_dim(
                    hvrow, p, axis=1, keepdims=False)        # (C,)
                okk = ~taken & (col != 0) & act
                sel = jnp.any(okk)
                c = jnp.argmin(jnp.where(okk, keyrow, inf))
                out = out.at[0, k].set(
                    jnp.where(sel, candrow[c], out[0, k]))
                taken = taken.at[c].set(taken[c] | sel)
                bud = bud - sel.astype(bud.dtype)
                return out, taken, bud

            init = (jnp.full((1, P), -1, dtype=jnp.int32),
                    ~okrow, bud_ref[...][0, 0])
            out, _, _ = jax.lax.fori_loop(0, P, body, init)
            out_ref[...] = out

        return pl.pallas_call(
            kernel,
            grid=(R,),
            in_specs=[
                pl.BlockSpec((1, P), lambda i: (i, 0)),
                pl.BlockSpec((1, 1), lambda i: (i, 0)),
                pl.BlockSpec((1, 1), lambda i: (i, 0)),
                pl.BlockSpec((1, C), lambda i: (i, 0)),
                pl.BlockSpec((1, C), lambda i: (i, 0)),
                pl.BlockSpec((1, C), lambda i: (i, 0)),
                pl.BlockSpec((1, C, P), lambda i: (i, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, P), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((R, P), jnp.int32),
            interpret=interpret,
        )(orders.astype(jnp.int32),
          n_walk.astype(jnp.int32)[:, None],
          budgets.astype(jnp.int32)[:, None],
          cand.astype(jnp.int32),
          cand_ok.astype(jnp.int32),
          cand_key.astype(jnp.int32),
          hv)


def match_requests(orders: np.ndarray, n_walk: np.ndarray,
                   budgets: np.ndarray, cand: np.ndarray,
                   cand_ok: np.ndarray, cand_key: np.ndarray,
                   have: np.ndarray, full: np.ndarray,
                   backend: Optional[str] = None) -> np.ndarray:
    b = get_backend(backend)
    if b == "numpy" or np.asarray(orders).shape[0] == 0 \
            or cand.shape[1] == 0:
        return match_requests_np(orders, n_walk, budgets, cand,
                                 cand_ok, cand_key, have, full)
    oj = jnp.asarray(np.asarray(orders, dtype=np.int32))
    wj = jnp.asarray(np.asarray(n_walk, dtype=np.int32))
    bj = jnp.asarray(np.asarray(budgets, dtype=np.int32))
    cj = jnp.asarray(np.asarray(cand, dtype=np.int32))
    okj = jnp.asarray(np.asarray(cand_ok, dtype=bool))
    kj = jnp.asarray(np.asarray(cand_key, dtype=np.int32))
    hj = jnp.asarray(np.asarray(have, dtype=bool))
    fj = jnp.asarray(np.asarray(full, dtype=bool))
    if b == "pallas":
        out = _match_requests_pallas(oj, wj, bj, cj, okj, kj, hj, fj)
    else:
        out = _match_requests_jax(oj, wj, bj, cj, okj, kj, hj, fj)
    return np.asarray(out, dtype=np.int32)


# ===================== endgame holder top-k ============================= #
# The fused endgame stage ranks, per piece, the K cheapest eligible
# holders once per tick, then every endgame row selects its duplicate
# targets from that shared shortlist with pure array ops.  K =
# 2 * endgame_cap + 1 guarantees the shortlist is never exhausted: a row
# excludes at most endgame_cap already-asked holders plus itself, and
# needs at most endgame_cap picks — so whenever more eligible holders
# exist than the shortlist shows, the shortlist still covers the need.

def holder_topk_np(keys: np.ndarray, k: int) -> np.ndarray:
    """(K, P) int32 row indices of the K smallest keys per column.

    ``keys`` is (N, P); invalid holders carry KEY_INF32.  Output entries
    whose key is KEY_INF32 (or beyond N) are -1.  Ordered by ascending
    key; keys are unique per column among valid holders (they embed the
    unique name rank), so the result is deterministic.
    """
    keys = np.asarray(keys)
    n, p = keys.shape
    kk = min(int(k), n)
    if kk <= 0 or p == 0:
        return np.full((max(int(k), 0), p), -1, dtype=np.int32)
    if kk < n:
        part = np.argpartition(keys, kk - 1, axis=0)[:kk]
    else:
        part = np.tile(np.arange(n)[:, None], (1, p))
    vals = np.take_along_axis(keys, part, axis=0)
    order = np.argsort(vals, axis=0, kind="stable")
    top = np.take_along_axis(part, order, axis=0)
    tv = np.take_along_axis(keys, top, axis=0)
    out = np.where(tv < np.int64(KEY_INF32), top, -1).astype(np.int32)
    if kk < int(k):
        pad = np.full((int(k) - kk, p), -1, dtype=np.int32)
        out = np.concatenate([out, pad], axis=0)
    return out


if _HAVE_JAX:
    from functools import partial as _partial

    @_partial(jax.jit, static_argnames=("k",))
    def _holder_topk_jax(keys, k: int):
        n, p = keys.shape
        kk = min(int(k), n)
        # top_k takes the LARGEST along the last axis; negate + transpose
        vals, idx = jax.lax.top_k(-keys.astype(jnp.int32).T, kk)
        valid = -vals < jnp.int32(KEY_INF32)
        out = jnp.where(valid, idx, -1).astype(jnp.int32).T   # (kk, P)
        if kk < int(k):
            pad = jnp.full((int(k) - kk, p), -1, dtype=jnp.int32)
            out = jnp.concatenate([out, pad], axis=0)
        return out


def holder_topk(keys: np.ndarray, k: int,
                backend: Optional[str] = None) -> np.ndarray:
    b = get_backend(backend)
    if b == "numpy":
        return holder_topk_np(keys, k)
    # the pallas backend shares the jax path (same discipline as
    # choke_order: selection/sort primitives stay in XLA)
    out = _holder_topk_jax(
        jnp.asarray(np.asarray(keys, dtype=np.int32)), int(k))
    return np.asarray(out, dtype=np.int32)


# ===================== scalar-compatible wrappers ======================= #
def rarest_order_single(missing: Sequence[int], counts: np.ndarray,
                        offset: int, n_pieces: int,
                        backend: Optional[str] = None) -> List[int]:
    """One-node convenience wrapper with `rarest_first_order_np`'s exact
    signature semantics — the differential tests' bridge between the
    scalar engine and the batched kernels."""
    m = np.zeros(n_pieces, dtype=bool)
    idx = np.asarray(list(missing), dtype=np.int64)
    if idx.size == 0:
        return []
    m[idx] = True
    order = rarest_orders(m[None, :], np.asarray(counts),
                          np.asarray([offset]), n_pieces, backend=backend)
    return order[0, : idx.size].tolist()
