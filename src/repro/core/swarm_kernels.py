"""Batched swarm decision kernels: rarest-first scoring and choke ranking
for ALL nodes in one vectorized pass (ROADMAP: "N=2000+ flash crowds via
batched, array-native simulation").

The scalar `PieceExchange` engine makes every decision one Python call at
a time: `rarest_first_order_np` sorts one node's missing pieces, and
`_rechoke_app` ranks one holder's candidates.  At N=2000 those calls —
not the protocol — dominate the simulation wall-clock.  This module
computes the same decisions for a whole swarm as array programs over the
`SwarmState` layout (core/swarm_arrays.py):

  * `rarest_keys` / `rarest_orders`  — per-(node, piece) composite sort
    keys reproducing `rarest_first_order_np`'s lexsort order
    ``(counts, (p + offset) % n, p)`` exactly, argsorted per row;
  * `choke_order` — per-holder candidate ranking reproducing
    `_rechoke_app`'s ``sorted(cands, key=(-rate_from, -rate_to, name))``
    via a chain of stable argsorts.

Three interchangeable backends hide behind the same API, mirroring the
repo's kernel discipline (`repro.kernels.ssd.ops`: reference impl +
differential tests + selectable fast path):

  * ``numpy``  — always available, the default on CPU-only images;
  * ``jax``    — jitted `jnp` version of the same math;
  * ``pallas`` — the rarest-first scoring inner loop as a Pallas kernel
    (interpret mode on CPU, compiled on TPU), argsort staying in XLA.

`set_backend` / the ``REPRO_SWARM_BACKEND`` env var select globally;
every function also takes an explicit ``backend=``.  Unknown or
unavailable backends fall back to numpy, so CPU-only CI never needs jax.
Differential tests (tests/test_swarm_batch.py) assert all backends
reproduce the scalar decisions bit-for-bit.
"""
from __future__ import annotations

import os
from typing import List, Optional, Sequence

import numpy as np

try:  # CPU-only protocol CI installs no jax; everything degrades to numpy
    import jax
    import jax.numpy as jnp
    _HAVE_JAX = True
except Exception:  # pragma: no cover - exercised on the no-jax CI image
    jax = None
    jnp = None
    _HAVE_JAX = False

# sentinel key for pieces a row must not request (held, pending, invalid):
# larger than any real composite key so they argsort to the back
KEY_INF = np.int64(2 ** 62)

_backend = os.environ.get("REPRO_SWARM_BACKEND", "numpy")


def available_backends() -> List[str]:
    return ["numpy"] + (["jax", "pallas"] if _HAVE_JAX else [])


def set_backend(name: str) -> str:
    """Select the default backend; unavailable ones fall back to numpy."""
    global _backend
    _backend = name if name in available_backends() else "numpy"
    return _backend


def get_backend(backend: Optional[str] = None) -> str:
    b = backend if backend is not None else _backend
    return b if b in available_backends() else "numpy"


# ====================== rarest-first scoring ============================ #
# The scalar order (swarm.rarest_first_order_np) is
#     np.lexsort((m, (m + offset) % n, counts[m]))
# i.e. sort missing piece ids by (availability, rotated id, raw id).  With
# counts < COUNT_CAP and piece ids < n the three keys pack losslessly into
# one int64:  key = (counts * n + rot) * n + p  — one argsort per row then
# reproduces the lexsort order for ALL rows at once.

def rarest_keys_np(counts: np.ndarray, offsets: np.ndarray,
                   n_pieces: int) -> np.ndarray:
    """(R, P) int64 composite keys; rows are nodes, columns pieces."""
    n = max(int(n_pieces), 1)
    p = np.arange(n, dtype=np.int64)
    rot = (p[None, :] + np.asarray(offsets, dtype=np.int64)[:, None]) % n
    return (counts.astype(np.int64)[None, :] * n + rot) * n + p[None, :]


if _HAVE_JAX:
    from functools import partial

    @partial(jax.jit, static_argnames=("n_pieces", "impl", "interpret"))
    def _rarest_keys_jax(counts, offsets, n_pieces: int,
                         impl: str = "jnp", interpret: bool = True):
        # int32 throughout (jax runs without x64 here): the composite key
        # needs counts * n^2 < 2^31, which holds for every simulated
        # swarm (counts <= N; see _rarest_keys_pallas)
        if impl == "pallas":
            return _rarest_keys_pallas(counts, offsets, n_pieces,
                                       interpret=interpret)
        n = max(int(n_pieces), 1)
        p = jnp.arange(n, dtype=jnp.int32)
        rot = (p[None, :] + offsets.astype(jnp.int32)[:, None]) % n
        return (counts.astype(jnp.int32)[None, :] * n + rot) * n + p[None, :]

    def _rarest_keys_pallas(counts, offsets, n_pieces: int,
                            interpret: bool = True):
        """Pallas scoring kernel: the fused multiply-add + rotated-modulo
        inner loop of the rarest-first key computation, one grid row per
        node block.  int32 on-chip (TPU-native); the (counts * n * n)
        product must stay below 2^31, which holds for every simulated
        swarm (counts <= N, N * P^2 < 2^31 up to N=2000, P=1024)."""
        import jax.experimental.pallas as pl

        n = max(int(n_pieces), 1)
        rows = offsets.shape[0]

        def kernel(counts_ref, off_ref, out_ref):
            c = counts_ref[...].astype(jnp.int32)            # (1, n)
            off = off_ref[...].astype(jnp.int32)             # (1, 1)
            p = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)
            rot = jax.lax.rem(p + off, jnp.int32(n))
            out_ref[...] = (c * n + rot) * n + p

        return pl.pallas_call(
            kernel,
            grid=(rows,),
            in_specs=[
                pl.BlockSpec((1, n), lambda i: (0, 0)),
                pl.BlockSpec((1, 1), lambda i: (i, 0)),
            ],
            out_specs=pl.BlockSpec((1, n), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((rows, n), jnp.int32),
            interpret=interpret,
        )(counts.astype(jnp.int32)[None, :],
          offsets.astype(jnp.int32)[:, None])


def rarest_keys(counts: np.ndarray, offsets: np.ndarray, n_pieces: int,
                backend: Optional[str] = None) -> np.ndarray:
    """Composite rarest-first sort keys for many nodes at once.

    ``counts``  — (P,) availability counts (partial holders; a uniform
                  full-seeder constant cannot change the order);
    ``offsets`` — (R,) per-node tie-break rotations (the scalar engine's
                  ``sum(ord(c) for c in node_id + app_id)``).
    Returns (R, P) int64 keys; ``argsort(keys[r])`` is exactly
    ``rarest_first_order_np(range(P), counts, offsets[r], P)``.
    """
    b = get_backend(backend)
    if b == "numpy":
        return rarest_keys_np(counts, offsets, n_pieces)
    impl = "pallas" if b == "pallas" else "jnp"
    out = _rarest_keys_jax(jnp.asarray(np.asarray(counts)),
                           jnp.asarray(np.asarray(offsets)),
                           int(n_pieces), impl=impl)
    return np.asarray(out, dtype=np.int64)


def rarest_orders(missing: np.ndarray, counts: np.ndarray,
                  offsets: np.ndarray, n_pieces: int,
                  backend: Optional[str] = None) -> np.ndarray:
    """Batched `rarest_first_order_np`: full piece order per node.

    ``missing`` is (R, P) bool — True where the node may request the
    piece.  Returns (R, P) int32 piece ids; row r's first
    ``missing[r].sum()`` entries are that node's missing pieces in
    rarest-first order (non-missing pieces sort to the back via KEY_INF).
    """
    keys = rarest_keys(counts, offsets, n_pieces, backend=backend)
    keys = np.where(np.asarray(missing, dtype=bool), keys, KEY_INF)
    return np.argsort(keys, axis=1, kind="stable").astype(np.int32)


# ========================= choke ranking ================================ #
def choke_order_np(recv: np.ndarray, sent: np.ndarray, cand: np.ndarray,
                   ranks: np.ndarray) -> np.ndarray:
    """Rank every holder's unchoke candidates in one pass.

    Reproduces `_rechoke_app`'s ``sorted(cands, key=lambda p:
    (-rate_from[p], -rate_to[p], p))`` for all holders at once via a
    chain of stable argsorts (last key applied last is primary).
    ``ranks`` maps column -> lexicographic rank of the node name, which
    is what the scalar string tie-break sorts by.  Non-candidate columns
    are pushed to the back.  Returns (H, C) int32 column indices.
    """
    cand = np.asarray(cand, dtype=bool)
    # non-candidates must lose every comparison: real rates are >= 0
    r1 = np.where(cand, recv, -1.0)
    r2 = np.where(cand, sent, -1.0)
    nm = np.where(cand, ranks[None, :], ranks.max() + 1 if ranks.size
                  else 1).astype(np.int64)
    # stable multi-key sort: name (tie-break), then -sent, then -recv
    order = np.argsort(nm, axis=1, kind="stable")
    for key in (-r2, -r1):
        k = np.take_along_axis(key, order, axis=1)
        order = np.take_along_axis(order,
                                   np.argsort(k, axis=1, kind="stable"),
                                   axis=1)
    return order.astype(np.int32)


if _HAVE_JAX:
    @jax.jit
    def _choke_order_jax(recv, sent, cand, ranks):
        r1 = jnp.where(cand, recv, -1.0)
        r2 = jnp.where(cand, sent, -1.0)
        maxr = jnp.max(ranks) + 1 if ranks.size else 1
        nm = jnp.where(cand, ranks[None, :], maxr).astype(jnp.int32)
        order = jnp.argsort(nm, axis=1, stable=True)
        for key in (-r2, -r1):
            k = jnp.take_along_axis(key, order, axis=1)
            order = jnp.take_along_axis(
                order, jnp.argsort(k, axis=1, stable=True), axis=1)
        return order.astype(jnp.int32)


def choke_order(recv: np.ndarray, sent: np.ndarray, cand: np.ndarray,
                ranks: np.ndarray,
                backend: Optional[str] = None) -> np.ndarray:
    b = get_backend(backend)
    if b == "numpy":
        return choke_order_np(recv, sent, cand, ranks)
    # the pallas backend shares the jax ranking path: the scoring kernel
    # only covers the rarest-first inner loop, where it wins
    out = _choke_order_jax(jnp.asarray(np.asarray(recv, dtype=np.float32)),
                           jnp.asarray(np.asarray(sent, dtype=np.float32)),
                           jnp.asarray(np.asarray(cand, dtype=bool)),
                           jnp.asarray(np.asarray(ranks, dtype=np.int32)))
    return np.asarray(out, dtype=np.int32)


# ===================== scalar-compatible wrappers ======================= #
def rarest_order_single(missing: Sequence[int], counts: np.ndarray,
                        offset: int, n_pieces: int,
                        backend: Optional[str] = None) -> List[int]:
    """One-node convenience wrapper with `rarest_first_order_np`'s exact
    signature semantics — the differential tests' bridge between the
    scalar engine and the batched kernels."""
    m = np.zeros(n_pieces, dtype=bool)
    idx = np.asarray(list(missing), dtype=np.int64)
    if idx.size == 0:
        return []
    m[idx] = True
    order = rarest_orders(m[None, :], np.asarray(counts),
                          np.asarray([offset]), n_pieces, backend=backend)
    return order[0, : idx.size].tolist()
