"""Array-native swarm state + the batched per-tick decision engine.

`PieceExchange` (core/piece_exchange.py) makes every scheduling decision
one Python call at a time — a pump per HAVE announce, a choke pass per
holder, one heap event per protocol message.  That per-message dispatch
caps practical swarm sizes near N=200 (ROADMAP: "N=2000+ flash crowds
via batched, array-native simulation").  This module is the batched
counterpart:

  * `SwarmState` — one app's swarm as flat numpy arrays over *rows*
    (nodes): peer x piece `have` bitmask matrix, per-piece availability
    `counts`, full-seeder / fetching flags, and — since ISSUE 10 — an
    array-native IN-FLIGHT REQUEST LEDGER plus sparse choke/rate
    structures that replace the former dense (cap, cap) matrices:

      - ledger: `pend_holder[node, piece, slot]` (holder row, -1 empty,
        -2 for holders without a hub row), `pend_t` (request timestamps,
        the deadline basis), `pend_cnt[node, piece]`, `pend_n[node]`
        (pieces in flight, the budget counter), and a compact
        `busy_rows[node, :busy_n]` list of holder rows with a request in
        flight (one in-flight request per holder).  Updated
        *incrementally* on PIECE_REQ / DATA / CANCEL via the
        `ledger_add/del/clear/drop` hooks `PieceExchange._req_*` fire.
      - unchoke graph: dual adjacency lists `uc_rows[h, :uc_n]` (rows
        holder h grants) and `ub_rows[l, :ub_n]` (rows granting leecher
        l) instead of a dense bool matrix — at N=10,000 the matrix alone
        would be 268 MB and its four float32 rate companions 4.3 GB.
      - rates: per-holder sparse edge dicts `edges[h][peer] ->
        [recv_cur, recv_prev, sent_cur, sent_prev]` (float32 scalar
        arithmetic, bit-identical to the old float32 matrix
        accumulation), tumbled and pruned on window expiry.

  * `SwarmHub` — the per-tick engine.  Agents' `PieceExchange` instances
    register with the hub (hub mode); verified pieces, completions and
    request-ledger changes are mirrored into the arrays, and once per
    simulation tick the hub runs the whole swarm's decisions as batched
    array passes using the `swarm_kernels` backends (numpy / jax /
    Pallas):

      1. slot release   — upload slots held by newly-completed leechers
                          are freed (the batched `_promote_full_seeder`);
      2. grants         — event-driven agenda of holders whose free-slot
                          or candidate set changed unchoke the
                          lowest-named interested leechers;
      3. rechoke        — every `rechoke_interval_s` of sim time, all
                          holders re-rank candidates by reciprocal
                          transfer rates in ONE `choke_order` kernel
                          call over per-holder shortlists (rate edges +
                          a rank-ordered zero-rate fill that provably
                          contains the true top slots-1), with the
                          scalar engine's deterministic
                          optimistic-unchoke rotation;
      4. pump           — piece orders from ONE `rarest_orders` kernel
                          call; holder matching for ALL rows in one
                          fused `match_requests` kernel that walks order
                          positions (<= P vectorized steps independent
                          of N), candidates taken straight from the
                          unchoke adjacency and the busy ledger;
      5. endgame        — rows whose every missing piece is in flight
                          (pure ledger-counter selection) duplicate
                          requests to the per-piece `holder_topk`
                          shortlist with vectorized exclusion of
                          already-asked holders.

    Rows with shunned or banned holders fall back per-row to the scalar
    `_match_row` walk, which still reads the `px.pending` dicts — those
    dicts remain maintained and serve as the DIFFERENTIAL REFERENCE the
    ledger is tested entry-for-entry against (tests/test_swarm_batch.py).

The *decisions* are the scalar engine's, bit for bit where the
information sets coincide.  What changes is the *information flow*: the
shared arrays stand in for the HAVE announce fan-out, INTERESTED
declarations, and UNCHOKE/CHOKE notifications, which in hub mode are
applied directly instead of being delivered as O(N^2) wire messages.
Piece traffic itself (PIECE_REQ / PIECE_DATA / PIECE_CANCEL) stays on
the simulated wire — link serialization, faults, chaos hooks and
partitions still apply to every byte moved.  Approximations are
documented in docs/torrent_protocol.md: control-plane updates have zero
latency (and ignore partitions), choke ranking reads two-bucket
tumbling-window rates instead of the scalar deque estimator, and the
fused endgame emits duplicates in ascending piece-id order rather than
pending-dict insertion order (same duplicate SET, different wire order).

Every suppressed control message is counted in `coalesced`, every
array-applied decision in `batch_ops`, and every incremental ledger
update in `ledger_ops`; `tick()` also keeps wall-clock totals split into
host-Python and kernel time for the `swarm_bench --profile` breakdown.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.swarm_kernels import (KEY_INF32, choke_order, cost_orders,
                                      get_backend, holder_topk, island_has,
                                      match_requests, min_island_cost,
                                      rarest_orders)

# holder-key layout under topology (P4P): rank fills the low 31 bits,
# the ALTO cost (<= COST_NONE = 64) sits above it, and the shun bit sits
# above the cost — so shunned holders lose to ANY live holder however
# expensive (the bias-decays-under-starvation property)
_COST_SHIFT = np.int64(2 ** 32)
_SHUN_INF = np.int64(2 ** 45)
# the choke-ranking / fused-matching tie-break must survive the jax
# backend's int32 keys: row ranks are < 2^20 for any simulable swarm,
# costs <= 64, so cost * 2^20 + rank < 2^27.  This orders identically
# to the scalar engine's rank + cost * 2^32 — both are the
# lexicographic (cost, rank) order, since rank < 2^20.
_CHOKE_COST_SHIFT = np.int64(2 ** 20)


class SwarmState:
    """One app's swarm as flat arrays; rows are nodes (stable ids)."""

    # per-row buffers grown together in ONE pass (ISSUE 10 satellite:
    # the former five dense (cap, cap) choke/rate matrices — five
    # separate copies per doubling — are gone entirely; everything left
    # is O(rows) and reallocated exactly once per growth)
    _ROW_FILL = {"opt_peer": -1, "pend_holder": -1, "uc_rows": -1,
                 "ub_rows": -1, "busy_rows": -1}
    _ROW_ARRAYS = ("have", "have_n", "full", "fetching", "alive",
                   "offsets", "_ranks", "starved", "opt_idx", "opt_peer",
                   "island", "pend_holder", "pend_t", "pend_cnt",
                   "pend_n", "pipeline", "eg_cap", "busy_rows", "busy_n",
                   "uc_rows", "uc_n", "ub_rows", "ub_n")

    def __init__(self, app_id: str, manifest, capacity: int = 64,
                 dup_slots: int = 4):
        self.app_id = app_id
        self.manifest = manifest
        self.P = int(manifest.n_pieces)
        cap = max(int(capacity), 4)
        self.names: List[str] = []
        self.row: Dict[str, int] = {}
        self.clients: List[Optional[object]] = []   # row -> PieceExchange
        self.n = 0                                  # rows in use
        self.n_alive = 0
        # --- holdings ----------------------------------------------------- #
        self.have = np.zeros((cap, self.P), dtype=bool)
        self.counts = np.zeros(self.P, dtype=np.int32)
        self.have_n = np.zeros(cap, dtype=np.int32)
        self.full = np.zeros(cap, dtype=bool)
        self.fetching = np.zeros(cap, dtype=bool)
        self.alive = np.zeros(cap, dtype=bool)
        # --- in-flight request ledger (ISSUE 10) --------------------------- #
        # pend_holder[i, p, s]: holder row of in-flight request slot s
        # (-1 empty, -2 = holder has no hub row); pend_t the request
        # timestamp (deadline basis); slots [0:pend_cnt) are compact
        d = max(int(dup_slots), 1)
        self.pend_holder = np.full((cap, self.P, d), -1, dtype=np.int32)
        self.pend_t = np.zeros((cap, self.P, d), dtype=np.float64)
        self.pend_cnt = np.zeros((cap, self.P), dtype=np.int16)
        self.pend_n = np.zeros(cap, dtype=np.int32)       # pieces in flight
        self.pipeline = np.zeros(cap, dtype=np.int32)     # per-row budget cap
        self.eg_cap = np.ones(cap, dtype=np.int16)        # per-row endgame dup
        # busy_rows[i, :busy_n]: holder rows with a request of i's in
        # flight (one request per holder — the matcher's exclusion list)
        self.busy_rows = np.full((cap, 4 * d), -1, dtype=np.int32)
        self.busy_n = np.zeros(cap, dtype=np.int16)
        # --- choke / link state (sparse; ISSUE 10) ------------------------- #
        # dual adjacency: uc_rows[h, :uc_n[h]] = leecher rows holder h
        # grants (bounded ~ upload_slots + 1); ub_rows[l, :ub_n[l]] =
        # holder rows granting leecher l (unbounded; width doubles)
        self.uc_rows = np.full((cap, 8), -1, dtype=np.int32)
        self.uc_n = np.zeros(cap, dtype=np.int32)
        self.ub_rows = np.full((cap, 8), -1, dtype=np.int32)
        self.ub_n = np.zeros(cap, dtype=np.int32)
        # rolling two-bucket transfer-byte windows as sparse edges:
        # edges[h][peer] = [recv_cur, recv_prev, sent_cur, sent_prev]
        # (float32 scalars — bit-identical to the old matrix += path)
        self.edges: List[Dict[int, List[np.float32]]] = []
        self.win_start = 0.0
        # optimistic-unchoke rotation (scalar `_opt_idx`/`opt_unchoked`)
        self.opt_idx = np.zeros(cap, dtype=np.int64)
        self.opt_peer = np.full(cap, -1, dtype=np.int32)
        # --- selection tie-breaks ----------------------------------------- #
        # per-node rarest-first rotation: sum(ord(c) for c in name+app_id)
        self.offsets = np.zeros(cap, dtype=np.int64)
        self._ranks = np.zeros(cap, dtype=np.int64)
        self._ranks_dirty = True
        # --- topology (P4P) ------------------------------------------------ #
        self.island = np.zeros(cap, dtype=np.int32)
        self.lookup_island = None
        # --- scheduling bookkeeping --------------------------------------- #
        self.dirty: Set[int] = set()       # rows to re-pump this tick
        self.starved = np.zeros(cap, dtype=bool)
        self.avail_epoch = 0               # bumped on any availability change
        self.pump_epoch = -1               # avail_epoch at the last pump pass
        self.newly_full: List[int] = []    # rows completed since last tick
        self.last_rechoke = 0.0
        self.rechoke_round = 0
        # event-driven grant agenda: holders whose free-slot or
        # candidate view changed since the last pass; grant_scan forces
        # a full holder sweep (new fetching rows make EVERY free-slot
        # holder relevant again)
        self.grant_agenda: Set[int] = set()
        self.grant_scan = True

    # ------------------------------ rows -------------------------------- #
    def _grow(self, need: int) -> None:
        cap = self.have.shape[0]
        new = cap
        while new < need:
            new *= 2
        for name in self._ROW_ARRAYS:
            a = getattr(self, name)
            fill = self._ROW_FILL.get(name, 0)
            b = np.full((new,) + a.shape[1:], fill, dtype=a.dtype)
            b[:cap] = a
            setattr(self, name, b)

    def _grow_cols(self, name: str, need: int, fill: int = -1) -> None:
        """Double the trailing (width) dimension of one list-shaped
        buffer until it holds `need` entries."""
        a = getattr(self, name)
        w = max(a.shape[-1], 1)
        while w < need:
            w *= 2
        if w == a.shape[-1]:
            return
        b = np.full(a.shape[:-1] + (w,), fill, dtype=a.dtype)
        b[..., : a.shape[-1]] = a
        setattr(self, name, b)

    def _grow_dups(self, need: int) -> None:
        self._grow_cols("pend_holder", need, fill=-1)
        self._grow_cols("pend_t", need, fill=0)

    def ensure_row(self, name: str) -> int:
        """Row id for a node, allocating (and growing) on first sight."""
        i = self.row.get(name)
        if i is not None:
            return i
        i = self.n
        if i >= self.have.shape[0]:
            self._grow(i + 1)
        self.row[name] = i
        self.names.append(name)
        self.clients.append(None)
        self.edges.append({})
        self.n += 1
        self.alive[i] = True
        self.n_alive += 1
        self.offsets[i] = sum(ord(c) for c in name + self.app_id)
        if self.lookup_island is not None:
            self.island[i] = self.lookup_island(name)
        self._ranks_dirty = True
        return i

    @property
    def ranks(self) -> np.ndarray:
        """Column -> lexicographic rank of the node name: what the scalar
        engine's string tie-breaks (`min(..., h)`, `sorted(...)`) sort
        by, as an integer the kernels can compare."""
        if self._ranks_dirty:
            order = sorted(range(self.n), key=self.names.__getitem__)
            for rank, i in enumerate(order):
                self._ranks[i] = rank
            self._ranks_dirty = False
        return self._ranks

    def holder_mask(self) -> np.ndarray:
        """(n,) bool: rows currently holding at least one piece."""
        n = self.n
        return ((self.have_n[:n] > 0) | self.full[:n]) & self.alive[:n]

    # --------------------- unchoke adjacency ---------------------------- #
    def uc_set(self, h: int) -> Set[int]:
        """Rows holder h currently grants (the old matrix row)."""
        return set(self.uc_rows[h, : self.uc_n[h]].tolist())

    def unchoked_matrix(self) -> np.ndarray:
        """Dense (n, n) unchoke matrix rebuilt from the adjacency —
        test/debug helper only; the engine never materializes it."""
        m = np.zeros((self.n, self.n), dtype=bool)
        for h in range(self.n):
            k = int(self.uc_n[h])
            if k:
                m[h, self.uc_rows[h, :k]] = True
        return m

    def _link(self, h: int, l: int) -> bool:
        """Add the h-grants-l edge to both adjacency sides (idempotent).
        Returns False when the edge already existed.  Segments are a
        handful of entries (bounded by upload_slots on the uc side), so
        the membership scans run as plain Python loops — numpy slice +
        any()/nonzero() overhead dominates actual work at these sizes."""
        uc, k = self.uc_rows[h], int(self.uc_n[h])
        for c in range(k):
            if uc[c] == l:
                return False
        if k >= self.uc_rows.shape[1]:
            self._grow_cols("uc_rows", k + 1)
        self.uc_rows[h, k] = l
        self.uc_n[h] = k + 1
        k = int(self.ub_n[l])
        if k >= self.ub_rows.shape[1]:
            self._grow_cols("ub_rows", k + 1)
        self.ub_rows[l, k] = h
        self.ub_n[l] = k + 1
        return True

    def _unlink(self, h: int, l: int) -> bool:
        """Remove the h-grants-l edge (swap-remove both sides)."""
        uc, k = self.uc_rows[h], int(self.uc_n[h])
        for c in range(k):
            if uc[c] == l:
                uc[c] = uc[k - 1]
                uc[k - 1] = -1
                self.uc_n[h] = k - 1
                break
        else:
            return False
        # the ub side is unbounded (popular leechers are granted by many
        # holders): scan small segments in Python, big ones vectorized
        ub, k = self.ub_rows[l], int(self.ub_n[l])
        if k <= 32:
            for c in range(k):
                if ub[c] == h:
                    ub[c] = ub[k - 1]
                    ub[k - 1] = -1
                    self.ub_n[l] = k - 1
                    break
        else:
            hit = np.nonzero(ub[:k] == h)[0]
            if hit.size:
                c = int(hit[0])
                ub[c] = ub[k - 1]
                ub[k - 1] = -1
                self.ub_n[l] = k - 1
        return True

    # ------------------------- request ledger --------------------------- #
    def ledger_add_row(self, i: int, piece_id: int, j: int,
                       t: float) -> None:
        """Record an in-flight request: row i asked holder row j (-2 when
        the holder has no hub row) for `piece_id` at time t."""
        d = int(self.pend_cnt[i, piece_id])
        if d >= self.pend_holder.shape[2]:
            self._grow_dups(d + 1)
        if d == 0:
            self.pend_n[i] += 1
        self.pend_holder[i, piece_id, d] = j
        self.pend_t[i, piece_id, d] = t
        self.pend_cnt[i, piece_id] = d + 1
        if j >= 0:
            b = int(self.busy_n[i])
            if b >= self.busy_rows.shape[1]:
                self._grow_cols("busy_rows", b + 1)
            self.busy_rows[i, b] = j
            self.busy_n[i] = b + 1

    def _busy_del(self, i: int, j: int) -> None:
        b = int(self.busy_n[i])
        seg = self.busy_rows[i, :b]
        hit = np.nonzero(seg == j)[0]
        if hit.size:
            k = int(hit[0])
            self.busy_rows[i, k] = self.busy_rows[i, b - 1]
            self.busy_rows[i, b - 1] = -1
            self.busy_n[i] = b - 1

    def ledger_del_row(self, i: int, piece_id: int, j: int) -> None:
        """Drop one in-flight entry (answered, cancelled or re-routed).
        Tolerates a holder that registered after the request was issued
        as -2 (falls back to removing a -2 slot)."""
        d = int(self.pend_cnt[i, piece_id])
        if d == 0:
            return
        slots = self.pend_holder[i, piece_id, :d]
        hit = np.nonzero(slots == j)[0]
        if hit.size == 0 and j >= 0:
            hit = np.nonzero(slots == -2)[0]
            j = -2
        if hit.size == 0:
            return
        k = int(hit[0])
        self.pend_holder[i, piece_id, k] = self.pend_holder[i, piece_id,
                                                            d - 1]
        self.pend_t[i, piece_id, k] = self.pend_t[i, piece_id, d - 1]
        self.pend_holder[i, piece_id, d - 1] = -1
        self.pend_t[i, piece_id, d - 1] = 0.0
        self.pend_cnt[i, piece_id] = d - 1
        if d == 1:
            self.pend_n[i] -= 1
        if j >= 0:
            self._busy_del(i, j)

    def ledger_clear_row(self, i: int, piece_id: int) -> None:
        """Drop every in-flight entry for one piece (reconcile path)."""
        d = int(self.pend_cnt[i, piece_id])
        if d == 0:
            return
        for s in range(d):
            j = int(self.pend_holder[i, piece_id, s])
            if j >= 0:
                self._busy_del(i, j)
        self.pend_holder[i, piece_id, :d] = -1
        self.pend_t[i, piece_id, :d] = 0.0
        self.pend_cnt[i, piece_id] = 0
        self.pend_n[i] -= 1

    def ledger_drop_row(self, i: int) -> None:
        """Wipe row i's whole ledger (app dropped / row reset)."""
        self.pend_holder[i] = -1
        self.pend_t[i] = 0.0
        self.pend_cnt[i] = 0
        self.pend_n[i] = 0
        self.busy_rows[i] = -1
        self.busy_n[i] = 0


class SwarmHub:
    """Shared array state + batched per-tick decisions for all swarms.

    One hub serves a whole simulation; `PieceExchange` instances attach
    per app via `register_seed` / `register_leech` and mirror their
    verified-piece / request-ledger changes in.  `tick(now)` (driven by
    `SimRuntime.run_batched`) then computes every node's grants, chokes,
    piece requests and endgame duplicates in batched array passes.
    """

    def __init__(self, backend: Optional[str] = None):
        self.backend = get_backend(backend)
        # keyed by (app_id, manifest version): revisions of one app are
        # DISJOINT swarms — a v(k) engine can neither read nor write
        # v(k+1) masks, so mixed-version flash crowds never cross
        self.states: Dict[Tuple[str, int], SwarmState] = {}
        self._cfg = None                   # choke parameters (first client)
        self.batch_ops = 0                 # array-applied decisions
        self.coalesced = 0                 # control messages replaced
        self.ledger_ops = 0                # incremental ledger updates
        self.ticks = 0
        # per-tick wall-clock split for `swarm_bench --profile`
        self.prof_tick_s = 0.0             # total time inside tick()
        self.prof_kernel_s = 0.0           # time inside kernel calls
        # topology (P4P mode): ALTO cost matrix folded into selection
        self.topology = None
        self.cost_matrix: Optional[np.ndarray] = None

    def _kernel(self, fn, *args, **kw):
        """Run one kernel call under the profile clock."""
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        self.prof_kernel_s += time.perf_counter() - t0
        return out

    # ========================= registration ============================= #
    def set_topology(self, topology) -> None:
        """Enable P4P selection: piece orders and holder tie-breaks fold
        in the topology's ALTO cost map.  `None` restores pure rarity
        (the no-topology decisions, bit for bit)."""
        self.topology = topology
        if topology is None:
            self.cost_matrix = None
            for st in self.states.values():
                st.lookup_island = None
                st.island[:] = 0
            return
        self.cost_matrix = np.asarray(topology.cost_map(), dtype=np.int64)
        for st in self.states.values():
            st.lookup_island = topology.island_of
            for i, name in enumerate(st.names):
                st.island[i] = topology.island_of(name)

    @staticmethod
    def _key(app_id: str, manifest) -> Tuple[str, int]:
        return (app_id, int(getattr(manifest, "version", 1) or 1))

    def _state(self, app_id: str, manifest) -> SwarmState:
        key = self._key(app_id, manifest)
        st = self.states.get(key)
        if st is None:
            dup = 4
            if self._cfg is not None:
                dup = max(int(getattr(self._cfg, "endgame_dup", 3)), 1) + 1
            st = self.states[key] = SwarmState(app_id, manifest,
                                               dup_slots=dup)
            if self.topology is not None:
                st.lookup_island = self.topology.island_of
        return st

    def _lookup(self, px, app_id: str) -> Optional[SwarmState]:
        """The state for `px`'s CURRENT revision of `app_id` (None when
        the engine has no manifest or never attached)."""
        m = px.manifests.get(app_id)
        if m is None:
            return None
        return self.states.get(self._key(app_id, m))

    def _attach(self, px, app_id: str, manifest) -> Tuple[SwarmState, int]:
        if self._cfg is None:
            self._cfg = px.cfg
        st = self._state(app_id, manifest)
        i = st.ensure_row(px.node_id)
        if st.clients[i] is not None and st.clients[i] is not px:
            # same name, new incarnation (crash + restart): the fresh
            # engine starts empty — wipe the row before re-use
            self._reset_row(st, i)
        if not st.alive[i]:
            st.alive[i] = True
            st.n_alive += 1
        st.clients[i] = px
        # per-row scheduling parameters the fused passes read in bulk
        st.pipeline[i] = int(px.cfg.piece_pipeline)
        cap = max(int(getattr(px.cfg, "endgame_dup", 3)), 1)
        st.eg_cap[i] = cap
        if cap > st.pend_holder.shape[2]:
            st._grow_dups(cap)
        return st, i

    def register_seed(self, px, app_id: str, manifest) -> None:
        """A node holding the complete image (origin, or a restored
        replica) joins the swarm as a pure seeder."""
        st, i = self._attach(px, app_id, manifest)
        st.full[i] = True
        st.fetching[i] = False
        st.grant_agenda.add(i)

    def register_leech(self, px, app_id: str, manifest) -> None:
        """A node starts fetching the image; pieces it already holds
        (cache rescan) are announced separately via `note_have`."""
        st, i = self._attach(px, app_id, manifest)
        st.fetching[i] = True
        st.full[i] = False
        st.dirty.add(i)
        # a new candidate makes every free-slot holder grantable again
        st.grant_scan = True

    def _reset_row(self, st: SwarmState, i: int) -> None:
        if st.have_n[i]:
            st.counts -= st.have[i].astype(np.int32)
            st.have[i, :] = False
            st.have_n[i] = 0
            st.avail_epoch += 1
        st.full[i] = False
        st.fetching[i] = False
        st.starved[i] = False
        st.opt_peer[i] = -1
        st.newly_full = [j for j in st.newly_full if j != i]
        self._release_slots(st, i)
        # grants row i made: adjacency-only unlink (the old code wiped
        # the matrix row without touching the leechers' engine dicts —
        # PEER_GONE handles those on the wire)
        for l in st.uc_rows[i, : st.uc_n[i]].tolist():
            st._unlink(i, l)
        # rate history: this row's own edges plus every edge TO it (the
        # old col+row matrix wipe); O(n) dict pops, resets are rare
        st.edges[i].clear()
        for d in st.edges[: st.n]:
            d.pop(i, None)
        st.ledger_drop_row(i)
        st.grant_agenda.discard(i)

    def has_row(self, app_id: str, name: str) -> bool:
        return any(aid == app_id and name in st.row
                   for (aid, _), st in self.states.items())

    def retire(self, px, app_id: str, manifest) -> None:
        """`px` upgraded away from `manifest`'s revision: detach its row
        from the superseded (app_id, version) state so stale masks can
        never leak into the new swarm; the state itself is pruned once
        its last live row retires."""
        st = self.states.get(self._key(app_id, manifest))
        if st is None:
            return
        i = st.row.get(px.node_id)
        if i is None:
            return
        if st.alive[i]:
            st.alive[i] = False
            st.n_alive -= 1
            self._reset_row(st, i)
            st.avail_epoch += 1
        st.clients[i] = None
        if st.n_alive <= 0:
            self.states.pop(self._key(app_id, manifest), None)

    # ====================== state change mirrors ======================== #
    def note_have(self, px, app_id: str, piece_id: int) -> None:
        """A piece verified locally at `px` — the array-native stand-in
        for the swarm-wide HAVE announce fan-out."""
        st = self._lookup(px, app_id)
        if st is None:
            return
        i = st.row.get(px.node_id)
        if i is None:
            return
        if not st.have[i, piece_id]:
            if st.have_n[i] == 0 and not st.full[i]:
                # first piece: the row just became a grant-capable holder
                st.grant_agenda.add(i)
            st.have[i, piece_id] = True
            st.have_n[i] += 1
            st.counts[piece_id] += 1
            st.avail_epoch += 1
            self.batch_ops += 1
            # the scalar engine would send one announce per swarm peer
            # plus the tracker copy (and the tracker would relay): count
            # the suppressed deliveries so events/s stays comparable
            self.coalesced += 2 * max(st.n_alive - 1, 0)
        st.dirty.add(i)

    def set_full(self, px, app_id: str) -> None:
        """`px` verified the whole image: seeder from now on."""
        st = self._lookup(px, app_id)
        if st is None:
            return
        i = st.row.get(px.node_id)
        if i is None:
            return
        st.full[i] = True
        st.fetching[i] = False
        st.starved[i] = False
        st.dirty.discard(i)
        st.newly_full.append(i)

    def mark_dirty(self, px, app_id: str) -> None:
        """`px`'s pending set (or choke view) changed: re-pump the row on
        the next tick."""
        st = self._lookup(px, app_id)
        if st is None:
            return
        i = st.row.get(px.node_id)
        if i is not None and st.fetching[i]:
            st.dirty.add(i)

    def node_gone(self, name: str) -> None:
        """A node crashed (PEER_GONE): drop its holdings, slots, ledger
        and rate history from every swarm.  Idempotent; a restart
        re-registers."""
        for st in self.states.values():
            i = st.row.get(name)
            if i is None or not st.alive[i]:
                continue
            st.alive[i] = False
            st.n_alive -= 1
            self._reset_row(st, i)
            st.avail_epoch += 1

    def credit(self, px, app_id: str, peer: str, nbytes: int,
               received: bool) -> None:
        """Mirror of `_credit_from` / `_credit_to`: transfer bytes into
        the rolling per-link windows the batched rechoke ranks on.
        Sparse: one float32 scalar accumulate per edge (bit-identical
        to the former float32 matrix `+=`)."""
        st = self._lookup(px, app_id)
        if st is None:
            return
        i = st.row.get(px.node_id)
        j = st.row.get(peer)
        if i is None or j is None:
            return
        e = st.edges[i].get(j)
        if e is None:
            z = np.float32(0.0)
            e = st.edges[i][j] = [z, z, z, z]
        k = 0 if received else 2
        e[k] = e[k] + np.float32(nbytes)

    # ---------------------- ledger notification hooks ------------------- #
    # Fired by PieceExchange._req_add/_req_del/_req_clear/_req_drop — the
    # single funnel every pending-dict mutation goes through — so the
    # array ledger tracks the dict truth entry for entry.
    def ledger_add(self, px, app_id: str, piece_id: int, peer: str,
                   t: float) -> None:
        st = self._lookup(px, app_id)
        if st is None:
            return
        i = st.row.get(px.node_id)
        if i is None or st.clients[i] is not px:
            return
        j = st.row.get(peer)
        st.ledger_add_row(i, int(piece_id), -2 if j is None else int(j),
                          float(t))
        self.ledger_ops += 1

    def ledger_del(self, px, app_id: str, piece_id: int,
                   peer: str) -> None:
        st = self._lookup(px, app_id)
        if st is None:
            return
        i = st.row.get(px.node_id)
        if i is None or st.clients[i] is not px:
            return
        j = st.row.get(peer)
        st.ledger_del_row(i, int(piece_id), -2 if j is None else int(j))
        self.ledger_ops += 1

    def ledger_clear(self, px, app_id: str, piece_id: int) -> None:
        st = self._lookup(px, app_id)
        if st is None:
            return
        i = st.row.get(px.node_id)
        if i is None or st.clients[i] is not px:
            return
        st.ledger_clear_row(i, int(piece_id))
        self.ledger_ops += 1

    def ledger_drop(self, px, app_id: str) -> None:
        st = self._lookup(px, app_id)
        if st is None:
            return
        i = st.row.get(px.node_id)
        if i is None or st.clients[i] is not px:
            return
        st.ledger_drop_row(i)
        self.ledger_ops += 1

    # ========================= choke mechanics ========================== #
    def _release_slots(self, st: SwarmState, i: int) -> None:
        """Free every upload slot granted TO row i (batched
        `_promote_full_seeder`): seeders stop being unchoke candidates."""
        name = st.names[i]
        k = int(st.ub_n[i])
        if not k:
            return
        holders = st.ub_rows[i, :k].tolist()
        for h in holders:
            st._unlink(h, i)
            st.grant_agenda.add(h)
            px_h = st.clients[h]
            if px_h is not None:
                px_h.unchoked[st.app_id].discard(name)
                px_h.interested[st.app_id].discard(name)
                px_h.queued_reqs[st.app_id].pop(name, None)
        self.batch_ops += len(holders)

    def _apply_grant(self, st: SwarmState, h: int, i: int) -> None:
        """Holder row h unchokes leecher row i: zero-latency stand-in for
        the INTERESTED -> UNCHOKE exchange.  Queued endgame requests are
        served immediately, exactly as the scalar `_unchoke` does."""
        st._link(h, i)
        app_id = st.app_id
        name_i, name_h = st.names[i], st.names[h]
        px_h, px_i = st.clients[h], st.clients[i]
        if px_h is not None:
            px_h.unchoked[app_id].add(name_i)
            queued = px_h.queued_reqs[app_id].pop(name_i, None)
            if queued:
                for piece_id in sorted(queued):
                    px_h._serve(app_id, name_i, piece_id)
        if px_i is not None:
            px_i.unchoked_by[app_id].add(name_h)
        st.dirty.add(i)
        self.batch_ops += 1
        self.coalesced += 2           # INTERESTED + UNCHOKE never sent

    def _apply_choke(self, st: SwarmState, h: int, i: int) -> None:
        """Holder row h chokes leecher row i; the leecher immediately
        re-routes solely-pending requests (the scalar `on_choke` body,
        via the holder-indexed `_route_choked`)."""
        st._unlink(h, i)
        st.grant_agenda.add(h)
        app_id = st.app_id
        name_i, name_h = st.names[i], st.names[h]
        px_h, px_i = st.clients[h], st.clients[i]
        if px_h is not None:
            px_h.unchoked[app_id].discard(name_i)
        if px_i is not None:
            px_i.unchoked_by[app_id].discard(name_h)
            px_i._route_choked(app_id, name_h)
            st.dirty.add(i)
        self.batch_ops += 1
        self.coalesced += 1           # CHOKE never sent

    def grant(self, px, app_id: str, peer: str) -> bool:
        """Holder-initiated unchoke (the scalar `_maybe_unchoke_now` fast
        path reacting to a live PIECE_REQ): applied through the arrays.
        Returns False when either side has no row yet — the caller then
        falls back to the wire message."""
        st = self._lookup(px, app_id)
        if st is None:
            return False
        h = st.row.get(px.node_id)
        i = st.row.get(peer)
        if h is None or i is None:
            return False
        self._apply_grant(st, h, i)
        return True

    def choke(self, px, app_id: str, peer: str) -> bool:
        """Holder-initiated choke, applied through the arrays (the peer
        re-routes immediately instead of waiting for a CHOKE message)."""
        st = self._lookup(px, app_id)
        if st is None:
            return False
        h = st.row.get(px.node_id)
        i = st.row.get(peer)
        if h is None or i is None:
            return False
        self._apply_choke(st, h, i)
        return True

    def _fill_list(self, st: SwarmState, glist: np.ndarray,
                   isl: Optional[int],
                   cache: Dict[Optional[int], np.ndarray]) -> np.ndarray:
        """Fetching rows in grant-preference order for a holder on
        island `isl`: (cost, name-rank) lexicographic under topology,
        pure name order otherwise.  `glist` is already rank-ordered, so
        a stable sort by cost alone preserves the within-cost order."""
        wl = cache.get(isl)
        if wl is None:
            if isl is None or self.cost_matrix is None:
                wl = glist
            else:
                costs = self.cost_matrix[isl, st.island[glist]]
                wl = glist[np.argsort(costs, kind="stable")]
            cache[isl] = wl
        return wl

    def _grants(self, st: SwarmState) -> None:
        """Fill free upload slots with the lowest-named fetching leechers
        (batched `_maybe_unchoke_now`).  Event-driven: only holders on
        the agenda (slot freed, candidate choked away, new holder) are
        visited, plus a full sweep whenever a new fetching row appeared;
        identical grants to the old full want-matrix scan, without the
        O(holders x leechers) rebuild per tick."""
        n = st.n
        cand = st.fetching[:n] & st.alive[:n]
        if not cand.any():
            return
        slots = max(int(self._cfg.upload_slots), 1)
        holders = st.holder_mask()
        free = st.uc_n[:n] < slots
        if st.grant_scan:
            hs = np.nonzero(holders & free)[0]
            st.grant_scan = False
            st.grant_agenda.clear()
        else:
            if not st.grant_agenda:
                return
            ag = np.fromiter(st.grant_agenda, dtype=np.int64,
                             count=len(st.grant_agenda))
            st.grant_agenda.clear()
            ag = ag[ag < n]
            hs = ag[holders[ag] & free[ag]]
            hs.sort()
        if hs.size == 0:
            return
        ranks = st.ranks
        glist = np.nonzero(cand)[0]
        glist = glist[np.argsort(ranks[glist], kind="stable")]
        cache: Dict[Optional[int], np.ndarray] = {}
        for h in hs:
            h = int(h)
            nfree = slots - int(st.uc_n[h])
            if nfree <= 0:
                continue
            isl = int(st.island[h]) if self.cost_matrix is not None else None
            wl = self._fill_list(st, glist, isl, cache)
            members = st.uc_set(h)
            granted = 0
            # the walk grants the first nfree non-member rows; at most
            # len(members) + 1 entries are skipped (self + existing
            # grants), so only a constant-size prefix is ever visited —
            # never materialize the full O(N) fetching list per holder
            for i in wl[: nfree + len(members) + 1].tolist():
                if granted >= nfree:
                    break
                if i == h or i in members:
                    continue
                self._apply_grant(st, h, i)
                granted += 1

    def _rechoke(self, st: SwarmState, now: float) -> None:
        """Batched periodic rechoke: one `choke_order` kernel call ranks
        every holder's candidate SHORTLIST — its nonzero-rate edge
        partners plus the first slots-1 rank-ordered zero-rate
        candidates, which provably contains the true top slots-1 (all
        other candidates tie at rate zero and lose the name tie-break to
        the fill) — by reciprocal rate; the optimistic slot rotates
        through the name-ordered rest via the scalar index arithmetic
        (`rest[self._opt_idx % len(rest)]`)."""
        st.rechoke_round += 1
        every = max(int(getattr(self._cfg, "optimistic_every", 3)), 1)
        rotate = st.rechoke_round % every == 0
        n = st.n
        slots = max(int(self._cfg.upload_slots), 1)
        cand = st.fetching[:n] & st.alive[:n]
        holders = np.nonzero(st.holder_mask())[0]
        ranks = st.ranks
        # fetching rows in name order: the scalar `rest = sorted(cands)`
        glist = np.nonzero(cand)[0]
        glist = glist[np.argsort(ranks[glist], kind="stable")]
        pos = np.full(n, -1, dtype=np.int64)
        pos[glist] = np.arange(glist.size)
        n_cand = int(cand.sum())
        ranked = [int(h) for h in holders
                  if n_cand - int(cand[h]) > slots]
        order = None
        shortlists: List[List[int]] = []
        if ranked:
            cache: Dict[Optional[int], np.ndarray] = {}
            for h in ranked:
                nz = [j for j in st.edges[h]
                      if j < n and cand[j] and j != h]
                members = set(nz)
                isl = int(st.island[h]) if self.cost_matrix is not None \
                    else None
                wl = self._fill_list(st, glist, isl, cache)
                fill: List[int] = []
                needed = slots - 1
                for x in wl.tolist():
                    if len(fill) >= needed:
                        break
                    if x == h or x in members:
                        continue
                    fill.append(x)
                shortlists.append(nz + fill)
            C = max(max((len(s) for s in shortlists), default=0), 1)
            H = len(ranked)
            recv_p = np.zeros((H, C), dtype=np.float32)
            sent_p = np.zeros((H, C), dtype=np.float32)
            cm = np.zeros((H, C), dtype=bool)
            rk = np.zeros((H, C), dtype=np.int64)
            for k, (h, sl) in enumerate(zip(ranked, shortlists)):
                if not sl:
                    continue
                cm[k, : len(sl)] = True
                d = st.edges[h]
                for m, j in enumerate(sl):
                    e = d.get(j)
                    if e is not None:
                        recv_p[k, m] = e[0] + e[1]
                        sent_p[k, m] = e[2] + e[3]
                slr = np.asarray(sl, dtype=np.int64)
                key = ranks[slr]
                if self.cost_matrix is not None:
                    # P4P tie-break: reciprocal rates stay primary, but
                    # rate ties resolve cheapest-island-first.  Small
                    # shift: the jax backend keys are int32.
                    key = self.cost_matrix[st.island[h],
                                           st.island[slr]] \
                        * _CHOKE_COST_SHIFT + key
                rk[k, : len(sl)] = key
            order = self._kernel(choke_order, recv_p, sent_p, cm, rk,
                                 backend=self.backend)
        krow = {h: k for k, h in enumerate(ranked)}
        for h in holders:
            h = int(h)
            k = krow.get(h)
            if k is None:
                # few candidates: everyone fetching gets a slot
                new = {int(i) for i in glist if i != h}
                st.opt_peer[h] = -1
            else:
                sl = shortlists[k]
                top = [sl[int(c)] for c in order[k, : slots - 1]]
                new = set(top)
                # optimistic slot from the name-ordered rest
                rest_len = n_cand - int(cand[h]) - (slots - 1)
                opt = int(st.opt_peer[h])
                in_rest = (opt >= 0 and opt != h and opt < n
                           and cand[opt] and opt not in new)
                if rotate or not in_rest:
                    st.opt_idx[h] += 1
                    t = int(st.opt_idx[h]) % rest_len
                    # rest == glist minus {h} and the top rows: selecting
                    # rest[t] = the t-th surviving element of glist
                    excl = sorted(int(pos[x]) for x in top + [h]
                                  if 0 <= x < n and pos[x] >= 0)
                    for e in excl:
                        if e <= t:
                            t += 1
                    opt = int(glist[t])
                st.opt_peer[h] = opt
                new.add(opt)
            old = st.uc_set(h)
            if old != new:
                for i in sorted(old - new, key=lambda x: ranks[x]):
                    self._apply_choke(st, h, int(i))
                for i in sorted(new - old, key=lambda x: ranks[x]):
                    self._apply_grant(st, h, int(i))
        # tumble the rate windows so ranking tracks *current* throughput
        window = float(getattr(self._cfg, "rate_window_s", 20.0))
        if now - st.win_start >= window:
            for d in st.edges[:n]:
                dead = []
                for j, e in d.items():
                    e[1] = e[0]
                    e[3] = e[2]
                    z = np.float32(0.0)
                    e[0] = z
                    e[2] = z
                    if e[1] == 0.0 and e[3] == 0.0:
                        dead.append(j)
                for j in dead:
                    del d[j]
            st.win_start = now

    # ========================== piece selection ========================= #
    def _piece_cost(self, st: SwarmState, rows: np.ndarray) -> np.ndarray:
        """(len(rows), P) cheapest-holder cost plane rows for the given
        leecher rows: `island_has` (backend kernel) reduces the alive
        have-matrix to island-level availability, `min_island_cost`
        derives the per-source-island cost plane, and each leecher reads
        its own island's row."""
        n = st.n
        k = self.topology.n_islands
        have = (st.have[:n, :] | st.full[:n, None]) & st.alive[:n, None]
        member = np.zeros((k, n), dtype=bool)
        member[st.island[:n], np.arange(n)] = True
        avail = self._kernel(island_has, have, member,
                             backend=self.backend)
        plane = min_island_cost(avail, self.cost_matrix)       # (K, P)
        return plane[st.island[rows]]

    def _holder_costs(self, st: SwarmState, i: int) -> Optional[np.ndarray]:
        """(n,) ALTO cost from leecher row i's island to every row's
        island, or None when no topology is set."""
        if self.cost_matrix is None:
            return None
        return self.cost_matrix[st.island[i], st.island[: st.n]]

    def _usable_rows(self, st: SwarmState, i: int) -> np.ndarray:
        """Holder rows leecher i may address a request to right now:
        unchoked-by (unless choking is globally off), holding something,
        alive, not this node, not banned, and with no request of ours
        already in flight (one in-flight request per holder).  Scalar
        slow path / test bridge; the fused pass reads the same facts
        from the adjacency + busy ledger in bulk."""
        n = st.n
        px = st.clients[i]
        if getattr(self._cfg, "choke", True):
            ux = np.zeros(n, dtype=bool)
            k = int(st.ub_n[i])
            if k:
                hb = st.ub_rows[i, :k]
                ux[hb[hb < n]] = True
        else:
            ux = np.ones(n, dtype=bool)
        ux &= st.holder_mask()
        ux[i] = False
        app_id = st.app_id
        busy = {peer for asked in px.pending.get(app_id, {}).values()
                for peer in asked}
        bad = px.bad_peers.get(app_id)
        if bad:
            busy = busy | bad
        for name in busy:
            j = st.row.get(name)
            if j is not None:
                ux[j] = False
        return ux

    def _match_row(self, st: SwarmState, i: int, order: np.ndarray,
                   now: float) -> Tuple[List[Tuple[int, int]], bool]:
        """Walk one leecher's rarest-first order and pick a holder per
        piece with the scalar tie-breaks (shunned holders last, then
        lowest name).  Pure: returns ([(piece, holder_row)], starved)
        without touching any state.  Slow path for rows with shun/ban
        state (and the decide_requests test bridge); the fused
        `match_requests` kernel reproduces this walk for all clean rows
        at once."""
        px = st.clients[i]
        app_id = st.app_id
        pending = px.pending.get(app_id, {})
        budget = int(px.cfg.piece_pipeline) - len(pending)
        left = st.P - int(st.have_n[i]) - len(pending)
        out: List[Tuple[int, int]] = []
        if budget <= 0 or left <= 0:
            return out, False
        ux = self._usable_rows(st, i)
        idx = np.nonzero(ux)[0]
        if idx.size == 0:
            return out, True
        stalled = px.stalled_holders.get(app_id, {})
        ranks = st.ranks
        costs = self._holder_costs(st, i)
        taken = np.zeros(idx.size, dtype=bool)
        n_missing = st.P - int(st.have_n[i]) - len(pending)
        for k in range(min(n_missing, order.shape[0])):
            if budget <= 0:
                break
            if taken.all():
                break
            p = int(order[k])
            ok = ~taken & (st.have[idx, p] | st.full[idx])
            cand = idx[ok]
            if cand.size == 0:
                continue
            key = ranks[cand].astype(np.int64)
            if costs is not None:
                # P4P holder tie-break: cheapest island first, then name;
                # the shun bit still dominates the cost (bias decays when
                # same-island holders starve)
                key = key + costs[cand] * _COST_SHIFT
            shun = stalled.get(p)
            if shun:
                key = key + np.array(
                    [st.names[int(j)] in shun for j in cand],
                    dtype=np.int64) * _SHUN_INF
            j = int(cand[int(np.argmin(key))])
            out.append((p, j))
            taken[np.searchsorted(idx, j)] = True
            budget -= 1
        starved = budget > 0 and len(out) < n_missing
        return out, starved

    def _issue(self, st: SwarmState, i: int, piece_id: int, j: int,
               now: float, endgame: bool = False) -> None:
        """Commit one request decision: engine dicts + ledger (via the
        `_req_add` funnel) + the real PIECE_REQ wire message (link
        model, faults and chaos still apply to it)."""
        px = st.clients[i]
        name_j = st.names[j]
        px._req_add(st.app_id, piece_id, name_j, now)
        px._send_req(st.app_id, piece_id, name_j, endgame=endgame)
        self.batch_ops += 1

    def _pump(self, st: SwarmState, now: float) -> None:
        """Fused pump: budgets and missing masks come straight off the
        ledger counters (no dict walks), piece orders from ONE
        `rarest_orders` kernel call, and holder matching for every clean
        row from `match_requests` — candidates gathered from the
        unchoke adjacency bucketed by degree so total work is O(edges),
        busy holders excluded via the compact per-row busy list.  Rows
        with shun/ban state (or choke globally off) fall back to the
        scalar `_match_row`."""
        n = st.n
        avail_moved = st.avail_epoch != st.pump_epoch
        sel = np.zeros(n, dtype=bool)
        for i in st.dirty:
            if i < n:
                sel[i] = True
        if avail_moved:
            sel |= st.starved[:n]
        sel &= st.fetching[:n] & st.alive[:n]
        st.dirty.clear()
        st.pump_epoch = st.avail_epoch
        rows = np.nonzero(sel)[0]
        if rows.size == 0:
            return
        app_id = st.app_id
        budgets = (st.pipeline[rows] - st.pend_n[rows]).astype(np.int64)
        n_missing = (st.P - st.have_n[rows] - st.pend_n[rows]) \
            .astype(np.int64)
        live = (budgets > 0) & (n_missing > 0)
        st.starved[rows[~live]] = False
        rows = rows[live]
        budgets = budgets[live]
        n_missing = n_missing[live]
        if rows.size == 0:
            return
        missing = ~st.have[rows, :] & ~(st.pend_cnt[rows, :] > 0)
        if self.cost_matrix is not None:
            pc = self._piece_cost(st, rows)
            orders = self._kernel(cost_orders, missing, st.counts,
                                  st.offsets[rows], pc, st.P,
                                  backend=self.backend)
        else:
            orders = self._kernel(rarest_orders, missing, st.counts,
                                  st.offsets[rows], st.P,
                                  backend=self.backend)
        # slow-path detection: shunned or banned holders need the
        # name-set exclusion logic only the dict walk implements
        slow = np.zeros(rows.size, dtype=bool)
        if not getattr(self._cfg, "choke", True):
            slow[:] = True
        else:
            for k, i in enumerate(rows):
                px = st.clients[int(i)]
                if px is None or px.stalled_holders.get(app_id) \
                        or px.bad_peers.get(app_id):
                    slow[k] = True
        decisions: List[Optional[List[Tuple[int, int]]]] = \
            [None] * rows.size
        starved_out = np.zeros(rows.size, dtype=bool)
        fast = np.nonzero(~slow)[0]
        if fast.size:
            self._match_fast(st, rows, fast, orders, budgets, n_missing,
                             decisions, starved_out)
        for k in np.nonzero(slow)[0]:
            decisions[k], starved_out[k] = self._match_row(
                st, int(rows[k]), orders[k], now)
        # commit in ascending row order (the old per-row loop's wire
        # order); decisions are row-independent so batch-then-issue is
        # exact
        for k in range(rows.size):
            i = int(rows[k])
            for piece_id, j in decisions[k] or ():
                self._issue(st, i, piece_id, j, now)
            st.starved[i] = bool(starved_out[k])

    # candidate-width buckets: padding waste is bounded (~4x) so total
    # matching work stays O(unchoke edges), not O(rows x max degree)
    _BUCKETS = (8, 32, 128, 512, 2048, 8192, 1 << 30)

    def _match_fast(self, st: SwarmState, rows: np.ndarray,
                    fast: np.ndarray, orders: np.ndarray,
                    budgets: np.ndarray, n_missing: np.ndarray,
                    decisions: List[Optional[List[Tuple[int, int]]]],
                    starved_out: np.ndarray) -> None:
        """Fused holder matching for the clean rows: one `match_requests`
        kernel call per degree bucket."""
        n = st.n
        deg = st.ub_n[rows[fast]]
        ranks = st.ranks
        lo = 0
        for hi in self._BUCKETS:
            inb = (deg > lo if lo else deg >= 0) & (deg <= hi)
            lo = hi
            if not inb.any():
                continue
            idx = fast[np.nonzero(inb)[0]]
            sub = rows[idx]
            C = int(st.ub_n[sub].max())
            if C == 0:
                # no unchoked-by holders at all: no requests, starved
                # (scalar `_usable_rows` empty -> ([], True))
                for k in idx.tolist():
                    decisions[k] = []
                    starved_out[k] = True
                continue
            cnts = st.ub_n[sub]
            cand = st.ub_rows[sub, :C]
            valid = np.arange(C)[None, :] < cnts[:, None]
            safe = np.where(valid, cand, 0)
            ok = valid & ((st.have_n[safe] > 0) | st.full[safe]) \
                & st.alive[safe] & (cand != sub[:, None])
            B = int(st.busy_n[sub].max())
            if B:
                bz = st.busy_rows[sub, :B]
                bval = np.arange(B)[None, :] < st.busy_n[sub][:, None]
                bz = np.where(bval, bz, -1)
                ok &= ~(cand[:, :, None] == bz[:, None, :]).any(axis=2)
            key = ranks[safe]
            if self.cost_matrix is not None:
                key = self.cost_matrix[st.island[sub][:, None],
                                       st.island[safe]] \
                    * _CHOKE_COST_SHIFT + key
            picks = self._kernel(
                match_requests, orders[idx], n_missing[idx],
                budgets[idx], cand.astype(np.int32), ok,
                key.astype(np.int32), st.have[:n], st.full[:n],
                backend=self.backend)
            for kk, k in enumerate(idx.tolist()):
                pk = picks[kk]
                got = np.nonzero(pk >= 0)[0]
                decisions[k] = [(int(orders[k, g]), int(pk[g]))
                                for g in got.tolist()]
                starved_out[k] = (got.size < n_missing[k]
                                  and got.size < budgets[k])

    def _endgame(self, st: SwarmState, now: float) -> None:
        """Fused endgame: row selection is pure ledger arithmetic
        (`P - have_n == pend_n`), per-piece candidate shortlists come
        from ONE `holder_topk` kernel call (K = 2*cap+1 provably covers
        every row's need), and the already-asked exclusion is a
        vectorized compare against the ledger slots.  Scalar fallback
        per row under shun/ban state.  Duplicates go out in ascending
        piece-id order (the dict path used insertion order — same
        duplicate set, different wire order; documented approximation).
        """
        if not getattr(self._cfg, "endgame", True):
            return
        n = st.n
        app_id = st.app_id
        miss = st.P - st.have_n[:n]
        eg = st.fetching[:n] & st.alive[:n] & (st.have_n[:n] > 0) \
            & (st.pend_n[:n] > 0) & (miss == st.pend_n[:n])
        rows = np.nonzero(eg)[0]
        if rows.size == 0:
            return
        fastrows: List[int] = []
        out: Dict[int, List[Tuple[int, int]]] = {}
        for i in rows.tolist():
            px = st.clients[i]
            if px is None:
                continue
            if px.stalled_holders.get(app_id) or px.bad_peers.get(app_id):
                out[i] = self._endgame_row(st, i)
            else:
                fastrows.append(i)
        if fastrows:
            out.update(self._endgame_fast(st, np.asarray(fastrows,
                                                         dtype=np.int64)))
        for i in sorted(out):
            for piece_id, j in out[i]:
                self._issue(st, i, piece_id, j, now, endgame=True)

    def _endgame_row(self, st: SwarmState,
                     i: int) -> List[Tuple[int, int]]:
        """Scalar per-row endgame decisions (dict-reading slow path for
        rows with shun/ban state); pure."""
        px = st.clients[i]
        app_id = st.app_id
        pending = px.pending.get(app_id)
        if not pending:
            return []
        n = st.n
        cap = max(int(getattr(px.cfg, "endgame_dup", 3)), 1)
        stalled = px.stalled_holders.get(app_id, {})
        bad = px.bad_peers.get(app_id, ())
        costs = self._holder_costs(st, i)
        ranks = st.ranks
        out: List[Tuple[int, int]] = []
        for piece_id, asked in list(pending.items()):
            room = cap - len(asked)
            if room <= 0:
                continue
            shun = stalled.get(piece_id, ())
            hm = (st.have[:n, piece_id] | st.full[:n]) & st.alive[:n]
            hm[i] = False
            cand = np.nonzero(hm)[0]
            hkey = ranks[cand]
            if costs is not None:
                # P4P endgame: duplicate to same-island holders first
                hkey = hkey + costs[cand] * _COST_SHIFT
            for j in cand[np.argsort(hkey, kind="stable")]:
                name = st.names[int(j)]
                if name in asked or name in shun or name in bad:
                    continue
                out.append((piece_id, int(j)))
                room -= 1
                if room <= 0:
                    break
        return out

    def _endgame_fast(self, st: SwarmState, rows: np.ndarray) \
            -> Dict[int, List[Tuple[int, int]]]:
        """Vectorized endgame duplicate selection for clean rows."""
        n = st.n
        D = st.pend_holder.shape[2]
        cnt = st.pend_cnt[rows].astype(np.int32)               # (R, P)
        caps = st.eg_cap[rows].astype(np.int32)[:, None]
        room = np.where(cnt > 0, caps - cnt, 0)
        np.clip(room, 0, None, out=room)
        out: Dict[int, List[Tuple[int, int]]] = {}
        if not (room > 0).any():
            return out
        K = int(2 * st.eg_cap[rows].max() + 1)
        hv = (st.have[:n, :] | st.full[:n, None]) & st.alive[:n, None]
        ranks = st.ranks[:n].astype(np.int64)
        islands = [None] if self.cost_matrix is None else \
            np.unique(st.island[rows]).tolist()
        for isl in islands:
            if isl is None:
                rsel = np.arange(rows.size)
                base = ranks
            else:
                rsel = np.nonzero(st.island[rows] == isl)[0]
                base = self.cost_matrix[isl, st.island[:n]] \
                    * _CHOKE_COST_SHIFT + ranks
            key = np.where(hv, base[:, None], np.int64(KEY_INF32)) \
                .astype(np.int32)
            top = self._kernel(holder_topk, key, K,
                               backend=self.backend)           # (K, P)
            rr = rows[rsel]
            cand = top.T[None, :, :]                           # (1, P, K)
            asked = st.pend_holder[rr][:, :, :D]               # (R', P, D)
            excl = (cand[:, :, :, None] == asked[:, :, None, :]) \
                .any(axis=3)
            valid = (cand >= 0) & ~excl \
                & (cand != rr[:, None, None]) \
                & (room[rsel][:, :, None] > 0)
            csum = np.cumsum(valid, axis=2)
            chosen = valid & (csum <= room[rsel][:, :, None])
            ri, pi, ki = np.nonzero(chosen)
            for a, p, c in zip(ri.tolist(), pi.tolist(), ki.tolist()):
                i = int(rr[a])
                out.setdefault(i, []).append((int(p), int(top[c, p])))
        return out

    # ============================== tick ================================ #
    def tick(self, now: float) -> None:
        """One batched decision pass over every registered swarm."""
        t0 = time.perf_counter()
        self.ticks += 1
        for st in self.states.values():
            if st.n == 0:
                continue
            for i in st.newly_full:
                self._release_slots(st, i)
            st.newly_full.clear()
            if self._cfg is not None and getattr(self._cfg, "choke", True):
                self._grants(st)
                interval = float(
                    getattr(self._cfg, "rechoke_interval_s", 10.0))
                if now - st.last_rechoke >= interval:
                    st.last_rechoke = now
                    self._rechoke(st, now)
            self._pump(st, now)
            self._endgame(st, now)
        self.prof_tick_s += time.perf_counter() - t0

    # ====================== queries / test bridges ====================== #
    def _find(self, app_id: str, node_id: str) -> Optional[SwarmState]:
        """Newest-revision state of `app_id` holding a row for `node_id`
        (test-bridge lookup where no engine handle is available)."""
        best = None
        for (aid, ver), st in self.states.items():
            if aid != app_id or node_id not in st.row:
                continue
            if best is None or ver > best[0]:
                best = (ver, st)
        return None if best is None else best[1]

    def stats(self) -> Dict[str, float]:
        return {"ticks": self.ticks, "batch_ops": self.batch_ops,
                "coalesced_events": self.coalesced,
                "ledger_ops": self.ledger_ops,
                "tick_wall_s": self.prof_tick_s,
                "kernel_wall_s": self.prof_kernel_s}

    def decide_requests(self, app_id: str, node_id: str,
                        now: float) -> List[Tuple[int, str]]:
        """Pure query: the (piece, holder) requests the batched engine
        would issue for one node right now — the differential tests'
        bridge to the scalar `pump`."""
        st = self._find(app_id, node_id)
        i = st.row[node_id]
        px = st.clients[i]
        missing = ~st.have[i, :]       # invert copies; safe to edit
        for p in px.pending.get(app_id, {}):
            missing[p] = False
        if self.cost_matrix is not None:
            pc = self._piece_cost(st, np.array([i], dtype=np.int64))
            order = cost_orders(missing[None, :], st.counts,
                                st.offsets[i:i + 1], pc, st.P,
                                backend=self.backend)[0]
        else:
            order = rarest_orders(missing[None, :], st.counts,
                                  st.offsets[i:i + 1], st.P,
                                  backend=self.backend)[0]
        decisions, _ = self._match_row(st, i, order, now)
        return [(p, st.names[j]) for p, j in decisions]

    def decide_endgame(self, app_id: str, node_id: str,
                       now: float) -> List[Tuple[int, str]]:
        """Pure query: the endgame duplicates the batched engine would
        issue for one node (scalar `_endgame` bridge)."""
        st = self._find(app_id, node_id)
        i = st.row[node_id]
        px = st.clients[i]
        pending = px.pending.get(app_id, {})
        if not pending or not int(st.have_n[i]):
            return []
        if st.P - int(st.have_n[i]) != len(pending):
            return []
        n = st.n
        cap = max(int(getattr(px.cfg, "endgame_dup", 3)), 1)
        stalled = px.stalled_holders.get(app_id, {})
        bad = px.bad_peers.get(app_id, ())
        ranks = st.ranks
        costs = self._holder_costs(st, i)
        out: List[Tuple[int, str]] = []
        for piece_id, asked in pending.items():
            room = cap - len(asked)
            if room <= 0:
                continue
            shun = stalled.get(piece_id, ())
            hm = (st.have[:n, piece_id] | st.full[:n]) & st.alive[:n]
            hm[i] = False
            cand = np.nonzero(hm)[0]
            hkey = ranks[cand]
            if costs is not None:
                hkey = hkey + costs[cand] * _COST_SHIFT
            for j in cand[np.argsort(hkey, kind="stable")]:
                name = st.names[int(j)]
                if name in asked or name in shun or name in bad:
                    continue
                out.append((piece_id, name))
                room -= 1
                if room <= 0:
                    break
        return out

    @classmethod
    def mirror_scalar(cls, px, app_id: str,
                      backend: Optional[str] = None) -> "SwarmHub":
        """Build a hub whose arrays mirror a *scalar-mode* engine's view
        of one swarm (peer masks, full seeders, choke view) — used by
        the differential tests to compare decisions on identical
        information sets."""
        hub = cls(backend=backend)
        manifest = px.manifests[app_id]
        hub.register_leech(px, app_id, manifest)
        st = hub.states[hub._key(app_id, manifest)]
        me = st.row[px.node_id]
        inv = px.inventories.get(app_id)
        if inv is not None:
            for p in inv.have:
                hub.note_have(px, app_id, p)
        full_mask = manifest.full_mask
        for peer, mask in px.peer_masks.get(app_id, {}).items():
            if peer == px.node_id:
                continue
            j = st.ensure_row(peer)
            mask &= full_mask
            while mask:
                low = mask & -mask
                p = low.bit_length() - 1
                mask ^= low
                st.have[j, p] = True
                st.have_n[j] += 1
                st.counts[p] += 1
        for peer in px.full_seeders.get(app_id, ()):
            st.full[st.ensure_row(peer)] = True
        for holder in px.unchoked_by.get(app_id, ()):
            st._link(st.ensure_row(holder), me)
        return hub
