"""Array-native swarm state + the batched per-tick decision engine.

`PieceExchange` (core/piece_exchange.py) makes every scheduling decision
one Python call at a time — a pump per HAVE announce, a choke pass per
holder, one heap event per protocol message.  That per-message dispatch
caps practical swarm sizes near N=200 (ROADMAP: "N=2000+ flash crowds
via batched, array-native simulation").  This module is the batched
counterpart:

  * `SwarmState` — one app's swarm as flat numpy arrays over *rows*
    (nodes): peer x piece `have` bitmask matrix, per-piece availability
    `counts`, full-seeder / fetching flags, the holder x leecher
    `unchoked` slot matrix, and per-link rolling transfer-byte matrices
    for the reciprocity ranking.  Rows are stable for a node's lifetime;
    capacity doubles on demand.

  * `SwarmHub` — the per-tick engine.  Agents' `PieceExchange` instances
    register with the hub (hub mode); verified pieces, completions and
    pending-set changes are mirrored into the arrays, and once per
    simulation tick the hub runs the whole swarm's decisions as batched
    array passes using the `swarm_kernels` backends (numpy / jax /
    Pallas):

      1. slot release   — upload slots held by newly-completed leechers
                          are freed (the batched `_promote_full_seeder`);
      2. grants         — holders with free slots unchoke the
                          lowest-named interested leechers (the batched
                          `_maybe_unchoke_now` fast path);
      3. rechoke        — every `rechoke_interval_s` of sim time, all
                          holders re-rank candidates by reciprocal
                          transfer rates in ONE `choke_order` kernel
                          call, with the scalar engine's deterministic
                          optimistic-unchoke rotation;
      4. pump           — all dirty/starved leechers' rarest-first
                          orders come from ONE `rarest_orders` kernel
                          call; request matching walks each order with
                          the scalar tie-breaks (shunned-last,
                          lowest name; one in-flight request per
                          holder);
      5. endgame        — leechers whose every missing piece is in
                          flight duplicate requests to alternate
                          holders, capped at `endgame_dup`, in the
                          scalar holder order.

The *decisions* are the scalar engine's, bit for bit where the
information sets coincide (the differential tests in
tests/test_swarm_batch.py mirror a scalar engine's view into a
`SwarmState` and assert request-for-request identical output).  What
changes is the *information flow*: the shared arrays stand in for the
HAVE announce fan-out, INTERESTED declarations, and UNCHOKE/CHOKE
notifications, which in hub mode are applied directly instead of being
delivered as O(N^2) wire messages.  Piece traffic itself (PIECE_REQ /
PIECE_DATA / PIECE_CANCEL) stays on the simulated wire — link
serialization, faults, chaos hooks and partitions still apply to every
byte moved.  Two measured approximations follow, both documented in
docs/torrent_protocol.md: control-plane updates have zero latency (and
ignore partitions), and choke ranking reads two-bucket tumbling-window
rates instead of the scalar deque estimator.

Every suppressed control message is counted in `coalesced` and every
array-applied decision in `batch_ops`, so benchmark events/s can be
reported both ways (logical vs heap events; see benchmarks/swarm_bench).
"""
from __future__ import annotations

import collections
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.swarm_kernels import (choke_order, cost_orders, get_backend,
                                      island_has, min_island_cost,
                                      rarest_orders)

# holder-key layout under topology (P4P): rank fills the low 31 bits,
# the ALTO cost (<= COST_NONE = 64) sits above it, and the shun bit sits
# above the cost — so shunned holders lose to ANY live holder however
# expensive (the bias-decays-under-starvation property)
_COST_SHIFT = np.int64(2 ** 32)
_SHUN_INF = np.int64(2 ** 45)
# the choke-ranking tie-break must survive the jax backend's int32 keys:
# row ranks are < 2^20 for any simulable swarm, costs <= 15, so
# cost * 2^20 + rank < 2^24
_CHOKE_COST_SHIFT = np.int64(2 ** 20)


class SwarmState:
    """One app's swarm as flat arrays; rows are nodes (stable ids)."""

    def __init__(self, app_id: str, manifest, capacity: int = 64):
        self.app_id = app_id
        self.manifest = manifest
        self.P = int(manifest.n_pieces)
        cap = max(int(capacity), 4)
        self.names: List[str] = []
        self.row: Dict[str, int] = {}
        self.clients: List[Optional[object]] = []   # row -> PieceExchange
        self.n = 0                                  # rows in use
        self.n_alive = 0
        # --- holdings ----------------------------------------------------- #
        self.have = np.zeros((cap, self.P), dtype=bool)
        self.counts = np.zeros(self.P, dtype=np.int32)
        self.have_n = np.zeros(cap, dtype=np.int32)
        self.full = np.zeros(cap, dtype=bool)
        self.fetching = np.zeros(cap, dtype=bool)
        self.alive = np.zeros(cap, dtype=bool)
        # --- choke / link state ------------------------------------------- #
        # unchoked[h, l]: holder h currently grants leecher l a slot
        self.unchoked = np.zeros((cap, cap), dtype=bool)
        # rolling two-bucket transfer-byte windows, [holder, leecher]:
        # recv = bytes the holder received FROM the peer (rate_from),
        # sent = bytes the holder served TO the peer (rate_to)
        self.recv = np.zeros((cap, cap), dtype=np.float32)
        self.sent = np.zeros((cap, cap), dtype=np.float32)
        self.recv_prev = np.zeros((cap, cap), dtype=np.float32)
        self.sent_prev = np.zeros((cap, cap), dtype=np.float32)
        self.win_start = 0.0
        # optimistic-unchoke rotation (scalar `_opt_idx`/`opt_unchoked`)
        self.opt_idx = np.zeros(cap, dtype=np.int64)
        self.opt_peer = np.full(cap, -1, dtype=np.int32)
        # --- selection tie-breaks ----------------------------------------- #
        # per-node rarest-first rotation: sum(ord(c) for c in name+app_id)
        self.offsets = np.zeros(cap, dtype=np.int64)
        self._ranks = np.zeros(cap, dtype=np.int64)
        self._ranks_dirty = True
        # --- topology (P4P) ------------------------------------------------ #
        # per-row island index; populated via `lookup_island` (set by
        # SwarmHub.set_topology) as rows are allocated
        self.island = np.zeros(cap, dtype=np.int32)
        self.lookup_island = None
        # --- scheduling bookkeeping --------------------------------------- #
        self.dirty: Set[int] = set()       # rows to re-pump this tick
        self.starved = np.zeros(cap, dtype=bool)
        self.avail_epoch = 0               # bumped on any availability change
        self.pump_epoch = -1               # avail_epoch at the last pump pass
        self.newly_full: List[int] = []    # rows completed since last tick
        self.last_rechoke = 0.0
        self.rechoke_round = 0

    # ------------------------------ rows -------------------------------- #
    def _grow(self, need: int) -> None:
        cap = self.have.shape[0]
        new = cap
        while new < need:
            new *= 2
        grown: Dict[str, np.ndarray] = {}
        for name in ("have",):
            a = getattr(self, name)
            b = np.zeros((new, self.P), dtype=a.dtype)
            b[:cap] = a
            grown[name] = b
        for name in ("have_n", "full", "fetching", "alive", "offsets",
                     "_ranks", "starved", "opt_idx", "opt_peer", "island"):
            a = getattr(self, name)
            b = np.zeros(new, dtype=a.dtype)
            if name == "opt_peer":
                b[:] = -1
            b[:cap] = a
            grown[name] = b
        for name in ("unchoked", "recv", "sent", "recv_prev", "sent_prev"):
            a = getattr(self, name)
            b = np.zeros((new, new), dtype=a.dtype)
            b[:cap, :cap] = a
            grown[name] = b
        for name, b in grown.items():
            setattr(self, name, b)

    def ensure_row(self, name: str) -> int:
        """Row id for a node, allocating (and growing) on first sight."""
        i = self.row.get(name)
        if i is not None:
            return i
        i = self.n
        if i >= self.have.shape[0]:
            self._grow(i + 1)
        self.row[name] = i
        self.names.append(name)
        self.clients.append(None)
        self.n += 1
        self.alive[i] = True
        self.n_alive += 1
        self.offsets[i] = sum(ord(c) for c in name + self.app_id)
        if self.lookup_island is not None:
            self.island[i] = self.lookup_island(name)
        self._ranks_dirty = True
        return i

    @property
    def ranks(self) -> np.ndarray:
        """Column -> lexicographic rank of the node name: what the scalar
        engine's string tie-breaks (`min(..., h)`, `sorted(...)`) sort
        by, as an integer the kernels can compare."""
        if self._ranks_dirty:
            order = sorted(range(self.n), key=self.names.__getitem__)
            for rank, i in enumerate(order):
                self._ranks[i] = rank
            self._ranks_dirty = False
        return self._ranks

    def holder_mask(self) -> np.ndarray:
        """(n,) bool: rows currently holding at least one piece."""
        n = self.n
        return ((self.have_n[:n] > 0) | self.full[:n]) & self.alive[:n]


class SwarmHub:
    """Shared array state + batched per-tick decisions for all swarms.

    One hub serves a whole simulation; `PieceExchange` instances attach
    per app via `register_seed` / `register_leech` and mirror their
    verified-piece / pending-set changes in.  `tick(now)` (driven by
    `SimRuntime.run_batched`) then computes every node's grants, chokes,
    piece requests and endgame duplicates in batched array passes.
    """

    def __init__(self, backend: Optional[str] = None):
        self.backend = get_backend(backend)
        # keyed by (app_id, manifest version): revisions of one app are
        # DISJOINT swarms — a v(k) engine can neither read nor write
        # v(k+1) masks, so mixed-version flash crowds never cross
        self.states: Dict[Tuple[str, int], SwarmState] = {}
        self._cfg = None                   # choke parameters (first client)
        self.batch_ops = 0                 # array-applied decisions
        self.coalesced = 0                 # control messages replaced
        self.ticks = 0
        # topology (P4P mode): ALTO cost matrix folded into selection
        self.topology = None
        self.cost_matrix: Optional[np.ndarray] = None

    # ========================= registration ============================= #
    def set_topology(self, topology) -> None:
        """Enable P4P selection: piece orders and holder tie-breaks fold
        in the topology's ALTO cost map.  `None` restores pure rarity
        (the no-topology decisions, bit for bit)."""
        self.topology = topology
        if topology is None:
            self.cost_matrix = None
            for st in self.states.values():
                st.lookup_island = None
                st.island[:] = 0
            return
        self.cost_matrix = np.asarray(topology.cost_map(), dtype=np.int64)
        for st in self.states.values():
            st.lookup_island = topology.island_of
            for i, name in enumerate(st.names):
                st.island[i] = topology.island_of(name)

    @staticmethod
    def _key(app_id: str, manifest) -> Tuple[str, int]:
        return (app_id, int(getattr(manifest, "version", 1) or 1))

    def _state(self, app_id: str, manifest) -> SwarmState:
        key = self._key(app_id, manifest)
        st = self.states.get(key)
        if st is None:
            st = self.states[key] = SwarmState(app_id, manifest)
            if self.topology is not None:
                st.lookup_island = self.topology.island_of
        return st

    def _lookup(self, px, app_id: str) -> Optional[SwarmState]:
        """The state for `px`'s CURRENT revision of `app_id` (None when
        the engine has no manifest or never attached)."""
        m = px.manifests.get(app_id)
        if m is None:
            return None
        return self.states.get(self._key(app_id, m))

    def _attach(self, px, app_id: str, manifest) -> Tuple[SwarmState, int]:
        if self._cfg is None:
            self._cfg = px.cfg
        st = self._state(app_id, manifest)
        i = st.ensure_row(px.node_id)
        if st.clients[i] is not None and st.clients[i] is not px:
            # same name, new incarnation (crash + restart): the fresh
            # engine starts empty — wipe the row before re-use
            self._reset_row(st, i)
        if not st.alive[i]:
            st.alive[i] = True
            st.n_alive += 1
        st.clients[i] = px
        return st, i

    def register_seed(self, px, app_id: str, manifest) -> None:
        """A node holding the complete image (origin, or a restored
        replica) joins the swarm as a pure seeder."""
        st, i = self._attach(px, app_id, manifest)
        st.full[i] = True
        st.fetching[i] = False

    def register_leech(self, px, app_id: str, manifest) -> None:
        """A node starts fetching the image; pieces it already holds
        (cache rescan) are announced separately via `note_have`."""
        st, i = self._attach(px, app_id, manifest)
        st.fetching[i] = True
        st.full[i] = False
        st.dirty.add(i)

    def _reset_row(self, st: SwarmState, i: int) -> None:
        if st.have_n[i]:
            st.counts -= st.have[i].astype(np.int32)
            st.have[i, :] = False
            st.have_n[i] = 0
            st.avail_epoch += 1
        st.full[i] = False
        st.fetching[i] = False
        st.starved[i] = False
        st.opt_peer[i] = -1
        st.newly_full = [j for j in st.newly_full if j != i]
        self._release_slots(st, i)
        st.unchoked[i, :] = False
        for m in (st.recv, st.sent, st.recv_prev, st.sent_prev):
            m[i, :] = 0.0
            m[:, i] = 0.0

    def has_row(self, app_id: str, name: str) -> bool:
        return any(aid == app_id and name in st.row
                   for (aid, _), st in self.states.items())

    def retire(self, px, app_id: str, manifest) -> None:
        """`px` upgraded away from `manifest`'s revision: detach its row
        from the superseded (app_id, version) state so stale masks can
        never leak into the new swarm; the state itself is pruned once
        its last live row retires."""
        st = self.states.get(self._key(app_id, manifest))
        if st is None:
            return
        i = st.row.get(px.node_id)
        if i is None:
            return
        if st.alive[i]:
            st.alive[i] = False
            st.n_alive -= 1
            self._reset_row(st, i)
            st.avail_epoch += 1
        st.clients[i] = None
        if st.n_alive <= 0:
            self.states.pop(self._key(app_id, manifest), None)

    # ====================== state change mirrors ======================== #
    def note_have(self, px, app_id: str, piece_id: int) -> None:
        """A piece verified locally at `px` — the array-native stand-in
        for the swarm-wide HAVE announce fan-out."""
        st = self._lookup(px, app_id)
        if st is None:
            return
        i = st.row.get(px.node_id)
        if i is None:
            return
        if not st.have[i, piece_id]:
            st.have[i, piece_id] = True
            st.have_n[i] += 1
            st.counts[piece_id] += 1
            st.avail_epoch += 1
            self.batch_ops += 1
            # the scalar engine would send one announce per swarm peer
            # plus the tracker copy (and the tracker would relay): count
            # the suppressed deliveries so events/s stays comparable
            self.coalesced += 2 * max(st.n_alive - 1, 0)
        st.dirty.add(i)

    def set_full(self, px, app_id: str) -> None:
        """`px` verified the whole image: seeder from now on."""
        st = self._lookup(px, app_id)
        if st is None:
            return
        i = st.row.get(px.node_id)
        if i is None:
            return
        st.full[i] = True
        st.fetching[i] = False
        st.starved[i] = False
        st.dirty.discard(i)
        st.newly_full.append(i)

    def mark_dirty(self, px, app_id: str) -> None:
        """`px`'s pending set (or choke view) changed: re-pump the row on
        the next tick.  The hub reads the pending/budget truth straight
        from the engine's dicts, so there is nothing else to sync."""
        st = self._lookup(px, app_id)
        if st is None:
            return
        i = st.row.get(px.node_id)
        if i is not None and st.fetching[i]:
            st.dirty.add(i)

    def node_gone(self, name: str) -> None:
        """A node crashed (PEER_GONE): drop its holdings, slots and rate
        history from every swarm.  Idempotent; a restart re-registers."""
        for st in self.states.values():
            i = st.row.get(name)
            if i is None or not st.alive[i]:
                continue
            st.alive[i] = False
            st.n_alive -= 1
            self._reset_row(st, i)
            st.avail_epoch += 1

    def credit(self, px, app_id: str, peer: str, nbytes: int,
               received: bool) -> None:
        """Mirror of `_credit_from` / `_credit_to`: transfer bytes into
        the rolling per-link windows the batched rechoke ranks on."""
        st = self._lookup(px, app_id)
        if st is None:
            return
        i = st.row.get(px.node_id)
        j = st.row.get(peer)
        if i is None or j is None:
            return
        (st.recv if received else st.sent)[i, j] += nbytes

    # ========================= choke mechanics ========================== #
    def _release_slots(self, st: SwarmState, i: int) -> None:
        """Free every upload slot granted TO row i (batched
        `_promote_full_seeder`): seeders stop being unchoke candidates."""
        name = st.names[i]
        holders = np.nonzero(st.unchoked[:st.n, i])[0]
        for h in holders:
            st.unchoked[h, i] = False
            px_h = st.clients[h]
            if px_h is not None:
                px_h.unchoked[st.app_id].discard(name)
                px_h.interested[st.app_id].discard(name)
                px_h.queued_reqs[st.app_id].pop(name, None)
        self.batch_ops += len(holders)

    def _apply_grant(self, st: SwarmState, h: int, i: int) -> None:
        """Holder row h unchokes leecher row i: zero-latency stand-in for
        the INTERESTED -> UNCHOKE exchange.  Queued endgame requests are
        served immediately, exactly as the scalar `_unchoke` does."""
        st.unchoked[h, i] = True
        app_id = st.app_id
        name_i, name_h = st.names[i], st.names[h]
        px_h, px_i = st.clients[h], st.clients[i]
        if px_h is not None:
            px_h.unchoked[app_id].add(name_i)
            queued = px_h.queued_reqs[app_id].pop(name_i, None)
            if queued:
                for piece_id in sorted(queued):
                    px_h._serve(app_id, name_i, piece_id)
        if px_i is not None:
            px_i.unchoked_by[app_id].add(name_h)
        st.dirty.add(i)
        self.batch_ops += 1
        self.coalesced += 2           # INTERESTED + UNCHOKE never sent

    def _apply_choke(self, st: SwarmState, h: int, i: int) -> None:
        """Holder row h chokes leecher row i; the leecher immediately
        re-routes solely-pending requests (the scalar `on_choke` body)."""
        st.unchoked[h, i] = False
        app_id = st.app_id
        name_i, name_h = st.names[i], st.names[h]
        px_h, px_i = st.clients[h], st.clients[i]
        if px_h is not None:
            px_h.unchoked[app_id].discard(name_i)
        if px_i is not None:
            px_i.unchoked_by[app_id].discard(name_h)
            pending = px_i.pending.get(app_id)
            if pending:
                for piece_id, asked in list(pending.items()):
                    if name_h in asked and len(asked) == 1:
                        del asked[name_h]
                        px_i.peer_load[name_h] = max(
                            0, px_i.peer_load[name_h] - 1)
                        del pending[piece_id]
            st.dirty.add(i)
        self.batch_ops += 1
        self.coalesced += 1           # CHOKE never sent

    def grant(self, px, app_id: str, peer: str) -> bool:
        """Holder-initiated unchoke (the scalar `_maybe_unchoke_now` fast
        path reacting to a live PIECE_REQ): applied through the arrays.
        Returns False when either side has no row yet — the caller then
        falls back to the wire message."""
        st = self._lookup(px, app_id)
        if st is None:
            return False
        h = st.row.get(px.node_id)
        i = st.row.get(peer)
        if h is None or i is None:
            return False
        self._apply_grant(st, h, i)
        return True

    def choke(self, px, app_id: str, peer: str) -> bool:
        """Holder-initiated choke, applied through the arrays (the peer
        re-routes immediately instead of waiting for a CHOKE message)."""
        st = self._lookup(px, app_id)
        if st is None:
            return False
        h = st.row.get(px.node_id)
        i = st.row.get(peer)
        if h is None or i is None:
            return False
        self._apply_choke(st, h, i)
        return True

    def _grants(self, st: SwarmState) -> None:
        """Fill free upload slots with the lowest-named fetching leechers
        (batched `_maybe_unchoke_now`)."""
        n = st.n
        cand = st.fetching[:n] & st.alive[:n]
        if not cand.any():
            return
        holders = st.holder_mask()
        slots = max(int(self._cfg.upload_slots), 1)
        used = st.unchoked[:n, :n].sum(axis=1)
        rows = holders & (used < slots)
        if not rows.any():
            return
        want = cand[None, :] & ~st.unchoked[:n, :n] & rows[:, None]
        np.fill_diagonal(want, False)
        ranks = st.ranks
        for h in np.nonzero(want.any(axis=1))[0]:
            free = slots - int(used[h])
            if free <= 0:
                continue
            cs = np.nonzero(want[h])[0]
            gkey = ranks[cs]
            if self.cost_matrix is not None:
                # P4P: grant free slots to same-island leechers first —
                # the unchoke graph, not just the request order, decides
                # which bytes cross an ISP boundary
                gkey = gkey + self._holder_costs(st, int(h))[cs] \
                    * _COST_SHIFT
            for i in cs[np.argsort(gkey, kind="stable")][:free]:
                self._apply_grant(st, h, int(i))

    def _rechoke(self, st: SwarmState, now: float) -> None:
        """Batched periodic rechoke: one `choke_order` kernel call ranks
        every holder's candidates by reciprocal rate; the optimistic slot
        rotates through the name-ordered rest via the scalar index
        arithmetic (`rest[self._opt_idx % len(rest)]`)."""
        st.rechoke_round += 1
        every = max(int(getattr(self._cfg, "optimistic_every", 3)), 1)
        rotate = st.rechoke_round % every == 0
        n = st.n
        slots = max(int(self._cfg.upload_slots), 1)
        cand = st.fetching[:n] & st.alive[:n]
        holders = np.nonzero(st.holder_mask())[0]
        ranks = st.ranks
        # fetching rows in name order: the scalar `rest = sorted(cands)`
        glist = np.nonzero(cand)[0]
        glist = glist[np.argsort(ranks[glist], kind="stable")]
        pos = np.full(n, -1, dtype=np.int64)
        pos[glist] = np.arange(glist.size)
        n_cand = int(cand.sum())
        ranked = np.array([h for h in holders
                           if n_cand - int(cand[h]) > slots], dtype=np.int64)
        order = None
        if ranked.size:
            cm = np.repeat(cand[None, :], ranked.size, axis=0)
            cm[np.arange(ranked.size), ranked] = False
            rank_key = ranks[:n]
            if self.cost_matrix is not None:
                # P4P tie-break: reciprocal rates stay primary, but rate
                # ties (the whole swarm, early in a flash crowd) resolve
                # cheapest-island-first instead of by name alone.  Small
                # shift: the jax backend keys are int32.
                rank_key = (self.cost_matrix[
                    st.island[ranked][:, None], st.island[None, :n]]
                    * _CHOKE_COST_SHIFT + ranks[None, :n])
            order = choke_order(
                st.recv[ranked][:, :n] + st.recv_prev[ranked][:, :n],
                st.sent[ranked][:, :n] + st.sent_prev[ranked][:, :n],
                cm, rank_key, backend=self.backend)
        krow = {int(h): k for k, h in enumerate(ranked)}
        for h in holders:
            h = int(h)
            k = krow.get(h)
            if k is None:
                # few candidates: everyone fetching gets a slot
                new = {int(i) for i in glist if i != h}
                st.opt_peer[h] = -1
            else:
                top = [int(i) for i in order[k, :slots - 1]]
                new = set(top)
                # optimistic slot from the name-ordered rest
                rest_len = n_cand - int(cand[h]) - (slots - 1)
                opt = int(st.opt_peer[h])
                in_rest = (opt >= 0 and opt != h and opt < n
                           and cand[opt] and opt not in new)
                if rotate or not in_rest:
                    st.opt_idx[h] += 1
                    t = int(st.opt_idx[h]) % rest_len
                    # rest == glist minus {h} and the top rows: selecting
                    # rest[t] = the t-th surviving element of glist
                    excl = sorted(int(pos[x]) for x in top + [h]
                                  if 0 <= x < n and pos[x] >= 0)
                    for e in excl:
                        if e <= t:
                            t += 1
                    opt = int(glist[t])
                st.opt_peer[h] = opt
                new.add(opt)
            old = set(np.nonzero(st.unchoked[h, :n])[0].tolist())
            for i in sorted(old - new, key=lambda x: ranks[x]):
                self._apply_choke(st, h, int(i))
            for i in sorted(new - old, key=lambda x: ranks[x]):
                self._apply_grant(st, h, int(i))
        # tumble the rate windows so ranking tracks *current* throughput
        window = float(getattr(self._cfg, "rate_window_s", 20.0))
        if now - st.win_start >= window:
            st.recv_prev, st.recv = st.recv, st.recv_prev
            st.sent_prev, st.sent = st.sent, st.sent_prev
            st.recv[:, :] = 0.0
            st.sent[:, :] = 0.0
            st.win_start = now

    # ========================== piece selection ========================= #
    def _piece_cost(self, st: SwarmState, rows: np.ndarray) -> np.ndarray:
        """(len(rows), P) cheapest-holder cost plane rows for the given
        leecher rows: `island_has` (backend kernel) reduces the alive
        have-matrix to island-level availability, `min_island_cost`
        derives the per-source-island cost plane, and each leecher reads
        its own island's row."""
        n = st.n
        k = self.topology.n_islands
        have = (st.have[:n, :] | st.full[:n, None]) & st.alive[:n, None]
        member = np.zeros((k, n), dtype=bool)
        member[st.island[:n], np.arange(n)] = True
        avail = island_has(have, member, backend=self.backend)
        plane = min_island_cost(avail, self.cost_matrix)       # (K, P)
        return plane[st.island[rows]]

    def _holder_costs(self, st: SwarmState, i: int) -> Optional[np.ndarray]:
        """(n,) ALTO cost from leecher row i's island to every row's
        island, or None when no topology is set."""
        if self.cost_matrix is None:
            return None
        return self.cost_matrix[st.island[i], st.island[:st.n]]

    def _usable_rows(self, st: SwarmState, i: int) -> np.ndarray:
        """Holder rows leecher i may address a request to right now:
        unchoked-by (unless choking is globally off), holding something,
        alive, not this node, not banned, and with no request of ours
        already in flight (one in-flight request per holder)."""
        n = st.n
        px = st.clients[i]
        if getattr(self._cfg, "choke", True):
            ux = st.unchoked[:n, i].copy()
        else:
            ux = np.ones(n, dtype=bool)
        ux &= st.holder_mask()
        ux[i] = False
        app_id = st.app_id
        busy = {peer for asked in px.pending.get(app_id, {}).values()
                for peer in asked}
        bad = px.bad_peers.get(app_id)
        if bad:
            busy = busy | bad
        for name in busy:
            j = st.row.get(name)
            if j is not None:
                ux[j] = False
        return ux

    def _match_row(self, st: SwarmState, i: int, order: np.ndarray,
                   now: float) -> Tuple[List[Tuple[int, int]], bool]:
        """Walk one leecher's rarest-first order and pick a holder per
        piece with the scalar tie-breaks (shunned holders last, then
        lowest name).  Pure: returns ([(piece, holder_row)], starved)
        without touching any state."""
        px = st.clients[i]
        app_id = st.app_id
        pending = px.pending.get(app_id, {})
        budget = int(px.cfg.piece_pipeline) - len(pending)
        left = st.P - int(st.have_n[i]) - len(pending)
        out: List[Tuple[int, int]] = []
        if budget <= 0 or left <= 0:
            return out, False
        ux = self._usable_rows(st, i)
        idx = np.nonzero(ux)[0]
        if idx.size == 0:
            return out, True
        stalled = px.stalled_holders.get(app_id, {})
        ranks = st.ranks
        costs = self._holder_costs(st, i)
        taken = np.zeros(idx.size, dtype=bool)
        n_missing = st.P - int(st.have_n[i]) - len(pending)
        for k in range(min(n_missing, order.shape[0])):
            if budget <= 0:
                break
            if taken.all():
                break
            p = int(order[k])
            ok = ~taken & (st.have[idx, p] | st.full[idx])
            cand = idx[ok]
            if cand.size == 0:
                continue
            key = ranks[cand].astype(np.int64)
            if costs is not None:
                # P4P holder tie-break: cheapest island first, then name;
                # the shun bit still dominates the cost (bias decays when
                # same-island holders starve)
                key = key + costs[cand] * _COST_SHIFT
            shun = stalled.get(p)
            if shun:
                key = key + np.array(
                    [st.names[int(j)] in shun for j in cand],
                    dtype=np.int64) * _SHUN_INF
            j = int(cand[int(np.argmin(key))])
            out.append((p, j))
            taken[np.searchsorted(idx, j)] = True
            budget -= 1
        starved = budget > 0 and len(out) < n_missing
        return out, starved

    def _issue(self, st: SwarmState, i: int, piece_id: int, j: int,
               now: float, endgame: bool = False) -> None:
        """Commit one request decision: engine dicts + the real PIECE_REQ
        wire message (link model, faults and chaos still apply to it)."""
        px = st.clients[i]
        name_j = st.names[j]
        asked = px.pending[st.app_id].setdefault(piece_id, {})
        asked[name_j] = now
        px.peer_load[name_j] += 1
        px._send_req(st.app_id, piece_id, name_j, endgame=endgame)
        self.batch_ops += 1

    def _pump(self, st: SwarmState, now: float) -> None:
        """Batched pump: one `rarest_orders` kernel call covers every row
        whose state changed (dirty) plus every previously-starved row if
        availability moved; then per-row request matching."""
        n = st.n
        avail_moved = st.avail_epoch != st.pump_epoch
        sel = np.zeros(n, dtype=bool)
        for i in st.dirty:
            if i < n:
                sel[i] = True
        if avail_moved:
            sel |= st.starved[:n]
        sel &= st.fetching[:n] & st.alive[:n]
        st.dirty.clear()
        st.pump_epoch = st.avail_epoch
        rows = np.nonzero(sel)[0]
        if rows.size == 0:
            return
        app_id = st.app_id
        missing = ~st.have[rows, :]
        for k, i in enumerate(rows):
            for p in st.clients[int(i)].pending.get(app_id, {}):
                missing[k, p] = False
        if self.cost_matrix is not None:
            pc = self._piece_cost(st, rows)
            orders = cost_orders(missing, st.counts, st.offsets[rows], pc,
                                 st.P, backend=self.backend)
        else:
            orders = rarest_orders(missing, st.counts, st.offsets[rows],
                                   st.P, backend=self.backend)
        for k, i in enumerate(rows):
            i = int(i)
            decisions, starved = self._match_row(st, i, orders[k], now)
            for piece_id, j in decisions:
                self._issue(st, i, piece_id, j, now)
            st.starved[i] = starved

    def _endgame(self, st: SwarmState, now: float) -> None:
        """Batched endgame: rows with real progress whose every missing
        piece is in flight duplicate the outstanding requests to other
        holders (scalar `_endgame`: name order, stalled holders shunned,
        `endgame_dup` cap; choked holders queue, PIECE_CANCEL prunes)."""
        if not getattr(self._cfg, "endgame", True):
            return
        n = st.n
        app_id = st.app_id
        rows = np.nonzero(st.fetching[:n] & st.alive[:n]
                          & (st.have_n[:n] > 0))[0]
        ranks = st.ranks
        for i in rows:
            i = int(i)
            px = st.clients[i]
            pending = px.pending.get(app_id)
            if not pending or st.P - int(st.have_n[i]) != len(pending):
                continue
            cap = max(int(getattr(px.cfg, "endgame_dup", 3)), 1)
            stalled = px.stalled_holders.get(app_id, {})
            bad = px.bad_peers.get(app_id, ())
            costs = self._holder_costs(st, i)
            for piece_id, asked in list(pending.items()):
                if len(asked) >= cap:
                    continue
                shun = stalled.get(piece_id, ())
                hm = (st.have[:n, piece_id] | st.full[:n]) & st.alive[:n]
                hm[i] = False
                cand = np.nonzero(hm)[0]
                hkey = ranks[cand]
                if costs is not None:
                    # P4P endgame: duplicate to same-island holders first
                    hkey = hkey + costs[cand] * _COST_SHIFT
                for j in cand[np.argsort(hkey, kind="stable")]:
                    name = st.names[int(j)]
                    if name in asked or name in shun or name in bad:
                        continue
                    self._issue(st, i, piece_id, int(j), now, endgame=True)
                    if len(asked) >= cap:
                        break

    # ============================== tick ================================ #
    def tick(self, now: float) -> None:
        """One batched decision pass over every registered swarm."""
        self.ticks += 1
        for st in self.states.values():
            if st.n == 0:
                continue
            for i in st.newly_full:
                self._release_slots(st, i)
            st.newly_full.clear()
            if self._cfg is not None and getattr(self._cfg, "choke", True):
                self._grants(st)
                interval = float(
                    getattr(self._cfg, "rechoke_interval_s", 10.0))
                if now - st.last_rechoke >= interval:
                    st.last_rechoke = now
                    self._rechoke(st, now)
            self._pump(st, now)
            self._endgame(st, now)

    # ====================== queries / test bridges ====================== #
    def _find(self, app_id: str, node_id: str) -> Optional[SwarmState]:
        """Newest-revision state of `app_id` holding a row for `node_id`
        (test-bridge lookup where no engine handle is available)."""
        best = None
        for (aid, ver), st in self.states.items():
            if aid != app_id or node_id not in st.row:
                continue
            if best is None or ver > best[0]:
                best = (ver, st)
        return None if best is None else best[1]

    def stats(self) -> Dict[str, int]:
        return {"ticks": self.ticks, "batch_ops": self.batch_ops,
                "coalesced_events": self.coalesced}

    def decide_requests(self, app_id: str, node_id: str,
                        now: float) -> List[Tuple[int, str]]:
        """Pure query: the (piece, holder) requests the batched engine
        would issue for one node right now — the differential tests'
        bridge to the scalar `pump`."""
        st = self._find(app_id, node_id)
        i = st.row[node_id]
        px = st.clients[i]
        missing = ~st.have[i, :]       # invert copies; safe to edit
        for p in px.pending.get(app_id, {}):
            missing[p] = False
        if self.cost_matrix is not None:
            pc = self._piece_cost(st, np.array([i], dtype=np.int64))
            order = cost_orders(missing[None, :], st.counts,
                                st.offsets[i:i + 1], pc, st.P,
                                backend=self.backend)[0]
        else:
            order = rarest_orders(missing[None, :], st.counts,
                                  st.offsets[i:i + 1], st.P,
                                  backend=self.backend)[0]
        decisions, _ = self._match_row(st, i, order, now)
        return [(p, st.names[j]) for p, j in decisions]

    def decide_endgame(self, app_id: str, node_id: str,
                       now: float) -> List[Tuple[int, str]]:
        """Pure query: the endgame duplicates the batched engine would
        issue for one node (scalar `_endgame` bridge)."""
        st = self._find(app_id, node_id)
        i = st.row[node_id]
        px = st.clients[i]
        pending = px.pending.get(app_id, {})
        if not pending or not int(st.have_n[i]):
            return []
        if st.P - int(st.have_n[i]) != len(pending):
            return []
        n = st.n
        cap = max(int(getattr(px.cfg, "endgame_dup", 3)), 1)
        stalled = px.stalled_holders.get(app_id, {})
        bad = px.bad_peers.get(app_id, ())
        ranks = st.ranks
        costs = self._holder_costs(st, i)
        out: List[Tuple[int, str]] = []
        for piece_id, asked in pending.items():
            room = cap - len(asked)
            if room <= 0:
                continue
            shun = stalled.get(piece_id, ())
            hm = (st.have[:n, piece_id] | st.full[:n]) & st.alive[:n]
            hm[i] = False
            cand = np.nonzero(hm)[0]
            hkey = ranks[cand]
            if costs is not None:
                hkey = hkey + costs[cand] * _COST_SHIFT
            for j in cand[np.argsort(hkey, kind="stable")]:
                name = st.names[int(j)]
                if name in asked or name in shun or name in bad:
                    continue
                out.append((piece_id, name))
                room -= 1
                if room <= 0:
                    break
        return out

    @classmethod
    def mirror_scalar(cls, px, app_id: str,
                      backend: Optional[str] = None) -> "SwarmHub":
        """Build a hub whose arrays mirror a *scalar-mode* engine's view
        of one swarm (peer masks, full seeders, choke view) — used by
        the differential tests to compare decisions on identical
        information sets."""
        hub = cls(backend=backend)
        manifest = px.manifests[app_id]
        hub.register_leech(px, app_id, manifest)
        st = hub.states[hub._key(app_id, manifest)]
        me = st.row[px.node_id]
        inv = px.inventories.get(app_id)
        if inv is not None:
            for p in inv.have:
                hub.note_have(px, app_id, p)
        full_mask = manifest.full_mask
        for peer, mask in px.peer_masks.get(app_id, {}).items():
            if peer == px.node_id:
                continue
            j = st.ensure_row(peer)
            mask &= full_mask
            while mask:
                low = mask & -mask
                p = low.bit_length() - 1
                mask ^= low
                st.have[j, p] = True
                st.have_n[j] += 1
                st.counts[p] += 1
        for peer in px.full_seeders.get(app_id, ()):
            st.full[st.ensure_row(peer)] = True
        for holder in px.unchoked_by.get(app_id, ()):
            st.unchoked[st.ensure_row(holder), me] = True
        return hub
