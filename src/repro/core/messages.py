"""Wire messages of the tracker/agent protocol (paper Figs. 1, 2, 4, 5)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple


@dataclass
class AppInfo:
    """One row of the tracker's applications list."""
    app_id: str
    host_id: str
    d: float = 0.0
    p: float = 0.0
    w: float = 0.0
    n_parts: int = 0
    parts_remaining: int = 0
    updated_at: float = 0.0            # tracker timestamp (liveness)
    # --- piece-wise swarm extension (paper §V, "torrent-like") ---------- #
    # every node currently holding a complete, validated copy of the app
    # image; the tracker keeps this sorted by reported seeder load so
    # leechers default to the least-loaded holder
    seeders: Tuple[str, ...] = ()
    # metainfo for piece-wise image download (None => monolithic APP_DATA)
    manifest: Optional["object"] = None


@dataclass
class Msg:
    kind: str
    src: str
    payload: Dict[str, Any] = field(default_factory=dict)
    size_bytes: int = 256              # protocol overhead default


# message kinds
REGISTER = "REGISTER"          # agent -> server: list[AppInfo] of A_self
APP_LIST = "APP_LIST"          # server -> agent: full applications list
PING = "PING"                  # server -> agent availability check
PONG = "PONG"                  # agent -> server
STATUS = "STATUS"              # agent -> server: validated work + (d, w)
REQ = "REQ"                    # leecher -> host: request app + next part
APP_DATA = "APP_DATA"          # host -> leecher: app file + part payload
NO_WORK = "NO_WORK"            # host -> leecher: nothing left
RESULT = "RESULT"              # leecher -> host: R + measured (d, w)
RESULT_ACK = "RESULT_ACK"      # host -> leecher: valid / invalid
DROP_APP = "DROP_APP"          # server -> agents: A removed from list
BYE = "BYE"                    # agent -> server: clean leave

# --- piece-wise swarm extension (paper §V) ------------------------------ #
HAVE = "HAVE"                  # peer -> peers: verified-piece bitmask announce
PIECE_REQ = "PIECE_REQ"        # leecher -> holder: request one image piece
PIECE_DATA = "PIECE_DATA"      # holder -> leecher: piece payload + proof
SEEDER_UPDATE = "SEEDER_UPDATE"  # agent -> server (and relayed to seeders):
                                 # node completed the image, joins seeder set
MANIFEST_UPDATE = "MANIFEST_UPDATE"  # host -> server -> swarm: a new revision
                                 # of an app image (versioned PieceManifest);
                                 # bypasses the SEEDER_UPDATE push limiter —
                                 # version gossip must never go stale
PART_DONE = "PART_DONE"        # seeder <-> seeder: validated-part gossip
PEER_GONE = "PEER_GONE"        # server -> agents: volunteer left/died;
                                 # reclaim its leases immediately

# --- topology / P4P (ALTO cost map, ISSUE 7) ---------------------------- #
COST_MAP = "COST_MAP"          # server -> agent on REGISTER: your island,
                               # endpoint costs to every island, and the
                               # node -> island directory

# --- choke scheduler + endgame (PieceExchange engine) ------------------- #
INTERESTED = "INTERESTED"      # leecher -> holder: I want pieces of app
CHOKE = "CHOKE"                # holder -> leecher: upload slot withdrawn
UNCHOKE = "UNCHOKE"            # holder -> leecher: upload slot granted
PIECE_CANCEL = "PIECE_CANCEL"  # leecher -> holder: drop my queued piece req
                               # (endgame reconciliation)
PART_CANCEL = "PART_CANCEL"    # seeder -> volunteer: part validated elsewhere,
                               # abort the leased execution
