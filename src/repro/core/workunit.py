"""Applications, parts, pieces and leases.

An Application is split into parts ("cycles" in the paper's tests); the host
leases parts to leechers, tracks them via TAIL, and re-DISTs on timeout.
Leases are also the framework's unit of data-pipeline fault tolerance.

The paper's §V extension adds a second axis of division: the application
*image* itself is broken into fixed-size, content-hashed pieces described by
a `PieceManifest` (metainfo, like a .torrent file).  Volunteers track their
holdings in a `PieceInventory`, verify every piece against the manifest, and
any volunteer with a complete image may re-seed it.  Executables are resolved
through a registry keyed by the manifest hash — possession of the verified
image is what grants the right to look up and run the code, replacing any
side-channel between nodes.
"""
from __future__ import annotations

import functools
import hashlib
import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Set, Tuple


def _hash(*fields: object) -> str:
    h = hashlib.sha1()
    for f in fields:
        h.update(str(f).encode())
        h.update(b"\0")
    return h.hexdigest()


# ---- piece bitmasks ------------------------------------------------------- #
# HAVE/PIECE_DATA announcements carry holdings as a compact int bitmask
# (bit p set <=> piece p held) so announce traffic scales O(pieces/8) bytes
# per message instead of O(pieces) list entries.
def mask_of(pieces) -> int:
    mask = 0
    for p in pieces:
        mask |= 1 << p
    return mask


def pieces_of(mask: int) -> Set[int]:
    out: Set[int] = set()
    p = 0
    while mask:
        if mask & 1:
            out.add(p)
        mask >>= 1
        p += 1
    return out


def mask_nbytes(mask: int) -> int:
    """On-wire size of a bitmask (for honest Msg.size_bytes accounting)."""
    return (mask.bit_length() + 7) // 8


@dataclass(frozen=True)
class PieceManifest:
    """Metainfo for piece-wise image distribution (paper §V).

    Mirrors a .torrent info dict: piece size, piece count and per-piece
    content hashes.  `manifest_hash` (the info-hash) identifies the exact
    application image and keys the executable registry.
    """
    app_id: str
    piece_bytes: int
    total_bytes: int
    piece_hashes: Tuple[str, ...]
    # True when piece_hashes are content hashes of real payload bytes
    # (from_bytes): verification then REQUIRES the bytes — the hashes are
    # public metainfo, so a bare proof proves nothing
    content_hashed: bool = False
    # revision chain: successive revisions of the same app_id carry a
    # monotonically increasing version and the manifest_hash of the
    # revision they supersede, so a swarm can diff v(k+1) against v(k)
    # and move only the changed pieces (delta distribution)
    version: int = 1
    prev_manifest_hash: Optional[str] = None

    @property
    def n_pieces(self) -> int:
        return len(self.piece_hashes)

    @functools.cached_property
    def manifest_hash(self) -> str:
        return _hash(self.app_id, self.piece_bytes, self.total_bytes,
                     self.version, self.prev_manifest_hash,
                     *self.piece_hashes)

    def supersedes(self, other: Optional["PieceManifest"]) -> bool:
        """True when this manifest is a strictly newer revision of the
        same application than `other` (None counts as "nothing held")."""
        if other is None:
            return True
        return (self.app_id == other.app_id
                and self.version > other.version)

    def delta(self, prev: Optional["PieceManifest"]) -> Set[int]:
        """Piece ids whose content differs from `prev` (positional hash
        compare).  Incomparable manifests (different piece size, different
        hashing mode, or no predecessor) conservatively report every
        piece as changed — nothing may be reused."""
        if (prev is None or prev.piece_bytes != self.piece_bytes
                or prev.content_hashed != self.content_hashed):
            return set(range(self.n_pieces))
        return {i for i, h in enumerate(self.piece_hashes)
                if i >= prev.n_pieces or prev.piece_hashes[i] != h}

    @functools.cached_property
    def full_mask(self) -> int:
        """Bitmask with every piece bit set (the complete-image HAVE)."""
        return (1 << self.n_pieces) - 1

    def piece_size(self, piece_id: int) -> int:
        if piece_id < self.n_pieces - 1:
            return self.piece_bytes
        rem = self.total_bytes - self.piece_bytes * (self.n_pieces - 1)
        return max(rem, 0)

    @classmethod
    def from_bytes(cls, app_id: str, image, piece_bytes: int, *,
                   version: int = 1,
                   prev: Optional["PieceManifest"] = None
                   ) -> "PieceManifest":
        # hash through zero-copy views: building a manifest for a large
        # image must not materialise a bytes copy per piece.  An empty
        # image is a 0-piece manifest (trivially complete, full_mask 0) —
        # a phantom zero-byte piece 0 could never be transferred or
        # verified, and a 0-delta upgrade would wedge on it.
        mv = memoryview(image)
        hashes = tuple(
            hashlib.sha1(mv[i:i + piece_bytes]).hexdigest()
            for i in range(0, len(mv), piece_bytes))
        return cls(app_id, piece_bytes, len(mv), hashes,
                   content_hashed=True, version=version,
                   prev_manifest_hash=prev.manifest_hash
                   if prev is not None else None)

    @classmethod
    def synthetic(cls, app_id: str, total_bytes: int, piece_bytes: int, *,
                  version: int = 1,
                  prev: Optional["PieceManifest"] = None,
                  changed: Optional[Set[int]] = None) -> "PieceManifest":
        """Manifest for a simulated image: hashes are derived, no bytes are
        materialised (benchmarks use multi-GB images).

        Piece hashes deliberately do NOT fold in the version, so a new
        revision of the same (app_id, total_bytes) shares hashes with its
        predecessor except for `changed` pieces — that is what makes the
        synthetic path a usable delta-distribution workload.
        """
        n = (-(-total_bytes // max(piece_bytes, 1))
             if total_bytes > 0 else 0)
        changed = changed or set()
        hashes = tuple(
            _hash(app_id, total_bytes, i, "rev", version) if i in changed
            else _hash(app_id, total_bytes, i)
            for i in range(n))
        return cls(app_id, piece_bytes, total_bytes, hashes,
                   version=version,
                   prev_manifest_hash=prev.manifest_hash
                   if prev is not None else None)


class PieceInventory:
    """Which pieces of one application image a volunteer holds (verified)."""

    def __init__(self, manifest: PieceManifest,
                 complete: bool = False):
        self.manifest = manifest
        self.have: Set[int] = (set(range(manifest.n_pieces)) if complete
                               else set())
        # holdings mirrored as an int bitmask so bitfield() is O(1): HAVE
        # announces fire once per verified piece per peer, and rebuilding
        # the mask from the set each time was O(pieces) on that hot path
        self._mask: int = (1 << manifest.n_pieces) - 1 if complete else 0

    def add(self, piece_id: int, proof: Optional[str] = None,
            data=None) -> bool:
        """Verify a piece against the manifest; reject corrupt pieces.

        Real transfers pass `data` (the payload slice) and the content hash
        is recomputed here — a peer cannot fake a proof for bogus bytes,
        and for a content-hashed manifest a bare proof is rejected outright
        (piece hashes are public metainfo; only the bytes prove holding).
        Synthetic (simulation) transfers pass only `proof`.
        """
        if not (0 <= piece_id < self.manifest.n_pieces):
            return False
        if data is not None:
            proof = hashlib.sha1(data).hexdigest()
        elif self.manifest.content_hashed:
            return False
        if proof != self.manifest.piece_hashes[piece_id]:
            return False
        self.have.add(piece_id)
        self._mask |= 1 << piece_id
        return True

    def has(self, piece_id: int) -> bool:
        return piece_id in self.have

    def missing(self) -> List[int]:
        return [i for i in range(self.manifest.n_pieces)
                if i not in self.have]

    @property
    def complete(self) -> bool:
        return len(self.have) == self.manifest.n_pieces

    def bitfield(self) -> int:
        """Holdings as a compact int bitmask (bit p set <=> piece p held)."""
        return self._mask

    def seed_from(self, prev: "PieceInventory",
                  read_piece: Optional[Callable[[int], Any]] = None
                  ) -> Set[int]:
        """Adopt still-valid pieces from a previous revision's inventory.

        Only pieces that are unchanged per ``manifest.delta(prev)`` AND
        verified in `prev` are candidates.  The reuse rule: for a
        content-hashed manifest the actual bytes are re-read through
        `read_piece(piece_id)` and re-hashed by add(data=...) — a reused
        piece is never trusted on faith, so a corrupt or stale cache can
        not leak into the new revision.  Synthetic manifests adopt by
        proof.  Returns the set of adopted piece ids.
        """
        changed = self.manifest.delta(prev.manifest)
        adopted: Set[int] = set()
        for pid in prev.have:
            if pid in changed or pid >= self.manifest.n_pieces:
                continue
            if self.manifest.content_hashed:
                data = read_piece(pid) if read_piece is not None else None
                if data is None:
                    continue
                ok = self.add(pid, data=data)
            else:
                ok = self.add(pid, proof=self.manifest.piece_hashes[pid])
            if ok:
                adopted.add(pid)
        return adopted


# --------------------------------------------------------------------------- #
# Executable registry: manifest hash -> runnable code + app blueprint.
#
# In a real deployment the verified image *is* the executable; in this
# in-process reproduction the registry stands in for "unpacking the image".
# An agent may only resolve a hash for an image it has fully verified, which
# removes the old back-door of reaching into the runtime's node table.
_EXECUTABLES: Dict[str, "ExecutableEntry"] = {}


@dataclass
class ExecutableEntry:
    run_fn: Optional[Callable[[Any], Any]]
    cost_fn: Optional[Callable[[Any, float], float]]
    blueprint: Optional[Callable[[], "Application"]] = None


def register_executable(manifest_hash: str,
                        run_fn: Optional[Callable[[Any], Any]],
                        cost_fn: Optional[Callable[[Any, float], float]],
                        blueprint: Optional[Callable[[], "Application"]] = None
                        ) -> None:
    _EXECUTABLES[manifest_hash] = ExecutableEntry(run_fn, cost_fn, blueprint)


def resolve_executable(manifest_hash: str) -> Optional[ExecutableEntry]:
    return _EXECUTABLES.get(manifest_hash)


@dataclass
class Part:
    part_id: int
    payload: Any                         # e.g. (lo, hi) range for primes
    data_bytes: int = 4096
    done: bool = False
    results: List[Tuple[str, Any, float]] = field(default_factory=list)
    # (volunteer_id, result, time_s) — for m_min-way majority voting
    # the majority_vote winner the part was validated with (set when
    # `done` flips); gossip must ship THIS, not a raw vote — results[0]
    # may be the minority/corrupt one
    winner: Any = None


@dataclass
class Application:
    app_id: str
    host_id: str
    run_fn: Optional[Callable[[Any], Any]] = None   # real execution
    cost_fn: Optional[Callable[[Any, float], float]] = None  # sim: (payload, speed)->s
    app_bytes: int = 4096
    parts: List[Part] = field(default_factory=list)
    m_min: int = 1
    m_max: int = 1
    # piece-wise distribution (paper §V): when `swarm` is set the image is
    # advertised via the manifest and moves as hashed pieces between
    # volunteers instead of riding on every APP_DATA
    swarm: bool = False
    piece_bytes: int = 1 << 16
    manifest: Optional[PieceManifest] = None
    # real application image: when set, pieces carry actual payload slices
    # of these bytes and the manifest hashes their content; when None the
    # image is synthetic (simulation) and pieces move as hash proofs
    image: Optional[bytes] = None
    # lazy open-part index (see _open); not part of the public state
    _open_idx: Optional["deque"] = field(default=None, repr=False)

    def ensure_manifest(self) -> PieceManifest:
        if self.manifest is None:
            if self.image is not None:
                self.manifest = PieceManifest.from_bytes(
                    self.app_id, self.image,
                    self.piece_bytes if self.swarm
                    else max(len(self.image), 1))
            else:
                self.manifest = PieceManifest.synthetic(
                    self.app_id, self.app_bytes,
                    self.piece_bytes if self.swarm
                    else max(self.app_bytes, 1))
        return self.manifest

    def blueprint(self) -> Callable[[], "Application"]:
        """Factory reconstructing this application from its image: fresh
        parts, same executables — what a replica seeder unpacks."""
        spec = [(p.part_id, p.payload, p.data_bytes) for p in self.parts]

        def make() -> "Application":
            return Application(
                self.app_id, self.host_id, run_fn=self.run_fn,
                cost_fn=self.cost_fn, app_bytes=self.app_bytes,
                parts=[Part(pid, payload, data_bytes=db)
                       for pid, payload, db in spec],
                m_min=self.m_min, m_max=self.m_max, swarm=self.swarm,
                piece_bytes=self.piece_bytes, manifest=self.manifest,
                image=self.image)
        return make

    def _open(self) -> "deque":
        """Positions of not-yet-done parts.  Built lazily, pruned as a
        side effect of every scan, so the per-DIST cost tracks the open
        part count instead of the full part list (`done` flips are
        monotonic; entries completed since the last scan self-heal out
        no matter who set the flag).  A deque so scans can rotate: the
        next grant resumes where the last one stopped instead of
        re-walking every currently-leased part at the front."""
        idx = self._open_idx
        if idx is None:
            idx = self._open_idx = deque(
                k for k, p in enumerate(self.parts) if not p.done)
        return idx

    def pending_parts(self, leased: Dict[int, list]) -> List[Part]:
        out = []
        idx = self._open()
        for _ in range(len(idx)):
            k = idx[0]
            part = self.parts[k]
            if part.done:
                idx.popleft()             # prune completed entries
                continue
            idx.rotate(-1)
            active = len(leased.get(part.part_id, []))
            needed = self.m_min - len(part.results) - active
            if needed > 0:
                out.append(part)
        return out

    def grant_candidate(self, leased: Dict[int, list],
                        in_partition: Callable[["Part"], bool],
                        acceptable: Callable[["Part"], bool]
                        ) -> Optional[Part]:
        """Next pending part in this seeder's partition that
        `acceptable` admits; when the partition holds no pending part at
        all, an acceptable pending part anywhere (the endgame fallback:
        a seeder whose partition drained helps finish the rest).

        Round-robin over the open-part index: every examined entry
        rotates to the back (done entries prune out instead), so the
        scan resumes after the previously granted part and the per-DIST
        cost is the distance to the next grantable part — NOT a re-walk
        of the O(active leases) saturated prefix that a front-first scan
        pays at N=10000 (the fallback still needs the one full cycle it
        always needed)."""
        idx = self._open()
        any_mine = False
        best_any = None
        for _ in range(len(idx)):
            k = idx[0]
            part = self.parts[k]
            if part.done:
                idx.popleft()             # prune completed entries
                continue
            idx.rotate(-1)
            active = len(leased.get(part.part_id, ()))
            if self.m_min - len(part.results) - active <= 0:
                continue
            if in_partition(part):
                any_mine = True
                if acceptable(part):
                    return part
            elif best_any is None and acceptable(part):
                best_any = part
        return None if any_mine else best_any

    @property
    def done(self) -> bool:
        # pop completed entries off the index tail until a live one is
        # found: each entry is discarded at most once across the app's
        # lifetime, so the check is amortized O(1) instead of a rescan
        idx = self._open()
        while idx:
            if self.parts[idx[-1]].done:
                idx.pop()
            else:
                return False
        return True

    @property
    def total_data_bytes(self) -> int:
        return sum(p.data_bytes for p in self.parts)


@dataclass
class Lease:
    part_id: int
    volunteer_id: str
    issued_at: float
    deadline: float


class LeaseTable:
    """TAIL's bookkeeping: part -> outstanding leases, with timeouts."""

    def __init__(self, timeout_s: float):
        self.timeout_s = timeout_s
        self.by_part: Dict[int, List[Lease]] = {}

    def grant(self, part_id: int, volunteer_id: str, now: float) -> Lease:
        lease = Lease(part_id, volunteer_id, now, now + self.timeout_s)
        self.by_part.setdefault(part_id, []).append(lease)
        return lease

    def release(self, part_id: int, volunteer_id: str) -> bool:
        ls = self.by_part.get(part_id, [])
        for i, l in enumerate(ls):
            if l.volunteer_id == volunteer_id:
                ls.pop(i)
                return True
        return False

    def expired(self, now: float) -> List[Lease]:
        out = []
        for ls in self.by_part.values():
            out.extend(l for l in ls if l.deadline <= now)
        return out

    def drop_volunteer(self, volunteer_id: str) -> List[int]:
        """Drop all leases of a volunteer; returns affected part ids."""
        parts = []
        for pid, ls in self.by_part.items():
            n0 = len(ls)
            ls[:] = [l for l in ls if l.volunteer_id != volunteer_id]
            if len(ls) != n0:
                parts.append(pid)
        return parts

    def active(self) -> Dict[int, list]:
        return {pid: ls for pid, ls in self.by_part.items() if ls}


def make_prime_app(app_id: str, host_id: str, lo: int, hi: int,
                   n_parts: int, *, app_bytes: int = 4096,
                   part_data_bytes: int = 4096, m_min: int = 1,
                   sim_time_per_number: float = 2.5e-3,
                   swarm: bool = False,
                   piece_bytes: int = 1 << 16,
                   image: Optional[bytes] = None) -> Application:
    """The paper's test application: prime search by exhaustion."""
    bounds = []
    step = (hi - lo) / n_parts
    for i in range(n_parts):
        a = int(lo + i * step)
        b = int(lo + (i + 1) * step) if i < n_parts - 1 else hi
        bounds.append((a, b))

    def run_fn(payload):
        a, b = payload
        return find_primes(a, b)

    def cost_fn(payload, speed):
        a, b = payload
        return (b - a) * sim_time_per_number / speed

    parts = [Part(i, bounds[i], data_bytes=part_data_bytes)
             for i in range(n_parts)]
    return Application(app_id, host_id, run_fn=run_fn, cost_fn=cost_fn,
                       app_bytes=len(image) if image is not None
                       else app_bytes,
                       parts=parts, m_min=m_min,
                       m_max=max(m_min, 1), swarm=swarm,
                       piece_bytes=piece_bytes, image=image)


def find_primes(lo: int, hi: int) -> list:
    """Exhaustion method, as in the paper's test application."""
    out = []
    for n in range(max(lo, 2), hi):
        if n % 2 == 0:
            if n == 2:
                out.append(n)
            continue
        i = 3
        prime = True
        while i * i <= n:
            if n % i == 0:
                prime = False
                break
            i += 2
        if prime:
            out.append(n)
    return out
