"""Applications, parts and leases.

An Application is split into parts ("cycles" in the paper's tests); the host
leases parts to leechers, tracks them via TAIL, and re-DISTs on timeout.
Leases are also the framework's unit of data-pipeline fault tolerance.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple


@dataclass
class Part:
    part_id: int
    payload: Any                         # e.g. (lo, hi) range for primes
    data_bytes: int = 4096
    done: bool = False
    results: List[Tuple[str, Any, float]] = field(default_factory=list)
    # (volunteer_id, result, time_s) — for m_min-way majority voting


@dataclass
class Application:
    app_id: str
    host_id: str
    run_fn: Optional[Callable[[Any], Any]] = None   # real execution
    cost_fn: Optional[Callable[[Any, float], float]] = None  # sim: (payload, speed)->s
    app_bytes: int = 4096
    parts: List[Part] = field(default_factory=list)
    m_min: int = 1
    m_max: int = 1

    def pending_parts(self, leased: Dict[int, list]) -> List[Part]:
        out = []
        for part in self.parts:
            if part.done:
                continue
            active = len(leased.get(part.part_id, []))
            needed = self.m_min - len(part.results) - active
            if needed > 0:
                out.append(part)
        return out

    @property
    def done(self) -> bool:
        return all(p.done for p in self.parts)

    @property
    def total_data_bytes(self) -> int:
        return sum(p.data_bytes for p in self.parts)


@dataclass
class Lease:
    part_id: int
    volunteer_id: str
    issued_at: float
    deadline: float


class LeaseTable:
    """TAIL's bookkeeping: part -> outstanding leases, with timeouts."""

    def __init__(self, timeout_s: float):
        self.timeout_s = timeout_s
        self.by_part: Dict[int, List[Lease]] = {}

    def grant(self, part_id: int, volunteer_id: str, now: float) -> Lease:
        lease = Lease(part_id, volunteer_id, now, now + self.timeout_s)
        self.by_part.setdefault(part_id, []).append(lease)
        return lease

    def release(self, part_id: int, volunteer_id: str) -> bool:
        ls = self.by_part.get(part_id, [])
        for i, l in enumerate(ls):
            if l.volunteer_id == volunteer_id:
                ls.pop(i)
                return True
        return False

    def expired(self, now: float) -> List[Lease]:
        out = []
        for ls in self.by_part.values():
            out.extend(l for l in ls if l.deadline <= now)
        return out

    def drop_volunteer(self, volunteer_id: str) -> List[int]:
        """Drop all leases of a volunteer; returns affected part ids."""
        parts = []
        for pid, ls in self.by_part.items():
            n0 = len(ls)
            ls[:] = [l for l in ls if l.volunteer_id != volunteer_id]
            if len(ls) != n0:
                parts.append(pid)
        return parts

    def active(self) -> Dict[int, list]:
        return {pid: ls for pid, ls in self.by_part.items() if ls}


def make_prime_app(app_id: str, host_id: str, lo: int, hi: int,
                   n_parts: int, *, app_bytes: int = 4096,
                   part_data_bytes: int = 4096, m_min: int = 1,
                   sim_time_per_number: float = 2.5e-3) -> Application:
    """The paper's test application: prime search by exhaustion."""
    bounds = []
    step = (hi - lo) / n_parts
    for i in range(n_parts):
        a = int(lo + i * step)
        b = int(lo + (i + 1) * step) if i < n_parts - 1 else hi
        bounds.append((a, b))

    def run_fn(payload):
        a, b = payload
        return find_primes(a, b)

    def cost_fn(payload, speed):
        a, b = payload
        return (b - a) * sim_time_per_number / speed

    parts = [Part(i, bounds[i], data_bytes=part_data_bytes)
             for i in range(n_parts)]
    return Application(app_id, host_id, run_fn=run_fn, cost_fn=cost_fn,
                       app_bytes=app_bytes, parts=parts, m_min=m_min,
                       m_max=max(m_min, 1))


def find_primes(lo: int, hi: int) -> list:
    """Exhaustion method, as in the paper's test application."""
    out = []
    for n in range(max(lo, 2), hi):
        if n % 2 == 0:
            if n == 2:
                out.append(n)
            continue
        i = 3
        prime = True
        while i * i <= n:
            if n % i == 0:
                prime = False
                break
            i += 2
        if prime:
            out.append(n)
    return out
