"""ChaosScenario: seeded fault-injection runs of the live swarm protocol.

One class builds the standard chaos experiment — tracker + origin host +
N volunteers leeching a swarm application over a SimRuntime with a
`FaultPlan` (core.faults): lossy links, duplicated/reordered messages,
timed partitions and volunteer crash/restart churn.  Crashed volunteers
restart as *fresh incarnations* (restart factories), so volatile state
dies with them and only an on-disk piece cache (when `root_dir` is set)
survives into the PR 3 rescan path.

`check_invariants()` asserts the convergence properties every fault trace
must preserve:

  * the application completes and every surviving volunteer converges to
    the byte-identical image (manifest-hash identity for synthetic ones);
  * no part is ever decided by a quorum larger than m_min + 1;
  * the incremental availability bookkeeping equals a naive recompute
    from the stored peer masks at every surviving node.

Used by tests/test_chaos.py (20-seed suite + hypothesis property test)
and benchmarks/paper_tables.scenario_viii.  A failing seed reproduces
with:  PYTHONPATH=src python -m repro.core.chaos --seed N --check
"""
from __future__ import annotations

import random
from typing import Dict, List, Optional

from repro.core.agent import Agent, AgentConfig
from repro.core.faults import Crash, FaultPlan, LinkFault, Partition
from repro.core.runtime import LinkModel, SimRuntime
from repro.core.topology import Topology
from repro.core.tracker_server import TrackerConfig, TrackerServer
from repro.core.workunit import make_prime_app


def make_chaos_plan(seed: int, volunteers: List[str], *,
                    horizon_s: float,
                    loss: float = 0.10, dup: float = 0.02,
                    jitter_s: float = 0.2, churn: float = 0.25,
                    n_partitions: int = 1,
                    partition_s: float = 20.0,
                    partition_groups: Optional[List[frozenset]] = None
                    ) -> FaultPlan:
    """Derive a FaultPlan from a seed and a few knobs.  All randomness
    comes from `random.Random(seed)`, so (seed, knobs) pins the plan:
    `churn` of the volunteers crash inside the first ~45% of `horizon_s`
    and restart after an outage of up to 20% of it; each partition
    isolates a random island of volunteers for `partition_s`.  When
    `partition_groups` is given (e.g. the node sets of a Topology's
    islands), every partition isolates one of those groups instead — the
    worst case for cost-biased selection, since a partitioned ISP island
    is exactly the peer set P4P steers its members toward."""
    rng = random.Random(seed)
    crashes = []
    n_crash = int(round(churn * len(volunteers)))
    for node in rng.sample(volunteers, n_crash):
        # churn concentrated in the distribution phase: crashes land in
        # the first ~45% of the horizon with outages up to 20% of it, so
        # every restart still fights the swarm while it is moving pieces
        at = rng.uniform(0.05, 0.45) * horizon_s
        outage = rng.uniform(0.05, 0.20) * horizon_s
        crashes.append(Crash(node, at, at + outage))
    partitions = []
    for _ in range(n_partitions):
        start = rng.uniform(0.1, 0.5) * horizon_s
        if partition_groups:
            island = frozenset(rng.choice(partition_groups))
        else:
            k = rng.randint(1, max(1, len(volunteers) // 4))
            island = frozenset(rng.sample(volunteers, k))
        partitions.append(Partition(start, start + partition_s, (island,)))
    return FaultPlan(seed=seed,
                     link=LinkFault(drop_p=loss, dup_p=dup,
                                    jitter_s=jitter_s),
                     partitions=partitions, crashes=crashes)


def _chaos_image(nbytes: int) -> bytes:
    return bytes((i * 89 + 17) % 256 for i in range(nbytes))


class ChaosScenario:
    """Build, run and verify one seeded chaos experiment."""

    APP_ID = "chaos"

    def __init__(self, seed: int = 0, *,
                 n_volunteers: int = 12, n_pieces: int = 16,
                 n_parts: int = 24, m_min: int = 2,
                 image_bytes: int = 160_000, real_image: bool = True,
                 loss: float = 0.10, dup: float = 0.02,
                 jitter_s: float = 0.2, churn: float = 0.25,
                 n_partitions: int = 1, partition_s: float = 20.0,
                 horizon_s: float = 120.0, until_s: float = 4000.0,
                 uplink_mbps: float = 100.0,
                 sim_time_per_number: float = 2e-3,
                 root_dir: Optional[str] = None,
                 plan: Optional[FaultPlan] = None,
                 batched: bool = False, tick_s: float = 0.5,
                 backend: Optional[str] = None,
                 n_islands: int = 0,
                 island_partitions: bool = False,
                 wan_trunk_Bps: Optional[float] = None):
        self.seed = seed
        self.m_min = m_min
        self.until_s = until_s
        self.tick_s = tick_s
        # batched mode: all PieceExchanges share a SwarmHub and the run
        # drives SimRuntime.run_batched — the array-native path under the
        # same fault plan (piece traffic still crosses the faulty links)
        self.hub = None
        if batched:
            from repro.core.swarm_arrays import SwarmHub
            self.hub = SwarmHub(backend=backend)
        self.vol_ids = [f"V{i:02d}" for i in range(n_volunteers)]
        # topology overlay (ISSUE 7): islands + WAN latencies under the
        # same fault plan; peer selection goes P4P via the tracker's
        # COST_MAP and (batched) the hub's cost-aware kernels
        self.topology = None
        if n_islands > 0:
            self.topology = Topology.make(["host"] + self.vol_ids,
                                          n_islands, seed=seed,
                                          trunk_Bps=wan_trunk_Bps)
        groups = None
        if island_partitions and self.topology is not None:
            by_isl: Dict[int, set] = {}
            for nid in self.vol_ids:
                by_isl.setdefault(self.topology.island_of(nid),
                                  set()).add(nid)
            groups = [frozenset(g) for _, g in sorted(by_isl.items())
                      if g]
        self.plan = plan if plan is not None else make_chaos_plan(
            seed, self.vol_ids, horizon_s=horizon_s, loss=loss, dup=dup,
            jitter_s=jitter_s, churn=churn, n_partitions=n_partitions,
            partition_s=partition_s, partition_groups=groups)
        self._perma_dead = {c.node for c in self.plan.crashes
                           if c.restart_s is None}
        link_Bps = uplink_mbps * 1e6 / 8
        self.rt = SimRuntime(link=LinkModel(uplink_Bps=link_Bps,
                                            downlink_Bps=link_Bps),
                             faults=self.plan, topology=self.topology)
        if self.hub is not None:
            # authoritative liveness for the shared arrays: reset a
            # crashed node's row at crash time, not on (possibly stale)
            # PEER_GONE relays that may trail its restart
            self.rt.crash_hooks.append(self.hub.node_gone)
            if self.topology is not None:
                self.hub.set_topology(self.topology)
        self.rt.add_node(TrackerServer(
            config=TrackerConfig(ping_interval_s=2.0),
            topology=self.topology))
        self.server = self.rt.nodes["server"]
        # recovery timescales sized to the fault model: leases must expire
        # well before a lost RESULT costs a makespan-visible stall, piece
        # re-requests faster still, and gossip/re-registration in between
        self._cfg = dict(work_timeout_s=10.0, status_interval_s=1.0,
                         rechoke_interval_s=5.0, piece_timeout_s=5.0,
                         reregister_s=15.0, gossip_interval_s=5.0,
                         replicate_completed=True, root_dir=root_dir)
        self.incarnations: Dict[str, List[Agent]] = {}
        self.host = self._make_agent("host")
        self.rt.add_node(self.host)
        self.image = _chaos_image(image_bytes) if real_image else None
        self.app = make_prime_app(
            self.APP_ID, "host", 3, 1000 * n_parts, n_parts=n_parts,
            sim_time_per_number=sim_time_per_number, m_min=m_min,
            swarm=True, app_bytes=image_bytes,
            piece_bytes=max(image_bytes // n_pieces, 1), image=self.image)
        self.host.host_app(self.app)
        for i, nid in enumerate(self.vol_ids):
            self.rt.add_node(self._make_agent(nid),
                             speed=1.0 - 0.3 * i / max(n_volunteers, 1))
            # crash-restarts build a fresh incarnation: volatile state is
            # lost, only the on-disk piece cache (root_dir) survives
            self.rt.restart_factory[nid] = \
                lambda n=nid: self._make_agent(n)
        self.makespan_s: Optional[float] = None

    def _make_agent(self, node_id: str) -> Agent:
        a = Agent(node_id, config=AgentConfig(**self._cfg), hub=self.hub)
        self.incarnations.setdefault(node_id, []).append(a)
        return a

    # ------------------------------------------------------------------ #
    def volunteers(self) -> List[Agent]:
        """Currently-live volunteer incarnations."""
        return [self.rt.nodes[nid] for nid in self.vol_ids
                if nid in self.rt.nodes]

    def _converged(self) -> bool:
        if not self.app.done:
            return False
        for nid in self.vol_ids:
            if nid in self._perma_dead:
                continue
            node = self.rt.nodes.get(nid)       # None while crashed
            if node is None or self.APP_ID not in node.images:
                return False
        return True

    def run(self) -> "ChaosScenario":
        if self.hub is not None:
            self.rt.run_batched(until=self.until_s,
                                stop_when=self._converged,
                                tick_s=self.tick_s, on_tick=self.hub.tick)
        else:
            self.rt.run(until=self.until_s, stop_when=self._converged)
        self.makespan_s = self.rt.now()
        return self

    # ------------------------------------------------------------------ #
    def _fail(self, what: str) -> str:
        return (f"[chaos seed={self.seed}] {what} — repro: "
                f"PYTHONPATH=src python -m repro.core.chaos "
                f"--seed {self.seed} --check")

    def check_invariants(self) -> None:
        """Assert the convergence/quorum/availability invariants; failure
        messages carry the seed for a one-line repro."""
        assert self.app.done, self._fail("application never completed")
        survivors = self.volunteers()
        manifest_hash = self.app.manifest.manifest_hash
        for a in survivors:
            assert self.APP_ID in a.images, \
                self._fail(f"{a.node_id} never replicated the image")
            assert a.images[self.APP_ID] == manifest_hash, \
                self._fail(f"{a.node_id} holds a different image")
            if self.image is not None:
                got = a.px.assembled_image(self.APP_ID)
                assert got == self.image, \
                    self._fail(f"{a.node_id} image not byte-identical")
        # no part was ever decided by more than m_min + 1 voters, at any
        # seeder incarnation that existed during the run
        for incs in self.incarnations.values():
            for a in incs:
                for (app_id, part_id), q in a.quorum_sizes.items():
                    assert q <= self.m_min + 1, self._fail(
                        f"{a.node_id} part {part_id} quorum {q} "
                        f"> m_min+1={self.m_min + 1}")
        # incremental availability equals the naive recompute after the
        # fault trace (the PR 3 fast path must not drift under chaos)
        for a in survivors + [self.host]:
            for app_id in list(a.px._counts):
                arr = a.px.avail_array(app_id)
                naive = a.px._avail_naive(app_id)
                for p in range(len(arr)):
                    assert int(arr[p]) == naive[p], self._fail(
                        f"{a.node_id} availability drift at piece {p}: "
                        f"incremental {int(arr[p])} != naive {naive[p]}")
        # batched mode: the shared arrays must agree with themselves and
        # with every live engine's verified inventory after the trace
        if self.hub is not None:
            for st in self.hub.states.values():
                n = st.n
                col_sums = st.have[:n].sum(axis=0, dtype=int)
                for p in range(st.P):
                    assert int(st.counts[p]) == int(col_sums[p]), \
                        self._fail(f"hub count drift at piece {p}: "
                                   f"{int(st.counts[p])} != "
                                   f"{int(col_sums[p])}")
                for a in survivors:
                    i = st.row.get(a.node_id)
                    if i is None or st.clients[i] is not a.px:
                        continue
                    inv = a.px.inventories.get(st.app_id)
                    if inv is None:
                        continue
                    row_have = {p for p in range(st.P) if st.have[i, p]}
                    assert row_have == set(inv.have), self._fail(
                        f"hub row for {a.node_id} disagrees with its "
                        f"inventory")
                # the in-flight array ledger (ISSUE 10) must mirror every
                # live engine's scalar pending dicts entry for entry after
                # the fault trace; dead/detached rows must be fully swept
                for name, i in st.row.items():
                    px_i = st.clients[i]
                    if px_i is None or not st.alive[i]:
                        assert int(st.pend_n[i]) == 0 \
                            and int(st.busy_n[i]) == 0, self._fail(
                                f"ledger not swept for dead row {name}")
                        continue
                    pending = px_i.pending.get(st.app_id, {})
                    assert int(st.pend_n[i]) == len(pending), self._fail(
                        f"ledger piece count drift for {name}")
                    for p, asked in pending.items():
                        cnt = int(st.pend_cnt[i, p])
                        assert cnt == len(asked), self._fail(
                            f"ledger slot count drift {name} piece {p}")
                        named = {}
                        for s in range(cnt):
                            j = int(st.pend_holder[i, p, s])
                            if j >= 0:
                                named[st.names[j]] = float(st.pend_t[i, p,
                                                                     s])
                        want = {h: float(t) for h, t in asked.items()
                                if h in st.row}
                        assert named == want, self._fail(
                            f"ledger holder drift {name} piece {p}")
        # version discipline: no engine ever accepted a stale piece
        for a in survivors + [self.host]:
            assert a.px.stale_accepts == 0, self._fail(
                f"{a.node_id} accepted {a.px.stale_accepts} stale pieces")

    def report(self) -> dict:
        rt = self.rt
        if self.hub is not None:
            hub_stats = self.hub.stats()
        else:
            hub_stats = {}
        return {
            "seed": self.seed,
            **hub_stats,
            "done": self.app.done,
            "replicated": self._converged(),
            "makespan_s": self.makespan_s if self.makespan_s is not None
            else rt.now(),
            "replicas": sum(1 for a in self.volunteers()
                            if self.APP_ID in a.images),
            "origin_up_mb": rt.tx_bytes.get("host", 0) / 1e6,
            "cross_isp_bytes": rt.cross_isp_bytes,
            "dropped_msgs": rt.dropped_msgs,
            "dup_msgs": rt.dup_msgs,
            "crashes": rt.crash_count,
            "restarts": rt.restart_count,
            "events": rt.events_processed,
        }


def main(argv=None) -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--volunteers", type=int, default=12)
    ap.add_argument("--loss", type=float, default=0.10)
    ap.add_argument("--jitter", type=float, default=0.2)
    ap.add_argument("--churn", type=float, default=0.25)
    ap.add_argument("--partitions", type=int, default=1)
    ap.add_argument("--check", action="store_true",
                    help="assert the chaos invariants after the run")
    ap.add_argument("--batched", action="store_true",
                    help="run the array-native batched swarm path")
    ap.add_argument("--islands", type=int, default=0,
                    help="WAN islands (0 = flat); partitions align with "
                         "island boundaries when set")
    args = ap.parse_args(argv)
    sc = ChaosScenario(seed=args.seed, n_volunteers=args.volunteers,
                       loss=args.loss, jitter_s=args.jitter,
                       churn=args.churn, n_partitions=args.partitions,
                       batched=args.batched, n_islands=args.islands,
                       island_partitions=args.islands > 0)
    sc.run()
    print(sc.report())
    if args.check:
        sc.check_invariants()
        print(f"[chaos] seed={args.seed}: invariants OK")


if __name__ == "__main__":
    main()
