from repro.core.agent import Agent, AgentConfig  # noqa: F401
from repro.core.chaos import ChaosScenario, make_chaos_plan  # noqa: F401
from repro.core.faults import (Crash, FaultPlan, LinkFault,  # noqa: F401
                               Partition)
from repro.core.messages import AppInfo, Msg  # noqa: F401
from repro.core.metrics import AppMetrics, complexity_hint  # noqa: F401
from repro.core.piece_exchange import (PieceExchange,  # noqa: F401
                                       RollingRate, iter_bits)
from repro.core.runtime import (CANCELLED, LinkModel, Node,  # noqa: F401
                                SimRuntime, ThreadRuntime)
from repro.core.swarm import (plan_broadcast, naive_rounds,  # noqa: F401
                              rarest_first_order, rarest_first_order_np)
from repro.core.swarm_arrays import SwarmHub, SwarmState  # noqa: F401
from repro.core.swarm_kernels import (available_backends,  # noqa: F401
                                      choke_order, cost_orders,
                                      island_has, min_island_cost,
                                      rarest_orders, set_backend)
from repro.core.topology import Topology  # noqa: F401
from repro.core.tracker_server import TrackerConfig, TrackerServer  # noqa: F401
from repro.core.validation import VotingPool, majority_vote  # noqa: F401
from repro.core.workunit import (Application, LeaseTable, Part,  # noqa: F401
                                 PieceInventory, PieceManifest,
                                 find_primes, make_prime_app, mask_of,
                                 mask_nbytes, pieces_of,
                                 register_executable, resolve_executable)
