"""Tracking server (paper §III.C-E, Fig. 2).

Three modules:
  * connection module  — procedures PING, PUSH, RECV
  * tracker module     — procedures VAL, INIT, INFO
  * synchronizer       — procedures WRITE, READ

The server holds ONLY the applications list (AppInfo rows) and the member
set; application payloads never transit it — that is the point of the
paper's torrent-like design, and why the same server scales as the
framework's multi-pod job coordinator (cluster/coordinator.py).

Liveness (§III.D): a host's rows survive only while the host keeps updating
within `t` seconds, for at most `f` missed checks; after that the rows are
dropped and a DROP_APP notice fans out so leechers STOP dependent work.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set

from repro.core.messages import (APP_LIST, BYE, DROP_APP, PING, PONG,
                                 REGISTER, STATUS, AppInfo, Msg)
from repro.core.runtime import Node, Runtime


@dataclass
class TrackerConfig:
    ping_interval_s: float = 2.0        # t
    max_missed: int = 3                 # f
    push_interval_s: float = 1.0        # INIT's refresh timer
    blocked: tuple = ()                 # RECV blocklist parameter


class TrackerServer(Node):
    def __init__(self, node_id: str = "server",
                 config: Optional[TrackerConfig] = None,
                 val_hook: Optional[Callable[[str, Msg], bool]] = None):
        self.node_id = node_id
        self.cfg = config or TrackerConfig()
        self.val_hook = val_hook            # VAL customisation point (§III.G)
        # synchronizer state
        self.app_list: Dict[str, AppInfo] = {}
        self.members: Set[str] = set()
        self.missed: Dict[str, int] = {}
        self.blocklist: Set[str] = set(self.cfg.blocked)
        self._init_cache: List[AppInfo] = []
        self._init_cache_at: float = -1e9
        self.log: List[tuple] = []

    # ------------------------------------------------------------------ #
    def start(self, rt: Runtime) -> None:
        super().start(rt)
        rt.set_timer(self.node_id, "ping", self.cfg.ping_interval_s,
                     periodic=True)

    # ======================= connection module ========================= #
    def PING(self) -> None:
        """Availability check with (t, f) semantics (§III.D, §III.G)."""
        now = self.rt.now()
        for member in list(self.members):
            self.missed[member] = self.missed.get(member, 0) + 1
            self.rt.send(member, Msg(PING, self.node_id,
                                     {"at": now}, size_bytes=64))
            if self.missed[member] > self.cfg.max_missed:
                self.VAL(member, None, alive=False)

    def PUSH(self, dst: Optional[str] = None) -> None:
        """Send the applications list to one volunteer (or broadcast)."""
        rows = self.READ()
        targets = [dst] if dst else list(self.members)
        for t in targets:
            self.rt.send(t, Msg(APP_LIST, self.node_id,
                                {"apps": rows},
                                size_bytes=256 + 64 * len(rows)))

    def RECV(self, msg: Msg) -> None:
        """Collect volunteer messages; honours the blocklist parameter."""
        if msg.src in self.blocklist:
            return
        self.log.append((self.rt.now(), msg.kind, msg.src))
        if msg.kind == PONG:
            self.missed[msg.src] = 0
        elif msg.kind == REGISTER:
            self.members.add(msg.src)
            self.missed[msg.src] = 0
            self.VAL(msg.src, msg, alive=True)
            self.INIT(msg.src)
        elif msg.kind == STATUS:
            self.VAL(msg.src, msg, alive=True)
        elif msg.kind == BYE:
            self.VAL(msg.src, msg, alive=False)

    # ========================= tracker module ========================== #
    def VAL(self, member: str, msg: Optional[Msg], alive: bool) -> None:
        """Validate host availability/updates; calls INFO on changes.

        Can be customised with `val_hook` (e.g. blacklist low-availability
        clients, §III.G)."""
        if self.val_hook is not None and msg is not None:
            if not self.val_hook(member, msg):
                self.blocklist.add(member)
                alive = False
        if not alive:
            self.INFO("drop_host", member)
            return
        self.missed[member] = 0
        if msg is not None and msg.kind in (REGISTER, STATUS):
            for row in msg.payload.get("apps", []):
                self.INFO("upsert", row)

    def INIT(self, member: str) -> None:
        """Push an initial applications list to a new volunteer.  Keeps a
        periodically refreshed cache (§III.G)."""
        now = self.rt.now()
        if now - self._init_cache_at > self.cfg.push_interval_s:
            self._init_cache = self.READ()
            self._init_cache_at = now
        self.rt.send(member, Msg(APP_LIST, self.node_id,
                                 {"apps": list(self._init_cache)},
                                 size_bytes=256 + 64 * len(self._init_cache)))

    def INFO(self, change: str, data) -> None:
        """Forward availability/update changes to the synchronizer."""
        if change == "upsert":
            self.WRITE(data)
        elif change == "drop_host":
            dropped = [a for a in self.app_list.values()
                       if a.host_id == data]
            self.members.discard(data)
            for row in dropped:
                del self.app_list[row.app_id]
            if dropped:
                note = Msg(DROP_APP, self.node_id,
                           {"app_ids": [r.app_id for r in dropped]},
                           size_bytes=128)
                for m in self.members:
                    self.rt.send(m, note)

    # ======================= synchronizer module ======================= #
    def WRITE(self, row: AppInfo) -> None:
        row.updated_at = self.rt.now()
        self.app_list[row.app_id] = row

    def READ(self) -> List[AppInfo]:
        return list(self.app_list.values())

    # ------------------------------------------------------------------ #
    def on_message(self, msg: Msg) -> None:
        self.RECV(msg)

    def on_timer(self, name: str) -> None:
        if name == "ping":
            self.PING()
            self.PUSH()
