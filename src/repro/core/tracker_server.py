"""Tracking server (paper §III.C-E, Fig. 2; §V swarm extension).

Three modules:
  * connection module  — procedures PING, PUSH, RECV
  * tracker module     — procedures VAL, INIT, INFO
  * synchronizer       — procedures WRITE, READ

The server holds ONLY the applications list (AppInfo rows) and the member
set; application payloads never transit it — that is the point of the
paper's torrent-like design, and why the same server scales as the
framework's multi-pod job coordinator (cluster/coordinator.py).

Liveness (§III.D): a host's rows survive only while the host keeps updating
within `t` seconds, for at most `f` missed checks; after that the rows are
dropped and a DROP_APP notice fans out so leechers STOP dependent work.

The §V extension makes the server a real torrent tracker: each row carries
the full *seeder set* (every volunteer holding a validated copy of the app
image), ordered least-loaded-first from STATUS-reported lease counts so new
leechers are routed to the least-loaded seeder.  When a host dies but
replica seeders remain, the row is not dropped — the least-loaded live
replica is promoted to host and the application survives.  Volunteer exits
(BYE or missed pings) additionally fan out PEER_GONE so seeders reclaim the
leaver's leases immediately instead of waiting for TAIL timeouts.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set

from repro.core.messages import (APP_LIST, BYE, COST_MAP, DROP_APP, HAVE,
                                 MANIFEST_UPDATE, PEER_GONE, PING, PONG,
                                 REGISTER, SEEDER_UPDATE, STATUS, AppInfo,
                                 Msg)
from repro.core.runtime import Node, Runtime
from repro.core.workunit import mask_nbytes


@dataclass
class TrackerConfig:
    ping_interval_s: float = 2.0        # t
    max_missed: int = 3                 # f
    push_interval_s: float = 1.0        # INIT's refresh timer
    blocked: tuple = ()                 # RECV blocklist parameter


class TrackerServer(Node):
    def __init__(self, node_id: str = "server",
                 config: Optional[TrackerConfig] = None,
                 val_hook: Optional[Callable[[str, Msg], bool]] = None,
                 topology=None):
        self.node_id = node_id
        self.cfg = config or TrackerConfig()
        self.val_hook = val_hook            # VAL customisation point (§III.G)
        # ALTO server role (P4P): when a core.topology.Topology is set,
        # every REGISTER is answered with a COST_MAP carrying the
        # registrant's island, its endpoint-cost row, and the node ->
        # island directory that peer selection ranks holders with
        self.topology = topology
        # synchronizer state
        self.app_list: Dict[str, AppInfo] = {}
        self.members: Set[str] = set()
        self.missed: Dict[str, int] = {}
        self.blocklist: Set[str] = set(self.cfg.blocked)
        self._init_cache: List[AppInfo] = []
        self._init_cache_at: float = -1e9
        self.log: List[tuple] = []
        # per-member boot nonce from REGISTER: a changed nonce means a
        # fresh process incarnation whose stale seeder claims must drop
        self.boot: Dict[str, float] = {}
        # per-app seeder load (active lease counts) from STATUS reports
        self.seeder_load: Dict[str, Dict[str, int]] = {}
        # per-app swarm membership (volunteers announcing via HAVE)
        self.swarms: Dict[str, Set[str]] = {}
        # cached per-app HAVE-relay fan-out (sorted, for determinism):
        # rebuilt only when membership or the seeder set changes, instead
        # of re-deriving an O(N) target set for every announce relayed
        self._relay_cache: Dict[str, tuple] = {}
        self._last_push: float = -1e9

    # ------------------------------------------------------------------ #
    def start(self, rt: Runtime) -> None:
        super().start(rt)
        rt.set_timer(self.node_id, "ping", self.cfg.ping_interval_s,
                     periodic=True)

    # ======================= connection module ========================= #
    def PING(self) -> None:
        """Availability check with (t, f) semantics (§III.D, §III.G)."""
        now = self.rt.now()
        for member in list(self.members):
            self.missed[member] = self.missed.get(member, 0) + 1
            self.rt.send(member, Msg(PING, self.node_id,
                                     {"at": now}, size_bytes=64))
            if self.missed[member] > self.cfg.max_missed:
                self.VAL(member, None, alive=False)

    def PUSH(self, dst: Optional[str] = None) -> None:
        """Send the applications list to one volunteer (or broadcast)."""
        rows = self.READ()
        if dst is None:
            self._last_push = self.rt.now()
        targets = [dst] if dst else list(self.members)
        for t in targets:
            self.rt.send(t, Msg(APP_LIST, self.node_id,
                                {"apps": rows},
                                size_bytes=256 + 64 * len(rows)))

    def RECV(self, msg: Msg) -> None:
        """Collect volunteer messages; honours the blocklist parameter."""
        if msg.src in self.blocklist:
            return
        self.log.append((self.rt.now(), msg.kind, msg.src))
        if msg.kind == PONG:
            self.missed[msg.src] = 0
        elif msg.kind == REGISTER:
            self.members.add(msg.src)
            self.missed[msg.src] = 0
            boot = msg.payload.get("boot")
            if boot is not None:
                prev = self.boot.get(msg.src)
                self.boot[msg.src] = boot
                if prev is not None and boot != prev:
                    # a NEW incarnation of a known node id: it crashed and
                    # restarted inside the liveness window, so its old
                    # seeder entries are claims about an image it no
                    # longer holds — drop them; a live replica re-earns
                    # its place via SEEDER_UPDATE once it re-verifies
                    self._drop_stale_seeder(msg.src)
            self.VAL(msg.src, msg, alive=True)
            self.INIT(msg.src)
            if self.topology is not None:
                isl = self.topology.island_of(msg.src)
                self.rt.send(msg.src, Msg(
                    COST_MAP, self.node_id,
                    {"island": isl,
                     "costs": self.topology.cost_row(isl),
                     "islands": dict(self.topology.islands)},
                    size_bytes=64 + 4 * len(self.topology.islands)))
        elif msg.kind == STATUS:
            # a STATUS from a volunteer we dropped (e.g. a ping false
            # positive under congestion) re-admits it
            self.members.add(msg.src)
            self.VAL(msg.src, msg, alive=True)
            for app_id, n in msg.payload.get("loads", {}).items():
                self.seeder_load.setdefault(app_id, {})[msg.src] = n
        elif msg.kind == SEEDER_UPDATE:
            self._on_seeder_update(msg)
        elif msg.kind == MANIFEST_UPDATE:
            self._on_manifest_update(msg)
        elif msg.kind == HAVE:
            self._on_have(msg)
        elif msg.kind == BYE:
            self.VAL(msg.src, msg, alive=False)

    # ========================= tracker module ========================== #
    def VAL(self, member: str, msg: Optional[Msg], alive: bool) -> None:
        """Validate host availability/updates; calls INFO on changes.

        Can be customised with `val_hook` (e.g. blacklist low-availability
        clients, §III.G)."""
        if self.val_hook is not None and msg is not None:
            if not self.val_hook(member, msg):
                self.blocklist.add(member)
                alive = False
        if not alive:
            self.INFO("drop_host", member)
            return
        self.missed[member] = 0
        if msg is not None and msg.kind in (REGISTER, STATUS):
            for row in msg.payload.get("apps", []):
                self.INFO("upsert", row)

    def INIT(self, member: str) -> None:
        """Push an initial applications list to a new volunteer.  Keeps a
        periodically refreshed cache (§III.G)."""
        now = self.rt.now()
        if now - self._init_cache_at > self.cfg.push_interval_s:
            self._init_cache = self.READ()
            self._init_cache_at = now
        self.rt.send(member, Msg(APP_LIST, self.node_id,
                                 {"apps": list(self._init_cache)},
                                 size_bytes=256 + 64 * len(self._init_cache)))

    def _on_have(self, msg: Msg) -> None:
        """Swarm announce: volunteers report verified pieces as a compact
        bitmask (or join with an empty one); the tracker relays so peers
        discover each other — its classic BitTorrent announce role."""
        app_id = msg.payload["app_id"]
        mask = msg.payload.get("mask", 0)
        swarm = self.swarms.setdefault(app_id, set())
        if msg.src not in swarm:
            swarm.add(msg.src)
            self._relay_cache.pop(app_id, None)
        targets = self._relay_cache.get(app_id)
        if targets is None:
            t = set(swarm)
            row = self.app_list.get(app_id)
            if row is not None:
                t |= set(row.seeders) | {row.host_id}
            t.discard(self.node_id)
            targets = self._relay_cache[app_id] = tuple(sorted(t))
        relay = Msg(HAVE, self.node_id,
                    {"app_id": app_id, "mask": mask, "peer": msg.src},
                    size_bytes=96 + mask_nbytes(mask))
        for t in targets:
            if t != msg.src:
                self.rt.send(t, relay)

    def _on_seeder_update(self, msg: Msg) -> None:
        """A volunteer finished (and verified) an app image: add it to the
        seeder set and let the existing seeders sync it up."""
        app_id = msg.payload["app_id"]
        seeder = msg.payload["seeder"]
        row = self.app_list.get(app_id)
        if row is None or seeder in self.blocklist:
            return
        mh = msg.payload.get("manifest_hash")
        if (mh is not None and row.manifest is not None
                and mh != row.manifest.manifest_hash):
            # the announce proves completion of a SUPERSEDED revision
            # (e.g. it raced a MANIFEST_UPDATE): admitting it would route
            # leechers to a node serving stale pieces as fresh
            return
        if seeder not in self.members:
            # a SEEDER_UPDATE from a node we already declared dead (e.g.
            # one that completed the image just before crashing, its
            # announce surviving in flight) must not enter the seeder set:
            # promoting a corpse to host would strand the app.  A live
            # sender re-announces after its next APP_LIST.
            return
        if seeder not in row.seeders:
            row.seeders = tuple(row.seeders) + (seeder,)
            row.updated_at = self.rt.now()
            self._relay_cache.pop(app_id, None)
            relay = Msg(SEEDER_UPDATE, self.node_id,
                        {"app_id": app_id, "seeder": seeder}, size_bytes=96)
            for peer in set(row.seeders) | {row.host_id}:
                if peer not in (seeder, self.node_id):
                    self.rt.send(peer, relay)
            # broadcast at most once per push interval: when a whole swarm
            # turns replica in a burst, one PUSH per completion is an
            # O(N²) APP_LIST storm; the periodic ping-time PUSH (and the
            # SEEDER_UPDATE relay above) still propagates the change
            if self.rt.now() - self._last_push >= self.cfg.push_interval_s:
                self.PUSH()

    def _on_manifest_update(self, msg: Msg) -> None:
        """The host published a new revision of an app image (versioned
        PieceManifest).  The seeder set is RESET to the publisher — every
        other entry describes the superseded revision — and the new
        metainfo is gossiped to the swarm immediately.  This path
        deliberately bypasses the SEEDER_UPDATE push limiter: version
        gossip that waits on `push_interval_s` leaves volunteers serving
        (and accepting) stale pieces as fresh."""
        app_id = msg.payload["app_id"]
        manifest = msg.payload.get("manifest")
        row = self.app_list.get(app_id)
        if row is None or manifest is None:
            return
        if msg.src != row.host_id:
            return                  # only the host may publish revisions
        if row.manifest is not None and not manifest.supersedes(row.manifest):
            return
        targets = set(self.swarms.get(app_id, ())) | set(row.seeders)
        targets.discard(msg.src)
        targets.discard(self.node_id)
        row.manifest = manifest
        row.seeders = (row.host_id,)
        row.updated_at = self.rt.now()
        self._relay_cache.pop(app_id, None)
        relay = Msg(MANIFEST_UPDATE, self.node_id,
                    {"app_id": app_id, "manifest": manifest},
                    size_bytes=512)
        for t in sorted(targets):
            self.rt.send(t, relay)
        # immediate broadcast, deliberately NOT gated on `_last_push`
        self.PUSH()

    def _drop_stale_seeder(self, member: str) -> None:
        """Remove `member` from every seeder set it does not host: its
        fresh incarnation lost the images backing those entries.  Rows it
        hosts are re-upserted by the REGISTER being processed."""
        for row in self.app_list.values():
            if member in row.seeders and row.host_id != member:
                row.seeders = tuple(s for s in row.seeders if s != member)
                self._relay_cache.pop(row.app_id, None)
        for swarm in self.swarms.values():
            swarm.discard(member)

    def _fail_hosts(self):
        """Re-elect a host for every row whose host is not a live member:
        promote the least-loaded live replica seeder, or mark the row for
        dropping when none is left.  Returns (dropped, promoted) rows —
        the caller sends the notifications (DROP_APP / PUSH) so message
        order stays under its control."""
        dropped, promoted = [], []
        for row in list(self.app_list.values()):
            if row.host_id in self.members:
                continue
            live = [s for s in row.seeders if s in self.members]
            if live:
                # replica failover: promote the least-loaded live
                # seeder instead of killing the application
                load = self.seeder_load.get(row.app_id, {})
                row.host_id = min(live,
                                  key=lambda s: (load.get(s, 0), s))
                row.updated_at = self.rt.now()
                promoted.append(row)
            else:
                dropped.append(row)
        for row in dropped:
            del self.app_list[row.app_id]
        return dropped, promoted

    def _reverify_rows(self) -> None:
        """Periodic re-verification (chaos hardening): prune seeders that
        are no longer live members from every row, and re-elect hosts for
        rows whose host died silently.  In a fault-free run this is a
        cheap no-op scan — the drop_host path keeps rows consistent — but
        under partitions/loss a row can go stale (e.g. a seeder announce
        that raced its sender's death), and a stale host would strand the
        app's leechers forever."""
        for row in self.app_list.values():
            live = tuple(s for s in row.seeders if s in self.members)
            if live != row.seeders:
                row.seeders = live
                self._relay_cache.pop(row.app_id, None)
        dropped, promoted = self._fail_hosts()
        if dropped:
            note = Msg(DROP_APP, self.node_id,
                       {"app_ids": [r.app_id for r in dropped]},
                       size_bytes=128)
            for m in self.members:
                self.rt.send(m, note)
        if promoted:
            self.PUSH()

    def INFO(self, change: str, data) -> None:
        """Forward availability/update changes to the synchronizer."""
        if change == "upsert":
            self.WRITE(data)
        elif change == "drop_host":
            member = data
            self.members.discard(member)
            self.missed.pop(member, None)
            self.boot.pop(member, None)
            self._relay_cache.clear()   # membership + seeder sets change
            for loads in self.seeder_load.values():
                loads.pop(member, None)
            for swarm in self.swarms.values():
                swarm.discard(member)
            for row in self.app_list.values():
                if member in row.seeders:
                    row.seeders = tuple(s for s in row.seeders
                                        if s != member)
            dropped, promoted = self._fail_hosts()
            if dropped:
                note = Msg(DROP_APP, self.node_id,
                           {"app_ids": [r.app_id for r in dropped]},
                           size_bytes=128)
                for m in self.members:
                    self.rt.send(m, note)
            # leavers' leases are reclaimed immediately at every seeder
            gone = Msg(PEER_GONE, self.node_id, {"node": member},
                       size_bytes=64)
            for m in self.members:
                self.rt.send(m, gone)
            if promoted:
                self.PUSH()

    # ======================= synchronizer module ======================= #
    def WRITE(self, row: AppInfo) -> None:
        row.updated_at = self.rt.now()
        self._relay_cache.pop(row.app_id, None)   # seeder set may change
        prev = self.app_list.get(row.app_id)
        if prev is not None:
            pv = getattr(prev.manifest, "version", None)
            rv = getattr(row.manifest, "version", None)
            if row.manifest is None or (pv is not None and rv is not None
                                        and pv > rv):
                # a stale upsert (e.g. a STATUS that raced an upgrade)
                # must never roll the metainfo back to a superseded
                # revision
                row.manifest = prev.manifest
                rv = pv
            if pv is not None and rv is not None and rv > pv:
                # the host republished via a plain upsert: every previous
                # seeder holds the superseded revision — reset the set
                row.seeders = (row.host_id,)
            else:
                # the seeder set is tracker-owned state: merge, don't
                # clobber
                merged = set(prev.seeders) | set(row.seeders) | {row.host_id}
                row.seeders = tuple(s for s in sorted(merged)
                                    if s == row.host_id or s in self.members)
        elif row.host_id not in row.seeders:
            row.seeders = tuple(row.seeders) + (row.host_id,)
        self.app_list[row.app_id] = row

    def READ(self) -> List[AppInfo]:
        rows = list(self.app_list.values())
        for row in rows:
            load = self.seeder_load.get(row.app_id, {})
            row.seeders = tuple(sorted(
                row.seeders, key=lambda s: (load.get(s, 0), s)))
        return rows

    # ------------------------------------------------------------------ #
    def on_message(self, msg: Msg) -> None:
        self.RECV(msg)

    def on_timer(self, name: str) -> None:
        if name == "ping":
            self.PING()
            self._reverify_rows()
            self.PUSH()
