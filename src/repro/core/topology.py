"""WAN topology model: ISP/cluster islands over the flat LinkModel.

`LinkModel` (core/runtime.py) gives every node an up/downlink, but the
world it connects is flat — no RTTs, no ISP boundaries.  At the scale the
ROADMAP targets ("millions of users") the economics that dominate are
exactly the ones a flat model cannot see: cross-ISP egress cost and WAN
tail latency (Anderson 2018, PAPERS.md).  `Topology` adds the missing
layer:

  * every node belongs to one **island** (an ISP / cluster / region);
  * an **inter-island latency matrix** adds one-way propagation delay to
    every message whose endpoints sit on different islands;
  * an optional **inter-island bandwidth matrix** models the bottleneck
    trunk between two islands: bulk transfers crossing it serialise
    through a shared per-(src-island, dst-island) pipe, exactly like the
    per-node uplink/downlink pipes — concurrent cross-ISP transfers
    queue behind each other while intra-island traffic flows free;
  * a derived **ALTO-style cost map** (`cost_map()` / `cost_row()`):
    small integers, 0 intra-island, scaled with latency across islands —
    what the tracker serves to agents (`COST_MAP`) and the batched
    kernels fold into piece/holder selection (P4P mode, SNIPPETS.md §2).

Flat identity (the invariant tests/test_topology.py pins): with
`topology=None` — or a single-island topology whose intra latency is
zero — `SimRuntime` produces an event-for-event identical trace to a
runtime with no topology at all.  No RNG is drawn, no extra events are
scheduled, and a zero extra latency is never added, mirroring how a
zero-fault `FaultPlan` is provably free.
"""
from __future__ import annotations

import random
from typing import Dict, List, Optional, Sequence

# ALTO cost ceiling: costs are small ints so selection kernels can fold
# them into composite sort keys without overflow headroom games
COST_CAP = 15


class Topology:
    """Island assignment + inter-island latency/bandwidth matrices."""

    def __init__(self, islands: Dict[str, int], n_islands: int,
                 latency_s: Sequence[Sequence[float]],
                 bandwidth_Bps: Optional[Sequence[Sequence[
                     Optional[float]]]] = None,
                 cost: Optional[Sequence[Sequence[int]]] = None):
        self.n_islands = max(int(n_islands), 1)
        self.islands = dict(islands)
        self.latency_s = [list(row) for row in latency_s]
        self.bandwidth_Bps = ([list(row) for row in bandwidth_Bps]
                              if bandwidth_Bps is not None else None)
        self._cost = ([list(row) for row in cost]
                      if cost is not None else self._derive_cost())

    # ------------------------------ queries ----------------------------- #
    def island_of(self, node_id: str) -> int:
        """Island index for a node; unmapped nodes live on island 0 (the
        tracker, late joiners a scenario never assigned)."""
        return self.islands.get(node_id, 0)

    def latency(self, si: int, di: int) -> float:
        return self.latency_s[si][di]

    def trunk_Bps(self, si: int, di: int) -> Optional[float]:
        if self.bandwidth_Bps is None:
            return None
        return self.bandwidth_Bps[si][di]

    def _derive_cost(self) -> List[List[int]]:
        """ALTO costs from the latency matrix: 0 intra-island, else a
        small integer growing with one-way latency (10ms per step),
        clamped to COST_CAP.  Cross-island is never cheaper than 1."""
        k = self.n_islands
        cost = [[0] * k for _ in range(k)]
        for i in range(k):
            for j in range(k):
                if i == j:
                    continue
                cost[i][j] = max(1, min(COST_CAP,
                                        1 + int(self.latency_s[i][j] / 0.01)))
        return cost

    def cost_map(self) -> List[List[int]]:
        """The full K x K ALTO cost matrix (row = source island)."""
        return [list(row) for row in self._cost]

    def cost_row(self, island: int) -> List[int]:
        """Endpoint costs from one island to every island — what an agent
        on that island receives in its COST_MAP message."""
        return list(self._cost[island])

    def cost(self, src: str, dst: str) -> int:
        return self._cost[self.island_of(src)][self.island_of(dst)]

    # ----------------------------- factories ---------------------------- #
    @classmethod
    def flat(cls, node_ids: Sequence[str] = ()) -> "Topology":
        """Single island, zero extra latency: provably inert (the flat
        trace-identity differential test runs against this)."""
        return cls({n: 0 for n in node_ids}, 1, [[0.0]])

    @classmethod
    def make(cls, node_ids: Sequence[str], n_islands: int, *,
             seed: int = 0,
             wan_latency_s: tuple = (0.02, 0.08),
             trunk_Bps: Optional[float] = None) -> "Topology":
        """Seeded heterogeneous WAN: nodes assigned round-robin to
        `n_islands` islands, symmetric inter-island latencies drawn from
        U(wan_latency_s) by `random.Random(seed)`, intra-island extra
        latency zero (the LinkModel base latency covers the LAN), and an
        optional uniform trunk bandwidth per island pair."""
        k = max(int(n_islands), 1)
        rng = random.Random(seed)
        lat = [[0.0] * k for _ in range(k)]
        lo, hi = wan_latency_s
        for i in range(k):
            for j in range(i + 1, k):
                d = rng.uniform(lo, hi)
                lat[i][j] = lat[j][i] = d
        bw = None
        if trunk_Bps is not None:
            bw = [[None if i == j else float(trunk_Bps)
                   for j in range(k)] for i in range(k)]
        islands = {n: i % k for i, n in enumerate(node_ids)}
        return cls(islands, k, lat, bandwidth_Bps=bw)
