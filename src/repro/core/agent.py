"""Volunteer agent (paper §III.E-G, Figs. 3-5; §V swarm extension).

Modules: connector (RECV, SEND), tracker (EVAL, DIST, STAT, VAL, TAIL) and
worker (REQ, SCAN, RUN, TIME, COLLECT, SAVE, LOAD, STOP) — the paper's 15
agent procedures.  Every agent is simultaneously:

  * a SEEDER for its own applications (A_self): answers REQ with app+data,
    validates RESULTs by m_min-way majority voting, reports status via STAT;
  * a LEECHER for other hosts' applications: REQ -> SCAN+RUN -> TIME ->
    COLLECT+LOAD -> SEND result, in a loop until the host runs dry.

The §V extension ("broken to pieces like regular file sharing in torrent")
adds a third role when an application is published with `swarm=True`:

  * a PIECE PEER: the app image moves as hashed pieces (PIECE_REQ /
    PIECE_DATA), chosen rarest-first from HAVE announcements — the same
    policy core/swarm.py's offline planner uses.  Verified pieces are
    announced (HAVE) and served to other leechers while crunching.  Once the
    image completes, the agent resolves the executable from the registry
    keyed by the manifest hash (no back-door into the runtime's node table)
    and becomes a REPLICA SEEDER: it answers REQ/DIST and VALidates results
    for the app, keeps in sync with the other seeders via PART_DONE gossip,
    and can be promoted to host by the tracker if the origin dies.

The dual Seed/ and Leech/ working directories (Fig. 3) are managed by
core.directory; TAIL's volunteer log lives under Seed/App/<id>/Data/Tracker
and TIME's under Leech/App/<id>/Data/Time, as in the paper.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Set, Tuple

from repro.core import directory as dirs
from repro.core.messages import (APP_DATA, APP_LIST, BYE, DROP_APP, HAVE,
                                 NO_WORK, PART_DONE, PEER_GONE, PIECE_DATA,
                                 PIECE_REQ, PING, PONG, REGISTER, REQ,
                                 RESULT, RESULT_ACK, SEEDER_UPDATE, STATUS,
                                 AppInfo, Msg)
from repro.core.metrics import AppMetrics
from repro.core.runtime import Node, Runtime
from repro.core.swarm import rarest_first_order
from repro.core.validation import majority_vote
from repro.core.workunit import (Application, LeaseTable, Part,
                                 PieceInventory, PieceManifest,
                                 register_executable, resolve_executable)


@dataclass
class AgentConfig:
    work_timeout_s: float = 60.0        # TAIL timeout parameter
    status_interval_s: float = 1.0
    retry_s: float = 2.0                # back-off after NO_WORK from a host
    # per-cycle protocol/VM overhead in simulation (calibrated from the
    # paper's Scenario I: w_parallel 6.35s vs sequential-VM 5.51s)
    cycle_overhead_s: float = 0.0
    accept_from: tuple = ()             # RECV accept/deny parameter
    deny_from: tuple = ()
    max_parallel_apps: int = 2          # leech this many apps concurrently
    self_leech: bool = False            # hosts also crunch their own apps
    root_dir: Optional[str] = None      # enables on-disk Fig. 3 layout
    piece_pipeline: int = 4             # outstanding PIECE_REQs per app
    replica_seed: bool = True           # re-seed completed swarm images


class Agent(Node):
    def __init__(self, node_id: str, server_id: str = "server",
                 config: Optional[AgentConfig] = None,
                 val_hook: Optional[Callable[[int, Any], bool]] = None):
        self.node_id = node_id
        self.server_id = server_id
        self.cfg = config or AgentConfig()
        self.val_hook = val_hook
        # --- seeder state -------------------------------------------------
        self.apps: Dict[str, Application] = {}         # A_self
        self.replicas: Dict[str, Application] = {}     # re-seeded swarm apps
        self.tail = LeaseTable(self.cfg.work_timeout_s)
        self.tails: Dict[str, LeaseTable] = {}
        self.metrics: Dict[str, AppMetrics] = {}
        # --- leecher state ------------------------------------------------
        self.app_list: List[AppInfo] = []
        self.current: Dict[str, dict] = {}             # app_id -> work ctx
        self.results_log: List[tuple] = []
        self.completed_cycles: Dict[str, int] = collections.defaultdict(int)
        self.leech_time: Dict[str, float] = collections.defaultdict(float)
        self.leech_bytes: Dict[str, float] = collections.defaultdict(float)
        self.stopped_apps: Set[str] = set()
        self.dry_until: Dict[str, float] = {}
        self.completed_at: Dict[str, float] = {}
        self.no_work_from: Dict[str, Set[str]] = collections.defaultdict(set)
        # --- piece-peer state (paper §V) ----------------------------------
        self.manifests: Dict[str, PieceManifest] = {}
        self.inventories: Dict[str, PieceInventory] = {}
        self.images: Dict[str, str] = {}        # app_id -> verified manifest
        self.full_seeders: Dict[str, Set[str]] = collections.defaultdict(set)
        self.peer_pieces: Dict[str, Dict[str, Set[int]]] = \
            collections.defaultdict(dict)       # app -> partial holders
        self.swarm_peers: Dict[str, Set[str]] = collections.defaultdict(set)
        self.piece_pending: Dict[str, Dict[int, tuple]] = \
            collections.defaultdict(dict)       # app -> piece -> (peer, t)
        self.peer_load: Dict[str, int] = collections.defaultdict(int)
        self.bad_piece_peers: Dict[str, Set[str]] = \
            collections.defaultdict(set)
        self.dir = (dirs.AgentDirs(self.cfg.root_dir, node_id)
                    if self.cfg.root_dir else None)

    # ------------------------------------------------------------------ #
    def host_app(self, app: Application) -> None:
        app.host_id = self.node_id
        manifest = app.ensure_manifest()
        # publishing an app puts its executable behind the manifest hash:
        # only holders of the verified image may resolve and run it
        register_executable(manifest.manifest_hash, app.run_fn, app.cost_fn,
                            blueprint=app.blueprint())
        self.apps[app.app_id] = app
        self.manifests[app.app_id] = manifest
        self.images[app.app_id] = manifest.manifest_hash
        self.tails[app.app_id] = LeaseTable(self.cfg.work_timeout_s)
        m = AppMetrics(d_app_bytes=app.app_bytes, m_min=app.m_min)
        self.metrics[app.app_id] = m
        if self.dir:
            self.dir.seed_app(app.app_id, app.app_bytes)

    def start(self, rt: Runtime) -> None:
        super().start(rt)
        self.SEND(self.server_id, Msg(REGISTER, self.node_id,
                                      {"apps": self._self_rows()}))
        rt.set_timer(self.node_id, "status", self.cfg.status_interval_s,
                     periodic=True)
        rt.set_timer(self.node_id, "tail", self.cfg.work_timeout_s / 2,
                     periodic=True)

    def shutdown(self) -> None:
        """Graceful leave: BYE tells the server to reclaim this volunteer's
        leases immediately instead of waiting for TAIL timeouts."""
        self.SEND(self.server_id, Msg(BYE, self.node_id,
                                      {"apps": list(self.apps)},
                                      size_bytes=64))

    def _self_rows(self) -> List[AppInfo]:
        rows = []
        for app in self.apps.values():
            m = self.metrics[app.app_id]
            rows.append(AppInfo(app.app_id, self.node_id, d=m.d, p=m.p,
                                w=m.w, n_parts=len(app.parts),
                                parts_remaining=sum(
                                    0 if p.done else 1 for p in app.parts),
                                seeders=(self.node_id,),
                                manifest=(app.manifest if app.swarm
                                          else None)))
        return rows

    def _seed_loads(self) -> Dict[str, int]:
        """Active lease counts for every app this node seeds (origin or
        replica); the tracker uses them for least-loaded routing."""
        loads = {}
        for app_id in list(self.apps) + list(self.replicas):
            tail = self.tails.get(app_id)
            if tail is not None:
                loads[app_id] = sum(len(ls) for ls in tail.active().values())
        return loads

    # ========================== connector =============================== #
    def RECV(self, msg: Msg) -> None:
        """Receive messages; accept/deny lists are the paper's parameter."""
        if self.cfg.accept_from and msg.src not in self.cfg.accept_from \
                and msg.src != self.server_id:
            return
        if msg.src in self.cfg.deny_from:
            return
        kind = msg.kind
        if kind == PING:
            self.SEND(self.server_id, Msg(PONG, self.node_id, size_bytes=64))
        elif kind == APP_LIST:
            self._on_app_list(msg.payload["apps"])
        elif kind == DROP_APP:
            for app_id in msg.payload["app_ids"]:
                self.STOP(app_id, reason="host dropped from list")
        elif kind == REQ:
            self.DIST(msg.src, msg.payload["app_id"])
        elif kind == APP_DATA:
            self._on_app_data(msg)
        elif kind == NO_WORK:
            self._on_no_work(msg)
        elif kind == RESULT:
            self.VAL(msg)
        elif kind == RESULT_ACK:
            self._on_result_ack(msg)
        elif kind == HAVE:
            self._on_have(msg)
        elif kind == PIECE_REQ:
            self._on_piece_req(msg)
        elif kind == PIECE_DATA:
            self._on_piece_data(msg)
        elif kind == PART_DONE:
            self._on_part_done(msg)
        elif kind == PEER_GONE:
            self._on_peer_gone(msg.payload["node"])
        elif kind == SEEDER_UPDATE:
            self._on_seeder_update(msg)

    def SEND(self, dst: str, msg: Msg) -> None:
        self.rt.send(dst, msg)

    # =========================== tracker ================================ #
    def EVAL(self, app_id: str, valid: bool) -> None:
        """Track m_min/m_max progress for an application's validation."""
        app = self.apps.get(app_id)
        if app is None:
            return
        if valid and app.m_min < app.m_max:
            app.m_min += 1
            self.metrics[app_id].m_min = app.m_min

    def _seeded_app(self, app_id: str) -> Optional[Application]:
        return self.apps.get(app_id) or self.replicas.get(app_id)

    def _partition_pending(self, app: Application,
                           pending: List[Part]) -> List[Part]:
        """Split the part space across the current seeder set so concurrent
        seeders rarely lease the same part; fall back to the full pending
        list when this seeder's partition is drained (endgame)."""
        if not app.swarm:
            return pending
        row = self._row_for(app.app_id)
        seeders = sorted(set(row.seeders if row else ()) | {self.node_id})
        if len(seeders) <= 1:
            return pending
        idx = seeders.index(self.node_id)
        mine = [p for p in pending if p.part_id % len(seeders) == idx]
        return mine or pending

    def DIST(self, volunteer: str, app_id: str) -> None:
        """Lease the next pending part to `volunteer` and ship app+data."""
        app = self._seeded_app(app_id)
        if app is None:
            self.SEND(volunteer, Msg(NO_WORK, self.node_id,
                                     {"app_id": app_id}, size_bytes=64))
            return
        tail = self.tails[app_id]
        pending = self._partition_pending(app,
                                          app.pending_parts(tail.active()))
        if not pending:
            self.SEND(volunteer, Msg(NO_WORK, self.node_id,
                                     {"app_id": app_id}, size_bytes=64))
            return
        part = pending[0]
        tail.grant(part.part_id, volunteer, self.rt.now())
        if self.dir:
            self.dir.tracker_log(app_id,
                                 f"{self.rt.now():.3f} lease part="
                                 f"{part.part_id} to={volunteer}")
        manifest = app.manifest
        if app.swarm:
            # piece-wise mode: the image moved separately as pieces, so
            # APP_DATA carries only the part payload
            size = 96 + part.data_bytes
            app_bytes = 0
        else:
            size = app.app_bytes + part.data_bytes
            app_bytes = app.app_bytes
        self.SEND(volunteer, Msg(
            APP_DATA, self.node_id,
            {"app_id": app_id, "part_id": part.part_id,
             "payload": part.payload, "app_bytes": app_bytes,
             "data_bytes": part.data_bytes,
             "manifest_hash": (manifest.manifest_hash if manifest
                               else None)},
            size_bytes=size))

    def STAT(self) -> None:
        """Update validated-work status (incl. d, w) to the server."""
        self.SEND(self.server_id, Msg(STATUS, self.node_id,
                                      {"apps": self._self_rows(),
                                       "loads": self._seed_loads()}))

    def VAL(self, msg: Msg) -> None:
        """Validate a RESULT by majority voting once m_min results arrived."""
        app_id = msg.payload["app_id"]
        app = self._seeded_app(app_id)
        if app is None:
            return
        part_id = msg.payload["part_id"]
        part = app.parts[part_id]
        tail = self.tails[app_id]
        tail.release(part_id, msg.src)
        if self.val_hook is not None and not self.val_hook(
                part_id, msg.payload["result"]):
            # malicious result: discard; status not updated (paper §III.D)
            self.SEND(msg.src, Msg(RESULT_ACK, self.node_id,
                                   {"app_id": app_id, "part_id": part_id,
                                    "valid": False}, size_bytes=64))
            return
        part.results.append((msg.src, msg.payload["result"],
                             msg.payload.get("time_s", 0.0)))
        if len(part.results) >= app.m_min and not part.done:
            winner, ok = majority_vote([r for _, r, _ in part.results],
                                       quorum=app.m_min)
            if ok:
                part.done = True
                m = self.metrics.get(app_id)
                if m is not None:
                    m.record_cycle(
                        msg.payload.get("data_bytes", part.data_bytes),
                        msg.payload.get("time_s", 0.0),
                        app_downloaded=not app.swarm)
                self.EVAL(app_id, True)
                if self.dir:
                    self.dir.save_seed_result(app_id, part_id, winner)
                if app.swarm:
                    self._gossip_part_done(app_id, [(part_id, winner)])
                if app.done and app_id not in self.completed_at:
                    self.completed_at[app_id] = self.rt.now()
                if app_id in self.apps:
                    self.STAT()
        self.SEND(msg.src, Msg(RESULT_ACK, self.node_id,
                               {"app_id": app_id, "part_id": part_id,
                                "valid": True}, size_bytes=64))

    def TAIL(self) -> None:
        """Expire overdue leases and re-DIST (straggler mitigation)."""
        now = self.rt.now()
        for app_id, tail in self.tails.items():
            for lease in tail.expired(now):
                tail.release(lease.part_id, lease.volunteer_id)
                if self.dir:
                    self.dir.tracker_log(app_id,
                                         f"{now:.3f} timeout part="
                                         f"{lease.part_id} "
                                         f"volunteer={lease.volunteer_id}")
                # the paper drops the volunteer from the mapping list and
                # redistributes on the next REQ; nothing else to do here

    # ================== seeder-set sync (paper §V) ====================== #
    def _other_seeders(self, app_id: str) -> Set[str]:
        row = self._row_for(app_id)
        peers = set(row.seeders) | {row.host_id} if row else set()
        peers |= self.swarm_peers.get(app_id, set())
        peers.discard(self.node_id)
        return peers

    def _gossip_part_done(self, app_id: str,
                          parts: List[tuple]) -> None:
        for peer in self._other_seeders(app_id):
            self.SEND(peer, Msg(PART_DONE, self.node_id,
                                {"app_id": app_id, "parts": parts},
                                size_bytes=96 + 32 * len(parts)))

    def _on_part_done(self, msg: Msg) -> None:
        app = self._seeded_app(msg.payload["app_id"])
        if app is None:
            return
        app_id = msg.payload["app_id"]
        for part_id, winner in msg.payload["parts"]:
            part = app.parts[part_id]
            if not part.done:
                part.done = True
                part.results.append((msg.src, winner, 0.0))
        if app.done and app_id not in self.completed_at:
            self.completed_at[app_id] = self.rt.now()

    def _on_seeder_update(self, msg: Msg) -> None:
        """Relayed by the tracker: a new replica joined the seeder set —
        bring it up to date on validated parts."""
        app_id = msg.payload["app_id"]
        new_seeder = msg.payload["seeder"]
        app = self._seeded_app(app_id)
        if app is None or new_seeder == self.node_id:
            return
        self.swarm_peers[app_id].add(new_seeder)
        done = [(p.part_id, (p.results[0][1] if p.results else None))
                for p in app.parts if p.done]
        if done:
            self.SEND(new_seeder, Msg(PART_DONE, self.node_id,
                                      {"app_id": app_id, "parts": done},
                                      size_bytes=96 + 32 * len(done)))

    def _on_peer_gone(self, node: str) -> None:
        """A volunteer left (BYE) or died: reclaim its leases immediately
        instead of waiting for TAIL timeout, and forget its pieces."""
        for app_id, tail in self.tails.items():
            freed = tail.drop_volunteer(node)
            if freed and self.dir:
                self.dir.tracker_log(app_id,
                                     f"{self.rt.now():.3f} peer_gone "
                                     f"volunteer={node} parts={freed}")
        for app_id in list(self.peer_pieces):
            self.peer_pieces[app_id].pop(node, None)
        for peers in self.swarm_peers.values():
            peers.discard(node)
        for app_id in list(self.full_seeders):
            self.full_seeders[app_id].discard(node)
        self.peer_load.pop(node, None)
        # re-route any piece requests outstanding at the dead peer
        for app_id, pending in self.piece_pending.items():
            stale = [pid for pid, (peer, _) in pending.items()
                     if peer == node]
            for pid in stale:
                del pending[pid]
            if stale:
                self._pump_pieces(app_id)
        # re-route in-flight work pointed at the dead peer
        for app_id, ctx in list(self.current.items()):
            if ctx.get("host") == node and not ctx.get("busy"):
                self._request_work(app_id)

    # ==================== piece transfer (paper §V) ===================== #
    def _piece_avail(self, app_id: str) -> Dict[int, int]:
        n_full = len(self.full_seeders.get(app_id, ()))
        avail: Dict[int, int] = collections.defaultdict(lambda: 0)
        manifest = self.manifests.get(app_id)
        if manifest is not None:
            for p in range(manifest.n_pieces):
                avail[p] = n_full
        for have in self.peer_pieces.get(app_id, {}).values():
            for p in have:
                avail[p] += 1
        return avail

    def _holders_of(self, app_id: str, piece_id: int) -> List[str]:
        holders = set(self.full_seeders.get(app_id, ()))
        for peer, have in self.peer_pieces.get(app_id, {}).items():
            if piece_id in have:
                holders.add(peer)
        holders.discard(self.node_id)
        holders -= self.bad_piece_peers.get(app_id, set())
        return sorted(holders)

    def _pump_pieces(self, app_id: str) -> None:
        """Issue PIECE_REQs, rarest-first, to the least-loaded holders."""
        inv = self.inventories.get(app_id)
        if inv is None or inv.complete:
            return
        pending = self.piece_pending[app_id]
        missing = [p for p in inv.missing() if p not in pending]
        # stable per-node offset staggers tie-breaks so leechers start on
        # different pieces (random-first-piece, deterministically)
        off = sum(ord(c) for c in self.node_id + app_id)
        order = rarest_first_order(missing, self._piece_avail(app_id),
                                   offset=off)
        now = self.rt.now()
        # at most one in-flight request per holder: committing several
        # pieces to one uplink queues them behind each other while other
        # holders idle, and starves the seeder-egress reduction
        busy = {peer for peer, _ in pending.values()}
        for piece_id in order:
            if len(pending) >= self.cfg.piece_pipeline:
                break
            holders = [h for h in self._holders_of(app_id, piece_id)
                       if h not in busy]
            if not holders:
                continue
            peer = min(holders, key=lambda h: (self.peer_load[h], h))
            pending[piece_id] = (peer, now)
            busy.add(peer)
            self.peer_load[peer] += 1
            self.SEND(peer, Msg(PIECE_REQ, self.node_id,
                                {"app_id": app_id, "piece_id": piece_id},
                                size_bytes=96))

    def _our_bitfield(self, app_id: str) -> Tuple[int, ...]:
        if app_id in self.images:
            manifest = self.manifests.get(app_id)
            return tuple(range(manifest.n_pieces)) if manifest else ()
        inv = self.inventories.get(app_id)
        return inv.bitfield() if inv else ()

    def _on_piece_req(self, msg: Msg) -> None:
        app_id = msg.payload["app_id"]
        piece_id = msg.payload["piece_id"]
        self.swarm_peers[app_id].add(msg.src)
        manifest = self.manifests.get(app_id)
        inv = self.inventories.get(app_id)
        holds = (app_id in self.images or (inv is not None
                                           and inv.has(piece_id)))
        if manifest is None or not holds:
            # tell the requester what we actually have so it re-routes
            self.SEND(msg.src, Msg(HAVE, self.node_id,
                                   {"app_id": app_id,
                                    "pieces": list(self._our_bitfield(
                                        app_id))},
                                   size_bytes=96))
            return
        self.SEND(msg.src, Msg(
            PIECE_DATA, self.node_id,
            {"app_id": app_id, "piece_id": piece_id,
             "proof": manifest.piece_hashes[piece_id],
             "have": list(self._our_bitfield(app_id))},
            size_bytes=96 + manifest.piece_size(piece_id)))

    def _on_piece_data(self, msg: Msg) -> None:
        app_id = msg.payload["app_id"]
        piece_id = msg.payload["piece_id"]
        self.peer_pieces[app_id][msg.src] = set(msg.payload.get("have", ()))
        self.swarm_peers[app_id].add(msg.src)
        pending = self.piece_pending[app_id]
        if pending.get(piece_id, (None,))[0] == msg.src:
            del pending[piece_id]
            self.peer_load[msg.src] = max(0, self.peer_load[msg.src] - 1)
        inv = self.inventories.get(app_id)
        if inv is None or inv.complete:
            return
        if not inv.add(piece_id, msg.payload["proof"]):
            # corrupt piece: never ask this peer again, fetch elsewhere
            self.bad_piece_peers[app_id].add(msg.src)
            self._pump_pieces(app_id)
            return
        manifest = inv.manifest
        self.leech_bytes[app_id] += manifest.piece_size(piece_id)
        if self.dir:
            self.dir.save_piece(app_id, piece_id, msg.payload["proof"])
        # announce to known peers directly AND via the tracker relay.  The
        # relay alone would suffice for reach, but the extra hop delays
        # rarity information enough to push measurably more piece traffic
        # back onto the origin; duplicate 96-byte announces are cheap next
        # to the pieces they steer.
        announce = {"app_id": app_id, "pieces": [piece_id]}
        for peer in sorted(self.swarm_peers[app_id] - {msg.src,
                                                       self.node_id}):
            self.SEND(peer, Msg(HAVE, self.node_id, dict(announce),
                                size_bytes=96))
        self.SEND(self.server_id, Msg(HAVE, self.node_id, dict(announce),
                                      size_bytes=96))
        if inv.complete:
            self._image_complete(app_id)
        else:
            self._pump_pieces(app_id)

    def _on_have(self, msg: Msg) -> None:
        app_id = msg.payload["app_id"]
        pieces = set(msg.payload["pieces"])
        # the tracker relays announces with the originating peer attached
        peer = msg.payload.get("peer", msg.src)
        if peer == self.node_id:
            return
        self.swarm_peers[app_id].add(peer)
        known = self.peer_pieces[app_id].setdefault(peer, set())
        known |= pieces
        # requests outstanding at a peer that turns out to lack the piece
        # are re-routed right away
        pending = self.piece_pending[app_id]
        stale = [pid for pid, (p, _) in pending.items()
                 if p == peer and pid not in known]
        for pid in stale:
            del pending[pid]
            self.peer_load[peer] = max(0, self.peer_load[peer] - 1)
        self._pump_pieces(app_id)

    def _image_complete(self, app_id: str) -> None:
        """All pieces verified: unpack the executable via the registry and
        join the seeder set as a replica."""
        inv = self.inventories[app_id]
        mh = inv.manifest.manifest_hash
        self.images[app_id] = mh
        entry = resolve_executable(mh)
        if (self.cfg.replica_seed and entry is not None
                and entry.blueprint is not None
                and app_id not in self.apps
                and app_id not in self.replicas):
            app = entry.blueprint()
            self.replicas[app_id] = app
            self.tails.setdefault(app_id,
                                  LeaseTable(self.cfg.work_timeout_s))
            self.metrics.setdefault(app_id, AppMetrics(
                d_app_bytes=app.app_bytes, m_min=app.m_min))
            self.SEND(self.server_id, Msg(SEEDER_UPDATE, self.node_id,
                                          {"app_id": app_id,
                                           "seeder": self.node_id},
                                          size_bytes=96))
        ctx = self.current.get(app_id)
        if ctx is not None and ctx.get("fetching"):
            self._request_work(app_id)

    # ============================ worker ================================ #
    def REQ(self, app_id: str, host_id: str) -> None:
        """Request application + next data part from the host."""
        ctx = self.current.setdefault(app_id, {"host": host_id,
                                               "busy": False})
        ctx["host"] = host_id
        ctx["fetching"] = False
        ctx["last_req"] = self.rt.now()
        self.SEND(host_id, Msg(REQ, self.node_id, {"app_id": app_id},
                               size_bytes=96))

    def SCAN(self, payload: dict) -> int:
        """Measure the size of the received application and data."""
        return int(payload.get("app_bytes", 0)) + int(
            payload.get("data_bytes", 0))

    def RUN(self, app_id: str, part_id: int, payload: Any,
            host_id: str) -> None:
        """Execute one part; TIME marks start/end via the runtime."""
        ctx = self.current.get(app_id)
        if ctx is None or ctx.get("busy"):
            return      # stale APP_DATA must not double-submit work
        ctx["busy"] = True
        sim_dur = None
        fn = None
        # resolve the executable from the registry, keyed by the manifest
        # hash of the (verified) image this agent holds
        mh = self.images.get(app_id)
        entry = resolve_executable(mh) if mh else None
        if entry is not None:
            if entry.cost_fn is not None:
                # work units at reference speed 1.0; the runtime's processor-
                # sharing executor applies node speed and contention
                sim_dur = entry.cost_fn(payload, 1.0) \
                    + self.cfg.cycle_overhead_s
            if entry.run_fn is not None:
                fn = (lambda p=payload, f=entry.run_fn: f(p))
        tag = (app_id, part_id, host_id)
        self.TIME(app_id, "start")
        self.rt.submit_work(self.node_id, tag, fn, sim_duration_s=sim_dur)

    def TIME(self, app_id: str, mark: str) -> None:
        """Track working time; log kept under Leech/App/Data/Time (Fig. 3)."""
        if self.dir:
            self.dir.time_log(app_id, f"{self.rt.now():.3f} {mark}")

    def COLLECT(self, app_id: str, elapsed_s: float, nbytes: int) -> dict:
        """Gather TIME and SCAN info about a finished part."""
        self.leech_time[app_id] += elapsed_s
        self.leech_bytes[app_id] += nbytes
        self.completed_cycles[app_id] += 1
        return {"time_s": elapsed_s, "data_bytes": nbytes}

    def SAVE(self, app_id: str, part_id: int, result: Any) -> None:
        if self.dir:
            self.dir.save_leech_result(app_id, part_id, result)

    def LOAD(self, app_id: str, part_id: int) -> Any:
        if self.dir:
            return self.dir.load_leech_result(app_id, part_id)
        return None

    def STOP(self, app_id: str, reason: str = "") -> None:
        """Drop an application: its data, results and pending work."""
        self.current.pop(app_id, None)
        self.stopped_apps.add(app_id)
        self.app_list = [a for a in self.app_list if a.app_id != app_id]
        for piece_id, (peer, _) in self.piece_pending.pop(app_id,
                                                          {}).items():
            self.peer_load[peer] = max(0, self.peer_load[peer] - 1)
        self.inventories.pop(app_id, None)
        self.replicas.pop(app_id, None)
        if app_id not in self.apps:
            self.images.pop(app_id, None)
            self.manifests.pop(app_id, None)
        self.peer_pieces.pop(app_id, None)
        self.swarm_peers.pop(app_id, None)
        self.full_seeders.pop(app_id, None)
        self.no_work_from.pop(app_id, None)
        if self.dir:
            self.dir.drop_leech_app(app_id)
        self._maybe_start_work()

    # ------------------------------------------------------------------ #
    def _row_for(self, app_id: str) -> Optional[AppInfo]:
        for a in self.app_list:
            if a.app_id == app_id:
                return a
        return None

    def _work_candidates(self, row: AppInfo) -> List[str]:
        """Seeders this leecher may REQ work from, least-loaded first (the
        tracker orders `row.seeders` by reported load)."""
        cands = [s for s in row.seeders if s != self.node_id]
        if row.host_id != self.node_id:
            if row.host_id not in cands:
                cands.insert(0, row.host_id)
        elif not cands:
            # self-leech (paper Scenario III/IV): the host crunches its own
            # application, REQ/DIST looping back through itself
            cands = [self.node_id]
        if not cands:
            return []
        # stable per-leecher rotation spreads first REQs across seeders
        off = sum(ord(c) for c in self.node_id + row.app_id) % len(cands)
        return cands[off:] + cands[:off]

    def _request_work(self, app_id: str) -> bool:
        row = self._row_for(app_id)
        if row is None:
            return False
        tried = self.no_work_from.get(app_id, set())
        for cand in self._work_candidates(row):
            if cand not in tried:
                self.REQ(app_id, cand)
                return True
        return False

    def _on_app_list(self, rows: List[AppInfo]) -> None:
        self.app_list = [r for r in rows if r.app_id not in self.stopped_apps]
        for row in self.app_list:
            if row.manifest is not None:
                self.full_seeders[row.app_id] = \
                    set(row.seeders) | {row.host_id}
            # tracker promoted this node from replica to host (origin died)
            if row.host_id == self.node_id and row.app_id in self.replicas:
                app = self.replicas.pop(row.app_id)
                app.host_id = self.node_id
                self.apps[row.app_id] = app
                self.current.pop(row.app_id, None)
                self.STAT()
            # the seeder this leecher worked with vanished: re-route
            ctx = self.current.get(row.app_id)
            if ctx is not None and ctx.get("fetching"):
                self._pump_pieces(row.app_id)
            elif ctx is not None:
                host = ctx.get("host")
                live = set(row.seeders) | {row.host_id}
                if host is not None and host not in live:
                    ctx["host"] = None
                    if not ctx.get("busy"):
                        self._request_work(row.app_id)
        self._maybe_start_work()

    def _maybe_start_work(self) -> None:
        active = len(self.current)
        now = self.rt.now()
        for row in self.app_list:
            if active >= self.cfg.max_parallel_apps:
                break
            if row.host_id == self.node_id and not self.cfg.self_leech:
                continue
            if row.app_id in self.current:
                continue
            if row.parts_remaining == 0 and row.p > 0:
                continue    # host reported it complete
            if self.dry_until.get(row.app_id, -1.0) > now:
                continue    # backing off after NO_WORK
            if row.manifest is not None and row.app_id not in self.images:
                # swarm app: fetch the image piece-wise before crunching
                self.current[row.app_id] = {"host": None, "busy": False,
                                            "fetching": True,
                                            "last_req": now}
                self.manifests.setdefault(row.app_id, row.manifest)
                self.inventories.setdefault(
                    row.app_id, PieceInventory(row.manifest))
                # join the swarm: the tracker relays this (empty) announce
                # so existing members learn about us and vice versa
                self.SEND(self.server_id, Msg(
                    HAVE, self.node_id,
                    {"app_id": row.app_id, "pieces": []}, size_bytes=96))
                self._pump_pieces(row.app_id)
            else:
                if not self._request_work(row.app_id):
                    continue
            active += 1

    def _on_no_work(self, msg: Msg) -> None:
        app_id = msg.payload["app_id"]
        ctx = self.current.get(app_id)
        if ctx is None:
            return
        # this seeder is (momentarily) dry; try the next replica before
        # backing off — other seeders may still hold leasable parts
        self.no_work_from[app_id].add(msg.src)
        if self._request_work(app_id):
            return
        self.current.pop(app_id, None)
        self.no_work_from.pop(app_id, None)
        # back off: the app may only be out of *leasable* parts right
        # now (all leased, not all validated) — retry later
        self.dry_until[app_id] = self.rt.now() + self.cfg.retry_s
        self.rt.set_timer(self.node_id, "retry", self.cfg.retry_s)
        self._maybe_start_work()

    def _on_app_data(self, msg: Msg) -> None:
        app_id = msg.payload["app_id"]
        ctx = self.current.get(app_id)
        if ctx is None or ctx.get("busy"):
            return
        mh = msg.payload.get("manifest_hash")
        if mh is not None and msg.payload.get("app_bytes", 0) > 0:
            # monolithic shipment: the full image rode along, so this agent
            # now holds it and may resolve the executable
            self.images.setdefault(app_id, mh)
        nbytes = self.SCAN(msg.payload)
        ctx["bytes"] = nbytes
        self.no_work_from.get(app_id, set()).discard(msg.src)
        self.RUN(app_id, msg.payload["part_id"], msg.payload["payload"],
                 msg.src)

    def on_work_done(self, tag, result, elapsed_s: float) -> None:
        app_id, part_id, host_id = tag
        self.TIME(app_id, "end")
        ctx = self.current.get(app_id)
        if ctx is None:
            return      # STOPped while running
        ctx["busy"] = False
        ctx["last_req"] = self.rt.now()
        info = self.COLLECT(app_id, elapsed_s, ctx.get("bytes", 0))
        self.SAVE(app_id, part_id, result)
        loaded = self.LOAD(app_id, part_id)
        # deliver to the live seeder for this app: if the one that leased
        # the part died meanwhile, its successor revalidates the part
        dest = ctx.get("host") or host_id
        self.SEND(dest, Msg(RESULT, self.node_id, {
            "app_id": app_id, "part_id": part_id,
            "result": loaded if loaded is not None else result,
            "time_s": info["time_s"], "data_bytes": info["data_bytes"],
        }, size_bytes=1024))
        self.results_log.append((self.rt.now(), app_id, part_id))

    def _on_result_ack(self, msg: Msg) -> None:
        app_id = msg.payload["app_id"]
        if app_id in self.current:
            # keep leeching the same app until the host runs dry
            self.REQ(app_id, msg.src)

    def _recover_stalled(self) -> None:
        """Periodic self-heal: re-issue piece requests and work REQs that
        went unanswered (e.g. the peer died before PEER_GONE propagated)."""
        now = self.rt.now()
        # the threshold must sit above any legitimate queueing delay of a
        # bulk APP_DATA/PIECE_DATA transfer (a saturated seeder uplink can
        # hold a reply for a long while) — use the TAIL timescale, same as
        # the seeders' own lease expiry
        stall = self.cfg.work_timeout_s
        for app_id, ctx in list(self.current.items()):
            if ctx.get("fetching"):
                pending = self.piece_pending.get(app_id, {})
                stale = [pid for pid, (peer, t) in pending.items()
                         if now - t > stall]
                for pid in stale:
                    peer, _ = pending.pop(pid)
                    self.peer_load[peer] = max(0, self.peer_load[peer] - 1)
                self._pump_pieces(app_id)
            elif not ctx.get("busy") and now - ctx.get("last_req",
                                                       0.0) > stall:
                self.no_work_from.pop(app_id, None)
                self._request_work(app_id)

    def on_message(self, msg: Msg) -> None:
        self.RECV(msg)

    def on_timer(self, name: str) -> None:
        if name == "status":
            # replicas must report too: their lease counts feed the
            # tracker's least-loaded routing and promotion choices
            if self.apps or self.replicas:
                self.STAT()
            self._recover_stalled()
        elif name == "tail":
            self.TAIL()
        elif name == "retry":
            self._maybe_start_work()
