"""Volunteer agent (paper §III.E-G, Figs. 3-5; §V swarm extension).

Modules: connector (RECV, SEND), tracker (EVAL, DIST, STAT, VAL, TAIL) and
worker (REQ, SCAN, RUN, TIME, COLLECT, SAVE, LOAD, STOP) — the paper's 15
agent procedures.  Every agent is simultaneously:

  * a SEEDER for its own applications (A_self): answers REQ with app+data,
    validates RESULTs by m_min-way majority voting, reports status via STAT;
  * a LEECHER for other hosts' applications: REQ -> SCAN+RUN -> TIME ->
    COLLECT+LOAD -> SEND result, in a loop until the host runs dry.

The §V extension ("broken to pieces like regular file sharing in torrent")
adds a third role when an application is published with `swarm=True`:

  * a PIECE PEER: the app image moves as hashed pieces (PIECE_REQ /
    PIECE_DATA), scheduled by the PieceExchange engine
    (core/piece_exchange.py): rarest-first selection from HAVE bitmask
    announcements, seeder-side choke scheduling (INTERESTED/CHOKE/UNCHOKE,
    fixed upload slots, optimistic unchoke) and endgame duplicate requests
    reconciled with PIECE_CANCEL.  Once the image completes, the agent
    resolves the executable from the registry keyed by the manifest hash
    (no back-door into the runtime's node table) and becomes a REPLICA
    SEEDER: it answers REQ/DIST and VALidates results for the app, keeps
    in sync with the other seeders via PART_DONE gossip (cancelling now-
    redundant leases with PART_CANCEL), and can be promoted to host by the
    tracker if the origin dies.

The dual Seed/ and Leech/ working directories (Fig. 3) are managed by
core.directory; TAIL's volunteer log lives under Seed/App/<id>/Data/Tracker
and TIME's under Leech/App/<id>/Data/Time, as in the paper.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Set

from repro.core import directory as dirs
from repro.core.messages import (APP_DATA, APP_LIST, BYE, CHOKE, COST_MAP,
                                 DROP_APP, HAVE, INTERESTED, MANIFEST_UPDATE,
                                 NO_WORK, PART_CANCEL, PART_DONE, PEER_GONE,
                                 PIECE_CANCEL, PIECE_DATA, PIECE_REQ, PING,
                                 PONG, REGISTER, REQ, RESULT, RESULT_ACK,
                                 SEEDER_UPDATE, STATUS, UNCHOKE, AppInfo, Msg)
from repro.core.metrics import AppMetrics
from repro.core.piece_exchange import PieceExchange
from repro.core.runtime import CANCELLED, Node, Runtime
from repro.core.validation import majority_vote
from repro.core.workunit import (Application, LeaseTable, Part,
                                 register_executable, resolve_executable)


@dataclass
class AgentConfig:
    work_timeout_s: float = 60.0        # TAIL timeout parameter
    status_interval_s: float = 1.0
    retry_s: float = 2.0                # back-off after NO_WORK from a host
    # per-cycle protocol/VM overhead in simulation (calibrated from the
    # paper's Scenario I: w_parallel 6.35s vs sequential-VM 5.51s)
    cycle_overhead_s: float = 0.0
    accept_from: tuple = ()             # RECV accept/deny parameter
    deny_from: tuple = ()
    max_parallel_apps: int = 2          # leech this many apps concurrently
    self_leech: bool = False            # hosts also crunch their own apps
    root_dir: Optional[str] = None      # enables on-disk Fig. 3 layout
    piece_pipeline: int = 4             # outstanding PIECE_REQs per app
    replica_seed: bool = True           # re-seed completed swarm images
    # --- PieceExchange choke scheduler / endgame ----------------------- #
    choke: bool = True                  # seeder-side upload-slot limiting
    upload_slots: int = 4               # unchoked peers per app
    rechoke_interval_s: float = 10.0    # periodic re-choke cadence
    optimistic_every: int = 3           # rotate optimistic slot every N
    endgame: bool = True                # dup requests + CANCEL reconcile
    endgame_dup: int = 3                # max concurrent holders per piece
    # rolling window for the rechoke ranking's byte-rate estimate: peers
    # are ranked by bytes moved in the last window, not lifetime totals
    rate_window_s: float = 20.0
    # --- fault recovery (chaos hardening, see docs "Fault model") ------ #
    # staleness threshold for the pending-PIECE_REQ sweep (a lost request
    # or reply is re-issued after this); None keeps the conservative
    # default of work_timeout_s, which sits above any legitimate bulk
    # queueing delay
    piece_timeout_s: Optional[float] = None
    # re-send REGISTER after this much tracker silence: a lost REGISTER
    # (or a membership drop while partitioned) otherwise leaves the agent
    # off the tracker's push list forever
    reregister_s: float = 30.0
    # periodic re-gossip of validated parts to the other seeders; repairs
    # lost PART_DONE messages so seeder done-sets re-converge.  None (the
    # default) disables it — chaos scenarios turn it on.
    gossip_interval_s: Optional[float] = None
    # fetch swarm images even when the app's work is already finished
    # (pure replication, BitTorrent-style seeding): lets a volunteer that
    # crash-restarted after completion still converge to a full replica
    replicate_completed: bool = False
    # stop registering as a replica *seeder* (SEEDER_UPDATE + scheduling
    # state) once the app already lists this many seeders.  None keeps
    # every completed volunteer a seeder; large-N benchmarks cap it so
    # per-seeder bookkeeping and gossip stay O(cap), not O(N).  Piece
    # serving is unaffected — completed nodes keep answering PIECE_REQs.
    max_replica_seeders: Optional[int] = None
    # restrict PIECE_REQs to these peers (scalar engine only): the
    # origin-only baseline of the checkpoint cold-start benchmarks, where
    # every replica pulls straight from the blob-store stand-in instead
    # of exchanging pieces.  () keeps normal swarm-wide selection.
    fetch_from: tuple = ()


class Agent(Node):
    def __init__(self, node_id: str, server_id: str = "server",
                 config: Optional[AgentConfig] = None,
                 val_hook: Optional[Callable[[int, Any], bool]] = None,
                 hub=None):
        self.node_id = node_id
        self.server_id = server_id
        self.cfg = config or AgentConfig()
        self.val_hook = val_hook
        # --- seeder state -------------------------------------------------
        self.apps: Dict[str, Application] = {}         # A_self
        self.replicas: Dict[str, Application] = {}     # re-seeded swarm apps
        self.tail = LeaseTable(self.cfg.work_timeout_s)
        self.tails: Dict[str, LeaseTable] = {}
        self.metrics: Dict[str, AppMetrics] = {}
        # --- leecher state ------------------------------------------------
        self.app_list: List[AppInfo] = []
        self.current: Dict[str, dict] = {}             # app_id -> work ctx
        self.results_log: List[tuple] = []
        self.part_results: Dict[tuple, Any] = {}       # (app, part) -> R
        # voters whose result for a part passed through this seeder (kept
        # even when the result is forwarded to the part's owner, so DIST
        # never re-grants a part to a volunteer that already voted)
        self.voted: Dict[tuple, Set[str]] = collections.defaultdict(set)
        self.completed_cycles: Dict[str, int] = collections.defaultdict(int)
        self.leech_time: Dict[str, float] = collections.defaultdict(float)
        self.leech_bytes: Dict[str, float] = collections.defaultdict(float)
        self.stopped_apps: Set[str] = set()
        # quorum size at the moment each part validated here (chaos
        # invariant: never more than m_min + 1 voters decide a part)
        self.quorum_sizes: Dict[tuple, int] = {}
        self._last_server = 0.0         # last message seen from the tracker
        self.dry_until: Dict[str, float] = {}
        self.completed_at: Dict[str, float] = {}
        # app_id -> sim time the full image verified here (Scenario IX's
        # per-node completion distribution; p99 comes from these)
        self.image_completed_at: Dict[str, float] = {}
        self.no_work_from: Dict[str, Set[str]] = collections.defaultdict(set)
        self.cancelled_parts = 0                # PART_CANCEL aborts
        self.dir = (dirs.AgentDirs(self.cfg.root_dir, node_id)
                    if self.cfg.root_dir else None)
        # --- piece-peer state (paper §V): the PieceExchange engine --------
        self.images: Dict[str, str] = {}        # app_id -> verified manifest
        self.px = PieceExchange(
            node_id, self.cfg, send=self.SEND, now=lambda: self.rt.now(),
            tracker_id=server_id, dirs=self.dir,
            on_image_complete=self._on_image_complete,
            on_bytes=self._on_piece_bytes, hub=hub)

    def _on_piece_bytes(self, app_id: str, nbytes: int) -> None:
        self.leech_bytes[app_id] += nbytes

    # engine views kept for tests/tools (the engine owns the state)
    @property
    def manifests(self):
        return self.px.manifests

    @property
    def inventories(self):
        return self.px.inventories

    @property
    def swarm_peers(self):
        return self.px.swarm_peers

    @property
    def full_seeders(self):
        return self.px.full_seeders

    # ------------------------------------------------------------------ #
    def host_app(self, app: Application) -> None:
        app.host_id = self.node_id
        manifest = app.ensure_manifest()
        # publishing an app puts its executable behind the manifest hash:
        # only holders of the verified image may resolve and run it
        register_executable(manifest.manifest_hash, app.run_fn, app.cost_fn,
                            blueprint=app.blueprint())
        self.apps[app.app_id] = app
        self.px.add_local_app(app.app_id, manifest, image=app.image)
        self.images[app.app_id] = manifest.manifest_hash
        self.tails[app.app_id] = LeaseTable(self.cfg.work_timeout_s)
        m = AppMetrics(d_app_bytes=app.app_bytes, m_min=app.m_min)
        self.metrics[app.app_id] = m
        if self.dir:
            self.dir.seed_app(app.app_id, app.app_bytes, image=app.image)

    def publish_update(self, app_id: str, new_manifest,
                       image: Optional[bytes] = None) -> bool:
        """Publish revision v(k+1) of a hosted app (delta distribution).

        Swaps the image behind a strictly newer versioned manifest,
        re-registers the executables under the new manifest hash, and
        announces MANIFEST_UPDATE to the tracker, which resets the seeder
        set to this host and gossips the new metainfo to the swarm —
        members then carry over unchanged verified pieces and fetch only
        the delta.  `image` carries the new bytes for real apps (synthetic
        revisions pass None).  Returns False for non-superseding updates."""
        app = self.apps.get(app_id)
        if app is None:
            return False
        old = app.manifest
        if old is not None and not new_manifest.supersedes(old):
            return False
        app.manifest = new_manifest
        if image is not None:
            app.image = image
            app.app_bytes = len(image)
        register_executable(new_manifest.manifest_hash, app.run_fn,
                            app.cost_fn, blueprint=app.blueprint())
        self.px.upgrade(app_id, new_manifest, image=app.image, full=True)
        self.images[app_id] = new_manifest.manifest_hash
        if self.dir:
            self.dir.seed_app(app_id, app.app_bytes, image=app.image)
        self.SEND(self.server_id, Msg(MANIFEST_UPDATE, self.node_id,
                                      {"app_id": app_id,
                                       "manifest": new_manifest},
                                      size_bytes=512))
        return True

    def start(self, rt: Runtime) -> None:
        super().start(rt)
        self._last_server = rt.now()
        # boot nonce: stable for this process incarnation, different after
        # a crash-restart — the tracker uses it to tell "same agent
        # re-registering" from "fresh process that lost its state" and
        # drops the stale seeder claims of the latter
        if not hasattr(self, "_boot"):
            self._boot = rt.now()
        self.SEND(self.server_id, Msg(REGISTER, self.node_id,
                                      {"apps": self._self_rows(),
                                       "boot": self._boot}))
        rt.set_timer(self.node_id, "status", self.cfg.status_interval_s,
                     periodic=True)
        rt.set_timer(self.node_id, "tail", self.cfg.work_timeout_s / 2,
                     periodic=True)
        if self.cfg.choke:
            rt.set_timer(self.node_id, "rechoke",
                         self.cfg.rechoke_interval_s, periodic=True)
        if self.cfg.gossip_interval_s:
            rt.set_timer(self.node_id, "gossip",
                         self.cfg.gossip_interval_s, periodic=True)

    def shutdown(self) -> None:
        """Graceful leave: BYE tells the server to reclaim this volunteer's
        leases immediately instead of waiting for TAIL timeouts."""
        self.SEND(self.server_id, Msg(BYE, self.node_id,
                                      {"apps": list(self.apps)},
                                      size_bytes=64))

    def _self_rows(self) -> List[AppInfo]:
        rows = []
        for app in self.apps.values():
            m = self.metrics[app.app_id]
            rows.append(AppInfo(app.app_id, self.node_id, d=m.d, p=m.p,
                                w=m.w, n_parts=len(app.parts),
                                parts_remaining=sum(
                                    0 if p.done else 1 for p in app.parts),
                                seeders=(self.node_id,),
                                manifest=(app.manifest if app.swarm
                                          else None)))
        return rows

    def _seed_loads(self) -> Dict[str, int]:
        """Per-app seeding pressure: active lease counts plus the choke
        scheduler's upload load (granted slots + queued piece requests);
        the tracker uses them for least-loaded routing."""
        loads = {}
        for app_id in list(self.apps) + list(self.replicas):
            tail = self.tails.get(app_id)
            if tail is not None:
                loads[app_id] = (sum(len(ls)
                                     for ls in tail.active().values())
                                 + self.px.seed_load(app_id))
        return loads

    # ========================== connector =============================== #
    def RECV(self, msg: Msg) -> None:
        """Receive messages; accept/deny lists are the paper's parameter."""
        if self.cfg.accept_from and msg.src not in self.cfg.accept_from \
                and msg.src != self.server_id:
            return
        if msg.src in self.cfg.deny_from:
            return
        if msg.src == self.server_id:
            self._last_server = self.rt.now()
        kind = msg.kind
        # swarm data-plane kinds first: HAVE announces alone are O(N) per
        # verified piece, so they dominate the dispatch at scale
        if kind == HAVE:
            self.px.on_have(msg)
        elif kind == PIECE_REQ:
            self._on_piece_req(msg)
        elif kind == PIECE_DATA:
            self.px.on_piece_data(msg)
        elif kind == INTERESTED:
            self.px.on_interested(msg)
        elif kind == CHOKE:
            self.px.on_choke(msg)
        elif kind == UNCHOKE:
            self.px.on_unchoke(msg)
        elif kind == PIECE_CANCEL:
            self.px.on_piece_cancel(msg)
        elif kind == PING:
            self.SEND(self.server_id, Msg(PONG, self.node_id, size_bytes=64))
        elif kind == APP_LIST:
            self._on_app_list(msg.payload["apps"])
        elif kind == DROP_APP:
            for app_id in msg.payload["app_ids"]:
                self.STOP(app_id, reason="host dropped from list")
        elif kind == REQ:
            self.DIST(msg.src, msg.payload["app_id"])
        elif kind == APP_DATA:
            self._on_app_data(msg)
        elif kind == NO_WORK:
            self._on_no_work(msg)
        elif kind == RESULT:
            self.VAL(msg)
        elif kind == RESULT_ACK:
            self._on_result_ack(msg)
        elif kind == PART_CANCEL:
            self._on_part_cancel(msg)
        elif kind == PART_DONE:
            self._on_part_done(msg)
        elif kind == PEER_GONE:
            self._on_peer_gone(msg.payload["node"])
        elif kind == SEEDER_UPDATE:
            self._on_seeder_update(msg)
        elif kind == MANIFEST_UPDATE:
            self._apply_manifest_update(msg.payload["app_id"],
                                        msg.payload["manifest"])
        elif kind == COST_MAP:
            self.px.set_cost_map(msg.payload["island"],
                                 msg.payload["costs"],
                                 msg.payload.get("islands"))

    def _on_piece_req(self, msg: Msg) -> None:
        # kept as a seam (tests stub a malicious serving path here); the
        # engine owns the real choke-aware serving logic
        self.px.on_piece_req(msg)

    def _our_bitfield(self, app_id: str) -> int:
        return self.px.bitfield_mask(app_id)

    def SEND(self, dst: str, msg: Msg) -> None:
        self.rt.send(dst, msg)

    # =========================== tracker ================================ #
    def EVAL(self, app_id: str, valid: bool) -> None:
        """Track m_min/m_max progress for an application's validation."""
        app = self.apps.get(app_id)
        if app is None:
            return
        if valid and app.m_min < app.m_max:
            app.m_min += 1
            self.metrics[app_id].m_min = app.m_min

    def _seeded_app(self, app_id: str) -> Optional[Application]:
        return self.apps.get(app_id) or self.replicas.get(app_id)

    def _seeder_ring(self, app_id: str) -> List[str]:
        row = self._row_for(app_id)
        return sorted(set(row.seeders if row else ()) | {self.node_id})

    def _part_owner(self, app_id: str, part_id: int) -> str:
        """The seeder responsible for a part: the owner of the partition
        DIST's grant scan assigns it to.  Results for the part converge
        there so the m_min quorum forms at one place even when endgame
        leases scatter across seeders."""
        seeders = self._seeder_ring(app_id)
        return seeders[part_id % len(seeders)]

    def DIST(self, volunteer: str, app_id: str) -> None:
        """Lease the next pending part to `volunteer` and ship app+data.

        The part space is split across the current seeder set so
        concurrent seeders rarely lease the same part; a seeder whose
        partition is drained falls back to any pending part (endgame)."""
        app = self._seeded_app(app_id)
        if app is None:
            self.SEND(volunteer, Msg(NO_WORK, self.node_id,
                                     {"app_id": app_id}, size_bytes=64))
            return
        tail = self.tails[app_id]
        leased = tail.by_part            # empty lists count as no lease
        seeders = self._seeder_ring(app_id) if app.swarm else []
        if len(seeders) > 1:
            s, me = len(seeders), seeders.index(self.node_id)

            def in_partition(p: Part) -> bool:
                return p.part_id % s == me
        else:
            def in_partition(p: Part) -> bool:
                return True
        voted = self.voted

        # skip parts this volunteer already contributed to (a result seen
        # or forwarded here, or an active lease): a quorum needs
        # *distinct* voters, and re-granting just burns a duplicate
        # execution or spins a cached-resend loop
        def acceptable(p: Part) -> bool:
            return (volunteer not in voted.get((app_id, p.part_id), ())
                    and not any(v == volunteer for v, _, _ in p.results)
                    and not any(l.volunteer_id == volunteer
                                for l in leased.get(p.part_id, ())))

        part = app.grant_candidate(leased, in_partition, acceptable)
        if part is None:
            self.SEND(volunteer, Msg(NO_WORK, self.node_id,
                                     {"app_id": app_id}, size_bytes=64))
            return
        tail.grant(part.part_id, volunteer, self.rt.now())
        if self.dir:
            self.dir.tracker_log(app_id,
                                 f"{self.rt.now():.3f} lease part="
                                 f"{part.part_id} to={volunteer}")
        manifest = app.manifest
        if app.swarm:
            # piece-wise mode: the image moved separately as pieces, so
            # APP_DATA carries only the part payload
            size = 96 + part.data_bytes
            app_bytes = 0
        else:
            size = app.app_bytes + part.data_bytes
            app_bytes = app.app_bytes
        self.SEND(volunteer, Msg(
            APP_DATA, self.node_id,
            {"app_id": app_id, "part_id": part.part_id,
             "payload": part.payload, "app_bytes": app_bytes,
             "data_bytes": part.data_bytes,
             "manifest_hash": (manifest.manifest_hash if manifest
                               else None)},
            size_bytes=size))

    def STAT(self) -> None:
        """Update validated-work status (incl. d, w) to the server."""
        self.SEND(self.server_id, Msg(STATUS, self.node_id,
                                      {"apps": self._self_rows(),
                                       "loads": self._seed_loads()}))

    def VAL(self, msg: Msg) -> None:
        """Validate a RESULT by majority voting once m_min results arrived.

        For swarm apps the quorum forms at the part's *owner* seeder:
        another seeder that leased the part in endgame fallback forwards
        the result there (ACKing its volunteer itself), so m_min is
        reached promptly instead of results scattering one-per-seeder and
        every seeder re-leasing the part."""
        app_id = msg.payload["app_id"]
        app = self._seeded_app(app_id)
        if app is None:
            return
        part_id = msg.payload["part_id"]
        part = app.parts[part_id]
        tail = self.tails[app_id]
        forwarded = msg.payload.get("forwarded", False)
        volunteer = msg.payload.get("volunteer", msg.src)
        tail.release(part_id, volunteer)
        if self.val_hook is not None and not self.val_hook(
                part_id, msg.payload["result"]):
            # malicious result: discard; status not updated (paper §III.D).
            # The rejected volunteer's vote is still *consumed* (recorded
            # in `voted`), so DIST never re-grants it the same part — a
            # cached resend would otherwise spin an unthrottled
            # grant->resend->reject loop
            self.voted[(app_id, part_id)].add(volunteer)
            # always tell the *volunteer* (the forwarder already ACKed it
            # optimistically): valid=False makes it drop its cached copy
            # so the bad result is not replayed to other seeders
            self.SEND(volunteer, Msg(RESULT_ACK, self.node_id,
                                     {"app_id": app_id,
                                      "part_id": part_id,
                                      "valid": False}, size_bytes=64))
            return
        self.voted[(app_id, part_id)].add(volunteer)
        if app.swarm and not forwarded and not part.done:
            # seeder ring views may diverge briefly while the tracker
            # propagates a new replica; a mis-routed forward is then
            # simply validated at the receiver (never re-forwarded), and
            # PART_DONE gossip re-converges the done sets
            owner = self._part_owner(app_id, part_id)
            if owner != self.node_id:
                self.SEND(owner, Msg(RESULT, self.node_id,
                                     {**msg.payload, "forwarded": True,
                                      "volunteer": volunteer},
                                     size_bytes=1024))
                self.SEND(volunteer, Msg(RESULT_ACK, self.node_id,
                                         {"app_id": app_id,
                                          "part_id": part_id,
                                          "valid": True}, size_bytes=64))
                return
        if any(v == volunteer for v, _, _ in part.results):
            # duplicate vote (e.g. a cached resend routed via another
            # seeder): m_min demands *distinct* voters
            if not forwarded:
                self.SEND(msg.src, Msg(RESULT_ACK, self.node_id,
                                       {"app_id": app_id,
                                        "part_id": part_id,
                                        "valid": True}, size_bytes=64))
            return
        part.results.append((volunteer, msg.payload["result"],
                             msg.payload.get("time_s", 0.0)))
        if len(part.results) >= app.m_min and not part.done:
            winner, ok = majority_vote([r for _, r, _ in part.results],
                                       quorum=app.m_min)
            if ok:
                part.done = True
                part.winner = winner
                self.quorum_sizes[(app_id, part_id)] = len(part.results)
                m = self.metrics.get(app_id)
                if m is not None:
                    m.record_cycle(
                        msg.payload.get("data_bytes", part.data_bytes),
                        msg.payload.get("time_s", 0.0),
                        app_downloaded=not app.swarm)
                self._cancel_part_leases(app_id, part_id)
                self.EVAL(app_id, True)
                if self.dir:
                    self.dir.save_seed_result(app_id, part_id, winner)
                if app.swarm:
                    self._gossip_part_done(app_id, [(part_id, winner)])
                if app.done and app_id not in self.completed_at:
                    self.completed_at[app_id] = self.rt.now()
                if app_id in self.apps:
                    self.STAT()
        if not forwarded:
            self.SEND(msg.src, Msg(RESULT_ACK, self.node_id,
                                   {"app_id": app_id, "part_id": part_id,
                                    "valid": True}, size_bytes=64))

    def TAIL(self) -> None:
        """Expire overdue leases and re-DIST (straggler mitigation)."""
        now = self.rt.now()
        for app_id, tail in self.tails.items():
            for lease in tail.expired(now):
                tail.release(lease.part_id, lease.volunteer_id)
                if self.dir:
                    self.dir.tracker_log(app_id,
                                         f"{now:.3f} timeout part="
                                         f"{lease.part_id} "
                                         f"volunteer={lease.volunteer_id}")
                # the paper drops the volunteer from the mapping list and
                # redistributes on the next REQ; nothing else to do here

    def _cancel_part_leases(self, app_id: str, part_id: int) -> None:
        """Endgame reconciliation for *work*: a part just validated, so any
        lease still outstanding for it (duplicate leasing happens when
        seeder partitions drain) is redundant — release it and PART_CANCEL
        the volunteer so the duplicate execution aborts."""
        if not self.cfg.endgame:
            return
        tail = self.tails.get(app_id)
        if tail is None:
            return
        for lease in list(tail.active().get(part_id, [])):
            tail.release(part_id, lease.volunteer_id)
            self.SEND(lease.volunteer_id,
                      Msg(PART_CANCEL, self.node_id,
                          {"app_id": app_id, "part_id": part_id},
                          size_bytes=64))

    def _on_part_cancel(self, msg: Msg) -> None:
        """The part this volunteer is crunching was validated elsewhere:
        abort the (now redundant) execution and move on to fresh work."""
        app_id = msg.payload["app_id"]
        part_id = msg.payload["part_id"]
        ctx = self.current.get(app_id)
        if ctx is None or not ctx.get("busy"):
            return
        tag = ctx.get("tag")
        if tag is None or tag[1] != part_id:
            return
        if self.rt.cancel_work(self.node_id, tag):
            # simulator path: the job is gone, continue leeching now
            self.cancelled_parts += 1
            ctx["busy"] = False
            ctx["tag"] = None
            self.TIME(app_id, "cancel")
            self._request_work(app_id)
        else:
            # real-time path: the result (or CANCELLED sentinel) still
            # arrives; mark it for discard in on_work_done
            ctx["drop"] = tag

    # ================== seeder-set sync (paper §V) ====================== #
    def _other_seeders(self, app_id: str) -> Set[str]:
        row = self._row_for(app_id)
        peers = set(row.seeders) | {row.host_id} if row else set()
        peers |= self.swarm_peers.get(app_id, set())
        peers.discard(self.node_id)
        return peers

    def _done_parts(self, app) -> List[tuple]:
        """(part_id, validated winner) for every done part — the payload
        PART_DONE syncs carry.  `winner` is the majority_vote result;
        falling back to the first recorded vote only covers parts from
        pre-`winner` state (e.g. a restore)."""
        return [(p.part_id, p.winner if p.winner is not None
                 else (p.results[0][1] if p.results else None))
                for p in app.parts if p.done]

    def _gossip_part_done(self, app_id: str,
                          parts: List[tuple]) -> None:
        for peer in self._other_seeders(app_id):
            self.SEND(peer, Msg(PART_DONE, self.node_id,
                                {"app_id": app_id, "parts": parts},
                                size_bytes=96 + 32 * len(parts)))

    def _on_part_done(self, msg: Msg) -> None:
        app = self._seeded_app(msg.payload["app_id"])
        if app is None:
            return
        app_id = msg.payload["app_id"]
        for part_id, winner in msg.payload["parts"]:
            part = app.parts[part_id]
            if not part.done:
                part.done = True
                part.winner = winner
                part.results.append((msg.src, winner, 0.0))
                # another seeder validated it first: any lease this seeder
                # still holds for the part is a duplicate — cancel it
                self._cancel_part_leases(app_id, part_id)
        if app.done and app_id not in self.completed_at:
            self.completed_at[app_id] = self.rt.now()

    def _on_seeder_update(self, msg: Msg) -> None:
        """Relayed by the tracker: a new replica joined the seeder set —
        bring it up to date on validated parts.  Only the app's host plus
        the three lowest-id seeders in this agent's current view send the
        sync: one copy suffices, and N existing seeders each shipping the
        full done list to every newcomer made replica formation
        O(N² · parts) in large swarms.  The host is always a sender
        because the tracker keeps `host_id` pointing at a live node
        (promotion pushes immediately), so even a stale seeder view
        cannot leave the newcomer without any sync."""
        app_id = msg.payload["app_id"]
        new_seeder = msg.payload["seeder"]
        app = self._seeded_app(app_id)
        if app is None or new_seeder == self.node_id:
            return
        self.swarm_peers[app_id].add(new_seeder)
        ring = [s for s in self._seeder_ring(app_id) if s != new_seeder]
        row = self._row_for(app_id)
        is_host = (app_id in self.apps
                   or (row is not None and row.host_id == self.node_id))
        if not is_host and self.node_id not in ring[:3]:
            return
        done = self._done_parts(app)
        if done:
            self.SEND(new_seeder, Msg(PART_DONE, self.node_id,
                                      {"app_id": app_id, "parts": done},
                                      size_bytes=96 + 32 * len(done)))

    def _on_peer_gone(self, node: str) -> None:
        """A volunteer left (BYE) or died: reclaim its leases immediately
        instead of waiting for TAIL timeout, and forget its pieces."""
        for app_id, tail in self.tails.items():
            freed = tail.drop_volunteer(node)
            if freed and self.dir:
                self.dir.tracker_log(app_id,
                                     f"{self.rt.now():.3f} peer_gone "
                                     f"volunteer={node} parts={freed}")
        # engine side: forget pieces/slots, re-route outstanding requests
        self.px.on_peer_gone(node)
        # re-route in-flight work pointed at the dead peer
        for app_id, ctx in list(self.current.items()):
            if ctx.get("host") == node and not ctx.get("busy"):
                self._request_work(app_id)

    # ==================== piece transfer (paper §V) ===================== #
    # All swarm transfer mechanics live in the PieceExchange engine
    # (core/piece_exchange.py); the agent only routes messages to it (see
    # RECV) and reacts to image completion below.
    def _apply_manifest_update(self, app_id: str, manifest) -> None:
        """A newer revision of an app we track was published (tracker
        MANIFEST_UPDATE gossip, or a fresher APP_LIST row): retire the
        old image identity and move the engine to the delta fetch.
        Idempotent; stale or duplicate updates are ignored."""
        if manifest is None or app_id in self.apps:
            return                       # we are the publisher (or junk)
        local = self.px.manifests.get(app_id)
        if local is None or not manifest.supersedes(local):
            return
        # the old manifest hash no longer names a valid image here: work
        # execution and replica seeding re-enable when v(k+1) verifies
        self.images.pop(app_id, None)
        self.image_completed_at.pop(app_id, None)
        if not self.px.upgrade(app_id, manifest):
            return
        if app_id in self.px.fetching:
            ctx = self.current.setdefault(app_id, {"host": None,
                                                   "busy": False})
            ctx["fetching"] = True
            ctx["last_req"] = self.rt.now()

    def _on_image_complete(self, app_id: str, manifest_hash: str,
                           image: Optional[bytes]) -> None:
        """Engine callback — all pieces verified: unpack the executable via
        the registry and join the seeder set as a replica."""
        self.images[app_id] = manifest_hash
        self.image_completed_at.setdefault(app_id, self.rt.now())
        entry = resolve_executable(manifest_hash)
        cap = self.cfg.max_replica_seeders
        if cap is not None:
            row = next((r for r in self.app_list if r.app_id == app_id),
                       None)
            if row is not None and len(row.seeders) >= cap:
                entry = None     # enough seeders already; serve pieces only
        if (self.cfg.replica_seed and entry is not None
                and entry.blueprint is not None
                and app_id not in self.apps
                and app_id not in self.replicas):
            app = entry.blueprint()
            self.replicas[app_id] = app
            self.tails.setdefault(app_id,
                                  LeaseTable(self.cfg.work_timeout_s))
            self.metrics.setdefault(app_id, AppMetrics(
                d_app_bytes=app.app_bytes, m_min=app.m_min))
            self.SEND(self.server_id, Msg(SEEDER_UPDATE, self.node_id,
                                          {"app_id": app_id,
                                           "seeder": self.node_id,
                                           "manifest_hash": manifest_hash},
                                          size_bytes=96))
        elif (self.cfg.replica_seed and entry is not None
                and app_id in self.replicas):
            # a revision upgrade completed while we were already a replica
            # seeder: the tracker reset the app's seeder set to the
            # publisher, so our membership must be re-announced
            self.replicas[app_id] = (entry.blueprint()
                                     if entry.blueprint is not None
                                     else self.replicas[app_id])
            self.SEND(self.server_id, Msg(SEEDER_UPDATE, self.node_id,
                                          {"app_id": app_id,
                                           "seeder": self.node_id,
                                           "manifest_hash": manifest_hash},
                                          size_bytes=96))
        ctx = self.current.get(app_id)
        if ctx is not None and ctx.get("fetching"):
            self._request_work(app_id)

    # ============================ worker ================================ #
    def REQ(self, app_id: str, host_id: str) -> None:
        """Request application + next data part from the host."""
        ctx = self.current.setdefault(app_id, {"host": host_id,
                                               "busy": False})
        ctx["host"] = host_id
        ctx["fetching"] = False
        ctx["awaiting"] = True          # a grant is in flight
        ctx["last_req"] = self.rt.now()
        self.SEND(host_id, Msg(REQ, self.node_id, {"app_id": app_id},
                               size_bytes=96))

    def SCAN(self, payload: dict) -> int:
        """Measure the size of the received application and data."""
        return int(payload.get("app_bytes", 0)) + int(
            payload.get("data_bytes", 0))

    def RUN(self, app_id: str, part_id: int, payload: Any,
            host_id: str) -> None:
        """Execute one part; TIME marks start/end via the runtime."""
        ctx = self.current.get(app_id)
        if ctx is None or ctx.get("busy"):
            return      # stale APP_DATA must not double-submit work
        ctx["busy"] = True
        sim_dur = None
        fn = None
        # resolve the executable from the registry, keyed by the manifest
        # hash of the (verified) image this agent holds
        mh = self.images.get(app_id)
        entry = resolve_executable(mh) if mh else None
        if entry is not None:
            if entry.cost_fn is not None:
                # work units at reference speed 1.0; the runtime's processor-
                # sharing executor applies node speed and contention
                sim_dur = entry.cost_fn(payload, 1.0) \
                    + self.cfg.cycle_overhead_s
            if entry.run_fn is not None:
                fn = (lambda p=payload, f=entry.run_fn: f(p))
        tag = (app_id, part_id, host_id)
        ctx["tag"] = tag                # PART_CANCEL needs the exact tag
        self.TIME(app_id, "start")
        self.rt.submit_work(self.node_id, tag, fn, sim_duration_s=sim_dur)

    def TIME(self, app_id: str, mark: str) -> None:
        """Track working time; log kept under Leech/App/Data/Time (Fig. 3)."""
        if self.dir:
            self.dir.time_log(app_id, f"{self.rt.now():.3f} {mark}")

    def COLLECT(self, app_id: str, elapsed_s: float, nbytes: int) -> dict:
        """Gather TIME and SCAN info about a finished part."""
        self.leech_time[app_id] += elapsed_s
        self.leech_bytes[app_id] += nbytes
        self.completed_cycles[app_id] += 1
        return {"time_s": elapsed_s, "data_bytes": nbytes}

    def SAVE(self, app_id: str, part_id: int, result: Any) -> None:
        if self.dir:
            self.dir.save_leech_result(app_id, part_id, result)

    def LOAD(self, app_id: str, part_id: int) -> Any:
        if self.dir:
            return self.dir.load_leech_result(app_id, part_id)
        return None

    def STOP(self, app_id: str, reason: str = "") -> None:
        """Drop an application: its data, results and pending work."""
        self.current.pop(app_id, None)
        self.stopped_apps.add(app_id)
        self.app_list = [a for a in self.app_list if a.app_id != app_id]
        self.replicas.pop(app_id, None)
        keep_image = app_id in self.apps
        if not keep_image:
            self.images.pop(app_id, None)
        self.px.drop_app(app_id, keep_image=keep_image)
        self.no_work_from.pop(app_id, None)
        for key in [k for k in self.part_results if k[0] == app_id]:
            del self.part_results[key]
        for key in [k for k in self.voted if k[0] == app_id]:
            del self.voted[key]
        if self.dir:
            self.dir.drop_leech_app(app_id)
        self._maybe_start_work()

    # ------------------------------------------------------------------ #
    def _row_for(self, app_id: str) -> Optional[AppInfo]:
        for a in self.app_list:
            if a.app_id == app_id:
                return a
        return None

    def _work_candidates(self, row: AppInfo) -> List[str]:
        """Seeders this leecher may REQ work from, least-loaded first (the
        tracker orders `row.seeders` by reported load)."""
        cands = [s for s in row.seeders if s != self.node_id]
        if row.host_id != self.node_id:
            if row.host_id not in cands:
                cands.insert(0, row.host_id)
        elif not cands:
            # self-leech (paper Scenario III/IV): the host crunches its own
            # application, REQ/DIST looping back through itself
            cands = [self.node_id]
        if not cands:
            return []
        # stable per-leecher rotation spreads first REQs across seeders
        off = sum(ord(c) for c in self.node_id + row.app_id) % len(cands)
        return cands[off:] + cands[:off]

    def _request_work(self, app_id: str) -> bool:
        row = self._row_for(app_id)
        if row is None:
            return False
        tried = self.no_work_from.get(app_id, set())
        for cand in self._work_candidates(row):
            if cand not in tried:
                self.REQ(app_id, cand)
                return True
        return False

    def _on_app_list(self, rows: List[AppInfo]) -> None:
        # an app the tracker advertises again revives: DROP_APP meant "gone
        # now", not "gone forever" — its host may have returned from a
        # crash-restart or a partition-induced false drop
        self.stopped_apps -= {r.app_id for r in rows}
        self.app_list = [r for r in rows if r.app_id not in self.stopped_apps]
        for row in self.app_list:
            if row.manifest is not None:
                local = self.px.manifests.get(row.app_id)
                if local is not None and row.manifest.supersedes(local):
                    # the tracker's row moved to a newer revision (our
                    # MANIFEST_UPDATE was lost, or we were partitioned):
                    # catch up before trusting any seeder set
                    self._apply_manifest_update(row.app_id, row.manifest)
                    local = self.px.manifests.get(row.app_id)
                if local is not None \
                        and local.version != row.manifest.version:
                    # a stale row (older revision than we track) must not
                    # feed its seeder set into our availability plane
                    continue
                self.px.note_full_seeders(row.app_id,
                                          set(row.seeders) | {row.host_id})
                if (row.app_id in self.replicas
                        and self.node_id not in row.seeders):
                    # our SEEDER_UPDATE was lost (or we were dropped while
                    # partitioned): repeat it — the tracker is idempotent
                    self.SEND(self.server_id,
                              Msg(SEEDER_UPDATE, self.node_id,
                                  {"app_id": row.app_id,
                                   "seeder": self.node_id,
                                   "manifest_hash":
                                       self.images.get(row.app_id)},
                                  size_bytes=96))
            # tracker promoted this node from replica to host (origin died)
            if row.host_id == self.node_id and row.app_id in self.replicas:
                app = self.replicas.pop(row.app_id)
                app.host_id = self.node_id
                self.apps[row.app_id] = app
                self.current.pop(row.app_id, None)
                self.STAT()
            # the seeder this leecher worked with vanished: re-route
            ctx = self.current.get(row.app_id)
            if ctx is not None and ctx.get("fetching"):
                self.px.pump(row.app_id)
            elif ctx is not None:
                host = ctx.get("host")
                live = set(row.seeders) | {row.host_id}
                if host is not None and host not in live:
                    ctx["host"] = None
                    if not ctx.get("busy"):
                        self._request_work(row.app_id)
        self._maybe_start_work()

    def _maybe_start_work(self) -> None:
        active = len(self.current)
        now = self.rt.now()
        for row in self.app_list:
            if active >= self.cfg.max_parallel_apps:
                break
            if row.host_id == self.node_id and not self.cfg.self_leech:
                continue
            if row.app_id in self.current:
                continue
            if row.parts_remaining == 0 and row.p > 0 \
                    and not (self.cfg.replicate_completed
                             and row.manifest is not None
                             and row.app_id not in self.images):
                continue    # host reported it complete
            if self.dry_until.get(row.app_id, -1.0) > now:
                continue    # backing off after NO_WORK
            if row.manifest is not None and row.app_id not in self.images:
                # swarm app: fetch the image piece-wise before crunching;
                # the engine announces the join (the tracker relays it so
                # existing members learn about us and vice versa)
                self.current[row.app_id] = {"host": None, "busy": False,
                                            "fetching": True,
                                            "last_req": now}
                self.px.join(row.app_id, row.manifest)
            else:
                if not self._request_work(row.app_id):
                    continue
            active += 1

    def _on_no_work(self, msg: Msg) -> None:
        app_id = msg.payload["app_id"]
        ctx = self.current.get(app_id)
        if ctx is None:
            return
        ctx["awaiting"] = False
        # this seeder is (momentarily) dry; try the next replica before
        # backing off — other seeders may still hold leasable parts
        self.no_work_from[app_id].add(msg.src)
        if self._request_work(app_id):
            return
        self.current.pop(app_id, None)
        self.no_work_from.pop(app_id, None)
        # back off: the app may only be out of *leasable* parts right
        # now (all leased, not all validated) — retry later
        self.dry_until[app_id] = self.rt.now() + self.cfg.retry_s
        self.rt.set_timer(self.node_id, "retry", self.cfg.retry_s)
        self._maybe_start_work()

    def _on_app_data(self, msg: Msg) -> None:
        app_id = msg.payload["app_id"]
        part_id = msg.payload["part_id"]
        ctx = self.current.get(app_id)
        if ctx is None or ctx.get("busy"):
            return
        ctx["awaiting"] = False
        mh = msg.payload.get("manifest_hash")
        if mh is not None and msg.payload.get("app_bytes", 0) > 0:
            # monolithic shipment: the full image rode along, so this agent
            # now holds it and may resolve the executable
            self.images.setdefault(app_id, mh)
        nbytes = self.SCAN(msg.payload)
        ctx["bytes"] = nbytes
        self.no_work_from.get(app_id, set()).discard(msg.src)
        cached = self.part_results.get((app_id, part_id))
        if cached is not None:
            # a different seeder re-leased a part this volunteer already
            # computed: resend the stored result instead of burning a
            # duplicate execution (SAVE/LOAD, endgame dedup)
            self.SEND(msg.src, Msg(RESULT, self.node_id, {
                "app_id": app_id, "part_id": part_id, "result": cached,
                "time_s": 0.0, "data_bytes": 0}, size_bytes=1024))
            return
        self.RUN(app_id, part_id, msg.payload["payload"], msg.src)

    def on_work_done(self, tag, result, elapsed_s: float) -> None:
        app_id, part_id, host_id = tag
        self.TIME(app_id, "end")
        ctx = self.current.get(app_id)
        if ctx is None:
            return      # STOPped while running
        ctx["busy"] = False
        ctx["last_req"] = self.rt.now()
        if result is CANCELLED or ctx.get("drop") == tag:
            # PART_CANCELled execution: discard, keep leeching
            ctx.pop("drop", None)
            ctx["tag"] = None
            self.cancelled_parts += 1
            self._request_work(app_id)
            return
        info = self.COLLECT(app_id, elapsed_s, ctx.get("bytes", 0))
        self.SAVE(app_id, part_id, result)
        loaded = self.LOAD(app_id, part_id)
        final = loaded if loaded is not None else result
        self.part_results[(app_id, part_id)] = final
        # deliver to the live seeder for this app: if the one that leased
        # the part died meanwhile, its successor revalidates the part
        dest = ctx.get("host") or host_id
        self.SEND(dest, Msg(RESULT, self.node_id, {
            "app_id": app_id, "part_id": part_id, "result": final,
            "time_s": info["time_s"], "data_bytes": info["data_bytes"],
        }, size_bytes=1024))
        self.results_log.append((self.rt.now(), app_id, part_id))

    def _on_result_ack(self, msg: Msg) -> None:
        app_id = msg.payload["app_id"]
        if not msg.payload.get("valid", True):
            # the seeder rejected this result: drop the cached copy so any
            # future grant (from a seeder that has not seen the vote)
            # re-executes instead of replaying known-bad data
            self.part_results.pop((app_id, msg.payload["part_id"]), None)
        ctx = self.current.get(app_id)
        if ctx is not None and not ctx.get("busy") \
                and not ctx.get("fetching") and not ctx.get("awaiting"):
            # keep leeching the same app until the host runs dry (the
            # busy/awaiting guards ignore duplicate ACKs, e.g. an owner's
            # late reject after the forwarder's optimistic accept, so one
            # ACK never spawns two competing leases)
            self.REQ(app_id, msg.src)

    def _recover_stalled(self) -> None:
        """Periodic self-heal: re-issue piece requests and work REQs that
        went unanswered (e.g. the peer died before PEER_GONE propagated)."""
        now = self.rt.now()
        # the threshold must sit above any legitimate queueing delay of a
        # bulk APP_DATA/PIECE_DATA transfer (a saturated seeder uplink can
        # hold a reply for a long while) — use the TAIL timescale, same as
        # the seeders' own lease expiry.  Chaos deployments set the
        # dedicated piece_timeout_s lower so lossy links re-request fast.
        stall = self.cfg.work_timeout_s
        piece_stall = self.cfg.piece_timeout_s or stall
        for app_id, ctx in list(self.current.items()):
            if ctx.get("fetching"):
                self.px.recover(app_id, piece_stall)
            elif not ctx.get("busy") and now - ctx.get("last_req",
                                                       0.0) > stall:
                self.no_work_from.pop(app_id, None)
                self._request_work(app_id)
        if now - self._last_server > self.cfg.reregister_s:
            # tracker silence: our REGISTER was lost, or the tracker
            # false-dropped us while our PONGs were dying on a lossy link.
            # Either way it no longer pushes us APP_LISTs — re-register
            # (idempotent at the tracker, throttled to once per window).
            self._last_server = now
            self.SEND(self.server_id, Msg(REGISTER, self.node_id,
                                          {"apps": self._self_rows(),
                                           "boot": self._boot}))

    def on_message(self, msg: Msg) -> None:
        self.RECV(msg)

    def on_timer(self, name: str) -> None:
        if name == "status":
            # replicas must report too: their lease counts feed the
            # tracker's least-loaded routing and promotion choices
            if self.apps or self.replicas:
                self.STAT()
            self._recover_stalled()
        elif name == "tail":
            self.TAIL()
        elif name == "rechoke":
            self.px.rechoke()
        elif name == "gossip":
            self._regossip()
        elif name == "retry":
            self._maybe_start_work()

    def _regossip(self) -> None:
        """Periodic PART_DONE re-gossip (gossip_interval_s): the done sets
        of the seeder ring re-converge even when individual gossip
        messages were lost to the network — receivers are idempotent."""
        for app_id in list(self.apps) + list(self.replicas):
            app = self._seeded_app(app_id)
            if app is None or not app.swarm:
                continue
            done = self._done_parts(app)
            if done:
                self._gossip_part_done(app_id, done)
