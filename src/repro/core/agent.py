"""Volunteer agent (paper §III.E-G, Figs. 3-5).

Modules: connector (RECV, SEND), tracker (EVAL, DIST, STAT, VAL, TAIL) and
worker (REQ, SCAN, RUN, TIME, COLLECT, SAVE, LOAD, STOP) — the paper's 15
agent procedures.  Every agent is simultaneously:

  * a SEEDER for its own applications (A_self): answers REQ with app+data,
    validates RESULTs by m_min-way majority voting, reports status via STAT;
  * a LEECHER for other hosts' applications: REQ -> SCAN+RUN -> TIME ->
    COLLECT+LOAD -> SEND result, in a loop until the host runs dry.

The dual Seed/ and Leech/ working directories (Fig. 3) are managed by
core.directory; TAIL's volunteer log lives under Seed/App/<id>/Data/Tracker
and TIME's under Leech/App/<id>/Data/Time, as in the paper.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Set, Tuple

from repro.core import directory as dirs
from repro.core.messages import (APP_DATA, APP_LIST, BYE, DROP_APP, NO_WORK,
                                 PING, PONG, REGISTER, REQ, RESULT,
                                 RESULT_ACK, STATUS, AppInfo, Msg)
from repro.core.metrics import AppMetrics
from repro.core.runtime import Node, Runtime
from repro.core.validation import majority_vote
from repro.core.workunit import Application, LeaseTable, Part


@dataclass
class AgentConfig:
    work_timeout_s: float = 60.0        # TAIL timeout parameter
    status_interval_s: float = 1.0
    retry_s: float = 2.0                # back-off after NO_WORK from a host
    # per-cycle protocol/VM overhead in simulation (calibrated from the
    # paper's Scenario I: w_parallel 6.35s vs sequential-VM 5.51s)
    cycle_overhead_s: float = 0.0
    accept_from: tuple = ()             # RECV accept/deny parameter
    deny_from: tuple = ()
    max_parallel_apps: int = 2          # leech this many apps concurrently
    self_leech: bool = False            # hosts also crunch their own apps
    root_dir: Optional[str] = None      # enables on-disk Fig. 3 layout


class Agent(Node):
    def __init__(self, node_id: str, server_id: str = "server",
                 config: Optional[AgentConfig] = None,
                 val_hook: Optional[Callable[[int, Any], bool]] = None):
        self.node_id = node_id
        self.server_id = server_id
        self.cfg = config or AgentConfig()
        self.val_hook = val_hook
        # --- seeder state -------------------------------------------------
        self.apps: Dict[str, Application] = {}         # A_self
        self.tail = LeaseTable(self.cfg.work_timeout_s)
        self.tails: Dict[str, LeaseTable] = {}
        self.metrics: Dict[str, AppMetrics] = {}
        # --- leecher state ------------------------------------------------
        self.app_list: List[AppInfo] = []
        self.current: Dict[str, dict] = {}             # app_id -> work ctx
        self.results_log: List[tuple] = []
        self.completed_cycles: Dict[str, int] = collections.defaultdict(int)
        self.leech_time: Dict[str, float] = collections.defaultdict(float)
        self.leech_bytes: Dict[str, float] = collections.defaultdict(float)
        self.stopped_apps: Set[str] = set()
        self.dry_until: Dict[str, float] = {}
        self.completed_at: Dict[str, float] = {}
        self.dir = (dirs.AgentDirs(self.cfg.root_dir, node_id)
                    if self.cfg.root_dir else None)

    # ------------------------------------------------------------------ #
    def host_app(self, app: Application) -> None:
        app.host_id = self.node_id
        self.apps[app.app_id] = app
        self.tails[app.app_id] = LeaseTable(self.cfg.work_timeout_s)
        m = AppMetrics(d_app_bytes=app.app_bytes, m_min=app.m_min)
        self.metrics[app.app_id] = m
        if self.dir:
            self.dir.seed_app(app.app_id, app.app_bytes)

    def start(self, rt: Runtime) -> None:
        super().start(rt)
        self.SEND(self.server_id, Msg(REGISTER, self.node_id,
                                      {"apps": self._self_rows()}))
        rt.set_timer(self.node_id, "status", self.cfg.status_interval_s,
                     periodic=True)
        rt.set_timer(self.node_id, "tail", self.cfg.work_timeout_s / 2,
                     periodic=True)

    def _self_rows(self) -> List[AppInfo]:
        rows = []
        for app in self.apps.values():
            m = self.metrics[app.app_id]
            rows.append(AppInfo(app.app_id, self.node_id, d=m.d, p=m.p,
                                w=m.w, n_parts=len(app.parts),
                                parts_remaining=sum(
                                    0 if p.done else 1 for p in app.parts)))
        return rows

    # ========================== connector =============================== #
    def RECV(self, msg: Msg) -> None:
        """Receive messages; accept/deny lists are the paper's parameter."""
        if self.cfg.accept_from and msg.src not in self.cfg.accept_from \
                and msg.src != self.server_id:
            return
        if msg.src in self.cfg.deny_from:
            return
        kind = msg.kind
        if kind == PING:
            self.SEND(self.server_id, Msg(PONG, self.node_id, size_bytes=64))
        elif kind == APP_LIST:
            self._on_app_list(msg.payload["apps"])
        elif kind == DROP_APP:
            for app_id in msg.payload["app_ids"]:
                self.STOP(app_id, reason="host dropped from list")
        elif kind == REQ:
            self.DIST(msg.src, msg.payload["app_id"])
        elif kind == APP_DATA:
            self._on_app_data(msg)
        elif kind == NO_WORK:
            app_id = msg.payload["app_id"]
            self.current.pop(app_id, None)
            # back off: the host may only be out of *leasable* parts right
            # now (all leased, not all validated) — retry later
            self.dry_until[app_id] = self.rt.now() + self.cfg.retry_s
            self.rt.set_timer(self.node_id, "retry", self.cfg.retry_s)
            self._maybe_start_work()
        elif kind == RESULT:
            self.VAL(msg)
        elif kind == RESULT_ACK:
            self._on_result_ack(msg)

    def SEND(self, dst: str, msg: Msg) -> None:
        self.rt.send(dst, msg)

    # =========================== tracker ================================ #
    def EVAL(self, app_id: str, valid: bool) -> None:
        """Track m_min/m_max progress for an application's validation."""
        app = self.apps.get(app_id)
        if app is None:
            return
        if valid and app.m_min < app.m_max:
            app.m_min += 1
            self.metrics[app_id].m_min = app.m_min

    def DIST(self, volunteer: str, app_id: str) -> None:
        """Lease the next pending part to `volunteer` and ship app+data."""
        app = self.apps.get(app_id)
        if app is None:
            self.SEND(volunteer, Msg(NO_WORK, self.node_id,
                                     {"app_id": app_id}, size_bytes=64))
            return
        tail = self.tails[app_id]
        pending = app.pending_parts(tail.active())
        if not pending:
            self.SEND(volunteer, Msg(NO_WORK, self.node_id,
                                     {"app_id": app_id}, size_bytes=64))
            return
        part = pending[0]
        tail.grant(part.part_id, volunteer, self.rt.now())
        if self.dir:
            self.dir.tracker_log(app_id,
                                 f"{self.rt.now():.3f} lease part="
                                 f"{part.part_id} to={volunteer}")
        self.SEND(volunteer, Msg(
            APP_DATA, self.node_id,
            {"app_id": app_id, "part_id": part.part_id,
             "payload": part.payload, "app_bytes": app.app_bytes,
             "data_bytes": part.data_bytes},
            size_bytes=app.app_bytes + part.data_bytes))

    def STAT(self) -> None:
        """Update validated-work status (incl. d, w) to the server."""
        self.SEND(self.server_id, Msg(STATUS, self.node_id,
                                      {"apps": self._self_rows()}))

    def VAL(self, msg: Msg) -> None:
        """Validate a RESULT by majority voting once m_min results arrived."""
        app_id = msg.payload["app_id"]
        app = self.apps.get(app_id)
        if app is None:
            return
        part_id = msg.payload["part_id"]
        part = app.parts[part_id]
        tail = self.tails[app_id]
        tail.release(part_id, msg.src)
        if self.val_hook is not None and not self.val_hook(
                part_id, msg.payload["result"]):
            # malicious result: discard; status not updated (paper §III.D)
            self.SEND(msg.src, Msg(RESULT_ACK, self.node_id,
                                   {"app_id": app_id, "part_id": part_id,
                                    "valid": False}, size_bytes=64))
            return
        part.results.append((msg.src, msg.payload["result"],
                             msg.payload.get("time_s", 0.0)))
        if len(part.results) >= app.m_min and not part.done:
            winner, ok = majority_vote([r for _, r, _ in part.results],
                                       quorum=app.m_min)
            if ok:
                part.done = True
                m = self.metrics[app_id]
                m.record_cycle(msg.payload.get("data_bytes", part.data_bytes),
                               msg.payload.get("time_s", 0.0))
                self.EVAL(app_id, True)
                if self.dir:
                    self.dir.save_seed_result(app_id, part_id, winner)
                if app.done and app_id not in self.completed_at:
                    self.completed_at[app_id] = self.rt.now()
                self.STAT()
        self.SEND(msg.src, Msg(RESULT_ACK, self.node_id,
                               {"app_id": app_id, "part_id": part_id,
                                "valid": True}, size_bytes=64))

    def TAIL(self) -> None:
        """Expire overdue leases and re-DIST (straggler mitigation)."""
        now = self.rt.now()
        for app_id, tail in self.tails.items():
            for lease in tail.expired(now):
                tail.release(lease.part_id, lease.volunteer_id)
                if self.dir:
                    self.dir.tracker_log(app_id,
                                         f"{now:.3f} timeout part="
                                         f"{lease.part_id} "
                                         f"volunteer={lease.volunteer_id}")
                # the paper drops the volunteer from the mapping list and
                # redistributes on the next REQ; nothing else to do here

    # ============================ worker ================================ #
    def REQ(self, app_id: str, host_id: str) -> None:
        """Request application + next data part from the host."""
        self.current.setdefault(app_id, {"host": host_id, "busy": False})
        self.SEND(host_id, Msg(REQ, self.node_id, {"app_id": app_id},
                               size_bytes=96))

    def SCAN(self, payload: dict) -> int:
        """Measure the size of the received application and data."""
        return int(payload.get("app_bytes", 0)) + int(
            payload.get("data_bytes", 0))

    def RUN(self, app_id: str, part_id: int, payload: Any,
            host_id: str) -> None:
        """Execute one part; TIME marks start/end via the runtime."""
        ctx = self.current.get(app_id)
        if ctx is None:
            return
        ctx["busy"] = True
        row = self._row_for(app_id)
        sim_dur = None
        fn = None
        app = None
        for a in self.app_list:
            if a.app_id == app_id:
                app = a
        # resolve executable: hosts ship cost/run fns out-of-band in this
        # in-process transport (a real deployment ships code in APP_DATA)
        host_app = self._resolve_app(app_id, host_id)
        if host_app is not None:
            if host_app.cost_fn is not None:
                # work units at reference speed 1.0; the runtime's processor-
                # sharing executor applies node speed and contention
                sim_dur = host_app.cost_fn(payload, 1.0) \
                    + self.cfg.cycle_overhead_s
            if host_app.run_fn is not None:
                fn = (lambda p=payload, f=host_app.run_fn: f(p))
        tag = (app_id, part_id, host_id)
        self.TIME(app_id, "start")
        self.rt.submit_work(self.node_id, tag, fn, sim_duration_s=sim_dur)

    def _resolve_app(self, app_id: str, host_id: str) -> Optional[Application]:
        host = getattr(self.rt, "nodes", {}).get(host_id)
        if host is not None and hasattr(host, "apps"):
            return host.apps.get(app_id)
        return None

    def TIME(self, app_id: str, mark: str) -> None:
        """Track working time; log kept under Leech/App/Data/Time (Fig. 3)."""
        if self.dir:
            self.dir.time_log(app_id, f"{self.rt.now():.3f} {mark}")

    def COLLECT(self, app_id: str, elapsed_s: float, nbytes: int) -> dict:
        """Gather TIME and SCAN info about a finished part."""
        self.leech_time[app_id] += elapsed_s
        self.leech_bytes[app_id] += nbytes
        self.completed_cycles[app_id] += 1
        return {"time_s": elapsed_s, "data_bytes": nbytes}

    def SAVE(self, app_id: str, part_id: int, result: Any) -> None:
        if self.dir:
            self.dir.save_leech_result(app_id, part_id, result)

    def LOAD(self, app_id: str, part_id: int) -> Any:
        if self.dir:
            return self.dir.load_leech_result(app_id, part_id)
        return None

    def STOP(self, app_id: str, reason: str = "") -> None:
        """Drop an application: its data, results and pending work."""
        self.current.pop(app_id, None)
        self.stopped_apps.add(app_id)
        self.app_list = [a for a in self.app_list if a.app_id != app_id]
        if self.dir:
            self.dir.drop_leech_app(app_id)
        self._maybe_start_work()

    # ------------------------------------------------------------------ #
    def _row_for(self, app_id: str) -> Optional[AppInfo]:
        for a in self.app_list:
            if a.app_id == app_id:
                return a
        return None

    def _on_app_list(self, rows: List[AppInfo]) -> None:
        self.app_list = [r for r in rows if r.app_id not in self.stopped_apps]
        self._maybe_start_work()

    def _maybe_start_work(self) -> None:
        active = len(self.current)
        now = self.rt.now()
        for row in self.app_list:
            if active >= self.cfg.max_parallel_apps:
                break
            if row.host_id == self.node_id and not self.cfg.self_leech:
                continue
            if row.app_id in self.current:
                continue
            if row.parts_remaining == 0 and row.p > 0:
                continue    # host reported it complete
            if self.dry_until.get(row.app_id, -1.0) > now:
                continue    # backing off after NO_WORK
            self.REQ(row.app_id, row.host_id)
            active += 1

    def _on_app_data(self, msg: Msg) -> None:
        app_id = msg.payload["app_id"]
        ctx = self.current.get(app_id)
        if ctx is None or ctx.get("busy"):
            return
        nbytes = self.SCAN(msg.payload)
        ctx["bytes"] = nbytes
        self.RUN(app_id, msg.payload["part_id"], msg.payload["payload"],
                 msg.src)

    def on_work_done(self, tag, result, elapsed_s: float) -> None:
        app_id, part_id, host_id = tag
        self.TIME(app_id, "end")
        ctx = self.current.get(app_id)
        if ctx is None:
            return      # STOPped while running
        ctx["busy"] = False
        info = self.COLLECT(app_id, elapsed_s, ctx.get("bytes", 0))
        self.SAVE(app_id, part_id, result)
        loaded = self.LOAD(app_id, part_id)
        self.SEND(host_id, Msg(RESULT, self.node_id, {
            "app_id": app_id, "part_id": part_id,
            "result": loaded if loaded is not None else result,
            "time_s": info["time_s"], "data_bytes": info["data_bytes"],
        }, size_bytes=1024))
        self.results_log.append((self.rt.now(), app_id, part_id))

    def _on_result_ack(self, msg: Msg) -> None:
        app_id = msg.payload["app_id"]
        if app_id in self.current:
            # keep leeching the same app until the host runs dry
            self.REQ(app_id, msg.src)

    def on_message(self, msg: Msg) -> None:
        self.RECV(msg)

    def on_timer(self, name: str) -> None:
        if name == "status":
            if self.apps:
                self.STAT()
        elif name == "tail":
            self.TAIL()
        elif name == "retry":
            self._maybe_start_work()
