"""Torrent-style piece distribution (rarest-first) for bulk payloads.

The paper's extension hook (§V: "allowing the applications to be mirrored or
to be broken to pieces like regular file sharing in torrent") — here it is the
engine behind checkpoint/weight distribution: one seeder holds all pieces;
every node that has a piece seeds it.  With u parallel uploads per node per
round, full replication of P pieces to N nodes completes in

    ~ P/u + log2(N) rounds         (vs. N*P/u for a pure client-server fan-out)

`plan_broadcast` produces a deterministic per-round transfer schedule that
parallel/weight_torrent.py maps onto ppermute steps; `SwarmSim` additionally
models per-link bandwidth for the benchmark.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np


@dataclass
class Transfer:
    round: int
    src: int
    dst: int
    piece: int


def plan_broadcast(n_nodes: int, n_pieces: int, fanout: int = 1,
                   seeder: int = 0) -> List[Transfer]:
    """Deterministic rarest-first broadcast plan.

    Each round every node may upload `fanout` pieces and download at most
    `fanout` pieces.  Returns the transfer list; completeness is guaranteed.
    """
    have: List[Set[int]] = [set() for _ in range(n_nodes)]
    have[seeder] = set(range(n_pieces))
    plan: List[Transfer] = []
    rnd = 0
    while any(len(h) < n_pieces for h in have):
        rnd += 1
        if rnd > 10 * (n_pieces + n_nodes + 2):
            raise RuntimeError("broadcast plan did not converge")
        up = collections.Counter()
        down = collections.Counter()
        # piece rarity = how many nodes hold it
        count = collections.Counter()
        for h in have:
            for p in h:
                count[p] += 1
        # rarest pieces first; for each, match a holder to a needer
        new_have = [set(h) for h in have]
        for piece in sorted(range(n_pieces), key=lambda p: (count[p], p)):
            holders = [n for n in range(n_nodes)
                       if piece in have[n] and up[n] < fanout]
            needers = [n for n in range(n_nodes)
                       if piece not in have[n] and down[n] < fanout
                       and piece not in new_have[n]]
            for dst in needers:
                if not holders:
                    break
                src = holders.pop(0)
                plan.append(Transfer(rnd, src, dst, piece))
                up[src] += 1
                down[dst] += 1
                new_have[dst].add(piece)
        have = new_have
    return plan


def rarest_first_order(missing: Sequence[int], avail: Dict[int, int],
                       offset: int = 0,
                       n_pieces: Optional[int] = None) -> List[int]:
    """Order `missing` pieces by swarm-wide availability, rarest first.

    The same policy `plan_broadcast` applies offline; the live piece
    engine (core/piece_exchange.py) feeds it HAVE-derived holder counts to
    pick which piece to request next.  `offset` rotates the tie-break so
    equal-rarity pieces are picked starting from different positions per
    caller (deterministic random-first-piece).

    `n_pieces` is the manifest's total piece count and fixes the rotation
    modulus: with the old `len(missing)` modulus the tie-break order
    changed every time a piece completed.  Callers that know the manifest
    should always pass it; the fallback (largest missing id + 1) only
    keeps the order stable for a fixed missing set.
    """
    n = max(n_pieces if n_pieces is not None
            else max(missing, default=0) + 1, 1)
    return sorted(missing, key=lambda p: (avail.get(p, 0), (p + offset) % n,
                                          p))


def rarest_first_order_np(missing: Sequence[int], counts: np.ndarray,
                          offset: int = 0,
                          n_pieces: Optional[int] = None) -> List[int]:
    """Vectorized `rarest_first_order` over a per-piece count array.

    `counts[p]` is piece `p`'s availability (the live engine maintains it
    incrementally; full seeders add the same constant everywhere, so the
    partial-holder counts alone produce the identical order).  One argsort
    replaces the per-piece dict lookups, dropping the sort from the pump
    hot path's profile; the scalar version above stays as the reference
    the differential tests compare against.
    """
    m = np.asarray(missing, dtype=np.int64)
    if m.size == 0:
        return []
    n = max(int(n_pieces) if n_pieces is not None else int(m.max()) + 1, 1)
    c = np.asarray(counts)
    # lexsort keys, last is primary: availability, rotated id, raw id
    order = np.lexsort((m, (m + offset) % n, c[m]))
    return m[order].tolist()


def rounds_of(plan: Sequence[Transfer]) -> int:
    return max((t.round for t in plan), default=0)


def naive_rounds(n_nodes: int, n_pieces: int, fanout: int = 1) -> int:
    """Client-server fan-out: the seeder uploads everything itself."""
    total = (n_nodes - 1) * n_pieces
    return (total + fanout - 1) // fanout


@dataclass
class SwarmStats:
    rounds: int
    transfers: int
    seeder_uploads: int
    makespan_s: float


def simulate(plan: Sequence[Transfer], piece_bytes: float,
             link_Bps: float, n_nodes: int, seeder: int = 0) -> SwarmStats:
    per_round_s = piece_bytes / link_Bps
    rounds = rounds_of(plan)
    seeder_up = sum(1 for t in plan if t.src == seeder)
    return SwarmStats(rounds=rounds, transfers=len(plan),
                      seeder_uploads=seeder_up,
                      makespan_s=rounds * per_round_s)
