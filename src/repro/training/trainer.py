"""Production train loop wiring the paper's machinery into JAX training.

Per step:
  1. lease a data piece from the coordinator (REQ),
  2. jitted train_step (pjit over the mesh),
  3. complete the lease with the measured (d, w) units (STAT),
  4. heartbeat; periodic sentinel-batch SDC vote; periodic async checkpoint.

Failure handling: dead member -> leases return to queue + elastic resize
plan; restore goes through the torrent path when a pod axis exists.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import CheckpointStore, async_save
from repro.cluster.coordinator import JobCoordinator
from repro.cluster.elastic import plan_resize
from repro.cluster.sdc import SDCValidator
from repro.configs.base import ModelConfig
from repro.data.pipeline import LeasedBatchPipeline, SyntheticTokens
from repro.optim.adamw import AdamWConfig
from repro.training.train_state import init_train_state, make_train_step


@dataclass
class TrainerConfig:
    batch: int = 8
    seq: int = 128
    steps: int = 50
    ckpt_every: int = 25
    sdc_every: int = 0            # 0 = off
    sdc_m_min: int = 2
    ckpt_dir: Optional[str] = None
    member_id: str = "pod0"
    log_every: int = 10
    grad_compress: str = "none"   # "none" | "int8" | "topk" (cross-pod leg)


class Trainer:
    def __init__(self, cfg: ModelConfig, opt: AdamWConfig,
                 tc: TrainerConfig, mesh=None, source=None):
        self.cfg = cfg
        self.opt = opt
        self.tc = tc
        self.mesh = mesh
        self.coord = JobCoordinator(lease_timeout_s=600.0)
        self.pipeline = LeasedBatchPipeline(
            source or SyntheticTokens(cfg.vocab_size), tc.batch, tc.seq,
            coordinator=self.coord, member_id=tc.member_id)
        self.sdc = SDCValidator(m_min=tc.sdc_m_min, every_steps=tc.sdc_every)
        self.store = (CheckpointStore(tc.ckpt_dir) if tc.ckpt_dir else None)
        compress = None
        if tc.grad_compress != "none":
            from repro.optim.compression import CompressionConfig
            compress = CompressionConfig(scheme=tc.grad_compress)
        self.step_fn = jax.jit(make_train_step(cfg, opt, mesh,
                                               compress=compress))
        self.state = None
        self.history: List[dict] = []
        self._ckpt_threads: List = []

    # ------------------------------------------------------------------ #
    def init(self, seed: int = 0) -> None:
        resumed = False
        if self.store is not None and self.store.latest_step() is not None:
            template = init_train_state(jax.random.PRNGKey(seed), self.cfg)
            self.state, extra = self.store.restore_distributed(
                template, self.mesh)
            if "pipeline" in extra:
                self.pipeline.load_state_dict(extra["pipeline"])
            resumed = True
        if not resumed:
            self.state = init_train_state(jax.random.PRNGKey(seed), self.cfg)

    def run(self) -> List[dict]:
        assert self.state is not None, "call init() first"
        start = int(self.state["step"])
        for _ in range(start, self.tc.steps):
            t0 = time.monotonic()
            item_id, host_batch = self.pipeline.next_batch()
            batch = {k: jnp.asarray(v) for k, v in host_batch.items()}
            self.state, metrics = self.step_fn(self.state, batch)
            loss = float(metrics["loss"])
            elapsed = time.monotonic() - t0
            self.pipeline.complete(item_id, elapsed_s=elapsed)
            self.coord.beat(self.tc.member_id)
            step = int(self.state["step"])
            rec = {"step": step, "loss": loss, "w_s": elapsed,
                   "d_bytes": self.pipeline._d}
            self.history.append(rec)
            # sentinel SDC vote: in a multi-pod job, each replica group
            # offers its fingerprint; single-controller runs degenerate to
            # the self-consistency case and are exercised in tests.
            if self.sdc.due(step):
                self.sdc.offer(step, self.tc.member_id,
                               jax.tree_util.tree_leaves(metrics))
            if self.store is not None and step % self.tc.ckpt_every == 0:
                self._ckpt_threads.append(async_save(
                    self.store, step, self.state,
                    extra={"pipeline": self.pipeline.state_dict()}))
            if self.tc.log_every and step % self.tc.log_every == 0:
                print(f"step {step}: loss={loss:.4f} w={elapsed:.2f}s",
                      flush=True)
        self.finish()
        return self.history

    def finish(self) -> None:
        if self.store is not None:
            for th in self._ckpt_threads:
                th.join(timeout=60.0)
            step = int(self.state["step"])
            if step % self.tc.ckpt_every != 0:
                self.store.save(step, jax.tree_util.tree_map(
                    np.asarray, self.state),
                    extra={"pipeline": self.pipeline.state_dict()})

    # failure-path helpers (exercised by tests) -------------------------- #
    def on_member_dead(self, member_id: str, alive_pods: int):
        self.coord._on_dead(member_id)
        return plan_resize(alive_pods)
