"""Train/serve state construction + step functions (pjit-ready)."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model as M
from repro.optim.adamw import AdamWConfig, adamw_init_specs, adamw_update
from repro.parallel.sharding import (DEFAULT_RULES, INFERENCE_RULES, infer_rules, ParamSpec,
                                     ShardingRules, init_params,
                                     sharding_ctx, specs_to_abstract)


def train_state_specs(cfg: ModelConfig, opt: Optional[AdamWConfig] = None
                      ) -> dict:
    pspecs = M.model_param_specs(cfg)
    return {
        "params": pspecs,
        "opt": adamw_init_specs(pspecs),
        "step": ParamSpec((), (), jnp.int32, init="zeros"),
    }


def init_train_state(key, cfg: ModelConfig) -> dict:
    specs = train_state_specs(cfg)
    params = init_params(key, specs["params"])
    opt = init_params(key, specs["opt"])
    return {"params": params, "opt": opt, "step": jnp.zeros((), jnp.int32)}


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, mesh=None,
                    rules: ShardingRules = DEFAULT_RULES,
                    compress=None):
    """compress: optional optim.compression.CompressionConfig — applied to
    gradients (with persistent error-feedback state in the train state)
    before the optimizer, modelling the cross-pod DCN reduction leg."""
    def grad_fn(params, batch):
        def lf(params):
            # cast master params to the compute dtype BEFORE the per-layer
            # FSDP all-gathers so they move bf16, not f32 (halves traffic)
            half = jax.tree_util.tree_map(
                lambda p: p.astype(cfg.act_dtype)
                if p.dtype == jnp.float32 and p.ndim >= 2 else p, params)
            return M.loss_fn(cfg, half, batch)
        return jax.value_and_grad(lf, has_aux=True)(params)

    def train_step(state, batch):
        with sharding_ctx(mesh, rules):
            n_micro = max(cfg.micro_steps, 1)
            if n_micro == 1:
                (loss, metrics), grads = grad_fn(state["params"], batch)
            else:
                # gradient accumulation over micro-batches (batch-major
                # split; mrope "positions" are (3, B, S) — split dim 1)
                mb = {}
                for k, v in batch.items():
                    if k == "positions":
                        vv = v.reshape((3, n_micro, v.shape[1] // n_micro)
                                       + v.shape[2:])
                        mb[k] = jnp.moveaxis(vv, 1, 0)
                    else:
                        mb[k] = v.reshape((n_micro, v.shape[0] // n_micro)
                                          + v.shape[1:])

                def body(acc, microbatch):
                    g_acc, loss_acc, aux_acc = acc
                    (_, met), g = grad_fn(state["params"], microbatch)
                    g_acc = jax.tree_util.tree_map(
                        lambda a, b: a + b.astype(a.dtype), g_acc, g)
                    return (g_acc, loss_acc + met["nll"],
                            aux_acc + met["aux"]), None

                g0 = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32),
                    state["params"])
                (grads, nll_sum, aux_sum), _ = jax.lax.scan(
                    body, (g0, jnp.zeros((), jnp.float32),
                           jnp.zeros((), jnp.float32)), mb)
                grads = jax.tree_util.tree_map(lambda g: g / n_micro, grads)
                nll = nll_sum / n_micro
                aux = aux_sum / n_micro
                loss = nll + cfg.router_aux_coef * aux
                metrics = {"loss": loss, "nll": nll, "aux": aux}
            err_state = None
            if compress is not None and compress.scheme != "none":
                from repro.optim.compression import compress_tree
                grads, err_state = compress_tree(
                    grads, state.get("err"), compress)
            new_params, new_opt, stats = adamw_update(
                opt_cfg, state["params"], grads, state["opt"], state["step"])
            metrics.update(stats)
            new_state = {"params": new_params, "opt": new_opt,
                         "step": state["step"] + 1}
            if err_state is not None:
                new_state["err"] = err_state
            return new_state, metrics
    return train_step


def make_prefill_step(cfg: ModelConfig, mesh=None,
                      rules: Optional[ShardingRules] = None):
    rules = rules or infer_rules(cfg)
    def prefill_step(params, batch, caches):
        with sharding_ctx(mesh, rules):
            last_logits, new_caches = M.prefill(cfg, params, batch, caches)
            next_tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
            return next_tok, new_caches
    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh=None,
                     rules: Optional[ShardingRules] = None):
    rules = rules or infer_rules(cfg)
    def decode_step(params, batch, caches):
        with sharding_ctx(mesh, rules):
            last_logits, new_caches = M.decode_step(cfg, params, batch, caches)
            next_tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
            return next_tok, new_caches
    return decode_step
