"""Production mesh construction.

A TPU v5e pod is a 16x16 chip torus; multi-pod jobs add a leading ``pod``
axis connected over DCN.  Functions, not module constants, so importing this
module never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — for tests."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"))


HARDWARE = {
    # TPU v5e, per chip
    "peak_flops_bf16": 197e12,     # FLOP/s
    "hbm_bandwidth": 819e9,        # B/s
    "ici_link_bandwidth": 50e9,    # B/s per link (~ per direction)
    "dcn_bandwidth": 25e9,         # B/s per host aggregate (cross-pod)
    "hbm_bytes": 16e9,
}
