"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs`` returns the abstract argument tuple for the step function a
given (arch, shape) cell lowers:

  train_*    -> train_step(state, batch)
  prefill_*  -> prefill_step(params, batch, caches)   caches zero-initialised
  decode_*   -> decode_step(params, batch, caches)    caches at full length
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig
from repro.models import model as M
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import (DEFAULT_RULES, INFERENCE_RULES,
                                     ShardingRules, infer_rules,
                                     named_sharding, specs_to_abstract)
from repro.training.train_state import train_state_specs


def _sds(mesh, rules, shape, dtype, logical):
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=named_sharding(mesh, shape, logical, rules))


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh=None,
                rules: Optional[ShardingRules] = None) -> dict:
    B, S = shape.global_batch, shape.seq_len
    rules = rules or (DEFAULT_RULES if shape.kind == "train"
                      else infer_rules(cfg))
    tok = lambda s: _sds(mesh, rules, s, jnp.int32, ("batch",) + (None,) * (len(s) - 1))
    emb = lambda s: _sds(mesh, rules, s, cfg.act_dtype,
                         ("batch", None, None))

    if shape.kind == "train":
        if cfg.is_encdec:
            St = S // cfg.encdec_tgt_ratio
            return {"enc_embeds": emb((B, S, cfg.d_model)),
                    "tokens": tok((B, St)), "labels": tok((B, St))}
        d = {"labels": tok((B, S))}
        if cfg.input_kind == "embeds":
            d["embeds"] = emb((B, S, cfg.d_model))
        else:
            d["tokens"] = tok((B, S))
        if cfg.mrope:
            d["positions"] = _sds(mesh, rules, (3, B, S), jnp.int32,
                                  (None, "batch", None))
        return d

    if shape.kind == "prefill":
        if cfg.is_encdec:
            St = S // cfg.encdec_tgt_ratio
            return {"enc_embeds": emb((B, S, cfg.d_model)),
                    "tokens": tok((B, St))}
        d = {}
        if cfg.input_kind == "embeds":
            d["embeds"] = emb((B, S, cfg.d_model))
        else:
            d["tokens"] = tok((B, S))
        if cfg.mrope:
            d["positions"] = _sds(mesh, rules, (3, B, S), jnp.int32,
                                  (None, "batch", None))
        return d

    # decode: one new token against a cache of length S
    d = {"tokens": tok((B, 1))}
    if cfg.mrope:
        d["positions"] = _sds(mesh, rules, (3, B, 1), jnp.int32,
                              (None, "batch", None))
    return d


def cache_abstract(cfg: ModelConfig, shape: ShapeConfig, mesh=None,
                   rules: Optional[ShardingRules] = None):
    rules = rules or infer_rules(cfg)
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "prefill":
        # enc-dec: encoder sees S source frames; decoder prefix is S//ratio
        cache_len = S // cfg.encdec_tgt_ratio if cfg.is_encdec else S
        src = S
    else:
        # decode: self-cache of seq_len (per assignment); cross-KV to the
        # S-frame source for enc-dec
        cache_len, src = S, S
    tree = M.cache_specs_tree(cfg, B, cache_len, src_len=src)
    return specs_to_abstract(tree, mesh, rules)


def state_abstract(cfg: ModelConfig, mesh=None,
                   rules: ShardingRules = DEFAULT_RULES):
    return specs_to_abstract(train_state_specs(cfg), mesh, rules)


def params_abstract(cfg: ModelConfig, mesh=None,
                    rules: Optional[ShardingRules] = None, dtype=None):
    rules = rules or infer_rules(cfg)
    return specs_to_abstract(M.model_param_specs(cfg), mesh, rules,
                             dtype_override=dtype or cfg.act_dtype)


def step_args_abstract(cfg: ModelConfig, shape: ShapeConfig, mesh=None
                       ) -> Tuple:
    """Full abstract argument tuple for the cell's step function."""
    if shape.kind == "train":
        return (state_abstract(cfg, mesh, DEFAULT_RULES),
                batch_specs(cfg, shape, mesh, DEFAULT_RULES))
    r = infer_rules(cfg)
    return (params_abstract(cfg, mesh, r),
            batch_specs(cfg, shape, mesh, r),
            cache_abstract(cfg, shape, mesh, r))
