import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count on first
initialisation, and the production meshes need 512 placeholder devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-20b \
      --shape train_4k [--multi-pod] [--out artifacts/]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs.base import ARCH_IDS, SHAPES, get_config
from repro.launch.mesh import HARDWARE, make_production_mesh
from repro.launch.specs import step_args_abstract
from repro.launch import hlo_analysis
from repro.optim.adamw import AdamWConfig
from repro.training.train_state import (make_decode_step, make_prefill_step,
                                        make_train_step)


def cell_is_skipped(arch: str, shape_name: str) -> str:
    """Returns a reason string if the cell is skipped, else ''."""
    cfg = get_config(arch)
    if shape_name == "long_500k" and not cfg.subquadratic:
        return ("full-attention arch: 500k decode requires sub-quadratic "
                "attention (see DESIGN.md §Arch-applicability)")
    return ""


VARIANTS = {
    "baseline": {},
    "tp_sp": {"tp_sp": True},
    "pad_heads": {"pad_attn_heads": True},
    "tp_sp+pad": {"tp_sp": True, "pad_attn_heads": True},
    "moe_int8": {"moe_a2a_int8": True},
    "remat_dots": {"remat": "dots"},
    "flash_full": {"attn_impl": "full"},   # pre-flash paper-faithful naive
    "tp_sp+moe_int8": {"tp_sp": True, "moe_a2a_int8": True},
    "tp_sp+remat_dots": {"tp_sp": True, "remat": "dots"},
}


def lower_cell(arch: str, shape_name: str, mesh, variant: str = "baseline"
               ) -> tuple:
    """Returns (lowered, compiled) for one cell."""
    cfg = get_config(arch).replace(**VARIANTS[variant])
    shape = SHAPES[shape_name]
    if shape.kind == "train" and cfg.micro_steps == 1 and cfg.d_model >= 3584:
        # auto gradient-accumulation: large models need 2 microbatches to fit
        # the 16 GB/chip activation budget at global_batch=256 x 4k
        cfg = cfg.replace(micro_steps=2)
    args = step_args_abstract(cfg, shape, mesh)
    if shape.kind == "train":
        step = make_train_step(cfg, AdamWConfig(), mesh)
        donate = (0,)
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg, mesh)
        donate = (2,)
    else:
        step = make_decode_step(cfg, mesh)
        donate = (2,)
    jitted = jax.jit(step, donate_argnums=donate)
    with mesh:
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    return lowered, compiled


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str = None,
             verbose: bool = True, variant: str = "baseline") -> dict:
    t0 = time.time()
    reason = cell_is_skipped(arch, shape_name)
    rec = {"arch": arch, "shape": shape_name, "variant": variant,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    if reason:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return rec
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        lowered, compiled = lower_cell(arch, shape_name, mesh, variant)
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if verbose:
            print(mem)
            print({k: v for k, v in cost.items()
                   if k in ("flops", "bytes accessed")})
        hlo = hlo_analysis.analyze_hlo(compiled.as_text(),
                                       n_devices=mesh.size)
        rec.update({
            "status": "ok",
            "compile_s": round(time.time() - t0, 1),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
            },
            "xla_cost": {"flops": cost.get("flops", 0.0),
                         "bytes_accessed": cost.get("bytes accessed", 0.0)},
            "hlo": hlo,
        })
    except Exception as e:  # noqa: BLE001 — sweep must record failures
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["wall_s"] = round(time.time() - t0, 1)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = "" if variant == "baseline" else f"__{variant}"
        fn = os.path.join(out_dir,
                          f"{arch}__{shape_name}__{rec['mesh']}{suffix}.json")
        with open(fn, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--variant", default="baseline", choices=list(VARIANTS))
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    ok = True
    for arch, shape in cells:
        rec = run_cell(arch, shape, args.multi_pod, args.out,
                       variant=args.variant)
        status = rec["status"]
        extra = ""
        if status == "ok":
            extra = (f"flops/dev={rec['hlo']['flops']:.3e} "
                     f"coll={rec['hlo']['collective_bytes']:.3e}B "
                     f"args={rec['memory']['argument_bytes']/2**30:.2f}GiB "
                     f"{rec['wall_s']}s")
        elif status == "error":
            ok = False
            extra = rec["error"][:200]
        print(f"[{status:7s}] {arch:24s} {shape:12s} {rec['mesh']:8s} {extra}",
              flush=True)
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
