"""Trip-count-aware cost analysis of optimized HLO text.

``compiled.cost_analysis()`` counts a ``while`` (scan) body exactly once,
which under-reports a 48-layer scanned transformer by ~48x.  XLA:CPU attaches
``backend_config={"known_trip_count":{"n":...}}`` to the while ops it derives
static trip counts for, so this module re-derives flops / bytes / collective
bytes by walking the computation call graph with multipliers:

  ENTRY -(x1)-> fusion/call computations
        -(x trip_count)-> while body/cond computations

Reported numbers are *per device* (the HLO module is the per-device SPMD
program).  Collective traffic is summed over operand bytes per collective
kind, with `-start/-done` async pairs counted once.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "s2": 1, "u2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"?(\d+)"?\}')
_CALL_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-broadcast", "ragged-all-to-all")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs", "compare",
    "select", "and", "or", "xor", "convert", "floor", "ceil", "sign",
    "cosine", "sine", "logistic", "expm1", "log1p", "atan2", "remainder",
    "clamp",
}


def _type_bytes(type_str: str) -> int:
    """Bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _type_elems(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dims = m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


class Instr:
    __slots__ = ("name", "type", "op", "rest")

    def __init__(self, name, type_, op, rest):
        self.name, self.type, self.op, self.rest = name, type_, op, rest


def parse_computations(hlo: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    cur = None
    for line in hlo.splitlines():
        if not line.startswith(" "):
            stripped = line.strip()
            m = _COMP_RE.match(stripped)
            if m and stripped.endswith("{") and "=" not in stripped.split("(")[0]:
                cur = m.group(1)
                comps[cur] = []
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if m:
            comps[cur].append(Instr(m.group(1), m.group(2), m.group(3),
                                    m.group(4)))
    return comps


def _operand_names(rest: str) -> List[str]:
    # operands are leading %names inside the first (...) — rest starts after '('
    depth = 1
    out = []
    i = 0
    while i < len(rest) and depth > 0:
        c = rest[i]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
        elif c == "%":
            j = i + 1
            while j < len(rest) and (rest[j].isalnum() or rest[j] in "._-"):
                j += 1
            out.append(rest[i + 1:j])
            i = j
            continue
        i += 1
    return out


def _update_bytes_of(root: Instr, callee_types: Dict[str, str]) -> float:
    """Traffic of an in-place dynamic-update-slice: read+write of the slice,
    not the whole (possibly 48-layer-stacked) buffer."""
    ops_ = _operand_names(root.rest)
    if len(ops_) > 1:
        return 2.0 * _type_bytes(callee_types.get(ops_[1], ""))
    return 0.0


def _local_cost(instrs: List[Instr],
                comps: Optional[Dict[str, List[Instr]]] = None) -> dict:
    name2type = {i.name: i.type for i in instrs}
    flops = 0.0
    dot_flops = 0.0
    bytes_acc = 0.0
    coll = defaultdict(float)
    coll_ops: List[dict] = []
    calls: List[Tuple[str, float]] = []
    for ins in instrs:
        op = ins.op
        if op in ("parameter", "constant", "get-tuple-element", "tuple",
                  "bitcast", "after-all", "partition-id", "replica-id",
                  "iota"):
            continue
        base = op[:-6] if op.endswith("-start") else op
        if op.endswith("-done"):
            continue
        if base in COLLECTIVES:
            ob = sum(_type_bytes(name2type.get(n, "")) for n in
                     _operand_names(ins.rest))
            out_b = _type_bytes(ins.type)
            group = 0
            mg = _GROUPS_IOTA_RE.search(ins.rest)
            if mg:
                group = int(mg.group(2))
            else:
                ml = _GROUPS_LIST_RE.search(ins.rest)
                if ml and ml.group(1).strip():
                    group = len(ml.group(1).split(","))
            coll[base] += ob
            coll_ops.append({"kind": base, "operand_bytes": ob,
                             "out_bytes": out_b, "group": group})
            bytes_acc += ob + out_b
            continue
        if op == "while":
            m = _TRIP_RE.search(ins.rest)
            trip = float(m.group(1)) if m else 1.0
            for cm in _CALL_RE.finditer(ins.rest):
                calls.append((cm.group(1), trip))
            continue
        if op == "conditional":
            mb = _BRANCH_RE.search(ins.rest)
            if mb:
                for b in mb.group(1).split(","):
                    calls.append((b.strip().lstrip("%"), 1.0))
            for cm in _CALL_RE.finditer(ins.rest):
                calls.append((cm.group(1), 1.0))
            continue
        if op in ("dynamic-slice",):
            bytes_acc += 2.0 * _type_bytes(ins.type)
            continue
        if op in ("dynamic-update-slice",):
            ops_ = _operand_names(ins.rest)
            upd = _type_bytes(name2type.get(ops_[1], "")) if len(ops_) > 1 \
                else 0
            bytes_acc += 2.0 * upd
            continue
        if op in ("fusion", "call", "custom-call", "map", "reduce",
                  "reduce-window", "sort", "scatter", "select-and-scatter"):
            for cm in _CALL_RE.finditer(ins.rest):
                calls.append((cm.group(1), 1.0))
            handled = False
            if op == "fusion" and comps is not None:
                m = _CALL_RE.search(ins.rest)
                callee = m.group(1) if m else None
                body = comps.get(callee) or []
                root = body[-1] if body else None
                if root is not None and root.op in ("dynamic-update-slice",
                                                    "scatter"):
                    # in-place update fusion: traffic = slice read+write +
                    # the non-aliased (small) operands
                    callee_types = {i.name: i.type for i in body}
                    upd = _update_bytes_of(root, callee_types)
                    out_b = _type_bytes(ins.type)
                    small = sum(
                        b for b in (_type_bytes(name2type.get(n, ""))
                                    for n in _operand_names(ins.rest))
                        if b != out_b)
                    bytes_acc += upd + small
                    handled = True
                elif root is not None and root.op == "dynamic-slice":
                    bytes_acc += 2.0 * _type_bytes(ins.type)
                    handled = True
            if not handled:
                ob = sum(_type_bytes(name2type.get(n, "")) for n in
                         _operand_names(ins.rest))
                bytes_acc += ob + _type_bytes(ins.type)
            if op in ("reduce", "reduce-window"):
                flops += sum(_type_elems(name2type.get(n, "")) for n in
                             _operand_names(ins.rest))
            continue
        if op == "dot":
            out_elems = _type_elems(ins.type)
            ops_ = _operand_names(ins.rest)
            k = 1
            mc = _CONTRACT_RE.search(ins.rest)
            if mc and ops_:
                lhs_dims = _shape_dims(name2type.get(ops_[0], ""))
                for di in (mc.group(1).split(",") if mc.group(1) else []):
                    idx = int(di)
                    if idx < len(lhs_dims):
                        k *= lhs_dims[idx]
            f = 2.0 * out_elems * k
            flops += f
            dot_flops += f
            ob = sum(_type_bytes(name2type.get(n, "")) for n in ops_)
            bytes_acc += ob + _type_bytes(ins.type)
            continue
        if op == "convolution":
            # approximate: 2 * out_elems * (kernel elems / out-channels)
            out_elems = _type_elems(ins.type)
            ops_ = _operand_names(ins.rest)
            kelems = _type_elems(name2type.get(ops_[1], "")) if len(ops_) > 1 \
                else 1
            odims = _shape_dims(ins.type)
            och = odims[-1] if odims else 1
            f = 2.0 * out_elems * max(kelems // max(och, 1), 1)
            flops += f
            dot_flops += f
            ob = sum(_type_bytes(name2type.get(n, "")) for n in ops_)
            bytes_acc += ob + _type_bytes(ins.type)
            continue
        # generic elementwise / data movement
        ob = sum(_type_bytes(name2type.get(n, "")) for n in
                 _operand_names(ins.rest))
        bytes_acc += ob + _type_bytes(ins.type)
        if op in _ELEMENTWISE:
            flops += _type_elems(ins.type)
    return {"flops": flops, "dot_flops": dot_flops, "bytes": bytes_acc,
            "coll": dict(coll), "coll_ops": coll_ops, "calls": calls}


def analyze_hlo(hlo: str, n_devices: int = 1) -> dict:
    comps = parse_computations(hlo)
    local = {name: _local_cost(instrs, comps) for name, instrs in
             comps.items()}

    # multipliers via DFS from ENTRY (the computation named in `ENTRY` line —
    # detect as a computation not called by anyone, preferring 'main')
    called = set()
    for lc in local.values():
        for callee, _ in lc["calls"]:
            called.add(callee)
    roots = [n for n in comps if n not in called]
    entry = None
    for r in roots:
        if "main" in r:
            entry = r
            break
    if entry is None and roots:
        entry = max(roots, key=lambda n: len(comps[n]))

    mult: Dict[str, float] = defaultdict(float)

    def visit(name: str, m: float, depth=0):
        if name not in local or depth > 64:
            return
        mult[name] += m
        for callee, cm in local[name]["calls"]:
            visit(callee, m * cm, depth + 1)

    if entry:
        visit(entry, 1.0)

    flops = dot_flops = bytes_acc = 0.0
    coll = defaultdict(float)
    coll_ops_agg: Dict[tuple, dict] = {}
    for name, m in mult.items():
        lc = local[name]
        flops += lc["flops"] * m
        dot_flops += lc["dot_flops"] * m
        bytes_acc += lc["bytes"] * m
        for k, v in lc["coll"].items():
            coll[k] += v * m
        for op in lc["coll_ops"]:
            key = (op["kind"], op["operand_bytes"], op["out_bytes"],
                   op["group"])
            e = coll_ops_agg.setdefault(key, dict(op, count=0.0))
            e["count"] += m

    total_coll = sum(coll.values())
    return {
        "flops": flops,
        "dot_flops": dot_flops,
        "bytes_accessed": bytes_acc,
        "collective_bytes": total_coll,
        "collectives": dict(coll),
        "coll_ops": sorted(coll_ops_agg.values(),
                           key=lambda e: -e["operand_bytes"] * e["count"]),
        "n_computations": len(comps),
        "entry": entry,
        "n_devices": n_devices,
    }


def collective_link_bytes(coll_ops: List[dict]) -> float:
    """Effective serialized bytes per device at link bandwidth, assuming
    ring algorithms: all-reduce 2(R-1)/R x operand; all-gather (R-1)/R x
    output; reduce-scatter / all-to-all (R-1)/R x operand; permute 1x."""
    total = 0.0
    for op in coll_ops:
        r = max(op.get("group", 0), 1)
        f = (r - 1) / r if r > 1 else 0.0
        kind = op["kind"]
        n = op.get("count", 1.0)
        if kind == "all-reduce":
            b = 2.0 * f * op["operand_bytes"]
        elif kind == "all-gather":
            b = f * max(op["out_bytes"], op["operand_bytes"])
        elif kind in ("reduce-scatter", "all-to-all", "ragged-all-to-all"):
            b = f * op["operand_bytes"]
        elif kind == "collective-broadcast":
            b = op["operand_bytes"]
        else:  # collective-permute
            b = op["operand_bytes"]
        total += b * n
    return total
