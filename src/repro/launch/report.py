"""Generate the data-driven sections of EXPERIMENTS.md from artifacts."""
from __future__ import annotations

import glob
import json
import os

from repro.launch.hlo_analysis import collective_link_bytes
from repro.launch.mesh import HARDWARE
from repro.launch.roofline import analyze_cell, load_cells, markdown_table


def load(art, arch, shape, mesh="16x16", variant=None):
    suffix = f"__{variant}" if variant else ""
    fn = os.path.join(art, f"{arch}__{shape}__{mesh}{suffix}.json")
    if not os.path.exists(fn):
        return None
    with open(fn) as f:
        return json.load(f)


def terms(rec):
    h = rec["hlo"]
    link = collective_link_bytes(h.get("coll_ops", []))
    return {
        "flops": h["flops"],
        "bytes": h["bytes_accessed"],
        "coll_raw": h["collective_bytes"],
        "coll_link": link,
        "compute_s": h["flops"] / HARDWARE["peak_flops_bf16"],
        "memory_s": h["bytes_accessed"] / HARDWARE["hbm_bandwidth"],
        "coll_s": link / HARDWARE["ici_link_bandwidth"],
        "temp_gib": rec["memory"]["temp_bytes"] / 2**30,
        "kinds": h.get("collectives", {}),
    }


def dryrun_section(art="artifacts/dryrun") -> str:
    rows = ["| arch | shape | mesh | status | HLO flops/dev | coll B/dev | "
            "args GiB | temp GiB |", "|---|---|---|---|---|---|---|---|"]
    for fn in sorted(glob.glob(os.path.join(art, "*.json"))):
        if "__tp_sp" in fn or "__pad" in fn or "__moe_int8" in fn \
                or "__flash_full" in fn:
            continue
        rec = json.load(open(fn))
        if rec["status"] == "ok":
            h = rec["hlo"]
            rows.append(
                f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | ok | "
                f"{h['flops']:.2e} | {h['collective_bytes']:.2e} | "
                f"{rec['memory']['argument_bytes'] / 2**30:.2f} | "
                f"{rec['memory']['temp_bytes'] / 2**30:.2f} |")
        elif rec["status"] == "skipped":
            rows.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
                        f"| skipped | - | - | - | - |")
        else:
            rows.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
                        f"| ERROR | - | - | - | - |")
    return "\n".join(rows)


def perf_row(label, rec):
    t = terms(rec)
    return (f"| {label} | {t['flops']:.3e} | {t['bytes']:.3e} | "
            f"{t['coll_link']:.3e} | {t['compute_s']:.2f} | "
            f"{t['memory_s']:.2f} | {t['coll_s']:.2f} | "
            f"{t['temp_gib']:.1f} |")


PERF_HDR = ("| variant | HLO flops/dev | HLO bytes/dev | coll link-B/dev | "
            "compute s | memory s | coll s | temp GiB |\n"
            "|---|---|---|---|---|---|---|---|")


def main():
    art = "artifacts/dryrun"
    print("## §Dry-run\n")
    print(dryrun_section(art))
    print("\n\n## §Roofline (single-pod 16x16)\n")
    cells = load_cells(art, "16x16")
    print(markdown_table(cells))
    print("\n\n## §Perf cells\n")
    for arch, shape, variants in [
        ("internlm2-20b", "train_4k",
         ["flash_full", None, "tp_sp", "tp_sp+remat_dots"]),
        ("qwen3-14b", "prefill_32k", [None, "pad_heads", "tp_sp+pad"]),
        ("qwen3-moe-30b-a3b", "train_4k",
         [None, "moe_int8", "tp_sp+moe_int8"]),
    ]:
        print(f"### {arch} / {shape}\n")
        print(PERF_HDR)
        for v in variants:
            rec = load(art, arch, shape, variant=v)
            if rec and rec.get("status") == "ok":
                print(perf_row(v or "baseline(flash)", rec))
        print()


if __name__ == "__main__":
    main()
