"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape) cell, all per-device seconds on TPU v5e:

  compute    = HLO_FLOPs / peak_FLOPs            (197 TFLOP/s bf16)
  memory     = HLO_bytes / HBM_bw                (819 GB/s)
  collective = ring-weighted collective bytes / ICI link bw (50 GB/s)

HLO_FLOPs/bytes come from the trip-count-aware HLO parse (hlo_analysis);
``xla.cost_analysis`` is recorded alongside but under-counts scan bodies.
MODEL_FLOPS uses the 6ND / 2ND convention (active params for MoE), so the
useful-fraction column exposes remat/padding/causal-waste overheads.
"""
from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.configs.base import SHAPES, get_config
from repro.launch.hlo_analysis import collective_link_bytes
from repro.launch.mesh import HARDWARE


def model_flops(arch: str, shape_name: str) -> float:
    """Global useful flops per step: 6ND train / 2ND inference (+ attention
    term for quadratic-attention archs at long S)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    from repro.models.model import count_params
    n_total = count_params(cfg, include_embed=True,
                           active_only=bool(cfg.num_experts))
    n = n_total - cfg.vocab_size * cfg.d_model   # embedding gather ~free
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens = B * (S // cfg.encdec_tgt_ratio if cfg.is_encdec else S)
        base = 6.0 * n * tokens
        # causal attention fwd+bwd ~ 3 x fwd; fwd = 4*B*S^2/2*H*D per layer
        attn = _attn_flops(cfg, B, S) * 3.0
    elif shape.kind == "prefill":
        tokens = B * S
        base = 2.0 * n * tokens
        attn = _attn_flops(cfg, B, S)
    else:  # decode: 1 token per sequence against an S-long cache
        base = 2.0 * n * B
        attn = _decode_attn_flops(cfg, B, S)
    return base + attn


def _layers_of(cfg, kind):
    n = 0
    for g in cfg.groups:
        for ls in g.layers:
            if ls.mixer == kind:
                n += g.repeat
            if ls.shared_attn and kind == "attn":
                n += g.repeat
    return n


def _attn_flops(cfg, B, S):
    if cfg.num_heads == 0:
        return 0.0
    hd = cfg.num_heads * cfg.head_dim
    full = _layers_of(cfg, "attn")
    local = _layers_of(cfg, "attn_local")
    w = min(cfg.window_size, S)
    f = 4.0 * B * (S * S / 2) * hd * full
    f += 4.0 * B * (S * w - w * w / 2) * hd * local
    return f


def _decode_attn_flops(cfg, B, S):
    if cfg.num_heads == 0:
        return 0.0
    hd = cfg.num_heads * cfg.head_dim
    full = _layers_of(cfg, "attn")
    local = _layers_of(cfg, "attn_local")
    return 4.0 * B * (S * full + min(cfg.window_size, S) * local) * hd


@dataclass
class CellRoofline:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_dev: float
    hlo_flops_dev: float
    useful_ratio: float
    roofline_fraction: float
    note: str


_NOTES = {
    "compute": ("compute-bound: cut remat recompute / causal-brick padding, "
                "or raise arithmetic intensity with larger per-chip tiles"),
    "memory": ("HBM-bound: fuse elementwise chains, keep activations bf16, "
               "shrink remat working set"),
    "collective": ("collective-bound: replace all-reduce with "
                   "reduce-scatter+all-gather (TP-SP), overlap FSDP gathers "
                   "with compute, compress cross-pod grads"),
}


def analyze_cell(rec: dict) -> Optional[CellRoofline]:
    if rec.get("status") != "ok":
        return None
    hlo = rec["hlo"]
    n_dev = hlo.get("n_devices", 256)
    peak = HARDWARE["peak_flops_bf16"]
    hbm = HARDWARE["hbm_bandwidth"]
    link = HARDWARE["ici_link_bandwidth"]
    compute_s = hlo["flops"] / peak
    memory_s = hlo["bytes_accessed"] / hbm
    link_bytes = collective_link_bytes(hlo.get("coll_ops", []))
    collective_s = link_bytes / link
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", collective_s), key=lambda kv: kv[1])[0]
    mf = model_flops(rec["arch"], rec["shape"]) / n_dev
    useful = mf / max(hlo["flops"], 1.0)
    frac = (mf / peak) / max(compute_s, memory_s, collective_s, 1e-12)
    return CellRoofline(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops_dev=mf, hlo_flops_dev=hlo["flops"],
        useful_ratio=useful, roofline_fraction=frac, note=_NOTES[dominant])


def load_cells(art_dir: str = "artifacts/dryrun", mesh: str = "16x16"
               ) -> List[CellRoofline]:
    out = []
    for fn in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(fn) as f:
            rec = json.load(f)
        if rec.get("mesh") != mesh:
            continue
        if rec.get("variant", "baseline") != "baseline":
            continue   # §Perf variants live in their own section
        cell = analyze_cell(rec)
        if cell:
            out.append(cell)
    return out


def markdown_table(cells: List[CellRoofline]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | bound | "
           "model/HLO flops | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|")
    rows = [hdr]
    for c in sorted(cells, key=lambda c: (c.arch, c.shape)):
        rows.append(
            f"| {c.arch} | {c.shape} | {c.compute_s:.3f} | {c.memory_s:.3f} "
            f"| {c.collective_s:.3f} | {c.dominant} | {c.useful_ratio:.2f} "
            f"| {c.roofline_fraction:.3f} |")
    return "\n".join(rows)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--art", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    cells = load_cells(args.art, args.mesh)
    print(markdown_table(cells))
    worst = sorted(cells, key=lambda c: c.roofline_fraction)[:3]
    collb = [c for c in cells if c.dominant == "collective"]
    print("\nworst roofline fractions:",
          [(c.arch, c.shape, round(c.roofline_fraction, 3)) for c in worst])
    print("collective-bound cells:",
          [(c.arch, c.shape) for c in collb][:8])


if __name__ == "__main__":
    main()
