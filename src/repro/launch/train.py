"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch granite-8b --reduced \
      --steps 50 --batch 8 --seq 128 [--ckpt-dir ckpt/]

``--reduced`` shrinks the architecture to a CPU-runnable width (same code
path as production).  On a TPU slice, omit --reduced and pass --mesh to
train the full config under the production sharding rules.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs.base import get_config, reduced_config
from repro.launch.mesh import make_production_mesh
from repro.optim.adamw import AdamWConfig
from repro.training.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--sdc-every", type=int, default=0)
    ap.add_argument("--mesh", choices=["none", "pod", "multipod"],
                    default="none")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    mesh = None
    if args.mesh != "none":
        mesh = make_production_mesh(multi_pod=args.mesh == "multipod")

    tc = TrainerConfig(batch=args.batch, seq=args.seq, steps=args.steps,
                       ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir,
                       sdc_every=args.sdc_every)
    tr = Trainer(cfg, AdamWConfig(lr=args.lr, warmup_steps=10,
                                  total_steps=args.steps), tc, mesh=mesh)
    tr.init()
    hist = tr.run()
    print(f"final loss: {hist[-1]['loss']:.4f} after {len(hist)} steps")


if __name__ == "__main__":
    main()
