"""Serving launcher: continuous batching with (d, p, w) publication.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --reduced \
      --requests 8 --max-new 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config, reduced_config
from repro.models import model as M
from repro.parallel.sharding import init_params
from repro.serving.engine import ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    params = init_params(jax.random.PRNGKey(0), M.model_param_specs(cfg))
    eng = ServingEngine(cfg, params, ServeConfig(slots=args.slots,
                                                 max_len=256))
    rng = np.random.RandomState(0)
    reqs = []
    for _ in range(args.requests):
        p = rng.randint(0, cfg.vocab_size, size=rng.randint(3, 17))
        eng.submit(p.astype(np.int32), max_new=args.max_new)
    reqs = list(eng.queue)
    t0 = time.monotonic()
    ticks = 0
    while (eng.queue or eng.active) and ticks < 10_000:
        eng.step()
        ticks += 1
    dt = time.monotonic() - t0
    toks = sum(len(r.out_tokens) for r in reqs)
    print(f"served {len(reqs)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks / max(dt, 1e-9):.1f} tok/s on CPU)")
    print("published (d,p,w) units per prompt bucket:")
    for b, row in sorted(eng.published_units().items()):
        print(f"  bucket<={b}: d={row['d']:.0f}B p={row['p']} "
              f"w={row['w']:.3f}s")


if __name__ == "__main__":
    main()
