"""repro: P2P torrent-like application/weight distribution for multi-pod JAX.

Reproduction of Soelistio (2015) volunteer-computing distribution model,
extended into a TPU-v5e-targeted training/inference framework.  See
DESIGN.md for the architecture and EXPERIMENTS.md for results.
"""
__version__ = "1.0.0"
