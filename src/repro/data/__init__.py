from repro.data.pipeline import (  # noqa: F401
    LeasedBatchPipeline,
    SyntheticTokens,
    TokenFileStore,
)
