"""Piece/lease-based data pipeline.

Every global batch is a *piece* with (d, w) units, leased from the
JobCoordinator exactly the way a volunteer leases a part (REQ/DIST/TAIL):
a straggling or dead host's lease expires and the piece is re-dispatched,
so batch delivery is exactly-once-per-step even under churn.  The pipeline
state (next piece id, epoch) is part of the checkpoint, making input
resumable and deterministic.
"""
from __future__ import annotations

import hashlib
import os
import struct
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.cluster.coordinator import JobCoordinator


class SyntheticTokens:
    """Deterministic synthetic LM tokens (hash-seeded, reproducible)."""

    def __init__(self, vocab_size: int, seed: int = 0):
        self.vocab = vocab_size
        self.seed = seed

    def piece(self, piece_id: int, batch: int, seq: int) -> dict:
        rng = np.random.RandomState(
            (self.seed * 1_000_003 + piece_id) % (2**31 - 1))
        toks = rng.randint(0, self.vocab, size=(batch, seq + 1),
                           dtype=np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class TokenFileStore:
    """Flat binary token shards on disk (one uint32 stream per shard)."""

    MAGIC = b"RTOK1\0"

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def write_shard(self, shard_id: int, tokens: np.ndarray) -> str:
        path = os.path.join(self.root, f"shard_{shard_id:05d}.tok")
        with open(path, "wb") as f:
            f.write(self.MAGIC)
            f.write(struct.pack("<q", tokens.size))
            f.write(tokens.astype(np.uint32).tobytes())
        return path

    def read_shard(self, shard_id: int) -> np.ndarray:
        path = os.path.join(self.root, f"shard_{shard_id:05d}.tok")
        with open(path, "rb") as f:
            magic = f.read(len(self.MAGIC))
            assert magic == self.MAGIC, "bad token shard"
            (n,) = struct.unpack("<q", f.read(8))
            return np.frombuffer(f.read(4 * n), dtype=np.uint32)

    def shards(self) -> List[int]:
        out = []
        for fn in sorted(os.listdir(self.root)):
            if fn.startswith("shard_") and fn.endswith(".tok"):
                out.append(int(fn[6:11]))
        return out

    def piece(self, piece_id: int, batch: int, seq: int,
              vocab_size: int) -> dict:
        shards = self.shards()
        tokens = self.read_shard(shards[piece_id % len(shards)])
        need = batch * (seq + 1)
        start = (piece_id * need) % max(tokens.size - need, 1)
        window = tokens[start:start + need]
        if window.size < need:
            window = np.pad(window, (0, need - window.size))
        toks = (window.astype(np.int64) % vocab_size).astype(np.int32)
        toks = toks.reshape(batch, seq + 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclass
class PipelineState:
    next_piece: int = 0
    epoch: int = 0
    delivered: int = 0


class LeasedBatchPipeline:
    """Coordinator-backed batch delivery with lease fault tolerance."""

    def __init__(self, source, batch: int, seq: int,
                 coordinator: Optional[JobCoordinator] = None,
                 pieces_per_epoch: int = 1 << 16,
                 member_id: str = "pod0",
                 token_bytes: int = 4):
        self.source = source
        self.batch = batch
        self.seq = seq
        self.coord = coordinator or JobCoordinator(lease_timeout_s=300.0)
        self.coord.join(member_id)
        self.member = member_id
        self.pieces_per_epoch = pieces_per_epoch
        self.state = PipelineState()
        self._d = batch * (seq + 1) * token_bytes

    def _submit_next(self) -> int:
        pid = self.state.next_piece
        self.state.next_piece += 1
        if self.state.next_piece >= self.pieces_per_epoch:
            self.state.next_piece = 0
            self.state.epoch += 1
        return self.coord.submit("data", {"piece": pid,
                                          "epoch": self.state.epoch},
                                 d_bytes=self._d)

    def next_batch(self) -> Tuple[int, dict]:
        """Lease the next piece and materialise its batch."""
        self.coord.expire_leases()
        item = self.coord.request(self.member)
        if item is None:
            self._submit_next()
            item = self.coord.request(self.member)
        piece_id = item.payload["piece"]
        batch = self.source.piece(piece_id, self.batch, self.seq)
        return item.item_id, batch

    def complete(self, item_id: int, elapsed_s: float = 0.0) -> None:
        self.coord.complete(self.member, item_id, elapsed_s=elapsed_s)
        self.state.delivered += 1

    # ---- checkpointable state -------------------------------------------
    def state_dict(self) -> dict:
        return {"next_piece": self.state.next_piece,
                "epoch": self.state.epoch,
                "delivered": self.state.delivered}

    def load_state_dict(self, d: dict) -> None:
        self.state = PipelineState(**d)
