from repro.cluster.heartbeat import HeartbeatMonitor, MemberState  # noqa: F401
from repro.cluster.coordinator import JobCoordinator, WorkItem  # noqa: F401
from repro.cluster.sdc import SDCValidator  # noqa: F401
from repro.cluster.elastic import ElasticPlan, plan_resize  # noqa: F401
