"""Job coordinator: the tracker server repurposed as a list-only scheduler.

Holds work items (data shards, eval tasks, sentinel batches) with the
paper's (d, p, w) cost units and lease/TAIL fault tolerance.  Payload bytes
never transit the coordinator — hosts exchange them peer-to-peer (the
data pipeline reads shards directly; weights move via the swarm).

Heterogeneity-aware placement (paper §III.B): long work (high w) goes to
fast members first; placement prefers members whose running average step
time is lowest, exactly how a volunteer uses published (d, w) to judge an
application.
"""
from __future__ import annotations

import collections
import heapq
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.cluster.heartbeat import HeartbeatMonitor
from repro.core.metrics import AppMetrics
from repro.core.workunit import LeaseTable


@dataclass
class WorkItem:
    item_id: int
    kind: str                      # "data" | "eval" | "sentinel"
    payload: dict
    d_bytes: float = 0.0           # size unit
    w_est_s: float = 0.0           # working-time unit (est.)
    p: int = 0                     # popularity: times leased
    done: bool = False
    result: Optional[dict] = None


class JobCoordinator:
    def __init__(self, lease_timeout_s: float = 120.0,
                 heartbeat_t_s: float = 10.0, heartbeat_f: int = 3,
                 clock: Callable[[], float] = time.monotonic):
        self.clock = clock
        self.items: Dict[int, WorkItem] = {}
        self.queue: List[Tuple[float, int]] = []   # (-w_est, id): long first
        self.leases = LeaseTable(lease_timeout_s)
        self.hb = HeartbeatMonitor(heartbeat_t_s, heartbeat_f,
                                   on_dead=self._on_dead, clock=clock)
        self.member_w: Dict[str, float] = collections.defaultdict(float)
        self.member_n: Dict[str, int] = collections.defaultdict(int)
        self.completed: List[int] = []
        self._next_id = 0

    # ---- membership ------------------------------------------------------
    def join(self, member_id: str, **meta) -> None:
        self.hb.register(member_id, **meta)

    def beat(self, member_id: str) -> None:
        self.hb.beat(member_id)

    def _on_dead(self, member_id: str) -> None:
        for pid in self.leases.drop_volunteer(member_id):
            item = self.items.get(pid)
            if item and not item.done:
                heapq.heappush(self.queue, (-item.w_est_s, pid))

    def sweep(self) -> List[str]:
        return self.hb.sweep()

    # ---- work ------------------------------------------------------------
    def submit(self, kind: str, payload: dict, d_bytes: float = 0.0,
               w_est_s: float = 0.0) -> int:
        iid = self._next_id
        self._next_id += 1
        item = WorkItem(iid, kind, payload, d_bytes, w_est_s)
        self.items[iid] = item
        heapq.heappush(self.queue, (-w_est_s, iid))
        return iid

    def request(self, member_id: str) -> Optional[WorkItem]:
        """Lease the next work item to `member_id` (longest-first)."""
        self.hb.beat(member_id)
        while self.queue:
            _, iid = heapq.heappop(self.queue)
            item = self.items[iid]
            if item.done:
                continue
            item.p += 1
            self.leases.grant(iid, member_id, self.clock())
            return item
        return None

    def complete(self, member_id: str, item_id: int, result: Optional[dict]
                 = None, elapsed_s: float = 0.0) -> bool:
        item = self.items.get(item_id)
        if item is None or item.done:
            return False
        self.leases.release(item_id, member_id)
        item.done = True
        item.result = result
        self.completed.append(item_id)
        # update the member's running w (speed estimate)
        self.member_w[member_id] += elapsed_s
        self.member_n[member_id] += 1
        return True

    def expire_leases(self) -> List[int]:
        """TAIL: re-queue items whose leases timed out."""
        out = []
        now = self.clock()
        for lease in self.leases.expired(now):
            self.leases.release(lease.part_id, lease.volunteer_id)
            item = self.items.get(lease.part_id)
            if item and not item.done:
                heapq.heappush(self.queue, (-item.w_est_s, lease.part_id))
                out.append(lease.part_id)
        return out

    # ---- introspection ----------------------------------------------------
    def member_avg_w(self, member_id: str) -> float:
        n = self.member_n.get(member_id, 0)
        return self.member_w[member_id] / n if n else 0.0

    @property
    def outstanding(self) -> int:
        return sum(1 for i in self.items.values() if not i.done)
