"""Pod/host liveness with the paper's (t, f) semantics (§III.D).

A member must report within `t` seconds; after `f` consecutive misses it is
declared dead, its leases are returned, and an elastic resize plan is
emitted.  This is the datacenter port of the tracker's PING/VAL loop.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from enum import Enum
from typing import Callable, Dict, List, Optional


class MemberState(str, Enum):
    ALIVE = "alive"
    SUSPECT = "suspect"
    DEAD = "dead"


@dataclass
class _Member:
    member_id: str
    last_seen: float
    missed: int = 0
    state: MemberState = MemberState.ALIVE
    meta: dict = field(default_factory=dict)


class HeartbeatMonitor:
    def __init__(self, t_interval_s: float = 10.0, f_max_missed: int = 3,
                 on_dead: Optional[Callable[[str], None]] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.t = t_interval_s
        self.f = f_max_missed
        self.on_dead = on_dead
        self.clock = clock
        self.members: Dict[str, _Member] = {}

    def register(self, member_id: str, **meta) -> None:
        self.members[member_id] = _Member(member_id, self.clock(), meta=meta)

    def beat(self, member_id: str) -> None:
        m = self.members.get(member_id)
        if m is None:
            self.register(member_id)
            return
        m.last_seen = self.clock()
        m.missed = 0
        if m.state is MemberState.SUSPECT:
            m.state = MemberState.ALIVE

    def sweep(self) -> List[str]:
        """Advance (t, f) accounting; returns members newly declared dead."""
        now = self.clock()
        newly_dead = []
        for m in self.members.values():
            if m.state is MemberState.DEAD:
                continue
            missed = int((now - m.last_seen) / self.t)
            m.missed = missed
            if missed > self.f:
                m.state = MemberState.DEAD
                newly_dead.append(m.member_id)
                if self.on_dead:
                    self.on_dead(m.member_id)
            elif missed >= 1:
                m.state = MemberState.SUSPECT
        return newly_dead

    def alive(self) -> List[str]:
        return [m.member_id for m in self.members.values()
                if m.state is not MemberState.DEAD]
