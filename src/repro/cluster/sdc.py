"""Silent-data-corruption detection by m_min-way majority voting.

The paper validates untrusted volunteers' results with majority voting
(§III.D); the datacenter analogue is defective chips producing silent data
corruption.  Every K steps the trainer executes a *sentinel batch* redundantly
on m_min data-parallel replica groups and majority-votes a gradient
fingerprint; a minority replica is flagged for quarantine.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.validation import VotingPool


def gradient_fingerprint(grads, n_moments: int = 4) -> Tuple[float, ...]:
    """Cheap, deterministic fingerprint of a gradient pytree."""
    leaves = jax.tree_util.tree_leaves(grads)
    acc = np.zeros(n_moments, np.float64)
    for leaf in leaves:
        x = np.asarray(leaf, np.float64).ravel()
        if x.size == 0:
            continue
        acc[0] += float(np.sum(x))
        acc[1] += float(np.sum(np.abs(x)))
        acc[2] += float(np.sum(x * x))
        acc[3] = max(acc[3], float(np.max(np.abs(x))))
    return tuple(np.round(acc, 6))


@dataclass
class SDCReport:
    step: int
    agree: bool
    winner: Optional[Tuple[float, ...]]
    flagged: List[str] = field(default_factory=list)


class SDCValidator:
    """m_min/m_max sentinel validation across replica groups."""

    def __init__(self, m_min: int = 2, m_max: int = 3, every_steps: int = 100):
        self.pool_cfg = (m_min, m_max)
        self.every = every_steps
        self.pools: Dict[int, VotingPool] = {}
        self.votes_raw: Dict[int, List[Tuple[str, Tuple[float, ...]]]] = {}
        self.reports: List[SDCReport] = []

    def due(self, step: int) -> bool:
        return self.every > 0 and step % self.every == 0

    def offer(self, step: int, replica_id: str, grads) -> Optional[SDCReport]:
        fp = gradient_fingerprint(grads)
        pool = self.pools.setdefault(step, VotingPool(*self.pool_cfg))
        self.votes_raw.setdefault(step, []).append((replica_id, fp))
        verdict = pool.offer(step, replica_id, fp)
        if verdict is None:
            return None
        winner, unanimous = verdict
        flagged = []
        if not unanimous and winner is not None:
            flagged = [rid for rid, v in self.votes_raw[step] if v != winner]
        report = SDCReport(step=step, agree=winner is not None,
                           winner=winner, flagged=flagged)
        self.reports.append(report)
        return report
