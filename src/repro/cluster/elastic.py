"""Elastic re-meshing: membership change -> (checkpoint, re-mesh, restart).

The tracker's liveness drop (§III.D) maps to a pod failure; the framework's
response is a deterministic resize plan: pick the largest feasible mesh from
the surviving pods, remap FSDP shards, and resume from the newest checkpoint.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple


@dataclass
class ElasticPlan:
    old_pods: int
    new_pods: int
    mesh_shape: Tuple[int, ...]
    mesh_axes: Tuple[str, ...]
    batch_scale: float            # global batch rescale to keep tokens/step
    needs_restart: bool
    reshard: str                  # "torrent" | "none"


def plan_resize(alive_pods: int, chips_per_pod: int = 256,
                model_parallel: int = 16,
                old_pods: Optional[int] = None) -> ElasticPlan:
    """Largest power-of-two pod count <= alive keeps collectives balanced."""
    assert alive_pods >= 1
    pods = 1
    while pods * 2 <= alive_pods:
        pods *= 2
    data = chips_per_pod // model_parallel
    if pods == 1:
        shape, axes = (data, model_parallel), ("data", "model")
    else:
        shape, axes = (pods, data, model_parallel), ("pod", "data", "model")
    old = old_pods if old_pods is not None else alive_pods
    return ElasticPlan(
        old_pods=old,
        new_pods=pods,
        mesh_shape=shape,
        mesh_axes=axes,
        batch_scale=pods / max(old, 1),
        needs_restart=pods != old,
        reshard="torrent" if pods != old else "none",
    )
