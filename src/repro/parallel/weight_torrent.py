"""Torrent-style weight distribution along the pod axis, in JAX collectives.

The paper's seeder/leecher duality applied to checkpoint restore: instead of
every pod hammering the blob store (N x bytes of egress), pod 0 reads once
and the pods exchange *pieces* peer-to-peer.  On a torus the optimal plan is
the classic two-phase broadcast, which is exactly a torrent swarm with a
deterministic schedule:

  phase 1 (scatter): the seeder sends piece j to pod j          (ring hops)
  phase 2 (ring all-gather): every pod forwards the piece it owns around the
  ring until all pods hold all pieces; every pod uploads in every round —
  total time ~ 2 * bytes / link_bw, independent of pod count.

Both phases are ``lax.ppermute`` steps inside one ``shard_map`` over the
``pod`` axis — no host round-trips.  ``core/swarm.py`` provides the
host-level (file) variant and the rarest-first plan used when pods hold
disjoint initial pieces.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from repro.compat import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _flatten_to_pieces(tree, n_pieces: int):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    flat = jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in leaves])
    pad = (-flat.size) % n_pieces
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat.reshape(n_pieces, -1), treedef, [l.shape for l in leaves], \
        [l.dtype for l in leaves], pad


def _unflatten(pieces, treedef, shapes, dtypes, pad):
    flat = pieces.reshape(-1)
    if pad:
        flat = flat[:-pad]
    out = []
    ofs = 0
    for shp, dt in zip(shapes, dtypes):
        n = int(np.prod(shp)) if shp else 1
        out.append(flat[ofs:ofs + n].reshape(shp).astype(dt))
        ofs += n
    return jax.tree_util.tree_unflatten(treedef, out)


def torrent_broadcast_pieces(local_views: jax.Array, mesh: Mesh,
                             axis: str = "pod", seeder: int = 0) -> jax.Array:
    """Broadcast the seeder pod's pieces to all pods.

    local_views: (n_pods, P, L), sharded over `axis` on dim 0 — each pod's
    slice is its local buffer (only the seeder's is meaningful, e.g. freshly
    read from the checkpoint store).  Returns the same shape with every pod
    holding the seeder's pieces.  Pipelined ring: 2P-ish ppermute steps, the
    seeder uploads each piece exactly once (vs (n-1)x for naive fan-out).
    """
    n = mesh.shape[axis]
    if n == 1:
        return local_views

    def body(view):
        local_pieces = view[0]              # (P, L) local slice
        idx = jax.lax.axis_index(axis)
        is_seeder = idx == seeder
        d = jnp.mod(idx - seeder, n)        # ring distance from the seeder
        fwd = [(i, (i + 1) % n) for i in range(n)]
        P_, L = local_pieces.shape

        received = jnp.zeros_like(local_pieces)
        cur = jnp.zeros((L,), local_pieces.dtype)
        # pipelined ring: the seeder emits piece t at step t; a node at
        # distance d >= 1 receives piece (t - d + 1) at step t and forwards
        # what it received last step.
        for t in range(P_ + n - 2):
            inject = local_pieces[min(t, P_ - 1)]
            send = jnp.where(is_seeder, inject, cur)
            cur = jax.lax.ppermute(send, axis, fwd)
            p = t - (d - 1)
            ok = (p >= 0) & (p < P_) & (d >= 1)
            p_safe = jnp.clip(p, 0, P_ - 1)
            old = jax.lax.dynamic_slice_in_dim(received, p_safe, 1, axis=0)
            upd = jnp.where(ok, cur[None], old)
            received = jax.lax.dynamic_update_slice_in_dim(
                received, upd, p_safe, axis=0)
        return jnp.where(is_seeder, local_pieces, received)[None]

    spec = P(axis, None, None)
    return shard_map(body, mesh=mesh, in_specs=(spec,), out_specs=spec,
                     check_vma=False)(local_views)


def torrent_broadcast(tree, mesh: Mesh, axis: str = "pod", seeder: int = 0,
                      n_pieces: int = 0):
    """Pytree flavour: flatten -> pieces -> ring broadcast -> unflatten.

    In a multi-controller deployment each pod process feeds its own local
    buffer; here the tree is materialised pod-replicated and the seeder's
    content wins (the collective schedule is identical).
    """
    n = mesh.shape[axis]
    if n == 1:
        return tree
    n_pieces = n_pieces or n
    pieces, treedef, shapes, dtypes, pad = _flatten_to_pieces(tree, n_pieces)
    views = jnp.broadcast_to(pieces[None], (n,) + pieces.shape)
    views = jax.device_put(views, NamedSharding(mesh, P(axis, None, None)))
    out = torrent_broadcast_pieces(views, mesh, axis, seeder)
    return _unflatten(out[0], treedef, shapes, dtypes, pad)


def broadcast_cost_model(bytes_total: float, n_pods: int,
                         link_Bps: float = 25e9) -> dict:
    """Analytic cost: torrent (scatter+allgather) vs naive seeder fan-out."""
    torrent_s = 2.0 * bytes_total * (n_pods - 1) / n_pods / link_Bps
    naive_s = bytes_total * (n_pods - 1) / link_Bps
    return {"torrent_s": torrent_s, "naive_s": naive_s,
            "speedup": naive_s / max(torrent_s, 1e-12)}


def cold_start_cost_model(bytes_total: float, n_replicas: int,
                          link_Bps: float = 12.5e6,
                          n_pieces: int = 128) -> dict:
    """Analytic replica cold-start: origin-only vs swarm flash crowd.

    Origin-only serialises R full images through the origin's uplink
    (time ~ R * bytes / link, origin egress R * bytes).  A piece-wise
    swarm needs the origin to upload each piece roughly once; the last
    replica finishes after its own download plus the pipeline ramp of
    ~log2(R) piece-times, and origin egress collapses to ~1 image —
    the bounds Scenario XI's simulated runs should approach.
    """
    piece_s = bytes_total / max(n_pieces, 1) / link_Bps
    origin_s = n_replicas * bytes_total / link_Bps
    swarm_s = bytes_total / link_Bps \
        + piece_s * max(1, n_replicas).bit_length()
    return {"origin_s": origin_s, "swarm_s": swarm_s,
            "origin_egress_bytes": n_replicas * bytes_total,
            "swarm_origin_egress_bytes": bytes_total,
            "speedup": origin_s / max(swarm_s, 1e-12)}
