"""Logical-axis sharding rules (DP / FSDP / TP / EP / SP).

Every parameter and activation is annotated with *logical* axis names; a
``ShardingRules`` table maps logical names to mesh axes.  Rules degrade
gracefully: a mesh axis is dropped from a dim whenever the dim size is not
divisible by the mesh-axis product or the axis is absent from the mesh (e.g.
``pod`` on the single-pod mesh, or 8 KV heads over a 16-way model axis) —
the dim is then simply less sharded / replicated, never mis-shaped.

Training ("DEFAULT_RULES"):
  batch      -> ("pod", "data")     pure DP over pods x data
  fsdp       -> ("data",)           ZeRO-3 parameter sharding (intra-pod only;
                                    cross-pod stays replicated: DCN all-gathers
                                    per layer would dominate)
  seq_act    -> ("model",)          Megatron-style sequence parallelism of the
                                    residual stream between blocks
  heads/mlp/experts/vocab -> model  tensor / expert parallelism

Inference ("INFERENCE_RULES"): params TP-only (replicated over data), batch
over (pod, data), long KV caches sequence-sharded over ("data", "model").
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


Logical = Tuple[Optional[str], ...]


@dataclass(frozen=True)
class ParamSpec:
    """Declarative parameter: shape + logical axes + init recipe."""
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    dtype: Any = jnp.float32
    init: str = "normal"          # normal | zeros | ones | embed
    scale: float = 1.0            # multiplier on fan-in init
    # name of the dim (index) eligible for extra FSDP sharding; -1 = auto
    fsdp_dim: int = -1

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


@dataclass(frozen=True)
class ShardingRules:
    rules: Dict[str, Tuple[str, ...]]
    fsdp_axes: Tuple[str, ...] = ()

    def mesh_axes(self, logical: Optional[str]) -> Tuple[str, ...]:
        if logical is None:
            return ()
        return tuple(self.rules.get(logical, ()))


DEFAULT_RULES = ShardingRules(
    rules={
        "batch": ("pod", "data"),
        "seq_act": ("model",),
        "kv_seq": (),
        "heads": ("model",),
        "kv_heads": ("model",),
        "mlp": ("model",),
        "experts": ("model",),
        "vocab": ("model",),
        "embed": (),
        "ssm_heads": ("model",),
        "ssm_inner": ("model",),
    },
    fsdp_axes=("data",),
)

def infer_rules(cfg=None) -> "ShardingRules":
    """Inference sharding rules for a config.

    MoE checkpoints (~100B+ total params at Scout scale) do not fit TP-only
    on 16 GB chips; serve them with 2D weight sharding (TP over `model` +
    FSDP-style sharding over `data`, gathered per layer)."""
    if cfg is not None and getattr(cfg, "num_experts", 0):
        return ShardingRules(rules=dict(INFERENCE_RULES.rules),
                             fsdp_axes=("data",))
    return INFERENCE_RULES


INFERENCE_RULES = ShardingRules(
    rules={
        "batch": ("pod", "data"),
        "seq_act": (),
        "kv_seq": ("data", "model"),   # long-context caches: sequence-sharded
        "heads": ("model",),
        "kv_heads": ("model",),
        "mlp": ("model",),
        "experts": ("model",),
        "vocab": ("model",),
        "embed": (),
        "ssm_heads": ("model",),
        "ssm_inner": ("model",),
    },
    fsdp_axes=(),
)


# --------------------------------------------------------------------------- #
def _axis_size(mesh: Mesh, axes: Sequence[str]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes], dtype=np.int64)) if axes else 1


def _present(mesh: Mesh, axes: Sequence[str]) -> Tuple[str, ...]:
    return tuple(a for a in axes if a in mesh.shape)


def _fit_axes(mesh: Mesh, dim: int, axes: Sequence[str]) -> Tuple[str, ...]:
    """Keep the longest prefix of `axes` whose size product divides `dim`."""
    axes = _present(mesh, axes)
    while axes and dim % _axis_size(mesh, axes) != 0:
        axes = axes[:-1]
    return axes


def logical_to_mesh_axes(mesh: Mesh, shape: Sequence[int], logical: Logical,
                         rules: ShardingRules) -> P:
    used: set = set()
    out = []
    for dim, name in zip(shape, logical):
        axes = tuple(a for a in rules.mesh_axes(name) if a not in used)
        axes = _fit_axes(mesh, dim, axes)
        used.update(axes)
        if len(axes) == 0:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(tuple(axes))
    return P(*out)


def named_sharding(mesh: Mesh, shape: Sequence[int], logical: Logical,
                   rules: ShardingRules) -> NamedSharding:
    return NamedSharding(mesh, logical_to_mesh_axes(mesh, shape, logical, rules))


def param_sharding(mesh: Mesh, spec: ParamSpec, rules: ShardingRules
                   ) -> NamedSharding:
    """TP sharding from logical axes + optional extra FSDP sharding."""
    pspec = list(logical_to_mesh_axes(mesh, spec.shape, spec.logical, rules))
    fsdp = _present(mesh, rules.fsdp_axes)
    if spec.fsdp_dim == -2:   # param opted out of FSDP
        fsdp = ()
    used = set()
    for entry in pspec:
        if isinstance(entry, str):
            used.add(entry)
        elif isinstance(entry, tuple):
            used.update(entry)
    if any(a in used for a in fsdp):
        fsdp = ()             # an fsdp axis is already consumed by this param
    if fsdp:
        fsdp_size = _axis_size(mesh, fsdp)
        # pick the dim to FSDP-shard: explicit, else the largest unsharded dim
        cand = None
        if spec.fsdp_dim >= 0 and pspec[spec.fsdp_dim] is None \
                and spec.shape[spec.fsdp_dim] % fsdp_size == 0:
            cand = spec.fsdp_dim
        else:
            dims = sorted(range(len(spec.shape)), key=lambda i: -spec.shape[i])
            for i in dims:
                if pspec[i] is None and spec.shape[i] % fsdp_size == 0:
                    cand = i
                    break
        if cand is not None:
            pspec[cand] = fsdp if len(fsdp) > 1 else fsdp[0]
    return NamedSharding(mesh, P(*pspec))


# --------------------------------------------------------------------------- #
# Activation constraints
# --------------------------------------------------------------------------- #
_CURRENT: dict = {"mesh": None, "rules": DEFAULT_RULES}


class sharding_ctx:
    """Context manager installing (mesh, rules) for `shard_act` constraints."""

    def __init__(self, mesh: Optional[Mesh], rules: ShardingRules):
        self.new = {"mesh": mesh, "rules": rules}

    def __enter__(self):
        self.old = dict(_CURRENT)
        _CURRENT.update(self.new)
        return self

    def __exit__(self, *exc):
        _CURRENT.update(self.old)
        return False


def current_mesh() -> Optional[Mesh]:
    return _CURRENT["mesh"]


def current_rules() -> ShardingRules:
    return _CURRENT["rules"]


def shard_act(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op outside a mesh ctx."""
    mesh, rules = _CURRENT["mesh"], _CURRENT["rules"]
    if mesh is None:
        return x
    ns = named_sharding(mesh, x.shape, tuple(logical), rules)
    return jax.lax.with_sharding_constraint(x, ns)


# --------------------------------------------------------------------------- #
# Spec-tree utilities
# --------------------------------------------------------------------------- #
def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn: Callable[[ParamSpec], Any], tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_spec)


def specs_to_shardings(tree, mesh: Mesh, rules: ShardingRules):
    return tree_map_specs(lambda s: param_sharding(mesh, s, rules), tree)


def specs_to_abstract(tree, mesh: Optional[Mesh] = None,
                      rules: ShardingRules = DEFAULT_RULES,
                      dtype_override=None):
    def mk(s: ParamSpec):
        dt = dtype_override or s.dtype
        if mesh is None:
            return jax.ShapeDtypeStruct(s.shape, dt)
        return jax.ShapeDtypeStruct(s.shape, dt,
                                    sharding=param_sharding(mesh, s, rules))
    return tree_map_specs(mk, tree)


def init_param(key, s: ParamSpec, dtype=None):
    dt = dtype or s.dtype
    if s.init == "zeros":
        return jnp.zeros(s.shape, dt)
    if s.init == "ones":
        return jnp.ones(s.shape, dt)
    if s.init == "embed":
        return (jax.random.normal(key, s.shape) * s.scale).astype(dt)
    # fan-in scaled normal
    fan_in = s.shape[0] if len(s.shape) >= 2 else max(s.shape[-1], 1)
    if len(s.shape) >= 3:  # stacked (layers, in, out) style
        fan_in = s.shape[-2]
    std = s.scale / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, s.shape) * std).astype(dt)


def init_params(key, tree, dtype=None):
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [init_param(k, s, dtype) for k, s in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, vals)
