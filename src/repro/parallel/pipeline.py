"""Pipeline parallelism over a mesh axis (GPipe schedule, ppermute ring).

Stages live on consecutive slices of the `stage` mesh axis (typically the
``pod`` axis: one stage per pod, DCN-friendly point-to-point activation
hand-off — the same ring the weight torrent uses).  Microbatches stream
through with the classic (M + L - 1)-step schedule; every step each stage
computes its resident microbatch and ``ppermute``s the activation to its
successor.  Bubble fraction = (L-1)/(M+L-1).

This is the optional PP dimension of the framework: the assigned 2-pod mesh
favours DP over pods (see DESIGN.md §9), but the combinator is exercised by
tests on a 4-stage host mesh so a deeper pod dimension is a config change,
not new code.
"""
from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def pipeline_apply(stage_fn: Callable, stage_params, x_microbatches,
                   mesh: Mesh, axis: str = "pod"):
    """Run `stage_fn(params_s, x) -> x` through L pipeline stages.

    stage_params: pytree with leading stage axis L (sharded over `axis`).
    x_microbatches: (M, ...) microbatch stack (replicated over `axis`).
    Returns (M, ...) outputs of the final stage (replicated over `axis`).
    """
    L = mesh.shape[axis]
    M = x_microbatches.shape[0]
    steps = M + L - 1
    fwd = [(i, i + 1) for i in range(L - 1)]

    def body(params_l, xs):
        s = jax.lax.axis_index(axis)
        params_stage = jax.tree_util.tree_map(lambda p: p[0], params_l)
        mb_shape = xs.shape[1:]
        recv = jnp.zeros(mb_shape, xs.dtype)
        outs = jnp.zeros((M,) + mb_shape, xs.dtype)
        for t in range(steps):
            inject = xs[min(t, M - 1)]
            live_in = jnp.where(s == 0,
                                inject if t < M else jnp.zeros_like(inject),
                                recv)
            out = stage_fn(params_stage, live_in)
            # emit on the last stage once the wavefront arrives
            emit_idx = t - (L - 1)
            if 0 <= emit_idx < M:
                take = jnp.where(s == L - 1, out, jnp.zeros_like(out))
                outs = outs.at[emit_idx].set(take)
            if t < steps - 1:
                recv = jax.lax.ppermute(out, axis, fwd)
        # broadcast final-stage outputs to every stage (replicated result)
        return jax.lax.psum(outs, axis) if L > 1 else outs

    other = [a for a in mesh.axis_names if a != axis]
    pspec = [axis] + [None] * (
        len(jax.tree_util.tree_leaves(stage_params)[0].shape) - 1)
    in_param_specs = jax.tree_util.tree_map(
        lambda p: P(*( [axis] + [None] * (p.ndim - 1))), stage_params)
    x_spec = P(*([None] * x_microbatches.ndim))

    return shard_map(body, mesh=mesh,
                     in_specs=(in_param_specs, x_spec),
                     out_specs=x_spec,
                     check_vma=False)(stage_params, x_microbatches)


def pipeline_bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
