"""Continuous-batching serving engine with (d, p, w)-aware admission.

Requests are the serving analogue of the paper's applications: each carries
  d — prompt+generation bytes,
  w — measured decode seconds (running average per bucket),
  p — how many requests of this bucket were served.
The engine publishes these units (like the tracker's list) and admission
prefers short-w buckets when the queue saturates — the volunteer's
"judge by d and w" heuristic as a scheduler policy.

Execution: fixed-shape prefill (padded to bucket) + one jitted decode step
for the whole active batch; finished slots are refilled from the queue
(continuous batching).  The KV cache is one fixed-size pool tensor per
layer — slots are rows, so refill is a dynamic row update, the TPU-friendly
variant of paged attention at slot granularity.
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.parallel.sharding import init_params, sharding_ctx, infer_rules
from repro.training.train_state import make_decode_step


@dataclass
class Request:
    req_id: int
    prompt: np.ndarray                 # (S,) int32
    max_new: int = 16
    arrived: float = 0.0
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False
    started: float = 0.0
    finished: float = 0.0


@dataclass
class ServeConfig:
    slots: int = 4                     # concurrent sequences
    max_len: int = 256                 # cache length
    prefill_bucket: int = 64


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, sc: ServeConfig, mesh=None):
        self.cfg = cfg
        self.sc = sc
        self.mesh = mesh
        self.params = params
        # checkpoint `extra` dict when this engine cold-started from the
        # swarm (from_swarm); None for directly-constructed engines
        self.restore_extra: Optional[dict] = None
        self.rules = infer_rules(cfg)
        self.queue: collections.deque = collections.deque()
        self.active: Dict[int, Request] = {}
        self.slot_req: List[Optional[int]] = [None] * sc.slots
        self.metrics = {"p": collections.Counter(),
                        "w": collections.defaultdict(float),
                        "d": collections.defaultdict(float)}
        self._init_cache()
        self._decode = jax.jit(make_decode_step(cfg, mesh))
        self._next_id = 0

    # ------------------------------------------------------------------ #
    @classmethod
    def from_swarm(cls, cfg: ModelConfig, template, sc: ServeConfig, *,
                   agent, app_id: str, mesh=None, pod_axis: str = "pod",
                   workdir=None) -> "ServingEngine":
        """Cold-start a replica from the distribution swarm.

        The replica's `agent` leeched the checkpoint Application like any
        other volunteer; the moment its piece set completes
        (`app_id in agent.images`) this reassembles the step image,
        re-hashes its content against the manifest, restores the params
        into `template`'s structure, and — when a mesh with a pod axis is
        given — fans the freshly-landed bytes out intra-pod over the
        `weight_torrent` ppermute ring, so only one host per pod pulls
        from the swarm.  Raises if the piece set is still incomplete.
        """
        from repro.checkpoint.swarm_restore import restore_from_agent
        params, extra = restore_from_agent(agent, app_id, template,
                                           workdir=workdir)
        if mesh is not None and pod_axis in getattr(mesh, "shape", {}):
            from repro.parallel.weight_torrent import torrent_broadcast
            params = torrent_broadcast(params, mesh, axis=pod_axis)
        eng = cls(cfg, params, sc, mesh=mesh)
        eng.restore_extra = extra
        return eng

    def _init_cache(self):
        tree = M.cache_specs_tree(self.cfg, self.sc.slots, self.sc.max_len)
        self.caches = init_params(jax.random.PRNGKey(0), tree)
        self.caches["index"] = jnp.zeros((), jnp.int32)
        self.positions = np.zeros(self.sc.slots, np.int64)
        self.tokens = np.zeros((self.sc.slots, 1), np.int32)

    def submit(self, prompt: np.ndarray, max_new: int = 16) -> int:
        rid = self._next_id
        self._next_id += 1
        req = Request(rid, np.asarray(prompt, np.int32), max_new,
                      arrived=time.monotonic())
        self.queue.append(req)
        return rid

    def _bucket(self, req: Request) -> int:
        b = self.sc.prefill_bucket
        return ((len(req.prompt) + b - 1) // b) * b

    def _admit(self) -> None:
        """Fill free slots; prefer short-w buckets under saturation."""
        free = [i for i, r in enumerate(self.slot_req) if r is None]
        if not free or not self.queue:
            return
        pending = sorted(
            self.queue,
            key=lambda r: self.metrics["w"].get(self._bucket(r), 0.0))
        for slot in free:
            if not pending:
                break
            req = pending.pop(0)
            self.queue.remove(req)
            self._prefill_into_slot(slot, req)

    def _prefill_into_slot(self, slot: int, req: Request) -> None:
        """Sequential prefill through the decode step (slot-local)."""
        req.started = time.monotonic()
        self.active[req.req_id] = req
        self.slot_req[slot] = req.req_id
        # reset this slot's position; feed prompt tokens one step at a time
        # through the shared decode path (slot-granular continuous batching;
        # a bucketed prefill graph is the natural next optimisation).
        self.positions[slot] = 0
        toks = req.prompt
        for t in toks[:-1]:
            self.tokens[slot, 0] = int(t)
            self._step_decode(only_slot=slot)
        self.tokens[slot, 0] = int(toks[-1])

    def _step_decode(self, only_slot: Optional[int] = None) -> np.ndarray:
        batch = {"tokens": jnp.asarray(self.tokens)}
        if self.cfg.mrope:
            pos = jnp.asarray(
                np.broadcast_to(self.positions[None, :, None],
                                (3, self.sc.slots, 1)).astype(np.int32))
            batch["positions"] = pos
        # per-slot positions: each sequence writes/masks at its own index
        self.caches["index"] = jnp.asarray(self.positions.astype(np.int32))
        next_tok, self.caches = self._decode(self.params, batch, self.caches)
        if only_slot is not None:
            # prefill microstep: only the target slot advances; other slots
            # rewrite their current position with identical K/V (idempotent)
            self.positions[only_slot] += 1
        else:
            self.positions += 1
        return np.asarray(next_tok)

    def step(self) -> int:
        """One engine tick: admit, decode the full batch, retire finished."""
        self._admit()
        if not self.active:
            return 0
        t0 = time.monotonic()
        nxt = self._step_decode()
        dt = time.monotonic() - t0
        produced = 0
        for slot, rid in enumerate(self.slot_req):
            if rid is None:
                continue
            req = self.active[rid]
            tok = int(nxt[slot])
            req.out_tokens.append(tok)
            self.tokens[slot, 0] = tok
            produced += 1
            if len(req.out_tokens) >= req.max_new:
                req.done = True
                req.finished = time.monotonic()
                b = self._bucket(req)
                self.metrics["p"][b] += 1
                self.metrics["w"][b] = (
                    0.8 * self.metrics["w"].get(b, dt) + 0.2 *
                    (req.finished - req.started))
                self.metrics["d"][b] += 4.0 * (len(req.prompt)
                                               + len(req.out_tokens))
                self.slot_req[slot] = None
                del self.active[rid]
        return produced

    def run_until_drained(self, max_ticks: int = 10_000) -> List[Request]:
        done: List[Request] = []
        seen = set()
        for _ in range(max_ticks):
            if not self.queue and not self.active:
                break
            self.step()
        return done

    def published_units(self) -> dict:
        """The tracker-style (d, p, w) listing per prompt bucket."""
        return {b: {"d": self.metrics["d"][b], "p": self.metrics["p"][b],
                    "w": self.metrics["w"][b]}
                for b in self.metrics["p"]}
