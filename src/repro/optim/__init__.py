from repro.optim.adamw import (  # noqa: F401
    AdamWConfig,
    adamw_init_specs,
    adamw_update,
    lr_schedule,
)
