"""Gradient compression for cross-pod (DCN) reduction.

Two schemes, both with error feedback so compression noise does not bias
the long-run gradient:

  * int8 stochastic-free symmetric quantisation (per-leaf scale)  — 4x
  * top-k magnitude sparsification (per-leaf)                     — ~d/k x

At 2+ pods the data-parallel all-reduce crosses DCN (~25 GB/s/host vs
~50 GB/s/link ICI); compressing the cross-pod leg is the standard trick to
keep the pod axis from becoming the collective bottleneck.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def topk_sparsify(x: jax.Array, k_frac: float
                  ) -> Tuple[jax.Array, jax.Array]:
    """Keep the top k_frac fraction by magnitude; returns (values, mask)."""
    flat = x.reshape(-1)
    k = max(1, int(flat.size * k_frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = (jnp.abs(x) >= thresh).astype(x.dtype)
    return x * mask, mask


@dataclass(frozen=True)
class CompressionConfig:
    scheme: str = "int8"          # "none" | "int8" | "topk"
    topk_frac: float = 0.01
    error_feedback: bool = True


def compress_leaf(g: jax.Array, err: Optional[jax.Array],
                  cfg: CompressionConfig) -> Tuple[jax.Array, jax.Array]:
    """Returns (compressed-then-decompressed gradient, new error state).

    The decompressed value is what enters the cross-pod psum; error feedback
    accumulates what was lost locally and re-injects it next step.
    """
    if cfg.scheme == "none" or g.ndim == 0:
        return g, jnp.zeros_like(g)
    gf = g.astype(jnp.float32)
    if err is not None and cfg.error_feedback:
        gf = gf + err
    if cfg.scheme == "int8":
        q, s = quantize_int8(gf)
        deq = dequantize_int8(q, s)
    elif cfg.scheme == "topk":
        deq, _ = topk_sparsify(gf, cfg.topk_frac)
    else:
        raise ValueError(cfg.scheme)
    new_err = (gf - deq) if cfg.error_feedback else jnp.zeros_like(gf)
    return deq.astype(g.dtype), new_err


def compress_tree(grads, err_tree, cfg: CompressionConfig):
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    errs = (treedef.flatten_up_to(err_tree) if err_tree is not None
            else [None] * len(leaves))
    outs = [compress_leaf(g, e, cfg) for g, e in zip(leaves, errs)]
    new_g = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_e = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    return new_g, new_e


def compression_ratio(cfg: CompressionConfig) -> float:
    if cfg.scheme == "int8":
        return 4.0
    if cfg.scheme == "topk":
        return 1.0 / max(cfg.topk_frac * 2, 1e-9)   # values + indices
    return 1.0
