"""AdamW with warmup-cosine schedule, global-norm clipping.

Written against spec-trees so the optimizer state inherits parameter
shardings (FSDP) without extra plumbing.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.parallel.sharding import ParamSpec, tree_map_specs


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = (step + 1.0) / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init_specs(param_specs) -> dict:
    """Optimizer-state spec tree mirroring the parameter spec tree."""
    def zero_like(s: ParamSpec) -> ParamSpec:
        return ParamSpec(s.shape, s.logical, jnp.float32, init="zeros")
    return {
        "m": tree_map_specs(zero_like, param_specs),
        "v": tree_map_specs(zero_like, param_specs),
    }


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree_util.tree_leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(cfg: AdamWConfig, params, grads, opt_state, step
                 ) -> Tuple[dict, dict, dict]:
    """Returns (new_params, new_opt_state, stats)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else jnp.float32(1.0)
    lr = lr_schedule(cfg, step)
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v}, {"grad_norm": gnorm, "lr": lr}
