"""Model assembly: layer-group scan, parameter/cache spec trees, forward passes.

The model is a sequence of layer groups (see configs.base); each group runs
under ``jax.lax.scan`` with parameters (and KV/SSM caches) stacked on a leading
repeat axis.  One code path serves all 10 assigned architectures.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import GroupSpec, LayerSpec, ModelConfig
from repro.models import attention as attn_lib
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.parallel.sharding import ParamSpec, shard_act, tree_map_specs


# --------------------------------------------------------------------------- #
# Param specs
# --------------------------------------------------------------------------- #
def layer_param_specs(cfg: ModelConfig, lspec: LayerSpec,
                      decoder_cross: bool = False) -> dict:
    d: Dict[str, Any] = {}
    if lspec.mixer in ("attn", "attn_local"):
        d["ln_mixer"] = L.norm_spec(cfg.d_model)
        d["attn"] = attn_lib.attn_specs(cfg)
    elif lspec.mixer == "ssd":
        d["ln_mixer"] = L.norm_spec(cfg.d_model)
        d["ssd"] = ssm_lib.ssd_specs(cfg)
    if decoder_cross:
        d["ln_cross"] = L.norm_spec(cfg.d_model)
        d["cross"] = attn_lib.cross_attn_specs(cfg)
    if lspec.mlp == "dense":
        d["ln_mlp"] = L.norm_spec(cfg.d_model)
        d["mlp"] = L.mlp_specs(cfg)
    elif lspec.mlp == "moe":
        d["ln_mlp"] = L.norm_spec(cfg.d_model)
        d["moe"] = moe_lib.moe_specs(cfg)
    return d


def _stack(tree, repeat: int):
    return tree_map_specs(
        lambda s: ParamSpec((repeat,) + s.shape, (None,) + s.logical,
                            s.dtype, s.init, s.scale), tree)


def group_param_specs(cfg: ModelConfig, g: GroupSpec,
                      decoder_cross: bool = False) -> dict:
    per_layer = {f"L{p}": layer_param_specs(cfg, ls, decoder_cross)
                 for p, ls in enumerate(g.layers)}
    return _stack(per_layer, g.repeat)


def shared_attn_specs(cfg: ModelConfig) -> dict:
    sub = cfg.replace(num_heads=cfg.shared_attn_heads or cfg.num_heads,
                      num_kv_heads=cfg.shared_attn_kv_heads or cfg.num_kv_heads)
    return {"ln": L.norm_spec(cfg.d_model),
            "attn": attn_lib.attn_specs(sub, heads=sub.num_heads,
                                        kv_heads=sub.num_kv_heads)}


def model_param_specs(cfg: ModelConfig) -> dict:
    tree: Dict[str, Any] = {"embed": L.embed_specs(cfg)}
    tree["decoder"] = {f"g{i}": group_param_specs(cfg, g, cfg.is_encdec)
                       for i, g in enumerate(cfg.groups)}
    if cfg.is_encdec:
        tree["encoder"] = {f"g{i}": group_param_specs(cfg, g, False)
                           for i, g in enumerate(cfg.encoder_groups)}
        tree["encoder"]["enc_norm"] = L.norm_spec(cfg.d_model)
    if any(ls.shared_attn for g in cfg.groups for ls in g.layers):
        tree["shared_attn"] = shared_attn_specs(cfg)
    return tree


def count_params(cfg: ModelConfig, include_embed: bool = True,
                 active_only: bool = False) -> int:
    import numpy as np
    tree = model_param_specs(cfg)
    total = 0
    for path, s in jax.tree_util.tree_flatten_with_path(
            tree, is_leaf=lambda x: isinstance(x, ParamSpec))[0]:
        keys = [getattr(k, "key", str(k)) for k in path]
        n = int(np.prod(s.shape))
        if not include_embed and ("embedding" in keys or "lm_head" in keys):
            continue
        if active_only and any("wi_gate" == k or "wi_up" == k or "wo" == k
                               for k in keys) and "moe" in keys:
            # routed experts: scale by activated fraction
            n = n * max(cfg.experts_per_token, 1) // max(cfg.num_experts, 1)
        total += n
    return total


# --------------------------------------------------------------------------- #
# Cache specs
# --------------------------------------------------------------------------- #
def layer_cache_specs(cfg: ModelConfig, lspec: LayerSpec, batch: int,
                      cache_len: int, src_len: int = 0,
                      decoder_cross: bool = False) -> dict:
    d: Dict[str, Any] = {}
    if lspec.mixer == "attn":
        d.update(attn_lib.cache_specs(cfg, batch, cache_len))
    elif lspec.mixer == "attn_local":
        d.update(attn_lib.cache_specs(cfg, batch,
                                      min(cache_len, cfg.window_size)))
    elif lspec.mixer == "ssd":
        d.update(ssm_lib.ssd_cache_specs(cfg, batch))
    if lspec.shared_attn:
        kh = cfg.shared_attn_kv_heads or cfg.num_kv_heads
        cs = attn_lib.cache_specs(cfg, batch, cache_len, kv_heads=kh)
        d["shared_k"] = cs["k"]
        d["shared_v"] = cs["v"]
    if decoder_cross:
        kh = cfg.num_kv_heads
        d["cross_k"] = ParamSpec((batch, src_len, kh, cfg.head_dim),
                                 ("batch", "kv_seq", "kv_heads", None),
                                 dtype=cfg.act_dtype, init="zeros")
        d["cross_v"] = d["cross_k"]
    return d


def cache_specs_tree(cfg: ModelConfig, batch: int, cache_len: int,
                     src_len: int = 0) -> dict:
    tree: Dict[str, Any] = {"decoder": {}}
    for i, g in enumerate(cfg.groups):
        per_layer = {f"L{p}": layer_cache_specs(cfg, ls, batch, cache_len,
                                                src_len, cfg.is_encdec)
                     for p, ls in enumerate(g.layers)}
        tree["decoder"][f"g{i}"] = _stack(per_layer, g.repeat)
    tree["index"] = ParamSpec((batch,), ("batch",), dtype=jnp.int32,
                              init="zeros")
    return tree


# --------------------------------------------------------------------------- #
# Forward
# --------------------------------------------------------------------------- #
def apply_layer(cfg: ModelConfig, lspec: LayerSpec, p: dict, x: jax.Array,
                aux: jax.Array, *, shared_params=None, mode: str,
                positions=None, cache=None, index=None, enc_kv=None,
                causal: bool = True) -> Tuple[jax.Array, jax.Array, Optional[dict]]:
    new_cache: Dict[str, Any] = {}
    if lspec.mixer in ("attn", "attn_local"):
        h = L.rms_norm(x, p["ln_mixer"], cfg.norm_eps)
        sub_cache = ({"k": cache["k"], "v": cache["v"]}
                     if cache and "k" in cache else None)
        h, nc = attn_lib.attention_block(
            p["attn"], h, cfg, local=(lspec.mixer == "attn_local"), mode=mode,
            positions=positions, cache=sub_cache, index=index, causal=causal)
        x = x + h
        if nc:
            new_cache.update(nc)
    elif lspec.mixer == "ssd":
        h = L.rms_norm(x, p["ln_mixer"], cfg.norm_eps)
        sub_cache = ({k: cache[k] for k in ("ssm", "conv_x", "conv_b", "conv_c")}
                     if cache and "ssm" in cache else None)
        h, nc = ssm_lib.ssd_block(p["ssd"], h, cfg, mode=mode, cache=sub_cache)
        x = x + h
        if nc:
            new_cache.update(nc)

    if lspec.shared_attn and shared_params is not None:
        h = L.rms_norm(x, shared_params["ln"], cfg.norm_eps)
        scfg = cfg.replace(num_heads=cfg.shared_attn_heads or cfg.num_heads,
                           num_kv_heads=cfg.shared_attn_kv_heads
                           or cfg.num_kv_heads, qk_norm=False)
        sub_cache = ({"k": cache["shared_k"], "v": cache["shared_v"]}
                     if cache and "shared_k" in cache else None)
        h, nc = attn_lib.attention_block(
            shared_params["attn"], h, scfg, local=False, mode=mode,
            positions=positions, cache=sub_cache, index=index)
        x = x + h
        if nc:
            new_cache["shared_k"] = nc["k"]
            new_cache["shared_v"] = nc["v"]

    if enc_kv is not None and "cross" in p:
        h = L.rms_norm(x, p["ln_cross"], cfg.norm_eps)
        h = attn_lib.cross_attention_block(p["cross"], h, enc_kv, cfg)
        x = x + h

    if lspec.mlp == "dense":
        h = L.rms_norm(x, p["ln_mlp"], cfg.norm_eps)
        x = x + L.mlp_apply(p["mlp"], h, tp_sp=cfg.tp_sp)
    elif lspec.mlp == "moe":
        h = L.rms_norm(x, p["ln_mlp"], cfg.norm_eps)
        h, a = moe_lib.moe_block(p["moe"], h, cfg)
        x = x + h
        aux = aux + a

    return x, aux, (new_cache or None)


def run_groups(cfg: ModelConfig, groups, params: dict, x: jax.Array, *,
               mode: str, positions=None, caches=None, index=None,
               shared_params=None, enc_out=None, causal: bool = True
               ) -> Tuple[jax.Array, jax.Array, Optional[dict]]:
    """Run all layer groups with per-group scan.  Returns (x, aux, caches)."""
    aux0 = jnp.zeros((), jnp.float32)
    new_caches: Dict[str, Any] = {}
    total_aux = aux0

    for gi, g in enumerate(groups):
        gp = params[f"g{gi}"]
        gc = caches[f"g{gi}"] if caches is not None else None

        def body(carry, xs, _g=g):
            x_, aux_ = carry
            p_slice, c_slice = xs
            new_c: Dict[str, Any] = {}
            for pidx, ls in enumerate(_g.layers):
                key = f"L{pidx}"
                lp = p_slice[key]
                lc = c_slice[key] if c_slice is not None else None
                enc_kv = None
                if enc_out is not None and "cross" in lp:
                    if mode == "decode" and lc is not None and "cross_k" in lc:
                        enc_kv = (lc["cross_k"], lc["cross_v"])
                    else:
                        enc_kv = attn_lib.encode_cross_kv(lp["cross"], enc_out,
                                                          cfg)
                x_, aux_, nc = apply_layer(
                    cfg, ls, lp, x_, aux_, shared_params=shared_params,
                    mode=mode, positions=positions, cache=lc, index=index,
                    enc_kv=enc_kv, causal=causal)
                if lc is not None:
                    out_c = dict(nc or {})
                    if "cross_k" in lc:
                        if enc_kv is not None and mode == "prefill":
                            out_c["cross_k"] = enc_kv[0].astype(
                                lc["cross_k"].dtype)
                            out_c["cross_v"] = enc_kv[1].astype(
                                lc["cross_v"].dtype)
                        elif "cross_k" not in out_c:
                            out_c["cross_k"] = lc["cross_k"]
                            out_c["cross_v"] = lc["cross_v"]
                    # carry through untouched entries so ys matches xs
                    for k in lc:
                        if k not in out_c:
                            out_c[k] = lc[k]
                    new_c[key] = out_c
                elif nc:
                    new_c[key] = nc
            return (x_, aux_), (new_c or None)

        if mode == "train" and cfg.remat != "none":
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if cfg.remat == "dots" else None)
            body = jax.checkpoint(body, policy=policy)

        (x, total_aux), ys = jax.lax.scan(body, (x, total_aux), (gp, gc))
        if ys is not None:
            new_caches[f"g{gi}"] = ys

    return x, total_aux, (new_caches or None)


# --------------------------------------------------------------------------- #
# Top-level entry points
# --------------------------------------------------------------------------- #
def _inputs_to_x(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    if "embeds" in batch:
        x = batch["embeds"].astype(cfg.act_dtype)
        return shard_act(x, "batch", "seq_act", None)
    return L.embed_tokens(params["embed"], batch["tokens"], cfg)


def _positions(cfg: ModelConfig, batch: dict, B: int, S: int,
               index=None) -> jax.Array:
    if "positions" in batch:
        return batch["positions"]
    if index is not None:
        idx = jnp.broadcast_to(jnp.atleast_1d(jnp.asarray(index)), (B,))
        pos = idx[:, None]
    else:
        pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    if cfg.mrope:
        pos = jnp.broadcast_to(pos[None], (3,) + pos.shape)
    return pos


def encode(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    x = batch["enc_embeds"].astype(cfg.act_dtype)
    x = shard_act(x, "batch", "seq_act", None)
    B, S = x.shape[0], x.shape[1]
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    x, _, _ = run_groups(cfg, cfg.encoder_groups, params["encoder"], x,
                         mode="train", positions=pos, causal=False)
    return L.rms_norm(x, params["encoder"]["enc_norm"], cfg.norm_eps)


def forward(cfg: ModelConfig, params: dict, batch: dict, *,
            mode: str = "train", caches=None, index=None
            ) -> Tuple[jax.Array, jax.Array, Optional[dict]]:
    """Returns (logits, aux_loss, new_caches)."""
    x, aux, new_caches = backbone(cfg, params, batch, mode=mode,
                                  caches=caches, index=index)
    if mode == "prefill":
        # only the last position's logits are needed to start decoding
        x = x[:, -1:]
    logits = L.lm_logits(params["embed"], x, cfg)
    return logits, aux, new_caches


def backbone(cfg: ModelConfig, params: dict, batch: dict, *,
             mode: str = "train", caches=None, index=None):
    """Everything up to (but excluding) the LM head."""
    enc_out = None
    if cfg.is_encdec:
        if mode == "decode" and "enc_embeds" not in batch:
            enc_out = None
        else:
            enc_out = encode(cfg, params, batch)
        x = L.embed_tokens(params["embed"], batch["tokens"], cfg)
        if enc_out is None:
            enc_out = jnp.zeros((x.shape[0], 1, cfg.d_model), cfg.act_dtype)
    else:
        x = _inputs_to_x(cfg, params, batch)
    B, S = x.shape[0], x.shape[1]
    positions = _positions(cfg, batch, B, S, index if mode == "decode" else None)
    shared = params.get("shared_attn")
    dec_caches = caches["decoder"] if caches is not None else None
    x, aux, new_dec = run_groups(cfg, cfg.groups, params["decoder"], x,
                                 mode=mode, positions=positions,
                                 caches=dec_caches, index=index,
                                 shared_params=shared, enc_out=enc_out,
                                 causal=True)
    new_caches = None
    if new_dec is not None:
        if index is not None:   # decode: advance each sequence's position
            idx = jnp.broadcast_to(jnp.atleast_1d(jnp.asarray(index)), (B,))
            new_idx = (idx + S).astype(jnp.int32)
        else:                    # prefill: every sequence sits at S
            new_idx = jnp.full((B,), S, jnp.int32)
        new_caches = {"decoder": new_dec, "index": new_idx}
    return x, aux, new_caches


def loss_fn(cfg: ModelConfig, params: dict, batch: dict
            ) -> Tuple[jax.Array, dict]:
    x, aux, _ = backbone(cfg, params, batch, mode="train")
    nll = L.lm_head_loss(params["embed"], x, batch["labels"], cfg,
                         batch.get("loss_mask"))
    loss = nll + cfg.router_aux_coef * aux
    return loss, {"loss": loss, "nll": nll, "aux": aux}


def prefill(cfg: ModelConfig, params: dict, batch: dict, caches
            ) -> Tuple[jax.Array, dict]:
    logits, _, new_caches = forward(cfg, params, batch, mode="prefill",
                                    caches=caches, index=None)
    return logits[:, -1], new_caches


def decode_step(cfg: ModelConfig, params: dict, batch: dict, caches
                ) -> Tuple[jax.Array, dict]:
    logits, _, new_caches = forward(cfg, params, batch, mode="decode",
                                    caches=caches, index=caches["index"])
    return logits[:, -1], new_caches
