"""Convenience re-exports for model construction."""
from repro.models.model import (  # noqa: F401
    cache_specs_tree,
    count_params,
    decode_step,
    forward,
    loss_fn,
    model_param_specs,
    prefill,
)
